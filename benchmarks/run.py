"""Benchmark harness - one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [SUITE | --only NAME]

Prints ``name,us_per_call,derived`` CSV rows:
  * pareto_*    - Figs 4/5/6 error sweeps + knee detection
  * mac_*       - Tables 4/5/6 MAC comparison (f32 / FxP8-int8 / bit-exact
                  CORDIC kernel) + SYCore 3 GHz throughput model
  * caesar_*    - Table 3 VGG-16 mapping + pruning co-design speedups
  * accuracy_*  - Fig 11 accuracy under CORDIC execution (+QAT recovery)
  * roofline_*  - roofline terms for representative (arch x shape) cells
  * tune_*      - kernel tile-candidate sweep (smoke), heuristic vs tuned;
                  writes the persistent tuned table (REPRO_TUNE_CACHE).
                  Full sweep: ``python -m benchmarks.tune``.
  * grads_*     - fused Pallas backward vs STE fallback (smoke) for the
                  float families.  Full sweep with long-context shapes:
                  ``python -m benchmarks.grad_bench``.
  * serve_*     - continuous batching vs gang scheduling on an arrival
                  trace (smoke); writes ``BENCH_serving.json``.  Full
                  replay: ``python -m benchmarks.serve_bench``.
  * spec_*      - speculative decoding vs plain decode on the draftable
                  motif trace (smoke); writes ``BENCH_spec.json`` and
                  fails on greedy divergence.  Full replay:
                  ``python -m benchmarks.serve_bench --spec``.
  * quant_*     - int8 quantized slot cache vs fp32 (smoke): slots-per-GB,
                  max logit error, trace replay tok/s; writes
                  ``BENCH_quant.json``.  Full sweep:
                  ``python -m benchmarks.quant_bench``.
  * paged_*     - paged slot memory + radix prefix cache vs the dense
                  layout on a shared-prefix trace (smoke); writes
                  ``BENCH_paged.json`` and fails on greedy divergence.
                  Full replay: ``python -m benchmarks.serve_bench
                  --paged``.
  * chaos_*     - kill/restore recovery cost (smoke): injected worker
                  death mid-trace, supervisor restores the last slot
                  snapshot; writes ``BENCH_chaos.json`` and fails if the
                  recovered outputs diverge from the undisturbed run.
  * mesh_*      - sharded serving over fake devices (smoke): slot state
                  on a 1/2/4/8-way mesh data axis + prefill/decode
                  split; writes ``BENCH_mesh.json`` and fails if sharded
                  outputs diverge from the single-device engine.  Full
                  replay: ``python -m benchmarks.serve_bench --mesh``.
"""
from __future__ import annotations

import argparse
import sys
import traceback


SUITE_NAMES = ("pareto", "mac", "caesar", "accuracy", "roofline", "tune",
               "grads", "serve", "spec", "quant", "paged", "chaos", "mesh")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("suite", nargs="?", default=None, choices=SUITE_NAMES,
                    help="run a single suite (same choices as --only)")
    ap.add_argument("--only", default=None, choices=SUITE_NAMES)
    args = ap.parse_args(argv)

    if (args.only or args.suite) == "mesh":
        # must land before jax initializes its backend (first bench import)
        import os
        os.environ.setdefault(
            "XLA_FLAGS", "--xla_force_host_platform_device_count=8")

    from benchmarks import (accuracy_bench, caesar_bench, grad_bench,
                            mac_bench, pareto_bench, quant_bench,
                            roofline_bench, serve_bench, tune_bench)
    suites = {
        "pareto": pareto_bench.run,
        "mac": mac_bench.run,
        "caesar": caesar_bench.run,
        "accuracy": accuracy_bench.run,
        "roofline": roofline_bench.run,
        "tune": tune_bench.run,
        "grads": grad_bench.run,
        "serve": serve_bench.run,
        "spec": serve_bench.run_spec,
        "quant": quant_bench.run,
        "paged": serve_bench.run_paged,
        "chaos": serve_bench.run_chaos,
        "mesh": serve_bench.run_mesh,
    }
    only = args.only or args.suite
    if only:
        suites = {only: suites[only]}

    rows = []
    failed = 0
    for name, fn in suites.items():
        try:
            fn(rows)
        except Exception:
            failed += 1
            print(f"# suite {name} FAILED:", file=sys.stderr)
            traceback.print_exc()
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
