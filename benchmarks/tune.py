"""``python -m benchmarks.tune`` — the tuning-sweep CLI.

Thin alias for :mod:`benchmarks.tune_bench` (which also registers as the
``tune`` suite of ``benchmarks/run.py``)."""
from __future__ import annotations

import sys

from benchmarks.tune_bench import main

if __name__ == "__main__":
    sys.exit(main())
