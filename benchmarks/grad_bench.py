"""Backward-pass benchmark: fused Pallas kernels vs the STE fallback.

    PYTHONPATH=src python -m benchmarks.grad_bench [--smoke] [--out BENCH_grads.json]

For the two float families (``flash_attention``, ``wkv``) this times one
full ``jax.value_and_grad`` step — forward + backward — twice per shape:
once through the fused backward kernels (``kernel_bwd.py``, the default)
and once through the STE fallback (``REPRO_FUSED_BWD=0``: the exact VJP
of the materialised-scores / float-scan reference).  Shapes derive from
the ``repro.configs`` registry plus fixed long-context cells (S >= 1024),
where the O(S^2) vs O(S) residual-memory gap is the point.

Each row also carries an **analytic peak-residual-memory estimate**
(bytes held between forward and backward):

  * flash STE  — the reference VJP stashes the (B, Hq, Sq, Sk) probability
    matrix plus its mask: ~2 f32 copies of S^2 per head.
  * flash fused — q/k/v/o/do plus the per-row lse and delta: O(S d).
  * wkv STE   — the scan VJP stashes every per-token carry:
    (B*H, T, dk, dv) f32.
  * wkv fused — inputs plus (B*H, T/bt, dk, dv) checkpoints: O(T/bt).

Writes ``BENCH_grads.json``; also registered as the ``grads`` suite of
``benchmarks/run.py`` (smoke shapes).  On CPU the kernels run in Pallas
interpret mode, so absolute timings are only comparable within a run.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

import repro.kernels as K
from repro.kernels import common, tuning
from repro.kernels.wkv.ops import bwd_block_cap


@dataclasses.dataclass
class GradProblem:
    family: str
    shape: Tuple[int, ...]       # reporting shape (see fields per family)
    make: Callable[[], Tuple[Any, ...]]   # fresh primals
    op: Callable[..., jax.Array]          # public wrapper, arrays only
    est_fused: int                        # residual bytes, fused path
    est_ste: int                          # residual bytes, STE path


def _flash_problems(shapes) -> List[GradProblem]:
    rng = np.random.default_rng(0)
    out = []
    for b, s, hq, hkv, d in shapes:
        def make(b=b, s=s, hq=hq, hkv=hkv, d=d):
            q = jnp.array(rng.normal(size=(b, s, hq, d)), jnp.float32)
            k = jnp.array(rng.normal(size=(b, s, hkv, d)), jnp.float32)
            v = jnp.array(rng.normal(size=(b, s, hkv, d)), jnp.float32)
            return q, k, v

        fused = 4 * (b * s * d * (hq + 2 * hkv) + 2 * b * hq * s)
        ste = 4 * 2 * b * hq * s * s
        out.append(GradProblem("flash_attention", (b, s, hq, hkv, d),
                               make, K.flash_attention, fused, ste))
    return out


def _wkv_problems(shapes) -> List[GradProblem]:
    rng = np.random.default_rng(1)
    out = []
    for b, t, h, d in shapes:
        def make(b=b, t=t, h=h, d=d):
            r = jnp.array(rng.normal(size=(b, t, h, d)), jnp.float32)
            k = jnp.array(rng.normal(size=(b, t, h, d)), jnp.float32)
            v = jnp.array(rng.normal(size=(b, t, h, d)), jnp.float32)
            w = jnp.array(rng.uniform(0.1, 0.9, (b, t, h, d)), jnp.float32)
            u = jnp.array(rng.normal(size=(h, d)), jnp.float32)
            return r, k, v, w, u

        # Checkpoint spacing = the wrapper's heuristic on this platform,
        # so the estimate matches the blocks the timed run used.
        bt = common.largest_divisor(t, bwd_block_cap(d))
        fused = 4 * (4 * b * t * h * d + b * h * (t // bt) * d * d)
        ste = 4 * (4 * b * t * h * d + b * h * t * d * d)
        out.append(GradProblem("wkv", (b, t, h, d), make, K.wkv,
                               fused, ste))
    return out


def _shapes(smoke: bool):
    if smoke:
        return ([(1, 64, 2, 1, 8)],        # flash: (B, S, Hq, Hkv, d)
                [(1, 32, 2, 8)])           # wkv:   (B, T, H, d)
    flash = [(1, 1024, 4, 2, 64), (1, 2048, 4, 2, 64), (2, 1024, 8, 8, 32)]
    wkv = [(1, 1024, 4, 32), (1, 2048, 4, 32), (2, 1024, 8, 16)]
    from repro.configs import ARCHS
    for cfg in (a.reduced() for a in ARCHS.values()):
        tokens = 4 * cfg.attn_chunk
        flash.append((1, tokens, cfg.n_heads, max(1, cfg.n_kv_heads),
                      cfg.head_dim_))
        if cfg.ssm_state:
            wkv.append((1, tokens, cfg.n_heads, cfg.head_dim_))
    return sorted(set(flash)), sorted(set(wkv))


def _time_grad(p: GradProblem, fused: bool, repeats: int) -> float:
    """us per value_and_grad call, built and traced under the given mode."""
    prev = os.environ.get("REPRO_FUSED_BWD")
    os.environ["REPRO_FUSED_BWD"] = "1" if fused else "0"
    try:
        args = p.make()

        # A fresh closure per mode: the wrapper reads REPRO_FUSED_BWD at
        # trace time, so the jitted program bakes the chosen path in.
        @jax.jit
        def step(*a):
            return jax.value_and_grad(
                lambda *aa: p.op(*aa).sum(), argnums=tuple(range(len(a))))(*a)

        jax.block_until_ready(step(*args))
        t0 = time.perf_counter()
        for _ in range(repeats):
            jax.block_until_ready(step(*args))
        return (time.perf_counter() - t0) / max(1, repeats) * 1e6
    finally:
        if prev is None:
            os.environ.pop("REPRO_FUSED_BWD", None)
        else:
            os.environ["REPRO_FUSED_BWD"] = prev


def sweep(smoke: bool = False, repeats: int = 3,
          out_path: Optional[str] = None) -> Dict[str, Any]:
    flash_shapes, wkv_shapes = _shapes(smoke)
    problems = _flash_problems(flash_shapes) + _wkv_problems(wkv_shapes)
    rows: List[Dict[str, Any]] = []
    for p in problems:
        us_fused = _time_grad(p, fused=True, repeats=repeats)
        us_ste = _time_grad(p, fused=False, repeats=repeats)
        rows.append({
            "family": p.family, "shape": list(p.shape),
            "us_fused": round(us_fused, 1), "us_ste": round(us_ste, 1),
            "speedup": round(us_ste / max(us_fused, 1e-9), 3),
            "est_peak_bytes_fused": p.est_fused,
            "est_peak_bytes_ste": p.est_ste,
            "mem_ratio": round(p.est_ste / max(p.est_fused, 1), 2),
        })
    report = {
        "meta": {**tuning.version_stamp(), "smoke": smoke,
                 "repeats": repeats},
        "rows": rows,
    }
    if out_path:
        with open(out_path, "w", encoding="utf-8") as f:
            json.dump(report, f, indent=1, sort_keys=True)
    return report


def run(csv_rows):
    """`benchmarks.run` suite entry: smoke shapes, CSV rows per cell."""
    report = sweep(smoke=True, repeats=1)
    for r in report["rows"]:
        shape = "x".join(str(s) for s in r["shape"])
        csv_rows.append((
            f"grads_{r['family']}_{shape}", r["us_fused"],
            f"ste_us={r['us_ste']};speedup={r['speedup']};"
            f"mem_ratio={r['mem_ratio']}"))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Fused vs STE backward benchmark for the float "
                    "kernel families.")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes, repeats=1 (CI lane)")
    ap.add_argument("--repeats", type=int, default=None,
                    help="timed calls per mode (default 3; 1 in smoke)")
    ap.add_argument("--out", default="BENCH_grads.json",
                    help="report path ('' to skip)")
    args = ap.parse_args(argv)
    repeats = args.repeats if args.repeats is not None else (
        1 if args.smoke else 3)
    report = sweep(smoke=args.smoke, repeats=repeats,
                   out_path=args.out or None)
    print("family,shape,us_fused,us_ste,speedup,mem_ratio")
    for r in report["rows"]:
        print(f"{r['family']},{'x'.join(str(s) for s in r['shape'])},"
              f"{r['us_fused']},{r['us_ste']},{r['speedup']},"
              f"{r['mem_ratio']}")
    return 0 if report["rows"] else 1


if __name__ == "__main__":
    import sys
    sys.exit(main())
