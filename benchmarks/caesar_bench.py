"""Paper Table 3: CAESAR mapping of VGG-16/CIFAR-100 onto the 32x32 SYCore
(op cycles, utilization, execution time, power) — dense and 40 %-pruned."""
from __future__ import annotations

import time

from repro.core import caesar
from repro.core.pruning import PruningPolicy


def run(csv_rows):
    t0 = time.time()
    layers = caesar.vgg16_cifar100()
    dense = caesar.Caesar(pruning=None).schedule(layers)
    pruned = caesar.Caesar(pruning=PruningPolicy(rate=0.40)).schedule(layers)
    nm = caesar.Caesar(pruning=PruningPolicy(n=4, m=9)).schedule(layers)
    dt_us = (time.time() - t0) * 1e6

    c11 = dense.layers[0]
    csv_rows.append(("caesar_vgg16_C1_1_cycles", dt_us / 3,
                     f"op_cycles={c11.op_cycles};paper=1728"))
    csv_rows.append(("caesar_vgg16_dense_total", dt_us / 3,
                     f"time_us={dense.total_time_us:.0f};"
                     f"util={dense.mean_utilization:.2f};"
                     f"frames_per_j={dense.frames_per_joule:.1f}"))
    csv_rows.append(("caesar_vgg16_pruned40_total", dt_us / 3,
                     f"time_us={pruned.total_time_us:.0f};"
                     f"speedup={dense.total_time_us / pruned.total_time_us:.2f}x"))
    csv_rows.append(("caesar_vgg16_nm49_total", dt_us / 3,
                     f"time_us={nm.total_time_us:.0f};"
                     f"speedup={dense.total_time_us / nm.total_time_us:.2f}x;"
                     f"paper=1.7x"))
    # transformer workload mapping (paper Fig 1b / §3.2 claim of generality)
    specs = caesar.transformer_block_specs("blk", 512, 1024, 16, 4096, 4)
    tsched = caesar.Caesar().schedule(specs)
    csv_rows.append(("caesar_transformer_block", dt_us / 3,
                     f"time_us={tsched.total_time_us:.0f};"
                     f"util={tsched.mean_utilization:.2f}"))
    return dense
