"""Quantized-cache serving benchmark: memory / fidelity / throughput.

    PYTHONPATH=src python -m benchmarks.quant_bench [--smoke] [--out BENCH_quant.json]

For each stateful serving family (dense GQA, rwkv ssm, hymba hybrid) this
compares the fp32 slot cache against the per-block int8 quantized mode
(``ServeEngine(cache_dtype="int8")``, see ``core/quant_cache.py``) on
three axes — the Pareto the ROADMAP's "2-4x more slots per HBM byte"
claim lives on:

  * **slots-per-GB**: bytes of one engine's slot state (``init_slot_state``,
    abstract — no allocation) per format: fp32, the arch's native mix
    (bf16 KV + f32 recurrent), int8+scales.  The headline ratio is
    int8 vs fp32 — the acceptance baseline — and must clear the
    committed ``slots_per_gb_floor``.
  * **max-logit-error**: side-by-side prefill + decode feeding the fp
    model's greedy tokens to both models; the max |logit diff| over the
    run plus the paper's error metrics (``core/pareto.py``, eqs 4-7).
    CI gates this against per-arch ceilings in
    ``benchmarks/quant_baseline.json``.
  * **tok/s**: the serve-bench arrival trace replayed through an fp and
    an int8 continuous engine (same requests, greedy), with the int8
    engine's ``trace_counts`` proving the bucketed one-trace-per-shape
    discipline survives the format change.

Writes ``BENCH_quant.json``; also registered as the ``quant`` suite of
``benchmarks/run.py`` (the CI serve-smoke lane runs and gates it).
"""
from __future__ import annotations

import argparse
import json
from typing import Any, Dict, List, Optional

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.serve_bench import _replay, make_trace
from repro.configs import get_arch
from repro.core.pareto import error_metrics
from repro.kernels import tuning
from repro.models.model_zoo import build_model
from repro.runtime.serve_loop import ServeConfig, ServeEngine

ARCHS = ("glm4-9b", "rwkv6-3b", "hymba-1.5b")


def state_bytes(state) -> int:
    """Total bytes of one slot state (works on abstract states)."""
    return int(sum(int(np.prod(leaf.shape)) * np.dtype(leaf.dtype).itemsize
                   for leaf in jax.tree_util.tree_leaves(state)))


def _logit_error(model_fp, model_q, params, cfg, steps: int, seed: int
                 ) -> Dict[str, Any]:
    """Side-by-side decode: both models eat the fp greedy stream."""
    rng = np.random.default_rng(seed)
    prompt = rng.integers(0, cfg.vocab_size, 9).astype(np.int32)
    batch = {"tokens": jnp.asarray(prompt[None, :])}
    lg_f, st_f = model_fp.prefill(params, batch, headroom=steps + 8)
    lg_q, st_q = model_q.prefill(params, batch, headroom=steps + 8)
    fp_rows: List[np.ndarray] = [np.asarray(lg_f, np.float32).ravel()]
    q_rows: List[np.ndarray] = [np.asarray(lg_q, np.float32).ravel()]
    cur = int(jnp.argmax(lg_f.reshape(1, -1)[0]))
    for _ in range(steps):
        nb = {"tokens": jnp.asarray([[cur]], jnp.int32)}
        lg_f, st_f = model_fp.decode_step(params, st_f, nb)
        lg_q, st_q = model_q.decode_step(params, st_q, nb)
        fp_rows.append(np.asarray(lg_f, np.float32).ravel())
        q_rows.append(np.asarray(lg_q, np.float32).ravel())
        cur = int(jnp.argmax(lg_f.reshape(1, -1)[0]))
    fp = np.concatenate(fp_rows)
    q = np.concatenate(q_rows)
    return {"max_logit_err": float(np.max(np.abs(fp - q))),
            "logit_span": float(np.max(np.abs(fp))),
            "err_metrics": {k: round(v, 8)
                            for k, v in error_metrics(q, fp).items()}}


def _arch_cell(arch: str, smoke: bool, max_batch: int, max_seq: int,
               seed: int) -> Dict[str, Any]:
    # fp32 end to end: the acceptance baseline is fp32-cache decode, and
    # an all-f32 pair isolates the cache format as the only difference
    cfg = get_arch(arch).reduced().scaled(dtype="float32")
    model_fp = build_model(cfg)
    model_q = model_fp.with_cache_dtype("int8")
    params = model_fp.init(jax.random.PRNGKey(seed))

    # memory: bytes of max_batch slots per format
    native = build_model(get_arch(arch).reduced())    # bf16 KV + f32 rec
    bytes_fp = state_bytes(model_fp.init_slot_state(max_batch, max_seq,
                                                    abstract=True))
    bytes_nat = state_bytes(native.init_slot_state(max_batch, max_seq,
                                                   abstract=True))
    bytes_q = state_bytes(model_q.init_slot_state(max_batch, max_seq,
                                                  abstract=True))
    gb = float(1 << 30)
    cell: Dict[str, Any] = {
        "state_bytes": {"fp32": bytes_fp, "native": bytes_nat,
                        "int8": bytes_q},
        "slots_per_gb": {"fp32": round(max_batch * gb / bytes_fp, 1),
                         "native": round(max_batch * gb / bytes_nat, 1),
                         "int8": round(max_batch * gb / bytes_q, 1)},
        "slots_per_gb_ratio": round(bytes_fp / bytes_q, 3),
        "slots_per_gb_ratio_native": round(bytes_nat / bytes_q, 3),
    }

    # fidelity: max logit error over a greedy-fed decode run
    cell.update(_logit_error(model_fp, model_q, params, cfg,
                             steps=12 if smoke else 48, seed=seed))

    # throughput: same arrival trace through fp and int8 engines
    n = 12 if smoke else 32
    eng_fp = ServeEngine(model_fp, params,
                         ServeConfig(max_batch=max_batch, max_seq=max_seq))
    fp_stats = _replay(eng_fp, make_trace(cfg, n, seed=seed))
    eng_q = ServeEngine(model_fp, params,
                        ServeConfig(max_batch=max_batch, max_seq=max_seq,
                                    cache_dtype="int8"))
    q_stats = _replay(eng_q, make_trace(cfg, n, seed=seed))
    cell.update({
        "fp": fp_stats,
        "int8": q_stats,
        "tok_s_ratio": round(q_stats["tok_s"]
                             / max(fp_stats["tok_s"], 1e-9), 3),
        # single-trace discipline must survive the format change
        "trace_counts": {k: int(v) for k, v in eng_q.trace_counts.items()},
    })
    return cell


def sweep(smoke: bool = False, out_path: Optional[str] = None,
          max_batch: int = 4, max_seq: int = 64, seed: int = 0
          ) -> Dict[str, Any]:
    report: Dict[str, Any] = {
        "meta": {**tuning.version_stamp(), "smoke": smoke,
                 "max_batch": max_batch, "max_seq": max_seq, "seed": seed,
                 "baseline": "fp32 slot caches (all-f32 model pair)"},
        "archs": {},
    }
    for arch in ARCHS:
        report["archs"][arch] = _arch_cell(arch, smoke, max_batch, max_seq,
                                           seed)
    if out_path:
        with open(out_path, "w", encoding="utf-8") as f:
            json.dump(report, f, indent=1, sort_keys=True)
    return report


def run(csv_rows):
    """`benchmarks.run` suite entry: smoke cells, writes BENCH_quant.json."""
    report = sweep(smoke=True, out_path="BENCH_quant.json")
    for arch, c in report["archs"].items():
        us = 1e6 * c["int8"]["wall_s"] / max(c["int8"]["delivered_tokens"], 1)
        csv_rows.append((
            f"quant_int8_{arch}", us,
            f"tok_s={c['int8']['tok_s']};"
            f"tok_s_ratio={c['tok_s_ratio']};"
            f"slots_per_gb_x={c['slots_per_gb_ratio']};"
            f"max_logit_err={c['max_logit_err']:.4f};"
            f"decode_traces={c['trace_counts'].get('decode', 0)}"))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Quantized int8 serving-cache benchmark "
                    "(memory / fidelity / throughput Pareto).")
    ap.add_argument("--smoke", action="store_true",
                    help="small cells (CI lane)")
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_quant.json",
                    help="report path ('' to skip)")
    args = ap.parse_args(argv)
    report = sweep(smoke=args.smoke, out_path=args.out or None,
                   max_batch=args.max_batch, max_seq=args.max_seq,
                   seed=args.seed)
    print("arch,slots_per_gb_x,max_logit_err,tok_s_fp,tok_s_int8,dropped")
    for arch, c in report["archs"].items():
        print(f"{arch},{c['slots_per_gb_ratio']},"
              f"{c['max_logit_err']:.4f},{c['fp']['tok_s']},"
              f"{c['int8']['tok_s']},{c['int8']['dropped']}")
    ok = all(c["int8"]["dropped"] == 0 and c["slots_per_gb_ratio"] >= 2.0
             for c in report["archs"].values())
    return 0 if ok else 1


if __name__ == "__main__":
    import sys
    sys.exit(main())
