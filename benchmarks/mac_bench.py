"""Paper Tables 4/5/6: MAC-level comparison.

Three execution variants of the same 512x512x512 GEMM:
  * bf16/f32 MXU reference (XLA dot),
  * FxP8/int8 quantized path (the production CORDIC mapping),
  * bit-exact 5-stage shift-add Pallas kernel (interpret mode on CPU —
    correctness datapoint, wall time not meaningful vs hardware),
plus the paper's cycle/throughput model at the quoted 3 GHz / 1024 RPEs
(TOPS, TOPS/W from Table 5's 109.8 uW/RPE figure).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fixed_point as fxp
from repro.core.quantization import QuantPolicy, quantized_dense
from repro.core.rpe import throughput_gops
from repro.core.sycore import SYCoreConfig
from repro.kernels.cordic_mac.ops import cordic_matmul
from repro.kernels.cordic_mac.ref import effective_weight


def _timeit(f, n=5):
    f()  # compile
    t0 = time.time()
    for _ in range(n):
        r = f()
    jax.block_until_ready(r)
    return (time.time() - t0) / n * 1e6


def run(csv_rows):
    rng = np.random.default_rng(0)
    m = k = n = 512
    x = jnp.array(rng.uniform(-2, 2, (m, k)), jnp.float32)
    w = jnp.array(rng.uniform(-1.9, 1.9, (k, n)), jnp.float32)
    ref = x @ w
    scale = float(jnp.abs(ref).max())

    us_f32 = _timeit(jax.jit(lambda: x @ w))
    csv_rows.append(("mac_gemm_f32", us_f32, "rel_err=0"))

    q = jax.jit(lambda: quantized_dense(x, w, QuantPolicy()))
    us_q = _timeit(q)
    err_q = float(jnp.abs(q() - ref).max()) / scale
    csv_rows.append(("mac_gemm_fxp8_int8path", us_q, f"rel_err={err_q:.3e}"))

    c = jax.jit(lambda: cordic_matmul(x, w, fmt=fxp.FXP16, n_stages=5,
                                      block=(128, 128, 128)))
    us_c = _timeit(c, n=1)
    err_c = float(jnp.abs(c() - ref).max()) / scale
    csv_rows.append(("mac_gemm_cordic5_kernel_interp", us_c,
                     f"rel_err={err_c:.3e}"))

    # signed-digit error model: |w_eff - w| governs the MAC's multiplicative
    # error (paper's 'normalized mean error' 6.31e-5 at fp-scale)
    w_eff = effective_weight(w, fxp.FXP16, 5)
    nme = float(jnp.mean(jnp.abs(w_eff - w)) / jnp.mean(jnp.abs(w)))
    csv_rows.append(("mac_signed_digit_nme_5stage", 0.0, f"nme={nme:.3e}"))

    # paper's hardware model: 32x32 RPEs at 3 GHz, pipelined
    tops = throughput_gops(3000.0, 1024, pipelined=True) / 1000.0
    power_w = 1024 * SYCoreConfig().rpe_power_uw * 1e-6 * 30  # 3 GHz/100 MHz
    csv_rows.append(("sycore_model_3ghz", 0.0,
                     f"tops={tops:.2f};tops_per_w={tops / power_w:.1f}"))
    # iterative (non-pipelined) variant => the paper's ~4.6x throughput gap
    tops_iter = throughput_gops(3000.0, 1024, pipelined=False) / 1000.0
    csv_rows.append(("sycore_pipelined_vs_iterative", 0.0,
                     f"speedup={tops / tops_iter:.2f}x"))
