"""Paper Fig 11: inference accuracy under CORDIC execution.

Trains a small MLP classifier (LeNet-5-class stand-in; MNIST is not
available offline, so a structured synthetic 10-class problem with the
same difficulty profile) in f32, then evaluates the SAME weights under
  * exact f32,
  * FxP8 CORDIC execution (int8 MACs + DA-VINCI AFs),
  * FxP8 + 40% magnitude pruning (+ brief QAT fine-tune to recover),
reporting the accuracy deltas the paper claims stay < 2%.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.activations import CordicPolicy, activate
from repro.core.pruning import PruningPolicy, apply_policy
from repro.core.quantization import QuantPolicy, quantized_dense


def _make_data(n, d, classes, key, protos):
    """Gaussian class clusters around shared prototypes."""
    kx, kn = jax.random.split(key, 2)
    labels = jax.random.randint(kx, (n,), 0, classes)
    x = protos[labels] + jax.random.normal(kn, (n, d))
    return x, labels


def _forward(params, x, mode, pol=None, masks=None, qbits=8):
    qp = QuantPolicy(bits=qbits, act_bits=qbits)
    h = x
    for i, (w, b) in enumerate(params[:-1]):
        if masks is not None and masks[i] is not None:
            w = w * masks[i]
        if mode == "f32":
            h = jnp.maximum(h @ w + b, 0.0)
        else:
            h = quantized_dense(h, w, qp) + b
            h = activate(h, "relu", pol)
    w, b = params[-1]
    if masks is not None and masks[-1] is not None:
        w = w * masks[-1]
    logits = (h @ w + b) if mode == "f32" else quantized_dense(h, w, qp) + b
    return logits


def _accuracy(params, x, y, mode, pol=None, masks=None):
    pred = jnp.argmax(_forward(params, x, mode, pol, masks), -1)
    return float(jnp.mean((pred == y).astype(jnp.float32)))


def _accuracy_bits(params, x, y, pol, qbits):
    pred = jnp.argmax(_forward(params, x, "cordic", pol, None, qbits), -1)
    return float(jnp.mean((pred == y).astype(jnp.float32)))


def run(csv_rows):
    t0 = time.time()
    key = jax.random.PRNGKey(0)
    d, classes = 64, 10
    protos = jax.random.normal(jax.random.PRNGKey(42), (classes, d)) * 0.45
    xtr, ytr = _make_data(4096, d, classes, jax.random.PRNGKey(1), protos)
    xte, yte = _make_data(1024, d, classes, jax.random.PRNGKey(2), protos)
    sizes = [d, 128, 64, classes]
    params = []
    for i in range(len(sizes) - 1):
        key, k = jax.random.split(key)
        params.append((jax.random.normal(k, (sizes[i], sizes[i + 1]))
                       / np.sqrt(sizes[i]), jnp.zeros(sizes[i + 1])))

    def loss(params, x, y, mode="f32", pol=None, masks=None):
        logits = _forward(params, x, mode, pol, masks)
        logz = jax.scipy.special.logsumexp(logits, -1)
        gold = jnp.take_along_axis(logits, y[:, None], 1)[:, 0]
        return jnp.mean(logz - gold)

    @jax.jit
    def step(params, x, y):
        g = jax.grad(loss)(params, x, y)
        return jax.tree_util.tree_map(lambda p, gg: p - 0.5 * gg, params, g)

    for epoch in range(400):
        params = step(params, xtr, ytr)

    pol = CordicPolicy(bits=16)
    acc_f32 = _accuracy(params, xte, yte, "f32")
    acc_cordic = _accuracy(params, xte, yte, "cordic", pol)

    # Fig 11's bit-width axis: same weights at FxP4/8/16/32 (MAC + AF width)
    bit_rows = []
    for bits in (4, 8, 16, 32):
        pb = CordicPolicy(bits=min(bits, 32))
        accb = _accuracy_bits(params, xte, yte, pb, min(bits, 8))
        bit_rows.append((bits, accb))

    # 40% pruning + short QAT fine-tune (paper §4.2 recovery)
    masks = []
    pruned = []
    for (w, b) in params:
        pw, m = apply_policy(w, PruningPolicy(rate=0.40))
        pruned.append((pw, b))
        masks.append(m)
    acc_pruned_raw = _accuracy(pruned, xte, yte, "cordic", pol, masks)

    @jax.jit
    def qat_step(params, x, y):
        g = jax.grad(lambda p: loss(p, x, y, "cordic", pol, masks))(params)
        new = jax.tree_util.tree_map(lambda p, gg: p - 0.05 * gg, params, g)
        return [(w * m, b) for (w, b), m in zip(new, masks)]

    tuned = pruned
    for _ in range(150):
        tuned = qat_step(tuned, xtr, ytr)
    acc_pruned_qat = _accuracy(tuned, xte, yte, "cordic", pol, masks)
    dt_us = (time.time() - t0) * 1e6

    csv_rows.append(("accuracy_f32", dt_us / 4, f"acc={acc_f32:.4f}"))
    csv_rows.append(("accuracy_cordic_fxp8", dt_us / 4,
                     f"acc={acc_cordic:.4f};delta={acc_f32 - acc_cordic:.4f}"))
    csv_rows.append(("accuracy_pruned40_raw", dt_us / 4,
                     f"acc={acc_pruned_raw:.4f}"))
    csv_rows.append(("accuracy_pruned40_qat", dt_us / 4,
                     f"acc={acc_pruned_qat:.4f};"
                     f"delta={acc_f32 - acc_pruned_qat:.4f};paper=<0.02"))
    for bits, accb in bit_rows:
        csv_rows.append((f"accuracy_fxp{bits}", dt_us / 8,
                         f"acc={accb:.4f};delta={acc_f32 - accb:.4f}"))
