"""Serving benchmark: continuous batching vs the gang scheduler.

    PYTHONPATH=src python -m benchmarks.serve_bench [--smoke] [--out BENCH_serving.json]

Replays a Poisson-ish arrival trace of mixed prompt/output lengths —
exponential inter-arrival gaps, prompt lengths spanning several shape
buckets, ``max_new_tokens`` drawn from a short/long mix — through both
engines in ``runtime/serve_loop.py``:

  * ``ServeEngine`` — slot-based continuous batching (bucketed shapes,
    retire-and-refill every decode step)
  * ``GangServeEngine`` — the old lockstep baseline (per-composition
    retraces, batch drains at the speed of its slowest request)

and writes ``BENCH_serving.json`` with token throughput (delivered
tokens/s over the whole replay, compiles included — reuse vs retrace *is*
the comparison), p50/p99 request latency from virtual arrival to
completion, slot occupancy, and the continuous/gang speedup.  The CI
``serve-smoke`` lane gates on this file: no replayed request may be
dropped, and throughput must stay within 2x of
``benchmarks/serving_baseline.json``.

**Speculative decoding** (``--spec`` / the ``spec`` suite): replays a
*draftable* trace — prompts built from short repeated motifs, the
list/code/template-shaped workload prompt-lookup drafting is designed
for — through a plain continuous engine and a ``spec_k`` speculative one
(same requests, greedy), asserts the outputs are **bit-identical**, and
writes ``BENCH_spec.json`` with both throughputs, the spec/plain speedup,
the draft-acceptance rate and tokens/step.  Both engines are warmed on a
small side trace first so the comparison is steady-state decode, not
compile time.  The CI ``serve-smoke`` lane gates on this file: greedy
outputs must match and acceptance must not fall below the committed
``benchmarks/spec_baseline.json`` floor.

**Paged prefix caching** (``--paged`` / the ``paged`` suite): replays a
*shared-prefix* trace — a few long "system prompts" each carrying many
short unique tails, the multi-turn/agentic workload prefix caching
targets — through a dense continuous engine and a paged one
(``CacheSpec(paged=True)`` + radix prefix cache), asserts the greedy
outputs are **bit-identical**, and writes ``BENCH_paged.json`` with both
engines' prefill token counts, the prefix-cache hit tokens, and the
**prefill amortization** ``dense_prefill / paged_prefill`` (how much
prompt compute the radix cache removed).  The CI ``serve-smoke`` lane
gates on this file: outputs must match and amortization must not fall
below the committed ``benchmarks/paged_baseline.json`` floor.

Also registered as the ``serve``, ``spec`` and ``paged`` suites of
``benchmarks/run.py``.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from typing import Any, Dict, List, Optional

import numpy as np

import jax

from repro.configs import CacheSpec, get_arch
from repro.kernels import tuning
from repro.models.model_zoo import build_model
from repro.runtime.serve_loop import (GangServeEngine, Request, ServeConfig,
                                      ServeEngine)


def make_trace(cfg, n_requests: int, seed: int = 0, rate_hz: float = 50.0,
               len_range=(3, 30), max_new_choices=(2, 4, 8, 24)
               ) -> List[Request]:
    """Poisson-ish arrivals, mixed prompt lengths, short/long outputs."""
    rng = np.random.default_rng(seed)
    t = 0.0
    reqs = []
    for i in range(n_requests):
        t += float(rng.exponential(1.0 / rate_hz))
        n = int(rng.integers(*len_range))
        if cfg.input_kind == "tokens":
            prompt = rng.integers(0, cfg.vocab_size, n).astype(np.int32)
        else:
            prompt = rng.standard_normal((n, cfg.d_model)).astype(np.float32)
        reqs.append(Request(i, prompt, arrival_s=t,
                            max_new_tokens=int(rng.choice(max_new_choices))))
    return reqs


def make_spec_trace(cfg, n_requests: int, seed: int = 0,
                    rate_hz: float = 200.0, len_range=(16, 48),
                    motif_range=(2, 5), max_new_choices=(32, 48, 64)
                    ) -> List[Request]:
    """Draftable arrival trace: motif-structured prompts, long outputs.

    Prompts tile a short random motif — the repetitive list/code/template
    shape that prompt-lookup speculative decoding targets (on such inputs
    greedy continuations fall into drafter-predictable cycles; fully
    random prompts are the adversarial case and verify-bound spec decode
    rightly loses there).  Outputs are decode-heavy so steady-state decode
    dominates the replay.
    """
    rng = np.random.default_rng(seed)
    t = 0.0
    reqs = []
    for i in range(n_requests):
        t += float(rng.exponential(1.0 / rate_hz))
        n = int(rng.integers(*len_range))
        m = int(rng.integers(*motif_range))
        motif = rng.integers(0, cfg.vocab_size, m)
        prompt = np.tile(motif, n // m + 1)[:n].astype(np.int32)
        reqs.append(Request(i, prompt, arrival_s=t,
                            max_new_tokens=int(rng.choice(max_new_choices))))
    return reqs


def make_prefix_trace(cfg, n_requests: int, seed: int = 0,
                      rate_hz: float = 200.0, n_prefixes: int = 2,
                      prefix_len: int = 24, tail_range=(4, 11),
                      max_new_choices=(2, 4, 8)) -> List[Request]:
    """Shared-prefix arrival trace: few long system prompts, many tails.

    Every request is one of ``n_prefixes`` fixed ``prefix_len``-token
    prefixes plus a short unique tail — the multi-turn / agentic
    workload where a radix prefix cache amortizes prompt prefill across
    requests (the first request per prefix pays it, the rest reference
    the cached pages).
    """
    rng = np.random.default_rng(seed)
    prefixes = [rng.integers(0, cfg.vocab_size, prefix_len)
                for _ in range(n_prefixes)]
    t = 0.0
    reqs = []
    for i in range(n_requests):
        t += float(rng.exponential(1.0 / rate_hz))
        tail = rng.integers(0, cfg.vocab_size, int(rng.integers(*tail_range)))
        prompt = np.concatenate([prefixes[i % n_prefixes],
                                 tail]).astype(np.int32)
        reqs.append(Request(i, prompt, arrival_s=t,
                            max_new_tokens=int(rng.choice(max_new_choices))))
    return reqs


def _replay(engine, requests: List[Request]) -> Dict[str, Any]:
    t0 = time.perf_counter()
    done = engine.serve(requests)
    wall = time.perf_counter() - t0
    delivered = sum(len(r.output) for r in done if r.output is not None)
    expected = sum(r.max_new_tokens for r in requests)
    lat = sorted(1e3 * (r.done_at - r.submitted_at) for r in done)
    pick = lambda q: lat[min(len(lat) - 1, int(q * len(lat)))] if lat else 0.0
    stats = {
        "requests": len(requests),
        "completed": len(done),
        "dropped": len(requests) - len(done)
        + sum(1 for r in done
              if r.output is None or len(r.output) < r.max_new_tokens),
        "delivered_tokens": delivered,
        "expected_tokens": expected,
        "wall_s": round(wall, 3),
        "tok_s": round(delivered / max(wall, 1e-9), 1),
        "latency_p50_ms": round(pick(0.50), 1),
        "latency_p99_ms": round(pick(0.99), 1),
    }
    m = getattr(engine, "metrics", {})
    if "slot_occupancy" in m:
        stats["slot_occupancy"] = round(m["slot_occupancy"], 3)
        stats["queue_wait_s"] = round(m["queue_wait_s"], 3)
        stats["decode_steps"] = int(m["decode_steps"])
        stats["tokens_per_step"] = round(m["tokens_per_step"], 3)
    if m.get("spec_steps"):
        stats["spec_acceptance"] = round(m["spec_acceptance"], 3)
        stats["draft_tokens"] = int(m["draft_tokens"])
        stats["draft_accepted"] = int(m["draft_accepted"])
        stats["model_drafts"] = int(m.get("model_drafts", 0))
        stats["fallback_drafts"] = int(m.get("fallback_drafts", 0))
        hist = m.get("spec_k_hist") or {}
        stats["spec_k_hist"] = {str(k): int(v)
                                for k, v in sorted(hist.items())}
    return stats


def sweep(smoke: bool = False, out_path: Optional[str] = None,
          arch: str = "glm4-9b", n_requests: Optional[int] = None,
          max_batch: int = 4, max_seq: int = 64, seed: int = 0
          ) -> Dict[str, Any]:
    cfg = get_arch(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    # smoke stays CI-sized but large enough that steady-state decode (the
    # thing continuous batching improves) dominates one-off compile time
    n = n_requests if n_requests is not None else (32 if smoke else 48)

    # fresh Request objects per engine: engines mutate timing fields
    gang = GangServeEngine(model, params, max_batch=max_batch,
                           max_seq=max_seq)
    gang_stats = _replay(gang, make_trace(cfg, n, seed=seed))

    cont = ServeEngine(model, params, ServeConfig(max_batch=max_batch,
                                                  max_seq=max_seq))
    cont_stats = _replay(cont, make_trace(cfg, n, seed=seed))

    report = {
        "meta": {**tuning.version_stamp(), "smoke": smoke, "arch": arch,
                 "max_batch": max_batch, "max_seq": max_seq,
                 "n_requests": n, "seed": seed,
                 # span of virtual arrivals: when walls approach this the
                 # replay is arrival-bound, not compute-bound, and the
                 # continuous/gang ratio converges to 1 by construction
                 "arrival_span_s": round(
                     max(r.arrival_s for r in make_trace(cfg, n, seed=seed)),
                     3)},
        "continuous": cont_stats,
        "gang": gang_stats,
        "speedup_tok_s": round(
            cont_stats["tok_s"] / max(gang_stats["tok_s"], 1e-9), 3),
        "prefill_traces": int(cont.trace_counts["prefill"]),
        "decode_traces": int(cont.trace_counts["decode"]),
    }
    if out_path:
        with open(out_path, "w", encoding="utf-8") as f:
            json.dump(report, f, indent=1, sort_keys=True)
    return report


def sweep_spec(smoke: bool = False, out_path: Optional[str] = None,
               arch: str = "glm4-9b", spec_k: int = 5,
               n_requests: Optional[int] = None, max_batch: int = 4,
               max_seq: int = 128, seed: int = 0,
               reps: int = 2, drafter: str = "ngram") -> Dict[str, Any]:
    """Spec-vs-plain comparison on the draftable trace (see module doc).

    ``drafter`` picks the speculation tier for the spec engine: ``ngram``
    (host-side prompt lookup) or ``draft_model`` (the batched tiny-LM
    drafter with n-gram fallback — the bench's derived draft LM is
    randomly initialised, so its confidence gate tiers most slot-steps
    down to the fallback; the number this row measures is the *tiered
    pipeline's* throughput including the draft-model dispatch overhead).

    Each engine replays the measured trace ``reps`` times (interleaved
    plain/spec) and the fastest replay is reported — shared CI runners
    and cpu-share-capped containers see invisible neighbour load, and
    best-of-N is the standard way to read a throughput *capability*
    through that noise.  Token/acceptance counters are reset before every
    measured replay, so the reported stats describe exactly the replay
    they came from.
    """
    cfg = get_arch(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    # long enough that steady-state decode dominates the slot ramp-up and
    # drain tails (a short trace under-reports both engines, the spec one
    # more: its fixed-shape verify pays full width for a draining batch)
    n = n_requests if n_requests is not None else (48 if smoke else 96)

    def build(k):
        eng = ServeEngine(model, params, ServeConfig(
            max_batch=max_batch, max_seq=max_seq, spec_k=k,
            drafter=(drafter if k else None)))
        # steady-state comparison: compiles and the tuned-table boot are
        # paid on a small side trace, then the measured trace replays
        # against warm programs (the plain-vs-gang bench measures the
        # compile story; here the question is decode throughput).  A
        # draft-model drafter pre-compiles its buckets the same way —
        # the warm trace's streams are too short to reach them all.
        if hasattr(eng.drafter, "warm"):
            eng.drafter.warm()
        eng.serve(make_spec_trace(cfg, 6, seed=seed + 1))
        return eng

    def replay(eng):
        # the engine's token/step/draft counters accumulate over its
        # lifetime: zero them so the reported (and CI-gated) stats
        # describe the measured trace only, not warmup + measured
        for key in ("prefill_tokens", "decode_tokens", "decode_steps",
                    "spec_steps", "draft_tokens", "draft_accepted",
                    "model_drafts", "fallback_drafts"):
            eng.metrics[key] = 0
        eng.metrics["spec_k_hist"] = {}
        # the tier counters are mirrored from the drafter at serve() end;
        # zero the source so the mirror describes this replay only
        for attr in ("model_dispatches", "fallback_dispatches"):
            if hasattr(eng.drafter, attr):
                setattr(eng.drafter, attr, 0)
        reqs = make_spec_trace(cfg, n, seed=seed)
        return _replay(eng, reqs), reqs

    engines = {0: build(0), spec_k: build(spec_k)}
    best: Dict[int, Any] = {}
    for _ in range(max(1, reps)):
        for k, eng in engines.items():          # interleave plain/spec
            stats, reqs = replay(eng)
            if k not in best or stats["tok_s"] > best[k][0]["tok_s"]:
                best[k] = (stats, reqs)
    plain_stats, plain_reqs = best[0]
    spec_stats, spec_reqs = best[spec_k]
    # greedy spec decode must be a pure scheduling change: every request's
    # tokens bit-identical to the plain engine's
    by_rid = {r.rid: r for r in plain_reqs}
    greedy_match = all(
        np.array_equal(r.output, by_rid[r.rid].output) for r in spec_reqs)

    report = {
        "meta": {**tuning.version_stamp(), "smoke": smoke, "arch": arch,
                 "max_batch": max_batch, "max_seq": max_seq,
                 "n_requests": n, "seed": seed, "spec_k": spec_k,
                 "drafter": drafter, "trace": "motif-prompt draftable"},
        "plain": plain_stats,
        "spec": spec_stats,
        "speedup_tok_s": round(
            spec_stats["tok_s"] / max(plain_stats["tok_s"], 1e-9), 3),
        "spec_acceptance": spec_stats.get("spec_acceptance", 0.0),
        "greedy_match": bool(greedy_match),
    }
    if out_path:
        with open(out_path, "w", encoding="utf-8") as f:
            json.dump(report, f, indent=1, sort_keys=True)
    return report


def sweep_paged(smoke: bool = False, out_path: Optional[str] = None,
                arch: str = "glm4-9b", n_requests: Optional[int] = None,
                max_batch: int = 4, max_seq: int = 64, page_size: int = 8,
                seed: int = 0) -> Dict[str, Any]:
    """Paged-vs-dense comparison on the shared-prefix trace (module doc).

    The headline number is **prefill amortization**: prompt tokens the
    dense engine prefilled divided by the tokens the paged engine
    actually computed (its radix cache serves the rest from shared
    pages).  Greedy outputs must stay bit-identical — prefix reuse is a
    pure scheduling/memory change, never a numerics change.  Block-pool
    telemetry (peak blocks vs the dense layout's fixed page equivalent)
    shows resident cache memory scaling with live tokens.
    """
    cfg = get_arch(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    n = n_requests if n_requests is not None else (32 if smoke else 64)

    # fresh Request objects per engine (engines mutate timing/output
    # fields); same seed -> identical prompts, so outputs are comparable
    dense = ServeEngine(model, params, ServeConfig(max_batch=max_batch,
                                                   max_seq=max_seq))
    dense_reqs = make_prefix_trace(cfg, n, seed=seed)
    dense_stats = _replay(dense, dense_reqs)

    paged = ServeEngine(model, params, ServeConfig(
        max_batch=max_batch, max_seq=max_seq,
        cache=CacheSpec(paged=True, page_size=page_size)))
    paged_reqs = make_prefix_trace(cfg, n, seed=seed)
    paged_stats = _replay(paged, paged_reqs)

    # bit-equality: prefix reuse must not change a single token
    by_rid = {r.rid: r.output for r in dense_reqs}
    greedy_match = all(np.array_equal(r.output, by_rid[r.rid])
                       for r in paged_reqs)

    paged_prefill = int(paged.metrics["prefill_tokens"])
    dense_prefill = int(dense.metrics["prefill_tokens"])
    report = {
        "meta": {**tuning.version_stamp(), "smoke": smoke, "arch": arch,
                 "max_batch": max_batch, "max_seq": max_seq,
                 "page_size": page_size, "n_requests": n, "seed": seed,
                 "trace": "shared-prefix"},
        "dense": dense_stats,
        "paged": paged_stats,
        "dense_prefill_tokens": dense_prefill,
        "paged_prefill_tokens": paged_prefill,
        "prefix_hit_tokens": int(paged.metrics["prefix_hit_tokens"]),
        "prefill_amortization": round(
            dense_prefill / max(paged_prefill, 1), 3),
        "peak_blocks": int(paged.metrics["peak_blocks"]),
        "dense_equiv_blocks": max_batch * (max_seq // page_size),
        "extend_traces": int(paged.trace_counts["extend"]),
        "reset_traces": int(paged.trace_counts["reset"]),
        "decode_traces": int(paged.trace_counts["decode"]),
        "greedy_match": bool(greedy_match),
    }
    if out_path:
        with open(out_path, "w", encoding="utf-8") as f:
            json.dump(report, f, indent=1, sort_keys=True)
    return report


def sweep_chaos(smoke: bool = False, out_path: Optional[str] = None,
                arch: str = "glm4-9b", n_requests: Optional[int] = None,
                max_batch: int = 4, max_seq: int = 64, kill_at: int = 6,
                snapshot_every: int = 3, seed: int = 0) -> Dict[str, Any]:
    """Kill/restore recovery cost on the mixed trace.

    Replays the trace twice: undisturbed, then under a supervisor with an
    injected worker death at decode step ``kill_at`` (snapshot cadence
    ``snapshot_every``).  Reports snapshot/restore latency, the wall-clock
    recovery overhead, and — the contract the chaos tests enforce —
    whether every request completed bit-identically to the undisturbed
    run.
    """
    import tempfile

    from repro.runtime.supervisor import ServeSupervisor

    cfg = get_arch(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    n = n_requests if n_requests is not None else (12 if smoke else 32)

    plain = ServeEngine(model, params,
                        ServeConfig(max_batch=max_batch, max_seq=max_seq))
    plain_stats = _replay(plain, make_trace(cfg, n, seed=seed))
    ref = {r.rid: list(r.output) for r in plain._done_live}

    with tempfile.TemporaryDirectory() as snapdir:
        def factory(incarnation):
            return ServeEngine(model, params, ServeConfig(
                max_batch=max_batch, max_seq=max_seq,
                snapshot_dir=snapdir, snapshot_every=snapshot_every,
                kill_at_step=kill_at if incarnation == 0 else None))

        sup = ServeSupervisor(factory)
        t0 = time.perf_counter()
        done = sup.run(make_trace(cfg, n, seed=seed))
        chaos_wall = time.perf_counter() - t0
        m = sup.engine.metrics
        got = {r.rid: list(r.output) for r in done}
        chaos_stats = {
            "wall_s": round(chaos_wall, 3),
            "restarts": len(sup.history),
            "resumed": len(sup.history[0].resumed_rids),
            "replayed": len(sup.history[0].replayed_rids),
            "recovered": len(sup.history[0].recovered_rids),
            "snapshots": int(m["snapshots"]),
            "snapshot_ms_mean": round(
                1e3 * m["snapshot_s"] / max(m["snapshots"], 1), 1),
            "restore_ms": round(1e3 * m["restore_s"], 1),
        }

    report = {
        "meta": {**tuning.version_stamp(), "smoke": smoke, "arch": arch,
                 "max_batch": max_batch, "max_seq": max_seq,
                 "n_requests": n, "seed": seed, "kill_at_step": kill_at,
                 "snapshot_every": snapshot_every},
        "undisturbed": plain_stats,
        "chaos": chaos_stats,
        "recovery_overhead": round(
            chaos_stats["wall_s"] / max(plain_stats["wall_s"], 1e-9), 3),
        "bit_identical": got == ref,
    }
    if out_path:
        with open(out_path, "w", encoding="utf-8") as f:
            json.dump(report, f, indent=1, sort_keys=True)
    return report


def _stall_p99_ms(engine) -> float:
    """p99 gap between consecutive decode steps of the last serve()."""
    walls = getattr(engine, "step_walls", [])
    if len(walls) < 2:
        return 0.0
    gaps = sorted(1e3 * (b - a) for a, b in zip(walls, walls[1:]))
    return round(gaps[min(len(gaps) - 1, int(0.99 * len(gaps)))], 2)


def sweep_mesh(smoke: bool = False, out_path: Optional[str] = None,
               arch: str = "glm4-9b", n_requests: Optional[int] = None,
               max_batch: int = 8, max_seq: int = 128, seed: int = 0
               ) -> Dict[str, Any]:
    """Sharded-serving scaling sweep on fake devices (the ``mesh`` suite).

    Replays one mixed-length trace — prompts spanning every shape bucket,
    decode-heavy outputs — through the single-device ``ServeEngine`` and
    through ``MeshServeEngine`` at every available power-of-two shard
    count (8 fake devices in CI:
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``).  Asserts the
    sharded outputs are **bit-identical** to the single-device engine at
    every width, and measures the thing the prefill/decode split is for:
    the p99 *decode stall* (gap between consecutive decode steps — an
    inline prefill of a long prompt shows up as one huge gap) with
    prefill workers on vs off at the widest mesh.  Every engine is warmed
    over all prompt buckets first so the stall distribution reads
    steady-state admission traffic, not compile time.

    Writes ``BENCH_mesh.json``; the CI ``mesh-smoke`` lane gates on the
    committed ``benchmarks/mesh_baseline.json`` floors: ``bit_identical``
    must hold, every width must keep one decode trace, the split run must
    show ``overlap_steps`` (decode steps executed while a prefill was in
    flight — structurally 0 without the split) and the widest mesh must
    keep ``tok_s_frac_of_single`` above the overhead floor.  The stall
    p99s are reported for the record: on a single *physical* CPU core the
    prefill compute steals the core from decode whether it runs inline or
    on a worker, so the stall win needs real parallel hardware — the
    correctness + overlap story does not.
    """
    from repro.runtime.mesh_serve import MeshServeEngine

    cfg = get_arch(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    n = n_requests if n_requests is not None else (24 if smoke else 48)
    # long-prompt-heavy mix: prefill stalls are what the split removes
    mk = lambda s=seed: make_trace(cfg, n, seed=s, rate_hz=200.0,
                                   len_range=(8, 97),
                                   max_new_choices=(8, 16, 24))
    # one warmup request per prompt bucket (16/32/64/128): compiles every
    # prefill trace + decode/insert before anything is measured
    warm = [Request(10_000 + i, np.full(ln, 3, np.int32), max_new_tokens=2)
            for i, ln in enumerate((8, 20, 40, 80))]

    def replay(eng):
        eng.serve([dataclasses.replace(r) for r in warm])
        for key in ("prefill_tokens", "decode_tokens", "decode_steps",
                    "overlap_steps"):
            eng.metrics[key] = 0
        stats = _replay(eng, mk())
        stats["stall_p99_ms"] = _stall_p99_ms(eng)
        stats["overlap_steps"] = int(eng.metrics["overlap_steps"])
        return stats, {r.rid: list(map(int, r.output))
                       for r in eng._done_live}

    single = ServeEngine(model, params,
                         ServeConfig(max_batch=max_batch, max_seq=max_seq))
    single_stats, ref = replay(single)

    devices = jax.devices()
    devcounts = [c for c in (1, 2, 4, 8) if c <= len(devices)]
    widest = devcounts[-1]
    scaling: Dict[str, Any] = {}
    all_identical = True
    split_stats = None
    for c in devcounts:
        eng = MeshServeEngine(model, params, ServeConfig(
            max_batch=max_batch, max_seq=max_seq, num_shards=c,
            prefill_workers=2))
        stats, got = replay(eng)
        stats["bit_identical"] = got == ref
        all_identical = all_identical and stats["bit_identical"]
        stats["decode_traces"] = int(eng.trace_counts["decode"])
        scaling[str(c)] = stats
        if c == widest:
            split_stats = stats

    nosplit = MeshServeEngine(model, params, ServeConfig(
        max_batch=max_batch, max_seq=max_seq, num_shards=widest,
        prefill_workers=0))
    nosplit_stats, got = replay(nosplit)
    all_identical = all_identical and got == ref

    report = {
        "meta": {**tuning.version_stamp(), "smoke": smoke, "arch": arch,
                 "max_batch": max_batch, "max_seq": max_seq,
                 "n_requests": n, "seed": seed,
                 "devices": len(devices), "devcounts": devcounts},
        "single": single_stats,
        "scaling": scaling,
        "nosplit": nosplit_stats,
        "bit_identical": all_identical,
        "stall_p99_ms_split": split_stats["stall_p99_ms"],
        "stall_p99_ms_nosplit": nosplit_stats["stall_p99_ms"],
        # > 1 means prefill workers shrank the worst decode gaps; on a
        # single *physical* core the prefill compute steals the core from
        # decode either way, so this is reported, not CI-gated — the
        # robust split signal is overlap_steps (decode steps taken while
        # a prefill was in flight: structurally 0 without the split)
        "stall_improvement": round(
            nosplit_stats["stall_p99_ms"]
            / max(split_stats["stall_p99_ms"], 1e-9), 3),
        "overlap_steps_split": split_stats["overlap_steps"],
        "overlap_steps_nosplit": nosplit_stats["overlap_steps"],
        # sharding-overhead bound: widest mesh vs single device (fake
        # shards only add partitioning cost on CPU, so a floor on this
        # ratio catches regressions without needing real accelerators)
        "tok_s_frac_of_single": round(
            split_stats["tok_s"] / max(single_stats["tok_s"], 1e-9), 3),
        "decode_traces_max": max(s["decode_traces"]
                                 for s in scaling.values()),
    }
    if out_path:
        with open(out_path, "w", encoding="utf-8") as f:
            json.dump(report, f, indent=1, sort_keys=True)
    return report


def run(csv_rows):
    """`benchmarks.run` suite entry: smoke trace, writes BENCH_serving.json."""
    report = sweep(smoke=True, out_path="BENCH_serving.json")
    for name in ("continuous", "gang"):
        s = report[name]
        us = 1e6 * s["wall_s"] / max(s["delivered_tokens"], 1)
        csv_rows.append((
            f"serve_{name}_{report['meta']['arch']}", us,
            f"tok_s={s['tok_s']};p50_ms={s['latency_p50_ms']};"
            f"p99_ms={s['latency_p99_ms']};dropped={s['dropped']}"))
    csv_rows.append((
        "serve_speedup", 0.0,
        f"continuous_over_gang={report['speedup_tok_s']};"
        f"occupancy={report['continuous'].get('slot_occupancy', 0)}"))


def run_spec(csv_rows):
    """`benchmarks.run` spec suite: smoke trace, writes BENCH_spec.json.

    Runs the full tiered pipeline (draft-model drafter with n-gram
    fallback) so the gated number covers the drafter the flag ships, not
    just the cheapest tier."""
    report = sweep_spec(smoke=True, out_path="BENCH_spec.json",
                        drafter="draft_model")
    for name in ("plain", "spec"):
        s = report[name]
        us = 1e6 * s["wall_s"] / max(s["delivered_tokens"], 1)
        csv_rows.append((
            f"spec_{name}_{report['meta']['arch']}", us,
            f"tok_s={s['tok_s']};steps={s['decode_steps']};"
            f"tokens_per_step={s.get('tokens_per_step', 1)}"))
    csv_rows.append((
        "spec_speedup", 0.0,
        f"spec_over_plain={report['speedup_tok_s']};"
        f"acceptance={report['spec_acceptance']};"
        f"drafter={report['meta']['drafter']};"
        f"greedy_match={report['greedy_match']}"))
    if not report["greedy_match"]:
        raise AssertionError(
            "speculative greedy outputs diverged from plain decode")


def run_paged(csv_rows):
    """`benchmarks.run` paged suite: smoke trace, writes BENCH_paged.json."""
    report = sweep_paged(smoke=True, out_path="BENCH_paged.json")
    for name in ("dense", "paged"):
        s = report[name]
        us = 1e6 * s["wall_s"] / max(s["delivered_tokens"], 1)
        csv_rows.append((
            f"paged_{name}_{report['meta']['arch']}", us,
            f"tok_s={s['tok_s']};dropped={s['dropped']}"))
    csv_rows.append((
        "paged_prefill_amortization", 0.0,
        f"dense_over_paged={report['prefill_amortization']};"
        f"prefix_hits={report['prefix_hit_tokens']};"
        f"peak_blocks={report['peak_blocks']}/"
        f"{report['dense_equiv_blocks']};"
        f"greedy_match={report['greedy_match']}"))
    if not report["greedy_match"]:
        raise AssertionError(
            "paged prefix-cached outputs diverged from dense decode")


def run_chaos(csv_rows):
    """`benchmarks.run` chaos suite: kill/restore recovery smoke, writes
    BENCH_chaos.json; fails if the recovered outputs diverge."""
    report = sweep_chaos(smoke=True, out_path="BENCH_chaos.json")
    for name in ("undisturbed", "chaos"):
        s = report[name]
        us = (1e6 * s["wall_s"]
              / max(report["undisturbed"]["delivered_tokens"], 1))
        csv_rows.append((f"chaos_{name}_{report['meta']['arch']}", us,
                         f"wall_s={s['wall_s']}"))
    c = report["chaos"]
    csv_rows.append((
        "chaos_recovery", 0.0,
        f"overhead={report['recovery_overhead']};"
        f"snapshot_ms={c['snapshot_ms_mean']};"
        f"restore_ms={c['restore_ms']};resumed={c['resumed']};"
        f"replayed={c['replayed']};"
        f"bit_identical={report['bit_identical']}"))
    if not report["bit_identical"]:
        raise AssertionError(
            "chaos-recovered outputs diverged from the undisturbed run")


def run_mesh(csv_rows):
    """`benchmarks.run` mesh suite: sharded-serving scaling smoke, writes
    BENCH_mesh.json; fails if any sharded output diverges."""
    report = sweep_mesh(smoke=True, out_path="BENCH_mesh.json")
    for c, s in report["scaling"].items():
        us = 1e6 * s["wall_s"] / max(s["delivered_tokens"], 1)
        csv_rows.append((
            f"mesh_{c}shard_{report['meta']['arch']}", us,
            f"tok_s={s['tok_s']};stall_p99_ms={s['stall_p99_ms']};"
            f"bit_identical={s['bit_identical']};"
            f"decode_traces={s['decode_traces']}"))
    csv_rows.append((
        "mesh_prefill_split", 0.0,
        f"overlap_steps={report['overlap_steps_split']};"
        f"stall_improvement={report['stall_improvement']};"
        f"split_p99_ms={report['stall_p99_ms_split']};"
        f"nosplit_p99_ms={report['stall_p99_ms_nosplit']};"
        f"tok_s_frac={report['tok_s_frac_of_single']};"
        f"bit_identical={report['bit_identical']}"))
    if not report["bit_identical"]:
        raise AssertionError(
            "sharded-mesh outputs diverged from the single-device engine")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Continuous-batching vs gang-scheduler serving "
                    "benchmark (arrival-trace replay).")
    ap.add_argument("--smoke", action="store_true",
                    help="small trace (CI lane)")
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=64,
                    help="slot cache length (--spec raises this to at "
                         "least 128: its trace carries longer outputs)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--spec", action="store_true",
                    help="speculative-vs-plain comparison on the "
                         "draftable trace (writes BENCH_spec.json)")
    ap.add_argument("--spec-k", type=int, default=5,
                    help="drafted tokens per slot per step (--spec)")
    ap.add_argument("--drafter", choices=("ngram", "draft_model"),
                    default="ngram",
                    help="speculation tier for the spec engine (--spec): "
                         "host-side n-gram lookup or the batched "
                         "draft-model drafter with n-gram fallback")
    ap.add_argument("--paged", action="store_true",
                    help="paged-vs-dense comparison on the shared-prefix "
                         "trace (writes BENCH_paged.json)")
    ap.add_argument("--page-size", type=int, default=8,
                    help="tokens per cache page (--paged)")
    ap.add_argument("--mesh", action="store_true",
                    help="sharded-serving scaling sweep over fake devices "
                         "(set XLA_FLAGS=--xla_force_host_platform_"
                         "device_count=8; writes BENCH_mesh.json)")
    ap.add_argument("--out", default=None,
                    help="report path ('' to skip); defaults to "
                         "BENCH_serving.json / BENCH_spec.json / "
                         "BENCH_paged.json / BENCH_mesh.json")
    args = ap.parse_args(argv)
    if sum((args.spec, args.paged, args.mesh)) > 1:
        ap.error("pick one of --spec / --paged / --mesh")
    out = args.out
    if out is None:
        out = ("BENCH_spec.json" if args.spec
               else "BENCH_paged.json" if args.paged
               else "BENCH_mesh.json" if args.mesh
               else "BENCH_serving.json")

    if args.mesh:
        report = sweep_mesh(smoke=args.smoke, out_path=out or None,
                            arch=args.arch, n_requests=args.requests,
                            max_batch=max(args.max_batch, 8),
                            max_seq=max(args.max_seq, 128),
                            seed=args.seed)
        print("shards,tok_s,stall_p99_ms,bit_identical,dropped")
        for c, s in report["scaling"].items():
            print(f"{c},{s['tok_s']},{s['stall_p99_ms']},"
                  f"{s['bit_identical']},{s['dropped']}")
        print(f"# prefill split at {report['meta']['devcounts'][-1]} "
              f"shards: {report['overlap_steps_split']} overlapped "
              f"decode steps; stall p99 {report['stall_p99_ms_nosplit']}"
              f"ms inline vs {report['stall_p99_ms_split']}ms async "
              f"({report['stall_improvement']}x); bit_identical "
              f"{report['bit_identical']}")
        return 0 if report["bit_identical"] else 1

    if args.paged:
        report = sweep_paged(smoke=args.smoke, out_path=out or None,
                             arch=args.arch, n_requests=args.requests,
                             max_batch=args.max_batch,
                             max_seq=args.max_seq,
                             page_size=args.page_size, seed=args.seed)
        print("engine,tok_s,prefill_tokens,dropped")
        for name in ("dense", "paged"):
            s = report[name]
            print(f"{name},{s['tok_s']},"
                  f"{report[f'{name}_prefill_tokens']},{s['dropped']}")
        print(f"# prefill amortization (dense/paged): "
              f"{report['prefill_amortization']}x; prefix hits "
              f"{report['prefix_hit_tokens']} tok; peak blocks "
              f"{report['peak_blocks']}/{report['dense_equiv_blocks']}; "
              f"greedy_match {report['greedy_match']}")
        ok = (report["greedy_match"] and report["dense"]["dropped"] == 0
              and report["paged"]["dropped"] == 0)
        return 0 if ok else 1

    if args.spec:
        report = sweep_spec(smoke=args.smoke, out_path=out or None,
                            arch=args.arch, spec_k=args.spec_k,
                            n_requests=args.requests,
                            max_batch=args.max_batch,
                            max_seq=max(args.max_seq, 128),
                            seed=args.seed, drafter=args.drafter)
        print("engine,tok_s,steps,tokens_per_step,dropped")
        for name in ("plain", "spec"):
            s = report[name]
            print(f"{name},{s['tok_s']},{s['decode_steps']},"
                  f"{s.get('tokens_per_step', '')},{s['dropped']}")
        print(f"# speedup (spec/plain, {report['meta']['drafter']}): "
              f"{report['speedup_tok_s']}x; "
              f"acceptance {report['spec_acceptance']}; "
              f"k hist {report['spec'].get('spec_k_hist', {})}; "
              f"greedy_match {report['greedy_match']}")
        ok = (report["greedy_match"] and report["plain"]["dropped"] == 0
              and report["spec"]["dropped"] == 0)
        return 0 if ok else 1

    report = sweep(smoke=args.smoke, out_path=out or None,
                   arch=args.arch, n_requests=args.requests,
                   max_batch=args.max_batch, max_seq=args.max_seq,
                   seed=args.seed)
    print("engine,tok_s,p50_ms,p99_ms,occupancy,dropped")
    for name in ("continuous", "gang"):
        s = report[name]
        print(f"{name},{s['tok_s']},{s['latency_p50_ms']},"
              f"{s['latency_p99_ms']},{s.get('slot_occupancy', '')},"
              f"{s['dropped']}")
    print(f"# speedup (continuous/gang): {report['speedup_tok_s']}x; "
          f"prefill traces {report['prefill_traces']}, "
          f"decode traces {report['decode_traces']}")
    return 0 if report["continuous"]["dropped"] == 0 else 1


if __name__ == "__main__":
    import sys
    sys.exit(main())
