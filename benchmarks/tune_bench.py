"""Tuning sweep: measure tile candidates per kernel family, persist winners.

    PYTHONPATH=src python -m benchmarks.tune [--smoke] [--out BENCH_kernels.json]

For every registered kernel family this sweeps the family's own
``KernelSpec.candidates(shape, dtype)`` tile candidates over representative
shapes (derived from the ``repro.configs`` registry; a tiny fixed set with
``--smoke``), using :func:`repro.kernels.common.autotune` for the
per-candidate timing.  Two artifacts come out:

  * the **persistent tuned table** (``REPRO_TUNE_CACHE`` / XDG default, or
    ``--cache``), which any later process — serving included — loads
    through the substrate's three-level block lookup, and
  * ``BENCH_kernels.json``: us_per_call per (family, shape), heuristic vs
    tuned, so the repo has a tracked perf trajectory.

Also registered as the ``tune`` suite of ``benchmarks/run.py`` (smoke
sweep).  On CPU the kernels run in Pallas interpret mode, so absolute
numbers are only comparable within a run; on TPU they are real.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

import repro.kernels as K
from repro.kernels import common, tuning


@dataclasses.dataclass
class Problem:
    """One (family, cache-key, shape) cell of the sweep.

    ``call`` runs the public op with whatever block the substrate cache
    currently serves — forcing a candidate is ``set_block`` + ``call``.
    """
    family: str
    key: str                  # cache-key kernel name (per-AF for act)
    shape: Tuple[int, ...]    # cache-key shape
    dtype: Any
    call: Callable[[], Any]


def _timeit(f: Callable[[], Any], repeats: int) -> float:
    """us per call; one untimed warmup, each timed call blocked on."""
    jax.block_until_ready(f())
    t0 = time.perf_counter()
    for _ in range(repeats):
        jax.block_until_ready(f())
    return (time.perf_counter() - t0) / max(1, repeats) * 1e6


def _shape_sets(smoke: bool) -> Dict[str, List[Tuple[int, ...]]]:
    """Representative cache-key shapes per family.

    Full mode derives them from the reduced architectures in the
    ``repro.configs`` registry (the same shapes the tier-1 models trace);
    smoke mode is one tiny cell per family, sized for CI's CPU interpret
    mode.
    """
    if smoke:
        return {
            "cordic_act": [(32, 64)],
            "cordic_softmax": [(16, 64)],
            "cordic_mac": [(64, 64, 64)],
            "flash_attention": [(32, 32, 2, 1, 8)],   # (sq, sk, hq, hkv, d)
            "wkv": [(32, 2, 8)],                      # (t, h, d)
            "flash_attention.bwd": [(32, 32, 2, 1, 8)],
            "wkv.bwd": [(32, 2, 8)],
            "flash_attention.q8": [(32, 32, 2, 1, 8)],
            "wkv.q8": [(32, 2, 8)],
        }
    from repro.configs import ARCHS
    acts, softs, macs, flashes, wkvs = set(), set(), set(), set(), set()
    for cfg in (a.reduced() for a in ARCHS.values()):
        tokens = 4 * cfg.attn_chunk
        acts.add((tokens, cfg.d_ff))
        softs.add((cfg.n_heads * tokens, tokens))
        macs.add((tokens, cfg.d_ff, cfg.d_model))
        flashes.add((tokens, tokens, cfg.n_heads,
                     max(1, cfg.n_kv_heads), cfg.head_dim_))
        if cfg.ssm_state:
            wkvs.add((tokens, cfg.n_heads, cfg.head_dim_))
    if not wkvs:
        wkvs.add((64, 2, 8))
    return {
        "cordic_act": sorted(acts),
        "cordic_softmax": sorted(softs),
        "cordic_mac": sorted(macs),
        "flash_attention": sorted(flashes),
        "wkv": sorted(wkvs),
        # Backward tiles tune over the same shapes, under their own keys.
        "flash_attention.bwd": sorted(flashes),
        "wkv.bwd": sorted(wkvs),
        # Quantized-cache forwards: same shapes, int8 dtype keys.
        "flash_attention.q8": sorted(flashes),
        "wkv.q8": sorted(wkvs),
    }


def _problems(smoke: bool) -> List[Problem]:
    rng = np.random.default_rng(0)
    shapes = _shape_sets(smoke)
    out: List[Problem] = []

    for r, c in shapes["cordic_act"]:
        x = jnp.array(rng.uniform(-2, 2, (r, c)), jnp.float32)
        out.append(Problem("cordic_act", "cordic_act.tanh", (r, c),
                           jnp.int32,
                           lambda x=x: K.cordic_act(x, "tanh")))

    for r, c in shapes["cordic_softmax"]:
        x = jnp.array(rng.normal(size=(r, c)), jnp.float32)
        out.append(Problem("cordic_softmax", "cordic_softmax", (r, c),
                           jnp.int32, lambda x=x: K.cordic_softmax(x)))

    for m, n, k in shapes["cordic_mac"]:
        x = jnp.array(rng.uniform(-1, 1, (m, k)), jnp.float32)
        w = jnp.array(rng.uniform(-1, 1, (k, n)), jnp.float32)
        out.append(Problem("cordic_mac", "cordic_mac", (m, n, k), jnp.int32,
                           lambda x=x, w=w: K.cordic_matmul(x, w)))

    for sq, sk, hq, hkv, d in shapes["flash_attention"]:
        q = jnp.array(rng.normal(size=(1, sq, hq, d)), jnp.float32)
        kk = jnp.array(rng.normal(size=(1, sk, hkv, d)), jnp.float32)
        v = jnp.array(rng.normal(size=(1, sk, hkv, d)), jnp.float32)
        out.append(Problem("flash_attention", "flash_attention", (sq, sk),
                           jnp.float32,
                           lambda q=q, kk=kk, v=v: K.flash_attention(
                               q, kk, v)))

    for t, h, d in shapes["wkv"]:
        r_ = jnp.array(rng.normal(size=(1, t, h, d)), jnp.float32)
        k_ = jnp.array(rng.normal(size=(1, t, h, d)), jnp.float32)
        v_ = jnp.array(rng.normal(size=(1, t, h, d)), jnp.float32)
        w_ = jnp.array(rng.uniform(0.1, 0.9, (1, t, h, d)), jnp.float32)
        u_ = jnp.array(rng.normal(size=(h, d)), jnp.float32)
        out.append(Problem("wkv", "wkv", (t, d), jnp.float32,
                           lambda r_=r_, k_=k_, v_=v_, w_=w_, u_=u_:
                           K.wkv(r_, k_, v_, w_, u_)))

    # Quantized-cache forwards: int8 inputs built with the serving-cache
    # quantizer, swept under the .q8 keys (int8 dtype).
    from repro.core.quant_cache import quantize_blocked

    for sq, sk, hq, hkv, d in shapes["flash_attention.q8"]:
        q = jnp.array(rng.normal(size=(1, sq, hq, d)), jnp.float32)
        kk, ks = quantize_blocked(
            jnp.array(rng.normal(size=(1, sk, hkv, d)), jnp.float32))
        v, vs = quantize_blocked(
            jnp.array(rng.normal(size=(1, sk, hkv, d)), jnp.float32))
        ks, vs = ks[..., 0], vs[..., 0]
        out.append(Problem(
            "flash_attention.q8", "flash_attention.q8", (sq, sk), jnp.int8,
            lambda q=q, kk=kk, v=v, ks=ks, vs=vs: K.flash_attention_q8(
                q, kk, v, ks, vs)))

    for t, h, d in shapes["wkv.q8"]:
        r_ = jnp.array(rng.normal(size=(1, t, h, d)), jnp.float32)
        k_ = jnp.array(rng.normal(size=(1, t, h, d)), jnp.float32)
        v_ = jnp.array(rng.normal(size=(1, t, h, d)), jnp.float32)
        w_ = jnp.array(rng.uniform(0.1, 0.9, (1, t, h, d)), jnp.float32)
        u_ = jnp.array(rng.normal(size=(h, d)), jnp.float32)
        s_, ss_ = quantize_blocked(
            jnp.array(rng.normal(size=(1, h, d, d)), jnp.float32))
        ss_ = ss_[..., 0]
        out.append(Problem(
            "wkv.q8", "wkv.q8", (t, d), jnp.int8,
            lambda r_=r_, k_=k_, v_=v_, w_=w_, u_=u_, s_=s_, ss_=ss_:
            K.wkv_q8(r_, k_, v_, w_, u_, s_, ss_)))

    # Backward tiles: the call is a full grad step, so the candidate under
    # test (installed by autotune under the .bwd key) is the block the
    # fused backward kernels actually run with.
    for sq, sk, hq, hkv, d in shapes["flash_attention.bwd"]:
        q = jnp.array(rng.normal(size=(1, sq, hq, d)), jnp.float32)
        kk = jnp.array(rng.normal(size=(1, sk, hkv, d)), jnp.float32)
        v = jnp.array(rng.normal(size=(1, sk, hkv, d)), jnp.float32)
        out.append(Problem(
            "flash_attention.bwd", "flash_attention.bwd", (sq, sk),
            jnp.float32,
            lambda q=q, kk=kk, v=v: jax.grad(
                lambda a, b, c: K.flash_attention(a, b, c).sum(),
                argnums=(0, 1, 2))(q, kk, v)))

    for t, h, d in shapes["wkv.bwd"]:
        r_ = jnp.array(rng.normal(size=(1, t, h, d)), jnp.float32)
        k_ = jnp.array(rng.normal(size=(1, t, h, d)), jnp.float32)
        v_ = jnp.array(rng.normal(size=(1, t, h, d)), jnp.float32)
        w_ = jnp.array(rng.uniform(0.1, 0.9, (1, t, h, d)), jnp.float32)
        u_ = jnp.array(rng.normal(size=(h, d)), jnp.float32)
        out.append(Problem(
            "wkv.bwd", "wkv.bwd", (t, d), jnp.float32,
            lambda r_=r_, k_=k_, v_=v_, w_=w_, u_=u_: jax.grad(
                lambda *a: K.wkv(*a).sum(),
                argnums=(0, 1, 2, 3, 4))(r_, k_, v_, w_, u_)))
    return out


def sweep(smoke: bool = False, repeats: int = 3,
          families: Optional[List[str]] = None,
          cache_path: Optional[str] = None,
          out_path: Optional[str] = None) -> Dict[str, Any]:
    """Run the sweep; write the tuned table (+ optionally the report).

    Returns the report dict (``meta`` + ``rows``).
    """
    # Empty the disk layer so the heuristic baseline really is the
    # heuristic, not a previously persisted winner.
    common.load_tuned_table(os.devnull)
    problems = _problems(smoke)
    if families:
        problems = [p for p in problems if p.family in families]

    table: tuning.Table = {}
    rows: List[Dict[str, Any]] = []
    for p in problems:
        spec = common.get_kernel(p.family)
        if spec.candidates is None:
            continue
        cands = tuple(tuple(int(b) for b in c)
                      for c in spec.candidates(p.shape, p.dtype))
        if not cands:
            continue

        common.clear_block_cache()
        us_heur = _timeit(p.call, repeats)     # warmup installs heuristic
        heur = common.cached_block(p.key, p.shape, p.dtype)

        def run(blk, p=p):
            common.set_block(p.key, p.shape, p.dtype, blk)
            return p.call()

        best = common.autotune(p.key, p.shape, p.dtype, cands, run,
                               repeats=repeats)
        us_tuned = _timeit(p.call, repeats)    # cache now serves the winner
        key = (p.key, tuple(p.shape), jnp.dtype(p.dtype).name)
        table[key] = best
        rows.append({
            "family": p.family, "kernel": p.key, "shape": list(p.shape),
            "dtype": jnp.dtype(p.dtype).name,
            "heuristic_block": list(heur) if heur else None,
            "tuned_block": list(best), "n_candidates": len(cands),
            "us_heuristic": round(us_heur, 1), "us_tuned": round(us_tuned, 1),
        })

    written = tuning.save(table, path=cache_path)
    report = {
        "meta": {**tuning.version_stamp(), "smoke": smoke,
                 "repeats": repeats, "tuned_table": written},
        "rows": rows,
    }
    if out_path:
        with open(out_path, "w", encoding="utf-8") as f:
            json.dump(report, f, indent=1, sort_keys=True)
    return report


def run(csv_rows):
    """`benchmarks.run` suite entry: smoke sweep, CSV rows per cell."""
    report = sweep(smoke=True, repeats=1)
    for r in report["rows"]:
        shape = "x".join(str(s) for s in r["shape"])
        csv_rows.append((
            f"tune_{r['kernel']}_{shape}", r["us_tuned"],
            f"heuristic_us={r['us_heuristic']};"
            f"block={'x'.join(str(b) for b in r['tuned_block'])}"))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Sweep kernel tile candidates; persist the tuned table.")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes, repeats=1 (CI lane)")
    ap.add_argument("--repeats", type=int, default=None,
                    help="timed calls per candidate (default 3; 1 in smoke)")
    ap.add_argument("--families", default=None,
                    help="comma-separated subset, e.g. cordic_mac,wkv")
    ap.add_argument("--cache", default=None,
                    help="tuned-table path (default REPRO_TUNE_CACHE / XDG)")
    ap.add_argument("--out", default="BENCH_kernels.json",
                    help="perf report path ('' to skip)")
    args = ap.parse_args(argv)

    repeats = args.repeats if args.repeats is not None else (
        1 if args.smoke else 3)
    fams = args.families.split(",") if args.families else None
    report = sweep(smoke=args.smoke, repeats=repeats, families=fams,
                   cache_path=args.cache, out_path=args.out or None)
    print(f"# tuned table -> {report['meta']['tuned_table']}")
    print("kernel,shape,us_heuristic,us_tuned,heuristic_block,tuned_block")
    for r in report["rows"]:
        print(f"{r['kernel']},{'x'.join(str(s) for s in r['shape'])},"
              f"{r['us_heuristic']},{r['us_tuned']},"
              f"{'x'.join(str(b) for b in (r['heuristic_block'] or []))},"
              f"{'x'.join(str(b) for b in r['tuned_block'])}")
    return 0 if report["rows"] else 1


if __name__ == "__main__":
    import sys
    sys.exit(main())
