"""Roofline summary rows from the dry-run records (EXPERIMENTS.md source).

Re-derives the three roofline terms with the current analytic model for a
representative subset (fast, no compilation), and reads the stored 80-cell
sweep (dryrun_baseline.jsonl) when present for the compiled-artifact
figures.
"""
from __future__ import annotations

import json
import os
import time

from repro.analysis.costmodel import MeshSpec, step_costs
from repro.analysis.roofline import analyze
from repro.configs import LM_SHAPES, get_arch

REPRESENTATIVE = [
    ("glm4-9b", "train_4k"), ("glm4-9b", "decode_32k"),
    ("arctic-480b", "train_4k"), ("granite-moe-3b-a800m", "train_4k"),
    ("qwen2.5-14b", "prefill_32k"), ("rwkv6-3b", "long_500k"),
]


def run(csv_rows):
    mesh = MeshSpec(data=16, model=16)
    for arch, shape in REPRESENTATIVE:
        t0 = time.time()
        cfg = get_arch(arch)
        row = analyze(cfg, LM_SHAPES[shape], mesh)
        dt_us = (time.time() - t0) * 1e6
        csv_rows.append((
            f"roofline_{arch}_{shape}", dt_us,
            f"bottleneck={row.bottleneck};frac={row.roofline_fraction:.3f};"
            f"step_s={row.step_time_s:.3e}"))
    path = "dryrun_baseline.jsonl"
    if os.path.exists(path):
        rows = [json.loads(l) for l in open(path)]
        ok = sum(r["status"] == "ok" for r in rows)
        skip = sum(r["status"] == "skipped" for r in rows)
        err = sum(r["status"] == "error" for r in rows)
        csv_rows.append(("dryrun_sweep", 0.0,
                         f"cells={len(rows)};ok={ok};skipped={skip};"
                         f"errors={err}"))
