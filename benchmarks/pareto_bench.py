"""Paper Figs 4-6: CORDIC error Pareto sweeps (bits x iterations) for
sigmoid / tanh / SoftMax (+ the MAC, §4.3)."""
from __future__ import annotations

import time

from repro.core import pareto


def run(csv_rows):
    t0 = time.time()
    report = pareto.full_report(iterations=(2, 3, 4, 5, 6, 8, 10, 12),
                                n_samples=512)
    dt_us = (time.time() - t0) * 1e6
    knees = {}
    for fn, pts in report.items():
        knees[fn] = pareto.knee(pts, "mae")
        for p in pts:
            if p.bits == 8 and p.iterations in (2, 5, 8):
                csv_rows.append(
                    (f"pareto_{fn}_8b_{p.iterations}it", dt_us / len(pts),
                     f"mae={p.mae:.2e}"))
    # headline: the paper's 5+2 conclusion — knee at or below 5 for 8-bit
    for fn in ("sigmoid", "tanh", "softmax", "mac"):
        csv_rows.append((f"pareto_knee_{fn}_8bit", dt_us / 4,
                         f"knee_iterations={knees[fn].get(8, '-')}"))
    return report
