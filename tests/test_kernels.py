"""Per-kernel validation: shape/dtype/format sweeps, bit-exactness vs the
pure-jnp oracles, and allclose vs float references (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests; see requirements-dev.txt
from hypothesis import given, settings, strategies as st

from repro.core import fixed_point as fxp
from repro.kernels.cordic_mac.kernel import cordic_matmul_raw
from repro.kernels.cordic_mac.ops import cordic_matmul
from repro.kernels.cordic_mac.ref import (cordic_matmul_raw_ref,
                                          effective_weight)
from repro.kernels.cordic_act.kernel import cordic_act_raw
from repro.kernels.cordic_act.ops import cordic_act
from repro.kernels.cordic_act.ref import cordic_act_raw_ref
from repro.kernels.cordic_softmax.kernel import cordic_softmax_raw
from repro.kernels.cordic_softmax.ops import cordic_softmax
from repro.kernels.cordic_softmax.ref import cordic_softmax_raw_ref


class TestCordicMacKernel:
    @pytest.mark.parametrize("shape", [(16, 16, 16), (32, 48, 16),
                                       (64, 64, 128), (8, 256, 24)])
    @pytest.mark.parametrize("fmt", [fxp.FXP8, fxp.FXP16])
    def test_bit_exact_vs_ref(self, shape, fmt, rng):
        m, k, n = shape
        x = fxp.quantize(jnp.array(rng.uniform(-2, 2, (m, k)), jnp.float32), fmt)
        w = fxp.quantize(jnp.array(rng.uniform(-1.9, 1.9, (k, n)), jnp.float32), fmt)
        import math
        bm = math.gcd(m, 16); bn = math.gcd(n, 16); bk = math.gcd(k, 16)
        got = cordic_matmul_raw(x, w, fmt=fmt, n_stages=5,
                                block=(bm, bn, bk), interpret=True)
        want = cordic_matmul_raw_ref(x, w, fmt=fmt, n_stages=5)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.parametrize("n_stages", [5, 8, 12])
    def test_allclose_vs_float(self, n_stages, rng):
        fmt = fxp.FXP16
        x = jnp.array(rng.uniform(-2, 2, (32, 64)), jnp.float32)
        w = jnp.array(rng.uniform(-1.9, 1.9, (64, 16)), jnp.float32)
        got = cordic_matmul(x, w, fmt=fmt, n_stages=n_stages, block=(16, 16, 16))
        want = x @ w
        # per-element error ~ K * (|x| 2^-n + trunc); relative band:
        tol = 64 * (2.0 * 2.0 ** (-n_stages) + (n_stages + 2) * fmt.resolution)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=tol)

    def test_uneven_shapes_padded(self, rng):
        fmt = fxp.FXP16
        x = jnp.array(rng.uniform(-1, 1, (13, 70)), jnp.float32)
        w = jnp.array(rng.uniform(-1, 1, (70, 9)), jnp.float32)
        got = cordic_matmul(x, w, fmt=fmt, n_stages=10, block=(16, 16, 16))
        assert got.shape == (13, 9)
        np.testing.assert_allclose(np.asarray(got), np.asarray(x @ w), atol=0.5)

    def test_effective_weight_is_signed_digit_value(self, rng):
        fmt = fxp.FXP16
        w = jnp.array(rng.uniform(-1.9, 1.9, (32, 8)), jnp.float32)
        w_eff = effective_weight(w, fmt, n_stages=10)
        assert float(jnp.abs(w_eff - w).max()) < 2.0 ** (-9) + 2 * fmt.resolution

    def test_grad_is_exact_matmul_vjp(self, rng):
        x = jnp.array(rng.uniform(-1, 1, (16, 32)), jnp.float32)
        w = jnp.array(rng.uniform(-1, 1, (32, 16)), jnp.float32)
        gx, gw = jax.grad(
            lambda a, b: cordic_matmul(a, b, block=(16, 16, 16)).sum(),
            argnums=(0, 1))(x, w)
        np.testing.assert_allclose(np.asarray(gx),
                                   np.asarray(jnp.ones((16, 16)) @ w.T),
                                   rtol=1e-5)
        np.testing.assert_allclose(np.asarray(gw),
                                   np.asarray(x.T @ jnp.ones((16, 16))),
                                   rtol=1e-5)

    @given(st.integers(1, 4), st.integers(1, 4), st.integers(1, 4),
           st.integers(0, 2 ** 31 - 1))
    @settings(max_examples=10, deadline=None)
    def test_property_random_tiles_bit_exact(self, gm, gn, gk, seed):
        fmt = fxp.FXP8
        r = np.random.default_rng(seed)
        m, n, k = 8 * gm, 8 * gn, 8 * gk
        x = fxp.quantize(jnp.array(r.uniform(-2, 2, (m, k)), jnp.float32), fmt)
        w = fxp.quantize(jnp.array(r.uniform(-1.9, 1.9, (k, n)), jnp.float32), fmt)
        got = cordic_matmul_raw(x, w, fmt=fmt, n_stages=5, block=(8, 8, 8),
                                interpret=True)
        want = cordic_matmul_raw_ref(x, w, fmt=fmt, n_stages=5)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


class TestCordicActKernel:
    @pytest.mark.parametrize("af", ["tanh", "sigmoid", "exp"])
    @pytest.mark.parametrize("fmt", [fxp.FXP8, fxp.FXP16])
    @pytest.mark.parametrize("shape", [(8, 128), (64, 64), (32, 96)])
    def test_bit_exact_vs_ref(self, af, fmt, shape, rng):
        x = fxp.quantize(jnp.array(rng.uniform(-6, 6, shape), jnp.float32), fmt)
        got = cordic_act_raw(x, af=af, fmt=fmt, block=(8, 32))
        want = cordic_act_raw_ref(x, af=af, fmt=fmt)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.parametrize("af,exact", [
        ("tanh", np.tanh),
        ("sigmoid", lambda v: 1 / (1 + np.exp(-v))),
        ("exp", lambda v: np.exp(np.minimum(v, 0)))])
    def test_allclose_vs_float(self, af, exact, rng):
        x = rng.uniform(-6, 6, (32, 64)).astype(np.float32)
        got = cordic_act(jnp.array(x), af, fmt=fxp.FXP16, n_hyp=12)
        np.testing.assert_allclose(np.asarray(got), exact(x), atol=0.02)

    def test_monotonicity_preserved(self, rng):
        """sigmoid/tanh outputs must be monotone in the input — the property
        QAT training relies on."""
        x = jnp.linspace(-5, 5, 257)[None, :]
        for af in ("tanh", "sigmoid"):
            y = np.asarray(cordic_act(x, af, fmt=fxp.FXP16, n_hyp=12))[0]
            assert np.all(np.diff(y) >= -1e-6), af

    def test_grad_shapes(self, rng):
        x = jnp.array(rng.normal(size=(8, 16)), jnp.float32)
        g = jax.grad(lambda v: cordic_act(v, "sigmoid").sum())(x)
        assert g.shape == x.shape


class TestCordicSoftmaxKernel:
    @pytest.mark.parametrize("fmt", [fxp.FXP8, fxp.FXP16])
    @pytest.mark.parametrize("shape", [(8, 32), (64, 256), (16, 1000)])
    def test_bit_exact_vs_ref(self, fmt, shape, rng):
        x = fxp.quantize(
            jnp.array(rng.normal(size=shape) * 2 - 3, jnp.float32), fmt)
        got = cordic_softmax_raw(x, fmt=fmt, block_rows=8)
        want = cordic_softmax_raw_ref(x, fmt=fmt)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_rows_sum_near_one(self, rng):
        x = jnp.array(rng.normal(size=(32, 128)) * 4, jnp.float32)
        sm = cordic_softmax(x, fmt=fxp.FXP16, n_hyp=10)
        sums = np.asarray(sm.sum(-1))
        assert np.all(np.abs(sums - 1.0) < 0.1)

    def test_argmax_preserved(self, rng):
        # fixed-point ties can legitimately flip argmax between near-equal
        # logits; require the true argmax to be within 1 ulp of the top.
        x = jnp.array(rng.normal(size=(64, 32)) * 3, jnp.float32)
        got = np.asarray(cordic_softmax(x, fmt=fxp.FXP16, n_hyp=10))
        want = np.asarray(jax.nn.softmax(x, -1))
        top = got.max(-1)
        at_true = got[np.arange(64), want.argmax(-1)]
        assert np.all(top - at_true <= fxp.FXP16.resolution + 1e-7)

    def test_allclose_vs_float(self, rng):
        x = jnp.array(rng.normal(size=(16, 64)) * 2, jnp.float32)
        got = cordic_softmax(x, fmt=fxp.FXP16, n_hyp=12)
        want = jax.nn.softmax(x, -1)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=0.02)

    def test_translation_invariance(self, rng):
        """softmax(x) == softmax(x + c) — survives the integer pipeline."""
        x = jnp.array(rng.normal(size=(4, 32)), jnp.float32)
        a = cordic_softmax(x, fmt=fxp.FXP16)
        b = cordic_softmax(x + 7.25, fmt=fxp.FXP16)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-2)


class TestFlashAttentionKernel:
    @pytest.mark.parametrize("shape", [
        # (hq, hkv, sq, sk, d, bq, bk, causal)
        (4, 4, 64, 64, 16, 16, 16, True),
        (8, 2, 128, 128, 32, 32, 64, True),
        (4, 1, 64, 64, 16, 64, 16, True),
        (4, 4, 64, 64, 16, 64, 64, False),
    ])
    def test_matches_ref(self, shape, rng):
        from repro.kernels.flash_attention.kernel import flash_attention_nhd
        from repro.kernels.flash_attention.ref import attention_nhd_ref
        hq, hkv, sq, sk, d, bq, bk, causal = shape
        g = hq // hkv
        q = jnp.array(rng.normal(size=(hq, sq, d)), jnp.float32)
        k = jnp.array(rng.normal(size=(hkv, sk, d)), jnp.float32)
        v = jnp.array(rng.normal(size=(hkv, sk, d)), jnp.float32)
        got = flash_attention_nhd(q, k, v, causal=causal, block_q=bq,
                                  block_k=bk, group=g)
        want = attention_nhd_ref(q, k, v, causal=causal, group=g)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)

    def test_gqa_frontend(self, rng):
        from repro.kernels.flash_attention.ops import flash_attention
        from repro.kernels.flash_attention.ref import attention_nhd_ref
        q = jnp.array(rng.normal(size=(2, 64, 8, 16)), jnp.float32)
        k = jnp.array(rng.normal(size=(2, 64, 2, 16)), jnp.float32)
        v = jnp.array(rng.normal(size=(2, 64, 2, 16)), jnp.float32)
        got = flash_attention(q, k, v, block_q=32, block_k=32)
        for b in range(2):
            want = attention_nhd_ref(
                q[b].transpose(1, 0, 2), k[b].transpose(1, 0, 2),
                v[b].transpose(1, 0, 2), causal=True, group=4)
            np.testing.assert_allclose(
                np.asarray(got[b].transpose(1, 0, 2)), np.asarray(want),
                atol=2e-5, rtol=2e-5)

    def test_bf16_inputs(self, rng):
        from repro.kernels.flash_attention.kernel import flash_attention_nhd
        from repro.kernels.flash_attention.ref import attention_nhd_ref
        q = jnp.array(rng.normal(size=(2, 64, 32)), jnp.bfloat16)
        k = jnp.array(rng.normal(size=(2, 64, 32)), jnp.bfloat16)
        v = jnp.array(rng.normal(size=(2, 64, 32)), jnp.bfloat16)
        got = flash_attention_nhd(q, k, v, block_q=32, block_k=32)
        want = attention_nhd_ref(q, k, v)
        assert got.dtype == jnp.bfloat16
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            atol=2e-2, rtol=2e-2)


class TestWkvKernel:
    @pytest.mark.parametrize("shape", [(4, 64, 16, 16, 16),
                                       (2, 128, 32, 32, 64),
                                       (8, 32, 8, 8, 32)])
    def test_matches_ref(self, shape, rng):
        from repro.kernels.wkv.kernel import wkv_recurrence
        from repro.kernels.wkv.ref import wkv_recurrence_ref
        bh, t, dk, dv, bt = shape
        r = jnp.array(rng.normal(size=(bh, t, dk)), jnp.float32)
        k = jnp.array(rng.normal(size=(bh, t, dk)), jnp.float32)
        v = jnp.array(rng.normal(size=(bh, t, dv)), jnp.float32)
        w = jnp.array(rng.uniform(0.3, 1.0, size=(bh, t, dk)), jnp.float32)
        u = jnp.array(rng.normal(size=(bh, dk)), jnp.float32)
        got = wkv_recurrence(r, k, v, w, u, block_t=bt)
        want = wkv_recurrence_ref(r, k, v, w, u)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=5e-5, rtol=5e-5)

    def test_matches_model_timemix_core(self, rng):
        """The kernel computes the same recurrence as models/ssm.py's
        chunked scan (state zero, identical inputs)."""
        from repro.kernels.wkv.ops import wkv
        from repro.kernels.wkv.ref import wkv_recurrence_ref
        b, t, h, d = 2, 32, 4, 8
        r = jnp.array(rng.normal(size=(b, t, h, d)), jnp.float32)
        k = jnp.array(rng.normal(size=(b, t, h, d)), jnp.float32)
        v = jnp.array(rng.normal(size=(b, t, h, d)), jnp.float32)
        w = jnp.array(rng.uniform(0.5, 1.0, size=(b, t, h, d)), jnp.float32)
        u = jnp.array(rng.normal(size=(h, d)), jnp.float32)
        got = wkv(r, k, v, w, u, block_t=16)

        # reference via the BH-flat oracle
        def flat(x):
            return x.transpose(0, 2, 1, 3).reshape(b * h, t, d)
        want = wkv_recurrence_ref(flat(r), flat(k), flat(v), flat(w),
                                  jnp.tile(u[None], (b, 1, 1)).reshape(-1, d))
        want = want.reshape(b, h, t, d).transpose(0, 2, 1, 3)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=5e-5, rtol=5e-5)
