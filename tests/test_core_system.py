"""Tests for pruning, quantization, Pareto, CAESAR and SYCore models."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests; see requirements-dev.txt
from hypothesis import given, settings, strategies as st

from repro.core import caesar, pareto, pruning, sycore
from repro.core.quantization import QuantPolicy, quantize_weight, quantized_dense
from repro.core.rpe import RPE, throughput_gops
from repro.core.activations import CordicPolicy


class TestPruning:
    def test_magnitude_rate(self, rng):
        w = jnp.array(rng.normal(size=(64, 64)), jnp.float32)
        _, mask = pruning.apply_policy(w, pruning.PruningPolicy(rate=0.40))
        got = 1.0 - float(mask.mean())
        assert abs(got - 0.40) < 0.01

    def test_magnitude_keeps_largest(self, rng):
        w = jnp.array(rng.normal(size=(32, 32)), jnp.float32)
        pw, mask = pruning.apply_policy(w, pruning.PruningPolicy(rate=0.5))
        kept_min = float(jnp.abs(w[mask]).min())
        dropped_max = float(jnp.abs(w[~mask]).max()) if bool(jnp.any(~mask)) else 0.0
        assert kept_min >= dropped_max

    @given(st.integers(1, 8), st.integers(2, 16))
    @settings(max_examples=30, deadline=None)
    def test_nm_mask_invariant(self, n, m):
        """Every complete group of m has exactly n survivors."""
        if n >= m:
            return
        r = np.random.default_rng(n * 100 + m)
        w = jnp.array(r.normal(size=(8, m * 6)), jnp.float32)
        mask = pruning.nm_mask(w, n, m, axis=-1)
        groups = np.asarray(mask).reshape(8, 6, m)
        assert np.all(groups.sum(-1) == n)

    def test_mask_grads_freezes_pruned(self, rng):
        w = jnp.array(rng.normal(size=(16, 16)), jnp.float32)
        params = {"w": w, "bias": jnp.zeros((16,))}
        pruned, masks = pruning.prune_tree(params, pruning.PruningPolicy(0.4),
                                           min_size=4)
        grads = jax.tree_util.tree_map(jnp.ones_like, params)
        mg = pruning.mask_grads(grads, masks)
        assert float(jnp.abs(mg["w"][~masks["w"]]).max()) == 0.0
        np.testing.assert_array_equal(np.asarray(mg["bias"]), np.ones(16))

    def test_stats(self, rng):
        w = {"w": jnp.array(rng.normal(size=(64, 64)), jnp.float32)}
        pruned, masks = pruning.prune_tree(w, pruning.PruningPolicy(0.4),
                                           min_size=4)
        s = pruning.sparsity_stats(pruned, masks)
        assert abs(s["sparsity"] - 0.4) < 0.02


class TestQuantization:
    def test_weight_roundtrip_error(self, rng):
        w = jnp.array(rng.normal(size=(128, 64)), jnp.float32)
        q, s = quantize_weight(w, QuantPolicy())
        back = q.astype(jnp.float32) * s
        # pow2 per-channel scale: error <= scale/2 <= amax/127
        amax = float(jnp.abs(w).max())
        assert float(jnp.abs(back - w).max()) <= amax / 127 * 2

    def test_quantized_dense_close(self, rng):
        x = jnp.array(rng.normal(size=(32, 128)), jnp.float32)
        w = jnp.array(rng.normal(size=(128, 64)) * 0.05, jnp.float32)
        got = quantized_dense(x, w, QuantPolicy())
        want = x @ w
        rel = float(jnp.abs(got - want).max() / jnp.abs(want).max())
        assert rel < 0.05

    def test_quantized_dense_grads_flow(self, rng):
        x = jnp.array(rng.normal(size=(8, 16)), jnp.float32)
        w = jnp.array(rng.normal(size=(16, 4)) * 0.1, jnp.float32)
        gx, gw = jax.grad(lambda a, b: quantized_dense(a, b, QuantPolicy()).sum(),
                          argnums=(0, 1))(x, w)
        assert np.isfinite(np.asarray(gx)).all()
        assert np.isfinite(np.asarray(gw)).all()

    def test_weight_only_mode(self, rng):
        x = jnp.array(rng.normal(size=(8, 16)), jnp.float32)
        w = jnp.array(rng.normal(size=(16, 4)) * 0.1, jnp.float32)
        got = quantized_dense(x, w, QuantPolicy(act_bits=None))
        assert got.shape == (8, 4)


class TestPareto:
    def test_mac_error_monotone_in_iterations(self):
        pts = pareto.sweep_mac(bits_list=(32,), iterations=(2, 4, 8, 12),
                               n_samples=512)
        errs = [p.mae for p in sorted(pts, key=lambda p: p.iterations)]
        assert errs[0] > errs[-1]

    def test_knee_detects_saturation(self):
        pts = pareto.sweep_activation("sigmoid", bits_list=(8,),
                                      iterations=tuple(range(2, 12)),
                                      n_samples=256)
        k = pareto.knee(pts, "mae")
        # paper's conclusion: ~5 stages suffice at 8-bit
        # 8-bit saturates at the resolution floor within a few stages
        assert 2 <= k[8] <= 8

    def test_more_bits_less_error(self):
        pts = pareto.sweep_activation("tanh", bits_list=(4, 16),
                                      iterations=(8,), n_samples=256)
        by_bits = {p.bits: p.mae for p in pts}
        assert by_bits[16] < by_bits[4]


class TestSYCoreCaesar:
    def test_vgg16_schedule_structure(self):
        sched = caesar.Caesar(pruning=None).schedule(caesar.vgg16_cifar100())
        assert len(sched.layers) == 16  # 13 conv + 3 fc (pool on host)
        c11 = sched.layers[0]
        # paper Table 3: C1_1 = 1728 op cycles at 32x32 dense
        assert c11.op_cycles == 1728
        assert c11.utilization == 1.0

    def test_pruning_reduces_cycles(self):
        dense = caesar.Caesar(pruning=None).schedule(caesar.vgg16_cifar100())
        sparse = caesar.Caesar(
            pruning=pruning.PruningPolicy(rate=0.40)).schedule(
                caesar.vgg16_cifar100())
        assert sparse.total_time_us < dense.total_time_us * 0.75

    def test_transformer_specs(self):
        specs = caesar.transformer_block_specs("b0", 128, 256, 8, 1024)
        sched = caesar.Caesar().schedule(specs)
        assert sched.total_time_us > 0
        assert len(sched.layers) == 7

    def test_pick_block_shape_fits_vmem(self):
        for dims in [(4096, 13696, 4096), (256, 256, 256), (7, 5, 3),
                     (32768, 128, 128)]:
            bm, bn, bk = caesar.pick_block_shape(*dims)
            fp = (bm * bk + bk * bn) * 2 + bm * bn * 4
            assert fp <= caesar.VMEM_BYTES * 0.60 + 1
            if min(dims) >= 128:
                assert bm % 128 == 0 and bn % 128 == 0 and bk % 128 == 0

    def test_output_stationary_matches_dot(self, rng):
        x = jnp.array(rng.normal(size=(50, 70)), jnp.float32)
        w = jnp.array(rng.normal(size=(70, 30)), jnp.float32)
        got = sycore.output_stationary_matmul(x, w, (32, 32, 32))
        np.testing.assert_allclose(np.asarray(got), np.asarray(x @ w),
                                   rtol=1e-4, atol=1e-4)

    def test_rpe_cycle_model(self):
        rpe = RPE()
        assert rpe.mac_cycles(1) == 5          # pipeline fill
        assert rpe.mac_cycles(100) == 104      # II=1 after fill
        assert rpe.af_cycles("tanh") == 9      # 5 hyperbolic + 4 division
        assert rpe.af_cycles("relu") == 1
        assert rpe.mac_cycles(10, pipelined=False) == 50  # iterative variant

    def test_rpe_neuron(self, rng):
        rpe = RPE(CordicPolicy(bits=16, n_linear=10))
        x = jnp.array(rng.uniform(-1, 1, (4, 8)), jnp.float32)
        w = jnp.array(rng.uniform(-1, 1, (8,)), jnp.float32)
        got = rpe.neuron(x, w, 0.1, af="sigmoid")
        want = jax.nn.sigmoid(x @ w + 0.1)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=0.05)

    def test_throughput_model_3ghz(self):
        # paper: 1024 RPEs at 3 GHz, 1 MAC/cycle => ~6.1 TOPS > 4.57 quoted
        tops = throughput_gops(3000, 1024) / 1000
        assert 4.0 < tops < 7.0


class TestShardingRuleProperties:
    """Property tests: the rule engine must always produce a valid spec."""

    @given(st.lists(st.sampled_from(
        ["batch", "seq", "embed", "vocab", "heads", "kv_heads", "mlp",
         "experts", "expert_mlp", "layers", None]), min_size=1, max_size=4),
        st.integers(0, 2 ** 31 - 1))
    @settings(max_examples=60, deadline=None)
    def test_spec_always_valid(self, axes, seed):
        import jax
        from repro.parallel.sharding import spec_for
        r = np.random.default_rng(seed)
        shape = tuple(int(r.choice([1, 3, 8, 16, 40, 128, 256]))
                      for _ in axes)
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        # single-device mesh: everything must replicate (sizes are 1)
        ps = spec_for(shape, tuple(axes), mesh)
        flat = []
        for e in ps:
            if e is None:
                continue
            flat += list(e) if isinstance(e, tuple) else [e]
        # no axis reused; every named axis exists in the mesh
        assert len(flat) == len(set(flat))
        assert all(a in mesh.shape for a in flat)

    @given(st.integers(1, 512), st.integers(0, 2 ** 31 - 1))
    @settings(max_examples=40, deadline=None)
    def test_sharded_dims_always_divide(self, dim, seed):
        import jax
        from repro.parallel.sharding import spec_for
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        ps = spec_for((dim, dim), ("vocab", "mlp"), mesh)
        for entry, d in zip(tuple(ps) + (None,) * 2, (dim, dim)):
            if entry is None:
                continue
            axes_ = entry if isinstance(entry, tuple) else (entry,)
            n = 1
            for a in axes_:
                n *= mesh.shape[a]
            assert d % n == 0
