"""Deterministic invariants of the int8 per-block quantized slot cache.

Hypothesis-free counterpart of ``tests/test_quant_numerics.py`` (the
property-based layer): this module must run even in minimal
environments, so the quantized-serving contract keeps coverage when
hypothesis is absent.

The contract under test (see ``core/quant_cache.py`` and the serving
plumbing in ``models/transformer.py`` / ``runtime/serve_loop.py``):

  * round-trip |x - dq(q(x))| <= scale/2 per trailing-axis block, and
    all-zero blocks come back exactly zero (scale stored as 0)
  * quantization is per-vector deterministic, so quantize-then-scatter
    equals scatter-then-quantize and any slot permutation commutes
  * ``cache_quant="int8"`` and the legacy fixed-scale ``kv_cache_bits=8``
    KV format are mutually exclusive (ValueError, not silent precedence)
  * ``ServeEngine(cache_dtype="int8")`` serves all three families within
    the committed logit-error ceiling, one decode trace per bucket
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core.quant_cache import dequantize_blocked, quantize_blocked
from repro.models.model_zoo import build_model
from repro.runtime.serve_loop import Request, ServeEngine

_BASELINE = os.path.join(os.path.dirname(__file__), os.pardir,
                         "benchmarks", "quant_baseline.json")
ARCHS = ("glm4-9b", "rwkv6-3b", "hymba-1.5b")


# ---------------------------------------------------------------- numerics

def test_roundtrip_error_bound():
    rng = np.random.default_rng(0)
    for shape in [(16,), (3, 5, 32), (2, 4, 8, 16)]:
        x = jnp.asarray(rng.normal(0, 3.0, shape).astype(np.float32))
        q, s = quantize_blocked(x)
        assert q.dtype == jnp.int8 and s.dtype == jnp.float32
        assert s.shape == x.shape[:-1] + (1,)
        dq = dequantize_blocked(q, s)
        # per-block bound: half a quantization step
        bound = np.broadcast_to(np.asarray(s) / 2.0 + 1e-12, x.shape)
        assert np.all(np.abs(np.asarray(x - dq)) <= bound)


def test_blocked_scales():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(0, 1.0, (4, 32)).astype(np.float32))
    q, s = quantize_blocked(x, block=8)
    assert s.shape == (4, 4)
    dq = dequantize_blocked(q, s)
    step = np.repeat(np.asarray(s), 8, axis=-1)
    assert np.all(np.abs(np.asarray(x - dq)) <= step / 2.0 + 1e-12)


def test_zero_block_exact():
    x = jnp.zeros((5, 16), jnp.float32)
    q, s = quantize_blocked(x)
    assert np.all(np.asarray(q) == 0)
    assert np.all(np.asarray(s) == 0.0)       # not a tiny epsilon scale
    assert np.all(np.asarray(dequantize_blocked(q, s)) == 0.0)
    # mixed: a zero row next to a live row stays exactly zero
    y = x.at[2].set(1.5)
    qy, sy = quantize_blocked(y)
    assert np.all(np.asarray(dequantize_blocked(qy, sy))[0] == 0.0)


def test_scatter_then_read_equals_read_then_scatter():
    """Per-vector scales make quantization commute with slot scatter:
    quantizing rows then scattering them into the int8 cache yields the
    same cache as quantizing the scattered fp cache (what slot_update
    relies on to touch only the updated slot)."""
    rng = np.random.default_rng(2)
    cache = jnp.asarray(rng.normal(0, 1.0, (4, 6, 16)).astype(np.float32))
    rows = jnp.asarray(rng.normal(0, 2.0, (2, 6, 16)).astype(np.float32))
    idx = jnp.asarray([3, 1])

    qc, sc = quantize_blocked(cache)
    qr, sr = quantize_blocked(rows)
    scat_q = qc.at[idx].set(qr)
    scat_s = sc.at[idx].set(sr)

    q2, s2 = quantize_blocked(cache.at[idx].set(rows))
    assert np.array_equal(np.asarray(scat_q), np.asarray(q2))
    assert np.array_equal(np.asarray(scat_s), np.asarray(s2))


def test_permutation_invariance():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(0, 1.0, (8, 3, 16)).astype(np.float32))
    perm = jnp.asarray(rng.permutation(8))
    q, s = quantize_blocked(x)
    qp, sp = quantize_blocked(x[perm])
    assert np.array_equal(np.asarray(q[perm]), np.asarray(qp))
    assert np.array_equal(np.asarray(s[perm]), np.asarray(sp))


# ------------------------------------------------------------- validation

def test_int8_and_legacy_kv_bits_are_mutually_exclusive():
    cfg = get_arch("glm4-9b").reduced().scaled(cache_quant="int8",
                                               kv_cache_bits=8)
    model = build_model(cfg)
    with pytest.raises(ValueError, match="mutually exclusive"):
        model.init_slot_state(2, 32, abstract=True)


def test_unknown_cache_quant_rejected():
    cfg = get_arch("glm4-9b").reduced().scaled(cache_quant="int4")
    with pytest.raises(ValueError):
        build_model(cfg).init_slot_state(2, 32, abstract=True)


def test_with_cache_dtype():
    model = build_model(get_arch("glm4-9b").reduced())
    assert model.with_cache_dtype(None) is model
    assert model.with_cache_dtype("none") is model
    q = model.with_cache_dtype("int8")
    assert q.cfg.cache_quant == "int8"
    assert q.with_cache_dtype("int8") is q
    with pytest.raises(ValueError):
        model.with_cache_dtype("fp8")


def test_int8_state_at_least_2x_smaller_than_fp32():
    base = json.load(open(_BASELINE))
    for arch in ARCHS:
        cfg = get_arch(arch).reduced().scaled(dtype="float32")
        model = build_model(cfg)
        sizes = {}
        for name, m in [("fp", model), ("q", model.with_cache_dtype("int8"))]:
            st = m.init_slot_state(4, 64, abstract=True)
            sizes[name] = sum(int(np.prod(x.shape)) * np.dtype(x.dtype).itemsize
                              for x in jax.tree_util.tree_leaves(st))
        ratio = sizes["fp"] / sizes["q"]
        assert ratio >= base["slots_per_gb_floor"], (arch, ratio)


# ------------------------------------------------------- engine integration

@pytest.mark.parametrize("arch", ARCHS)
def test_engine_int8_within_committed_ceiling(arch):
    """The acceptance criterion: int8-cache decode tracks fp32-cache
    decode within the committed per-arch logit-error ceiling, with the
    bucketed single-trace discipline intact."""
    ceiling = json.load(open(_BASELINE))["max_logit_err"][arch]
    cfg = get_arch(arch).reduced().scaled(dtype="float32")
    model = build_model(cfg)
    model_q = model.with_cache_dtype("int8")
    params = model.init(jax.random.PRNGKey(0))

    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab_size, 7).astype(np.int32)
    batch = {"tokens": jnp.asarray(prompt[None, :])}
    lg_f, st_f = model.prefill(params, batch, headroom=16)
    lg_q, st_q = model_q.prefill(params, batch, headroom=16)
    worst = float(jnp.max(jnp.abs(lg_f - lg_q)))
    cur = int(jnp.argmax(lg_f.reshape(1, -1)[0]))
    for _ in range(8):
        nb = {"tokens": jnp.asarray([[cur]], jnp.int32)}
        lg_f, st_f = model.decode_step(params, st_f, nb)
        lg_q, st_q = model_q.decode_step(params, st_q, nb)
        worst = max(worst, float(jnp.max(jnp.abs(lg_f - lg_q))))
        cur = int(jnp.argmax(lg_f.reshape(1, -1)[0]))
    assert worst <= ceiling, (arch, worst, ceiling)

    # engine end to end: mixed lengths, no drops, one decode trace
    reqs = []
    for i, (n, m) in enumerate([(3, 4), (9, 3), (5, 5)]):
        p = rng.integers(0, cfg.vocab_size, n).astype(np.int32)
        reqs.append(Request(i, p, max_new_tokens=m))
    eng = ServeEngine(model, params, max_batch=4, max_seq=64,
                      cache_dtype="int8")
    done = {r.rid: r for r in eng.serve(reqs)}
    assert len(done) == 3
    assert all(len(done[i].output) == m
               for i, (_, m) in enumerate([(3, 4), (9, 3), (5, 5)]))
    assert eng.trace_counts["decode"] == 1, eng.trace_counts
