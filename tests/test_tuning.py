"""Persistent tuned-table layer: round-trip, invalidation, precedence.

Covers `repro/kernels/tuning.py` and the three-level block lookup in
`repro/kernels/common.py` (in-process cache beats disk table beats
heuristic), plus the candidates hooks and the sweep harness's smoke path.
"""
import json
import types

import jax
import jax.numpy as jnp
import pytest

from repro.kernels import common, tuning


@pytest.fixture
def table_path(tmp_path, monkeypatch):
    """Point the disk layer at a fresh per-test file; clean caches both
    sides so lookups re-read it."""
    p = tmp_path / "tuned_blocks.json"
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(p))
    common.clear_block_cache()
    common.reset_disk_table()
    yield p
    common.clear_block_cache()
    common.reset_disk_table()


KEY = ("tt.kernel", (64, 64), "int32")


class TestTableIO:
    def test_round_trip(self, table_path):
        tuning.save({KEY: (8, 16)})
        assert table_path.exists()
        assert tuning.load() == {KEY: (8, 16)}

    def test_missing_file_loads_empty(self, table_path):
        assert tuning.load() == {}

    def test_version_mismatch_invalidates(self, table_path):
        tuning.save({KEY: (8, 16)})
        doc = json.loads(table_path.read_text())
        doc["version"]["jax"] = "0.0.0"
        table_path.write_text(json.dumps(doc))
        assert tuning.load() == {}

    def test_platform_mismatch_invalidates(self, table_path):
        tuning.save({KEY: (8, 16)})
        doc = json.loads(table_path.read_text())
        doc["version"]["platform"] = "warp-drive"
        table_path.write_text(json.dumps(doc))
        assert tuning.load() == {}

    def test_schema_bump_invalidates(self, table_path):
        tuning.save({KEY: (8, 16)})
        doc = json.loads(table_path.read_text())
        doc["version"]["schema"] = tuning.SCHEMA_VERSION + 1
        table_path.write_text(json.dumps(doc))
        assert tuning.load() == {}

    def test_corrupt_file_recovers(self, table_path):
        table_path.write_text("{this is not json")
        assert tuning.load() == {}
        # and save() replaces the corpse rather than crashing on merge
        tuning.save({KEY: (4, 4)})
        assert tuning.load() == {KEY: (4, 4)}

    def test_malformed_entries_skipped(self, table_path):
        tuning.save({KEY: (8, 16)})
        doc = json.loads(table_path.read_text())
        doc["entries"].append({"kernel": "bad", "shape": "nope",
                               "dtype": 3, "block": []})
        doc["entries"].append("not even a dict")
        table_path.write_text(json.dumps(doc))
        assert tuning.load() == {KEY: (8, 16)}

    def test_save_merges_with_existing(self, table_path):
        other = ("tt.other", (32,), "float32")
        tuning.save({KEY: (8, 16)})
        tuning.save({other: (32,)})
        assert tuning.load() == {KEY: (8, 16), other: (32,)}
        # collisions: the newer write wins
        tuning.save({KEY: (2, 2)})
        assert tuning.load()[KEY] == (2, 2)

    def test_save_without_merge_clobbers(self, table_path):
        tuning.save({KEY: (8, 16)})
        tuning.save({("tt.other", (32,), "f32"): (32,)}, merge=False)
        assert KEY not in tuning.load()

    def test_env_var_overrides_default_path(self, table_path):
        assert tuning.default_path() == str(table_path)

    def test_xdg_default_path(self, monkeypatch, tmp_path):
        monkeypatch.delenv("REPRO_TUNE_CACHE", raising=False)
        monkeypatch.setenv("XDG_CACHE_HOME", str(tmp_path / "xdg"))
        assert tuning.default_path() == str(
            tmp_path / "xdg" / "repro" / "tuned_blocks.json")


class TestThreeLevelLookup:
    def test_heuristic_when_no_table(self, table_path):
        assert common.pick_block_2d("tt.h", (64, 64)) == (64, 64)

    def test_disk_beats_heuristic(self, table_path):
        tuning.save({("tt.d", (64, 64), "int32"): (4, 4)})
        common.reset_disk_table()
        assert common.pick_block_2d("tt.d", (64, 64)) == (4, 4)

    def test_in_process_beats_disk(self, table_path):
        tuning.save({("tt.p", (64, 64), "int32"): (4, 4)})
        common.reset_disk_table()
        common.set_block("tt.p", (64, 64), jnp.int32, (2, 2))
        assert common.pick_block_2d("tt.p", (64, 64)) == (2, 2)
        # and with the in-process entry gone, disk shows through again
        common.clear_block_cache()
        assert common.pick_block_2d("tt.p", (64, 64)) == (4, 4)

    def test_rows_and_matmul_pickers_hit_disk(self, table_path):
        tuning.save({("tt.rows", (64, 32), "int32"): (8, 32),
                     ("tt.mm", (64, 64, 64), "int32"): (16, 16, 16)})
        common.reset_disk_table()
        assert common.pick_block_rows("tt.rows", (64, 32)) == 8
        assert common.pick_block_matmul("tt.mm", 64, 64, 64) == (16, 16, 16)

    def test_stale_table_falls_back_to_heuristic(self, table_path):
        tuning.save({("tt.s", (64, 64), "int32"): (4, 4)})
        doc = json.loads(table_path.read_text())
        doc["version"]["jax"] = "0.0.0"
        table_path.write_text(json.dumps(doc))
        common.reset_disk_table()
        assert common.pick_block_2d("tt.s", (64, 64)) == (64, 64)

    def test_load_tuned_table_counts(self, table_path):
        tuning.save({("tt.c", (8, 8), "int32"): (8, 8)})
        assert common.load_tuned_table() == 1
        assert common.load_tuned_table(str(table_path)) == 1


class TestCandidatesHooks:
    def test_every_family_enumerates_candidates(self):
        shapes = {
            "cordic_act": (32, 64),
            "cordic_softmax": (16, 64),
            "cordic_mac": (64, 64, 64),
            "flash_attention": (32, 32),
            "wkv": (32, 8),
        }
        for name, shape in shapes.items():
            spec = common.get_kernel(name)
            assert spec.candidates is not None, name
            cands = tuple(spec.candidates(shape, jnp.int32))
            assert cands, name
            for c in cands:
                assert len(c) == len(shape), (name, c)
                assert all(isinstance(b, int) and b >= 1 for b in c), (name, c)

    def test_divisor_families_emit_divisors(self):
        spec = common.get_kernel("cordic_act")
        for br, bc in spec.candidates((24, 36), jnp.int32):
            assert 24 % br == 0 and 36 % bc == 0

    def test_divisor_candidates_helper(self):
        assert common.divisor_candidates(64, 16, 3) == (16, 8, 4)
        assert common.divisor_candidates(7, 512, 4) == (7, 1)
        assert common.divisor_candidates(1, 8) == (1,)


class TestSweepHarness:
    def test_smoke_sweep_persists_and_fresh_lookup_serves(
            self, table_path, tmp_path):
        from benchmarks.tune_bench import sweep
        out = tmp_path / "BENCH_kernels.json"
        report = sweep(smoke=True, repeats=1, families=["cordic_softmax"],
                       out_path=str(out))
        assert len(report["rows"]) == 1
        row = report["rows"][0]
        assert row["us_heuristic"] > 0 and row["us_tuned"] > 0
        assert json.loads(out.read_text())["meta"]["smoke"] is True
        # a fresh lookup state (new process analogue) serves the winner
        common.clear_block_cache()
        common.reset_disk_table()
        shape = tuple(row["shape"])
        assert common.pick_block_rows("cordic_softmax", shape) == \
            row["tuned_block"][0]

    def test_autotune_rejects_keyboard_interrupt(self):
        common.clear_block_cache()

        def run(blk):
            raise KeyboardInterrupt

        with pytest.raises(KeyboardInterrupt):
            common.autotune("tt.ki", (8, 8), jnp.int32, [(8, 8)], run,
                            repeats=1)


class TestServeWarmBoot:
    def test_engine_init_loads_tuned_table(self, table_path):
        from repro.runtime.serve_loop import ServeEngine
        tuning.save({("tt.serve", (8, 8), "int32"): (2, 2)})
        common.reset_disk_table()
        model = types.SimpleNamespace(
            cfg=None,
            prefill=lambda p, b: (_ for _ in ()).throw(AssertionError),
            decode_step=lambda p, st, b: None)
        engine = ServeEngine(model, params=None)
        assert engine.tuned_blocks == 1
        assert common.pick_block_2d("tt.serve", (8, 8)) == (2, 2)
