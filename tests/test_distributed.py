"""Distributed tests on a small virtual mesh (subprocess with 8 host
devices): collectives correctness, MoE shard_map equivalence, sharding
rule engine, and a reduced-mesh dry-run of every family."""
import json
import os
import subprocess
import sys
import tempfile
import textwrap

import numpy as np
import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(code: str, devices: int = 8, timeout: int = 480) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["JAX_PLATFORMS"] = "cpu"
    # Hermeticity: conftest pins REPRO_TUNE_CACHE for *this* process, but
    # when pytest runs without the fixture env (or a dev shell exports a
    # real table) the subprocess would inherit — and autotune paths could
    # write — the user's persistent tuned table.  Pin a fresh absent path
    # per call, and pin the interpret knob to the parent's resolved value
    # so subprocess kernels compile the same way the parent's would.
    env["REPRO_TUNE_CACHE"] = os.path.join(
        tempfile.mkdtemp(prefix="repro-dist-tuned-"), "absent.json")
    env["REPRO_KERNEL_INTERPRET"] = os.environ.get(
        "REPRO_KERNEL_INTERPRET", "1")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


class TestShardingRules:
    def test_divisibility_fallback(self):
        out = run_py("""
            import jax, json
            from repro.parallel.sharding import spec_for
            mesh = jax.make_mesh((2, 4), ("data", "model"))
            specs = {
                # vocab divisible by model=4 -> sharded
                "embed": str(spec_for((1024, 64), ("vocab", "embed"), mesh)),
                # 6 kv heads not divisible by 4 -> replicated
                "kv": str(spec_for((64, 6), ("embed", "kv_heads"), mesh)),
                # batch over data
                "x": str(spec_for((8, 16, 64), ("batch", "seq", "embed"), mesh)),
            }
            print(json.dumps(specs))
        """)
        specs = json.loads(out)
        assert "model" in specs["embed"]
        assert "model" not in specs["kv"]
        assert "data" in specs["x"]

    def test_no_axis_reused_in_one_tensor(self):
        out = run_py("""
            import jax
            from repro.parallel.sharding import spec_for
            mesh = jax.make_mesh((2, 4), ("data", "model"))
            ps = spec_for((8, 4, 64), ("experts", "expert_mlp", "embed"), mesh)
            flat = []
            for e in ps:
                if e is None: continue
                flat += list(e) if isinstance(e, tuple) else [e]
            assert len(flat) == len(set(flat)), ps
            print("ok")
        """)
        assert "ok" in out


class TestCollectives:
    def test_ring_allreduce_matches_sum(self):
        out = run_py("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.parallel.collectives import ring_allreduce
            mesh = jax.make_mesh((8,), ("data",))
            x = jnp.arange(8 * 16, dtype=jnp.float32).reshape(8, 16)
            got = ring_allreduce(x, mesh, "data")
            want = np.tile(np.asarray(x).sum(0), (8, 1))
            np.testing.assert_allclose(np.asarray(got), want, rtol=1e-6)
            print("ok")
        """)
        assert "ok" in out

    def test_hierarchical_allreduce(self):
        out = run_py("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.parallel.collectives import hierarchical_allreduce
            mesh = jax.make_mesh((2, 4), ("pod", "data"))
            x = jnp.arange(2 * 4 * 8, dtype=jnp.float32).reshape(2, 4, 8)
            got = hierarchical_allreduce(x, mesh)
            want = np.broadcast_to(np.asarray(x).sum((0, 1)), (2, 4, 8))
            np.testing.assert_allclose(np.asarray(got), want, rtol=1e-6)
            print("ok")
        """)
        assert "ok" in out


class TestMoEShardMap:
    def test_sharded_matches_local(self):
        """EP shard_map MoE == local dispatch (same routing, same weights)."""
        out = run_py("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.configs import get_arch
            from repro.models import moe as M
            from repro.models.model_zoo import build_model
            cfg = get_arch("arctic-480b").reduced().scaled(
                n_experts=8, top_k=2, moe_d_ff=32, capacity_factor=4.0,
                dtype="float32")
            model = build_model(cfg)
            params = model.init(jax.random.PRNGKey(0))
            bp = jax.tree_util.tree_map(lambda x: x[0],
                                        params["blocks"]["moe"])
            p = M.MoEParams(**bp)
            x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model),
                                  jnp.float32)
            local, aux_l = M._moe_ffn_local(x, p, cfg, cfg.exec_policy)
            mesh = jax.make_mesh((2, 4), ("data", "model"))
            with mesh:
                shmap, aux_s = jax.jit(
                    lambda xx: M._moe_ffn_sharded(xx, p, cfg,
                                                  cfg.exec_policy, mesh))(x)
            err = float(jnp.abs(local - shmap).max())
            # capacity grouping differs (per-seq vs per-shard) => tiny drop
            # differences possible; with cf=4 nothing drops
            assert err < 1e-4, err
            print("ok", err)
        """)
        assert "ok" in out


class TestReducedMeshDryrun:
    @pytest.mark.parametrize("arch", ["glm4-9b", "arctic-480b", "rwkv6-3b",
                                      "hymba-1.5b"])
    def test_train_step_lowers_on_mesh(self, arch):
        """Reduced config, 2x4 mesh: train step lower+compile succeeds and
        SPMD partitions (collectives present for sharded params)."""
        out = run_py(f"""
            import jax, jax.numpy as jnp
            from repro.configs import get_arch
            from repro.models.model_zoo import build_model
            from repro.models import spec as pspec
            from repro.parallel import sharding as shd
            from repro.optim import adamw

            cfg = get_arch("{arch}").reduced()
            model = build_model(cfg)
            mesh = jax.make_mesh((2, 4), ("data", "model"))
            p_sh = shd.tree_shardings(model.params_spec(), mesh)
            params_abs = model.abstract_params()
            batch_abs = model.input_specs(4, 32, "train")
            ocfg = adamw.AdamWConfig()
            opt_abs = jax.eval_shape(lambda: adamw.init(
                ocfg, pspec.abstract(model.params_spec())))

            def step(params, opt_state, batch):
                (l, m), g = jax.value_and_grad(
                    lambda p: model.loss(p, batch), has_aux=True)(params)
                p2, o2, _ = adamw.update(ocfg, g, opt_state, params)
                return p2, o2, l

            with mesh:
                lowered = jax.jit(step, in_shardings=(p_sh, None, None)
                                  ).lower(params_abs, opt_abs, batch_abs)
                compiled = lowered.compile()
            txt = compiled.as_text()
            has_coll = any(k in txt for k in
                           ("all-reduce", "all-gather", "reduce-scatter",
                            "all-to-all", "collective-permute"))
            print("compiled", len(txt), "collectives:", has_coll)
            assert has_coll
        """)
        assert "compiled" in out


class TestElasticResharding:
    def test_checkpoint_restores_on_shrunk_mesh(self):
        """Save params sharded on a 2x4 mesh; restore onto 1x4 (simulating
        the loss of half the chips) — values identical, new shardings
        applied.  This is the elastic-rescale path end to end."""
        out = run_py("""
            import tempfile
            import jax, jax.numpy as jnp, numpy as np
            from jax.sharding import NamedSharding, PartitionSpec as PS
            from repro.checkpoint.manager import CheckpointManager
            from repro.configs import get_arch
            from repro.models.model_zoo import build_model
            from repro.parallel import sharding as shd
            from repro.parallel.fault_tolerance import plan_elastic_remesh

            cfg = get_arch("glm4-9b").reduced()
            model = build_model(cfg)
            mesh_a = jax.make_mesh((2, 4), ("data", "model"))
            sh_a = shd.tree_shardings(model.params_spec(), mesh_a)
            params = jax.tree_util.tree_map(
                lambda p, s: jax.device_put(p, s),
                model.init(jax.random.PRNGKey(0)), sh_a)

            with tempfile.TemporaryDirectory() as d:
                mgr = CheckpointManager(d, async_save=False)
                mgr.save(5, {"params": params})
                # lose 4 chips: plan keeps tp=4, data 2->1
                data, tp = plan_elastic_remesh(4, model_parallel=4)
                assert (data, tp) == (1, 4)
                mesh_b = jax.make_mesh((1, 4), ("data", "model"))
                sh_b = shd.tree_shardings(model.params_spec(), mesh_b)
                got = mgr.restore({"params": params},
                                  shardings={"params": sh_b})["params"]
            a = jax.tree_util.tree_leaves(params)
            b = jax.tree_util.tree_leaves(got)
            for x, y in zip(a, b):
                np.testing.assert_array_equal(
                    np.asarray(x, np.float32), np.asarray(y, np.float32))
            print("ok")
        """)
        assert "ok" in out


class TestDataParallelEquivalence:
    def test_sharded_loss_matches_single_device(self):
        """The same batch gives the same loss on a 2x4 mesh as unsharded —
        the sharding layer must be semantics-preserving."""
        out = run_py("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.configs import get_arch
            from repro.models.model_zoo import build_model
            from repro.parallel import sharding as shd

            cfg = get_arch("glm4-9b").reduced().scaled(dtype="float32")
            model = build_model(cfg)
            params = model.init(jax.random.PRNGKey(0))
            batch = model.make_batch(jax.random.PRNGKey(1), 8, 32, "train")
            base, _ = jax.jit(lambda p, b: model.loss(p, b))(params, batch)

            mesh = jax.make_mesh((2, 4), ("data", "model"))
            p_sh = shd.tree_shardings(model.params_spec(), mesh)
            params_s = jax.tree_util.tree_map(jax.device_put, params, p_sh)
            with mesh:
                sharded, _ = jax.jit(
                    lambda p, b: model.loss(p, b))(params_s, batch)
            a, b = float(base), float(sharded)
            assert abs(a - b) / abs(a) < 1e-4, (a, b)
            print("ok", a, b)
        """)
        assert "ok" in out

    def test_sharded_moe_loss_matches(self):
        out = run_py("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.configs import get_arch
            from repro.models.model_zoo import build_model
            from repro.parallel import sharding as shd

            cfg = get_arch("arctic-480b").reduced().scaled(
                dtype="float32", n_experts=8, capacity_factor=4.0)
            model = build_model(cfg)
            params = model.init(jax.random.PRNGKey(0))
            batch = model.make_batch(jax.random.PRNGKey(1), 8, 32, "train")
            base, _ = jax.jit(lambda p, b: model.loss(p, b))(params, batch)
            mesh = jax.make_mesh((2, 4), ("data", "model"))
            p_sh = shd.tree_shardings(model.params_spec(), mesh)
            params_s = jax.tree_util.tree_map(jax.device_put, params, p_sh)
            with mesh:
                sharded, _ = jax.jit(
                    lambda p, b: model.loss(p, b))(params_s, batch)
            a, b = float(base), float(sharded)
            # shard_map MoE groups tokens per data shard instead of per
            # sequence; with cf=4 nothing drops and losses agree tightly
            assert abs(a - b) / abs(a) < 5e-3, (a, b)
            print("ok", a, b)
        """)
        assert "ok" in out
