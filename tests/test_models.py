"""Per-architecture smoke tests (reduced configs) + model-level invariants.

Each of the 10 assigned architectures instantiates a reduced config of the
same family and runs one forward/train step on CPU, asserting output shapes
and the absence of NaNs — plus serving-path consistency checks.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, CORDIC_EXEC, get_arch
from repro.configs.base import ExecutionPolicy
from repro.models import transformer as T
from repro.models.model_zoo import build_model

ALL_ARCHS = sorted(ARCHS)


def _batch(model, key, b=2, s=16):
    return model.make_batch(key, b, s, "train")


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_arch(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(model, jax.random.PRNGKey(1))
    logits = model.forward(params, batch)
    b, s = 2, 16
    if cfg.n_codebooks:
        assert logits.shape == (b, s, cfg.n_codebooks, cfg.vocab_size)
    else:
        assert logits.shape == (b, s, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())

    # one SGD step: loss must be finite and decrease-able (grads finite)
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: model.loss(p, batch), has_aux=True)(params)
    assert np.isfinite(float(loss))
    leaves = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.isfinite(g.astype(jnp.float32)).all()) for g in leaves)
    params2 = jax.tree_util.tree_map(
        lambda p, g: p - 0.5 * g.astype(p.dtype), params, grads)
    loss2, _ = model.loss(params2, batch)
    assert np.isfinite(float(loss2))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_serving_consistency(arch):
    """prefill's last logits == forward's last logits; decode runs."""
    cfg = get_arch(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = model.make_batch(jax.random.PRNGKey(1), 2, 16, "prefill")
    lf = model.forward(params, batch)[:, -1:]
    lp, state = model.prefill(params, batch)
    np.testing.assert_allclose(
        np.asarray(lp.astype(jnp.float32)).reshape(2, -1),
        np.asarray(lf.astype(jnp.float32)).reshape(2, -1),
        atol=1e-2, rtol=1e-2)
    nb = model.make_batch(jax.random.PRNGKey(2), 2, 1, "decode")
    dl, state2 = model.decode_step(params, state, nb)
    assert int(state2.pos) == 17
    assert bool(jnp.isfinite(dl.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", ["glm4-9b", "rwkv6-3b", "hymba-1.5b"])
def test_decode_matches_forward(arch):
    """Teacher-forced decode of the last token == forward at that position."""
    cfg = get_arch(arch).reduced().scaled(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    full = model.make_batch(jax.random.PRNGKey(1), 2, 16, "prefill")
    key = "tokens" if cfg.input_kind == "tokens" else "frames"
    prefix = {key: full[key][:, :15]}
    last = {key: full[key][:, 15:16]}
    _, state = model.prefill(params, prefix)
    dl, _ = model.decode_step(params, state, last)
    lf = model.forward(params, full)[:, -1:]
    np.testing.assert_allclose(
        np.asarray(dl.astype(jnp.float32)).reshape(2, -1),
        np.asarray(lf.astype(jnp.float32)).reshape(2, -1),
        atol=1e-3, rtol=1e-3)


def test_chunked_matches_naive_attention():
    cfg = get_arch("glm4-9b").reduced().scaled(attn_impl="naive",
                                               dtype="float32")
    cfg_c = cfg.scaled(attn_impl="chunked", attn_chunk=8)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = model.make_batch(jax.random.PRNGKey(1), 2, 32, "prefill")
    a = build_model(cfg).forward(params, batch)
    b = build_model(cfg_c).forward(params, batch)
    np.testing.assert_allclose(np.asarray(a.astype(jnp.float32)),
                               np.asarray(b.astype(jnp.float32)),
                               atol=1e-4, rtol=1e-4)


def test_sliding_window_limits_context():
    """A token outside every window cannot influence the output."""
    cfg = get_arch("hymba-1.5b").reduced().scaled(
        sliding_window=4, global_attn_every=0, attn_impl="naive")
    # pure-window attention (no global layers): perturb token 0, check the
    # last position (t=15, window 4 => sees 12..15 only) via attention-only
    # model: isolate by zeroing the ssm branch is overkill; instead compare
    # attention masks directly.
    from repro.models.attention import _causal_window_mask
    m = _causal_window_mask(jnp.arange(16), jnp.arange(16), 4)
    assert not bool(m[15, 0])
    assert bool(m[15, 12]) and bool(m[15, 15])
    assert not bool(m[0, 1])  # causal


@pytest.mark.parametrize("arch", ["glm4-9b", "granite-moe-3b-a800m"])
def test_cordic_execution_mode(arch):
    """The paper's FxP8+DA-VINCI policy runs end-to-end without NaNs and
    stays close to the bf16 reference (QAT-grade fidelity)."""
    cfg = get_arch(arch).reduced().scaled(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = model.make_batch(jax.random.PRNGKey(1), 2, 16, "train")
    ref = model.forward(params, batch, ExecutionPolicy(matmul="bf16"))
    got = model.forward(params, batch, CORDIC_EXEC)
    assert bool(jnp.isfinite(got.astype(jnp.float32)).all())
    # logits correlate strongly with the float path
    a = np.asarray(ref.astype(jnp.float32)).ravel()
    g = np.asarray(got.astype(jnp.float32)).ravel()
    corr = np.corrcoef(a, g)[0, 1]
    assert corr > 0.95, corr


def test_moe_router_load_properties():
    """Capacity dispatch drops at most the expected fraction; gates sum 1."""
    from repro.models import moe as M
    cfg = get_arch("granite-moe-3b-a800m").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    bp = jax.tree_util.tree_map(lambda x: x[0], params["blocks"]["moe"])
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 16, cfg.d_model))
    out, aux = M.moe_ffn(x, M.MoEParams(**bp), cfg, cfg.exec_policy)
    assert out.shape == x.shape
    assert np.isfinite(float(aux))
    assert float(aux) >= 1.0 - 1e-3  # Switch aux lower bound ~1 at balance


def test_musicgen_codebook_heads():
    cfg = get_arch("musicgen-medium").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = model.make_batch(jax.random.PRNGKey(1), 2, 8, "train")
    assert batch["labels"].shape == (2, 8, cfg.n_codebooks)
    loss, _ = model.loss(params, batch)
    assert np.isfinite(float(loss))


def test_long_context_state_is_o1_for_ssm():
    """rwkv6 decode state must not scale with context length."""
    cfg = get_arch("rwkv6-3b").reduced()
    model = build_model(cfg)
    s1 = model.init_decode_state(1, 1024, abstract=True)
    s2 = model.init_decode_state(1, 524288, abstract=True)
    sz = lambda s: sum(np.prod(x.shape) for x in jax.tree_util.tree_leaves(s))
    assert sz(s1) == sz(s2)


def test_hymba_long_mode_ring_cache():
    """long_500k: hybrid cache is the sliding window, not the full context."""
    cfg = get_arch("hymba-1.5b")
    model = build_model(cfg)
    st = model.init_decode_state(1, 524288, abstract=True)
    assert st.cache_k.shape[2] == cfg.sliding_window


def test_int8_kv_cache_decode_fidelity():
    """FxP8 (Q3.4) KV cache: decode logits stay faithful to the bf16 cache
    (the #Perf decode hillclimb's accuracy leg)."""
    cfg16 = get_arch("glm4-9b").reduced().scaled(dtype="float32")
    cfg8 = cfg16.scaled(kv_cache_bits=8)
    m16, m8 = build_model(cfg16), build_model(cfg8)
    params = m16.init(jax.random.PRNGKey(0))
    batch = m16.make_batch(jax.random.PRNGKey(1), 2, 15, "prefill")
    last = {"tokens": jnp.zeros((2, 1), jnp.int32)}
    _, st16 = m16.prefill(params, batch)
    _, st8 = m8.prefill(params, batch)
    assert st8.cache_k.dtype == jnp.int8
    l16, _ = m16.decode_step(params, st16, last)
    l8, _ = m8.decode_step(params, st8, last)
    a = np.asarray(l16.astype(jnp.float32)).ravel()
    b = np.asarray(l8.astype(jnp.float32)).ravel()
    assert np.corrcoef(a, b)[0, 1] > 0.99


def test_fused_moe_ffn_matches_unfused():
    """arctic's fused dense-FFN+MoE psum == separate computation (local)."""
    cfg = get_arch("arctic-480b").reduced().scaled(dtype="float32")
    cfg_f = cfg.scaled(fuse_moe_ffn_ar=True)
    m, mf = build_model(cfg), build_model(cfg_f)
    params = m.init(jax.random.PRNGKey(0))
    batch = m.make_batch(jax.random.PRNGKey(1), 2, 16, "train")
    a = np.asarray(m.forward(params, batch))
    b = np.asarray(mf.forward(params, batch))
    np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-4)
