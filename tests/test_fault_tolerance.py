"""Fault-tolerance tests: heartbeats, stragglers, elastic re-mesh, and the
full crash->restore->resume loop with real checkpoints."""
import tempfile

import numpy as np
import pytest

from repro.parallel.fault_tolerance import (HeartbeatMonitor,
                                            StragglerDetector,
                                            TrainSupervisor, WorkerKilled,
                                            plan_elastic_remesh)


class TestHeartbeat:
    def test_detects_dead_worker(self):
        t = [0.0]
        mon = HeartbeatMonitor(["w0", "w1"], timeout_s=10,
                               clock=lambda: t[0])
        t[0] = 5.0
        mon.beat("w0")
        t[0] = 12.0
        assert mon.dead_workers() == ["w1"]
        assert mon.alive_count == 1

    def test_beat_revives(self):
        t = [0.0]
        mon = HeartbeatMonitor(["w0"], timeout_s=1, clock=lambda: t[0])
        t[0] = 5.0
        assert mon.dead_workers() == ["w0"]
        mon.beat("w0")
        assert mon.dead_workers() == []

    def test_add_worker_registers_fresh_beat(self):
        """A respawned worker is not born dead from its predecessor's
        silence: its first beat is its registration time."""
        t = [0.0]
        mon = HeartbeatMonitor(["w0"], timeout_s=10, clock=lambda: t[0])
        t[0] = 100.0
        mon.add_worker("w0-r1")
        assert mon.dead_workers() == ["w0"]
        assert "w0-r1" not in mon.dead_workers()
        t[0] = 105.0
        assert mon.workers["w0-r1"].alive
        assert mon.alive_count == 1

    def test_mark_dead_is_immediate(self):
        """An externally-confirmed death (a caught WorkerKilled) takes
        effect without waiting out the heartbeat timeout."""
        t = [0.0]
        mon = HeartbeatMonitor(["w0"], timeout_s=1000, clock=lambda: t[0])
        mon.mark_dead("w0")
        assert not mon.workers["w0"].alive
        assert mon.alive_count == 0
        assert mon.dead_workers() == ["w0"]   # -inf beat trips the sweep
        mon.beat("w0")                        # explicit revival
        assert mon.workers["w0"].alive
        mon.mark_dead("ghost")                # unknown worker is a no-op

    def test_worker_killed_is_runtime_error(self):
        with pytest.raises(RuntimeError):
            raise WorkerKilled("injected")


class TestStraggler:
    def test_flags_slow_worker(self):
        det = StragglerDetector(factor=1.5)
        for _ in range(10):
            for w in ("a", "b", "c", "d"):
                det.record(w, 1.0)
            det.record("slow", 2.5)
        names = [w for w, _ in det.stragglers()]
        assert names == ["slow"]

    def test_mitigation_policy(self):
        det = StragglerDetector(factor=1.5)
        for _ in range(10):
            for w in ("a", "b", "c"):
                det.record(w, 1.0)
            det.record("mild", 1.8)
            det.record("severe", 5.0)
        assert det.mitigation("mild") == "rebalance"
        assert det.mitigation("severe") == "evict"
        assert det.mitigation("a") == "none"

    def test_ewma_update_rule(self):
        """ewma' = (1-alpha)*ewma + alpha*x, seeded at the first sample."""
        det = StragglerDetector(alpha=0.2)
        det.record("w", 1.0)
        assert det.ewma["w"] == pytest.approx(1.0)
        det.record("w", 2.0)
        assert det.ewma["w"] == pytest.approx(0.8 * 1.0 + 0.2 * 2.0)
        det.record("w", 2.0)
        assert det.ewma["w"] == pytest.approx(0.8 * 1.2 + 0.2 * 2.0)

    def test_ewma_converges_and_forgets_transient(self):
        """A single spike decays geometrically: ~(1-alpha)^n of the spike
        remains after n clean steps, so a one-off hiccup never flags."""
        det = StragglerDetector(factor=1.5, alpha=0.2)
        for w in ("a", "b", "c", "d"):
            det.record(w, 1.0)
        det.record("a", 10.0)                 # transient spike
        assert [w for w, _ in det.stragglers()] == ["a"]
        for _ in range(20):
            for w in ("a", "b", "c", "d"):
                det.record(w, 1.0)
        assert det.stragglers() == []
        assert det.ewma["a"] == pytest.approx(1.0, abs=0.05)

    def test_empty_detector_no_stragglers(self):
        assert StragglerDetector().stragglers() == []


class TestElasticRemesh:
    def test_preserves_tp(self):
        data, model = plan_elastic_remesh(512 - 16, model_parallel=16)
        assert model == 16
        assert data == 31

    def test_pod_rounding(self):
        data, model = plan_elastic_remesh(500, model_parallel=16,
                                          pod_size=256)
        assert (data * model) % 256 == 0

    def test_too_few_chips_raises(self):
        with pytest.raises(RuntimeError):
            plan_elastic_remesh(8, model_parallel=16)

    def test_exact_fit_and_remainder(self):
        """The data axis is the floor multiple: leftover chips idle rather
        than change the TP degree (weight shards are pinned to it)."""
        assert plan_elastic_remesh(256, model_parallel=16) == (16, 16)
        assert plan_elastic_remesh(255, model_parallel=16) == (15, 16)
        assert plan_elastic_remesh(17, model_parallel=16) == (1, 16)

    def test_pod_rounding_keeps_at_least_one_data_shard(self):
        # fewer survivors than a pod: fall back to the un-rounded plan
        data, model = plan_elastic_remesh(32, model_parallel=16,
                                          pod_size=256)
        assert (data, model) == (2, 16)


class TestSupervisor:
    def test_restart_resumes_from_checkpoint(self):
        events = []

        def run_fn(start, mesh, total):
            events.append(("run", start, mesh))
            if start < 50 and len(events) < 3:
                return start + 25, {"lost_chips": 16,
                                    "alive_chips": 240}
            return total, None

        def restore_fn(mesh):
            events.append(("restore", mesh))
            return 20  # latest checkpoint step

        sup = TrainSupervisor(run_fn, restore_fn, initial_mesh=(16, 16))
        end = sup.run(100)
        assert end == 100
        assert any(e[0] == "restore" for e in events)
        # mesh shrank to 15x16 = 240 chips
        assert sup.mesh == (15, 16)

    def test_restart_budget(self):
        def run_fn(start, mesh, total):
            return start, {"lost_chips": 0, "alive_chips": 256}

        sup = TrainSupervisor(run_fn, lambda m: 0, (16, 16), max_restarts=3)
        with pytest.raises(RuntimeError):
            sup.run(10)


class TestEndToEndCrashRestore:
    def test_trainer_crash_and_resume(self):
        """Real integration: train, crash (injected), restore, finish; the
        resumed run must continue from the checkpointed step and reach a
        comparable loss to an uninterrupted run."""
        import jax
        from repro.configs import get_arch
        from repro.data.pipeline import DataConfig, SyntheticStream
        from repro.models.model_zoo import build_model
        from repro.optim.adamw import AdamWConfig
        from repro.runtime.train_loop import TrainConfig, Trainer

        cfg = get_arch("glm4-9b").reduced()
        model = build_model(cfg)
        stream = SyntheticStream(DataConfig(vocab_size=cfg.vocab_size,
                                            seq_len=16, global_batch=2,
                                            seed=0))
        with tempfile.TemporaryDirectory() as d:
            tcfg = TrainConfig(optimizer=AdamWConfig(lr=1e-3, warmup_steps=2,
                                                     total_steps=30),
                               ckpt_dir=d, ckpt_every=5, log_every=5)
            t1 = Trainer(model, tcfg, stream)
            with pytest.raises(RuntimeError, match="injected fault"):
                t1.run(30, fault_at=12)
            # restart: restore_or_init should pick up step 10's checkpoint
            t2 = Trainer(model, tcfg, stream)
            _, _, _, start = t2.restore_or_init()
            assert start == 11
            out = t2.run(30)
            assert np.isfinite(out["final_loss"])

    def test_elastic_restore_new_sharding(self):
        """Checkpoint saved unsharded restores under a different device
        placement (single-device stand-in for a shrunk mesh)."""
        import jax
        import jax.numpy as jnp
        from repro.checkpoint.manager import CheckpointManager

        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d, async_save=False)
            state = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
            mgr.save(1, state)
            sh = jax.sharding.SingleDeviceSharding(jax.devices()[0])
            got = mgr.restore(state, shardings={"w": sh})
            np.testing.assert_array_equal(np.asarray(got["w"]),
                                          np.asarray(state["w"]))

    def test_load_arrays_roundtrip_and_resave(self):
        """The templateless loader (serve snapshots have no pytree to
        mirror) returns raw arrays + metadata, preserves exotic dtypes and
        dotted keys, and a re-save at the same step atomically replaces
        the previous snapshot."""
        from repro.checkpoint.manager import CheckpointManager

        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d, async_save=False)
            arrays = {"slot0.tokens": np.arange(5, dtype=np.int32),
                      "slot0.cache_k": np.ones((2, 3), np.int8)}
            mgr.save(3, arrays, metadata={"snapshot_version": 1})
            got, meta = mgr.load_arrays()
            assert meta["snapshot_version"] == 1
            assert got["slot0.cache_k"].dtype == np.int8
            np.testing.assert_array_equal(got["slot0.tokens"],
                                          arrays["slot0.tokens"])
            # overwrite-in-place: same step, new contents
            mgr.save(3, {"slot0.tokens": np.zeros(2, np.int32)},
                     metadata={"snapshot_version": 1})
            got2, _ = mgr.load_arrays(3)
            assert list(got2) == ["slot0.tokens"]
            assert got2["slot0.tokens"].tolist() == [0, 0]
            with pytest.raises(FileNotFoundError):
                CheckpointManager(d + "/nope").load_arrays()
