"""Fault-tolerance tests: heartbeats, stragglers, elastic re-mesh, and the
full crash->restore->resume loop with real checkpoints."""
import tempfile

import numpy as np
import pytest

from repro.parallel.fault_tolerance import (HeartbeatMonitor,
                                            StragglerDetector,
                                            TrainSupervisor,
                                            plan_elastic_remesh)


class TestHeartbeat:
    def test_detects_dead_worker(self):
        t = [0.0]
        mon = HeartbeatMonitor(["w0", "w1"], timeout_s=10,
                               clock=lambda: t[0])
        t[0] = 5.0
        mon.beat("w0")
        t[0] = 12.0
        assert mon.dead_workers() == ["w1"]
        assert mon.alive_count == 1

    def test_beat_revives(self):
        t = [0.0]
        mon = HeartbeatMonitor(["w0"], timeout_s=1, clock=lambda: t[0])
        t[0] = 5.0
        assert mon.dead_workers() == ["w0"]
        mon.beat("w0")
        assert mon.dead_workers() == []


class TestStraggler:
    def test_flags_slow_worker(self):
        det = StragglerDetector(factor=1.5)
        for _ in range(10):
            for w in ("a", "b", "c", "d"):
                det.record(w, 1.0)
            det.record("slow", 2.5)
        names = [w for w, _ in det.stragglers()]
        assert names == ["slow"]

    def test_mitigation_policy(self):
        det = StragglerDetector(factor=1.5)
        for _ in range(10):
            for w in ("a", "b", "c"):
                det.record(w, 1.0)
            det.record("mild", 1.8)
            det.record("severe", 5.0)
        assert det.mitigation("mild") == "rebalance"
        assert det.mitigation("severe") == "evict"
        assert det.mitigation("a") == "none"


class TestElasticRemesh:
    def test_preserves_tp(self):
        data, model = plan_elastic_remesh(512 - 16, model_parallel=16)
        assert model == 16
        assert data == 31

    def test_pod_rounding(self):
        data, model = plan_elastic_remesh(500, model_parallel=16,
                                          pod_size=256)
        assert (data * model) % 256 == 0

    def test_too_few_chips_raises(self):
        with pytest.raises(RuntimeError):
            plan_elastic_remesh(8, model_parallel=16)


class TestSupervisor:
    def test_restart_resumes_from_checkpoint(self):
        events = []

        def run_fn(start, mesh, total):
            events.append(("run", start, mesh))
            if start < 50 and len(events) < 3:
                return start + 25, {"lost_chips": 16,
                                    "alive_chips": 240}
            return total, None

        def restore_fn(mesh):
            events.append(("restore", mesh))
            return 20  # latest checkpoint step

        sup = TrainSupervisor(run_fn, restore_fn, initial_mesh=(16, 16))
        end = sup.run(100)
        assert end == 100
        assert any(e[0] == "restore" for e in events)
        # mesh shrank to 15x16 = 240 chips
        assert sup.mesh == (15, 16)

    def test_restart_budget(self):
        def run_fn(start, mesh, total):
            return start, {"lost_chips": 0, "alive_chips": 256}

        sup = TrainSupervisor(run_fn, lambda m: 0, (16, 16), max_restarts=3)
        with pytest.raises(RuntimeError):
            sup.run(10)


class TestEndToEndCrashRestore:
    def test_trainer_crash_and_resume(self):
        """Real integration: train, crash (injected), restore, finish; the
        resumed run must continue from the checkpointed step and reach a
        comparable loss to an uninterrupted run."""
        import jax
        from repro.configs import get_arch
        from repro.data.pipeline import DataConfig, SyntheticStream
        from repro.models.model_zoo import build_model
        from repro.optim.adamw import AdamWConfig
        from repro.runtime.train_loop import TrainConfig, Trainer

        cfg = get_arch("glm4-9b").reduced()
        model = build_model(cfg)
        stream = SyntheticStream(DataConfig(vocab_size=cfg.vocab_size,
                                            seq_len=16, global_batch=2,
                                            seed=0))
        with tempfile.TemporaryDirectory() as d:
            tcfg = TrainConfig(optimizer=AdamWConfig(lr=1e-3, warmup_steps=2,
                                                     total_steps=30),
                               ckpt_dir=d, ckpt_every=5, log_every=5)
            t1 = Trainer(model, tcfg, stream)
            with pytest.raises(RuntimeError, match="injected fault"):
                t1.run(30, fault_at=12)
            # restart: restore_or_init should pick up step 10's checkpoint
            t2 = Trainer(model, tcfg, stream)
            _, _, _, start = t2.restore_or_init()
            assert start == 11
            out = t2.run(30)
            assert np.isfinite(out["final_loss"])

    def test_elastic_restore_new_sharding(self):
        """Checkpoint saved unsharded restores under a different device
        placement (single-device stand-in for a shrunk mesh)."""
        import jax
        import jax.numpy as jnp
        from repro.checkpoint.manager import CheckpointManager

        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d, async_save=False)
            state = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
            mgr.save(1, state)
            sh = jax.sharding.SingleDeviceSharding(jax.devices()[0])
            got = mgr.restore(state, shardings={"w": sh})
            np.testing.assert_array_equal(np.asarray(got["w"]),
                                          np.asarray(state["w"]))
