"""Speculative decoding: the contract under test.

  * **Greedy bit-exactness** — spec decode is a pure scheduling change:
    per-request outputs are bit-identical to plain single-token decode
    across the dense (KV cache), ssm (recurrent) and hybrid families.
  * **Rollback** — rejected draft positions leave no trace: after a
    partial commit the recurrent state equals the plain-decode state and
    the stale K/V writes stay masked until overwritten.
  * **Per-slot mixed acceptance** — one batch can advance every slot by a
    different 0..k+1 without cross-talk.
  * **Drafter** — n-gram prompt lookup proposes through runs/cycles,
    rolls its speculative index back, and never exceeds k; the
    draft-model drafter reproduces its model's greedy chain and tiers
    down to the n-gram fallback when the model has no signal.
  * **Ring caches** — the long-context sliding-window preset verifies
    too: outputs stay bit-exact at and past the window boundary, and
    only a verify window wider than the ring is refused.
  * **Adaptive spec_k** — per-slot draft budgets walk to 0 on
    undraftable traffic (cutting verify dispatches) and back to
    spec_k_max on draftable traffic.
  * **Metrics** — spec_acceptance / tokens_per_step bookkeeping is sane
    and token conservation holds.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models.model_zoo import build_model, draft_arch
from repro.runtime.drafter import (DraftModelDrafter, Drafter, DraftSession,
                                   NGramDrafter, make_drafter)
from repro.runtime.serve_loop import Request, ServeConfig, ServeEngine

MAX_SEQ = 64


@pytest.fixture(scope="module")
def served():
    """One model + params (+ jitted decode oracle) per family."""
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = get_arch(arch).reduced()
            model = build_model(cfg)
            params = model.init(jax.random.PRNGKey(0))
            dec = jax.jit(
                lambda p, s, t: model.decode_step(p, s, {"tokens": t}))
            cache[arch] = (cfg, model, params, dec)
        return cache[arch]

    return get


def _single_stream(model, params, dec, prompt, max_new):
    """Plain greedy decode — the engine's correctness oracle."""
    lg, st = model.prefill(
        params, {"tokens": jnp.asarray(prompt[None, :])},
        headroom=MAX_SEQ - len(prompt))
    cur = int(jnp.argmax(lg.reshape(1, -1), axis=-1)[0])
    seq = [cur]
    for _ in range(max_new - 1):
        lg, st = dec(params, st, jnp.asarray([[cur]], jnp.int32))
        cur = int(jnp.argmax(lg.reshape(1, -1), axis=-1)[0])
        seq.append(cur)
    return seq


def _mixed_requests(cfg, lens, max_news, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(i, rng.integers(0, cfg.vocab_size, n).astype(np.int32),
                    max_new_tokens=m)
            for i, (n, m) in enumerate(zip(lens, max_news))]


# ---------------------------------------------------------------------------
# Greedy bit-exactness across every stateful family
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["glm4-9b", "rwkv6-3b", "hymba-1.5b"])
def test_greedy_bitexact_vs_plain_decode(served, arch):
    """Spec decode must not change a single token — attention KV, rwkv
    recurrent and hybrid conv/ssm state all roll back exactly."""
    cfg, model, params, dec = served(arch)
    engine = ServeEngine(model, params, max_batch=4, max_seq=MAX_SEQ,
                         spec_k=4)
    reqs = _mixed_requests(cfg, lens=[5, 11, 16, 3, 24, 8],
                           max_news=[4, 9, 2, 12, 1, 14])
    done = engine.serve(reqs)
    assert len(done) == len(reqs)
    for r in done:
        ref = _single_stream(model, params, dec, r.prompt, r.max_new_tokens)
        assert list(r.output) == ref, (arch, r.rid)
    # greedy engines take the fused verify+accept+commit path: at most one
    # verify trace for the whole run (none if no step had drafts worth
    # verifying — the plain fallback), never a separate commit program
    assert engine.trace_counts["verify"] <= 1
    assert engine.trace_counts["commit"] == 0


# ---------------------------------------------------------------------------
# Rollback correctness after rejection (model-layer contract)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["glm4-9b", "rwkv6-3b", "hymba-1.5b"])
def test_rollback_after_rejection(served, arch):
    """verify_step + spec_commit with a partial advance must reproduce the
    plain-decode state exactly: logits, pos, recurrent fields — and the
    continuation after the rollback."""
    cfg, model, params, dec = served(arch)
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab_size, 9).astype(np.int32)
    lg, st0 = model.prefill(params, {"tokens": jnp.asarray(prompt[None])},
                            headroom=MAX_SEQ - len(prompt))
    cur = int(jnp.argmax(lg.reshape(1, -1)))
    # plain chain: 5 steps from the prefill state
    st = st0
    seq = [cur]
    seq_logits = []
    for _ in range(5):
        lg, st = dec(params, st, jnp.asarray([[seq[-1]]], jnp.int32))
        seq_logits.append(np.asarray(lg.reshape(-1).astype(jnp.float32)))
        seq.append(int(jnp.argmax(lg.reshape(1, -1))))
    # verify a window where drafts go wrong after 2 matches
    window = [seq[0], seq[1], seq[2],
              (seq[3] + 1) % cfg.vocab_size, 7]
    logits, stv, rec = model.verify_step(
        params, st0, {"tokens": jnp.asarray(np.array([window], np.int32))})
    par = np.asarray(logits.astype(jnp.float32))[0]
    for j in range(3):      # scored positions match plain logits bit-exact
        np.testing.assert_array_equal(par[j], seq_logits[j], err_msg=arch)
    # commit only the 3 verified-correct tokens (advance = accepted+1)
    stc = model.spec_commit(stv, rec, jnp.asarray([3], jnp.int32))
    np.testing.assert_array_equal(np.asarray(stc.pos).ravel(),
                                  [len(prompt) + 3])
    # recurrent fields equal the plain-decode state after 3 steps
    st3 = st0
    for tok in window[:3]:
        _, st3 = dec(params, st3, jnp.asarray([[tok]], jnp.int32))
    for f in ("x_prev", "cm_prev", "wkv", "conv_tail", "ssm_h"):
        a, b = getattr(stc, f), getattr(st3, f)
        if a is None:
            continue
        np.testing.assert_array_equal(
            np.asarray(a.astype(jnp.float32)),
            np.asarray(b.astype(jnp.float32)), err_msg=(arch, f))
    # and decode continues identically despite the stale rejected writes
    lg_c, _ = model.decode_step(params, stc,
                                {"tokens": jnp.asarray([[seq[3]]],
                                                       jnp.int32)})
    np.testing.assert_array_equal(
        np.asarray(lg_c.reshape(-1).astype(jnp.float32)), seq_logits[3],
        err_msg=arch)


# ---------------------------------------------------------------------------
# Per-slot mixed acceptance in one batch
# ---------------------------------------------------------------------------

class _ScriptedSession(DraftSession):
    def __init__(self, stream):
        self.stream = list(stream)
        self.pos = 0

    def extend(self, tokens):
        self.pos += len(tokens)

    def draft(self, k):
        return self.stream[self.pos:self.pos + k]


class _ScriptedDrafter(Drafter):
    """Drafts the request's true continuation (keyed by prompt) for some
    requests and garbage for the rest — forcing full and zero acceptance
    side by side in one batch."""

    def __init__(self, streams):
        self.streams = streams          # first-token -> oracle stream

    def begin(self, context, slot=None, rid=None):
        key = context[0]
        if key in self.streams:
            return _ScriptedSession(self.streams[key][1:])  # after tok 1
        return _ScriptedSession([])


def test_mixed_acceptance_one_batch(served):
    cfg, model, params, dec = served("glm4-9b")
    rng = np.random.default_rng(5)
    p_full = rng.integers(0, cfg.vocab_size, 7).astype(np.int32)
    p_none = rng.integers(0, cfg.vocab_size, 9).astype(np.int32)
    p_full[0], p_none[0] = 1, 2         # drafter keys
    ref_full = _single_stream(model, params, dec, p_full, 12)
    ref_none = _single_stream(model, params, dec, p_none, 12)
    drafter = _ScriptedDrafter({1: ref_full})
    engine = ServeEngine(model, params, max_batch=2, max_seq=MAX_SEQ,
                         spec_k=4, drafter=drafter)
    done = engine.serve([Request(0, p_full, max_new_tokens=12),
                         Request(1, p_none, max_new_tokens=12)])
    outs = {r.rid: list(r.output) for r in done}
    assert outs[0] == ref_full
    assert outs[1] == ref_none
    # the scripted slot advanced k+1 per step, the other 1 per step: the
    # perfectly-drafted request must finish in far fewer steps
    ev = {(kind, rid): step for kind, rid, _, step in engine.events}
    assert ev[("retire", 0)] < ev[("retire", 1)]
    assert engine.metrics["draft_accepted"] > 0
    assert engine.metrics["tokens_per_step"] > 1.0


# ---------------------------------------------------------------------------
# Drafter unit tests
# ---------------------------------------------------------------------------

def test_ngram_drafter_run_and_cycle():
    d = NGramDrafter()
    # token run: proposes through the whole window, not one token
    assert d.draft([1, 2, 3, 7, 7, 7, 7], 4) == [7, 7, 7, 7]
    # period-2 cycle continues in phase
    assert d.draft([8, 5, 9, 5, 9, 5], 4) == [9, 5, 9, 5]
    # prompt lookup: the continuation of the matched prefix
    assert d.draft([10, 11, 12, 13, 20, 10, 11, 12], 3) == [13, 20, 10]
    # no repetition -> nothing proposed (never a wild guess)
    assert d.draft([1, 2, 3, 4, 5, 6], 4) == []
    # never more than k
    assert len(d.draft([7] * 30, 3)) == 3


def test_ngram_session_rollback_and_extend():
    d = NGramDrafter()
    s = d.begin([1, 2, 3, 7, 7, 7])
    first = s.draft(4)
    # drafting is speculative: the internal index rolls back, so a repeat
    # draft from the same state is identical
    assert s.draft(4) == first == [7, 7, 7, 7]
    # committing tokens shifts proposals like a fresh session would
    s.extend([7, 9])
    fresh = d.begin([1, 2, 3, 7, 7, 7, 7, 9])
    assert s.draft(4) == fresh.draft(4)


def test_ngram_drafter_validation():
    with pytest.raises(ValueError):
        NGramDrafter(max_ngram=2, min_ngram=3)
    with pytest.raises(ValueError):
        NGramDrafter(min_ngram=0)


# ---------------------------------------------------------------------------
# Engine metrics, validation, sampling fallback
# ---------------------------------------------------------------------------

def test_acceptance_metrics_and_conservation(served):
    cfg, model, params, dec = served("glm4-9b")
    engine = ServeEngine(model, params, max_batch=2, max_seq=MAX_SEQ,
                         spec_k=4)
    # motif prompts so some drafts actually land
    rng = np.random.default_rng(2)
    reqs = []
    for i, m in enumerate([10, 14, 8, 12]):
        motif = rng.integers(0, cfg.vocab_size, 3)
        prompt = np.tile(motif, 6)[:14].astype(np.int32)
        reqs.append(Request(i, prompt, max_new_tokens=m))
    done = engine.serve(reqs)
    assert len(done) == len(reqs)
    m = engine.metrics
    # motif prompts draft from the first step; the last step of a request
    # (budget 1 left) may fall back to the plain program
    assert 0 < m["spec_steps"] <= m["decode_steps"]
    assert 0.0 <= m["spec_acceptance"] <= 1.0
    assert m["draft_accepted"] <= m["draft_tokens"]
    assert m["tokens_per_step"] >= 1.0
    # conservation: decode tokens + one prefill token per request
    assert m["decode_tokens"] + len(reqs) == sum(r.max_new_tokens
                                                 for r in reqs)
    for r in done:
        assert len(r.output) == r.max_new_tokens


def test_spec_validation(served):
    cfg, model, params, _ = served("glm4-9b")
    with pytest.raises(ValueError):
        ServeEngine(model, params, spec_k=-1)
    # frame frontends have no draftable vocabulary: engine and model layer
    # both refuse
    frames_cfg = get_arch("llava-next-mistral-7b").reduced()
    frames_model = build_model(frames_cfg)
    assert frames_cfg.input_kind != "tokens"
    with pytest.raises(ValueError):
        ServeEngine(frames_model, None, spec_k=4)
    with pytest.raises(ValueError):
        frames_model.verify_step(None, None, {"frames": None})


# ---------------------------------------------------------------------------
# Fallback paths: ring-cache refusal, no-draft plain fallback, rejection
# sampling distribution
# ---------------------------------------------------------------------------

class _EmptySession(DraftSession):
    def extend(self, tokens):
        pass

    def draft(self, k):
        return []


class _EmptyDrafter(Drafter):
    """A drafter that never proposes — every step must take the plain
    single-token program, not a degenerate (B, k+1) verify."""

    def begin(self, context, slot=None, rid=None):
        return _EmptySession()


RING_SEQ = 131072   # hymba reduced: sliding_window=32 -> 32-slot ring


def test_ring_cache_spec_greedy_bitexact(served):
    """Long-context sliding-window decode stores a ring K/V cache whose
    seq axis is shorter than max_seq.  Ring verify wraps candidate
    writes and restores rejected wrapped columns on commit, so greedy
    spec outputs must stay bit-identical to plain ring decode — at and
    well past the window boundary (prompt + output > window means every
    late step verifies against a fully wrapped ring)."""
    cfg, model, params, _ = served("hymba-1.5b")
    assert cfg.sliding_window and cfg.supports_long_context
    window = cfg.sliding_window
    # outputs cross the eviction boundary: 20 + 30 tokens > 32 window
    reqs = lambda: _mixed_requests(cfg, lens=[20, 7, 26],
                                   max_news=[30, 40, 18], seed=6)
    plain = ServeEngine(model, params, ServeConfig(max_batch=2,
                                                   max_seq=RING_SEQ))
    ref = {r.rid: list(r.output) for r in plain.serve(reqs())}
    spec = ServeEngine(model, params, ServeConfig(max_batch=2,
                                                  max_seq=RING_SEQ,
                                                  spec_k=4))
    st = spec._init_state()
    assert st.cache_k.shape[2] == window     # really a ring allocation
    done = spec.serve(reqs())
    for r in done:
        assert list(r.output) == ref[r.rid], r.rid
    assert max(len(r.prompt) + len(r.output) for r in done) > window
    # speculation engaged on the ring (motif-free prompts still draft
    # occasionally; conservation is the hard check above)
    assert spec.metrics["decode_steps"] > 0


def test_ring_cache_spec_window_guard(served):
    """The one remaining ring constraint: a k+1 verify window wider than
    the ring would evict columns the same verify still reads — refused
    up front (abstract shape check, no 128k allocation)."""
    cfg, model, params, _ = served("hymba-1.5b")
    with pytest.raises(ValueError, match="verify window"):
        ServeEngine(model, params, ServeConfig(
            max_batch=2, max_seq=RING_SEQ, spec_k=cfg.sliding_window))
    # at the boundary (k+1 == window) and below, construction succeeds
    ServeEngine(model, params, ServeConfig(
        max_batch=2, max_seq=RING_SEQ, spec_k=cfg.sliding_window - 1))


def test_no_draft_fallback_zero_verify_dispatches(served):
    """With a drafter that never proposes, the engine must ride the plain
    decode program every step: zero verify/commit dispatches, outputs
    still bit-exact."""
    cfg, model, params, dec = served("glm4-9b")
    engine = ServeEngine(model, params, max_batch=2, max_seq=MAX_SEQ,
                         spec_k=4, drafter=_EmptyDrafter())
    reqs = _mixed_requests(cfg, lens=[5, 9], max_news=[6, 8], seed=4)
    done = engine.serve(reqs)
    assert engine.trace_counts["verify"] == 0
    assert engine.trace_counts["commit"] == 0
    assert engine.trace_counts["decode"] == 1
    assert engine.metrics["draft_tokens"] == 0
    for r in done:
        ref = _single_stream(model, params, dec, r.prompt, r.max_new_tokens)
        assert list(r.output) == ref


# ---------------------------------------------------------------------------
# Draft-model drafter (tiered) + adaptive per-slot spec_k
# ---------------------------------------------------------------------------

def test_draft_model_drafter_greedy_bitexact(served):
    """The batched draft-model drafter with the *target* as its own draft
    model: drafts reproduce the greedy chain, so acceptance is ~total and
    outputs stay bit-identical to plain decode while advancing k+1 per
    step.  model-tier dispatches dominate (the model always has signal
    about itself)."""
    cfg, model, params, dec = served("glm4-9b")
    drafter = DraftModelDrafter(model, params, max_batch=4,
                                max_seq=MAX_SEQ, min_conf=0.0)
    engine = ServeEngine(model, params, ServeConfig(
        max_batch=4, max_seq=MAX_SEQ, spec_k=4, drafter=drafter))
    reqs = _mixed_requests(cfg, lens=[5, 11, 16, 3, 24, 8],
                           max_news=[12, 9, 6, 12, 8, 14], seed=7)
    done = engine.serve(reqs)
    assert len(done) == len(reqs)
    for r in done:
        ref = _single_stream(model, params, dec, r.prompt,
                             r.max_new_tokens)
        assert list(r.output) == ref, r.rid
    m = engine.metrics
    assert m["model_drafts"] > 0
    assert m["spec_acceptance"] > 0.9          # self-drafting: ~all accept
    assert m["tokens_per_step"] > 2.0
    # batched drafting holds the engine's trace discipline: one draft
    # decode trace total, regardless of slot churn
    assert drafter.trace_counts["draft_decode"] == 1


def test_draft_model_tiered_fallback_dispatch(served):
    """A draft model gated to zero confidence (min_conf > 1) must never
    place model-tier drafts: every drafting slot-step tiers down to the
    n-gram fallback, and outputs stay bit-exact."""
    cfg, model, params, dec = served("glm4-9b")
    drafter = DraftModelDrafter(model, params, max_batch=2,
                                max_seq=MAX_SEQ, min_conf=1.1)
    engine = ServeEngine(model, params, ServeConfig(
        max_batch=2, max_seq=MAX_SEQ, spec_k=4, drafter=drafter))
    rng = np.random.default_rng(2)
    motif = rng.integers(0, cfg.vocab_size, 3)
    prompt = np.tile(motif, 6)[:14].astype(np.int32)   # ngram-draftable
    done = engine.serve([Request(0, prompt, max_new_tokens=10)])
    assert engine.metrics["model_drafts"] == 0
    assert engine.metrics["fallback_drafts"] > 0
    ref = _single_stream(model, params, dec, prompt, 10)
    assert list(done[0].output) == ref


def test_drafter_factory(served):
    cfg, model, params, _ = served("glm4-9b")
    assert isinstance(make_drafter("ngram"), NGramDrafter)
    d = make_drafter("draft_model", target=model, max_batch=2,
                     max_seq=MAX_SEQ)
    assert isinstance(d, DraftModelDrafter)
    assert d.model.cfg.vocab_size == cfg.vocab_size
    assert d.model.cfg.n_layers < cfg.n_layers or d.model.cfg.d_model \
        <= cfg.d_model
    with pytest.raises(ValueError):
        make_drafter("nope")
    with pytest.raises(ValueError):
        make_drafter("draft_model")            # needs model= or target=
    # the derived tiny arch keeps the target's token space, dense family
    da = draft_arch(cfg)
    assert (da.family, da.vocab_size) == ("dense", cfg.vocab_size)
    # engines resolve factory names themselves (ServeConfig.drafter str)
    eng = ServeEngine(model, params, ServeConfig(
        max_batch=2, max_seq=MAX_SEQ, spec_k=2, drafter="ngram"))
    assert isinstance(eng.drafter, NGramDrafter)


class _WrongSession(DraftSession):
    """Proposes k tokens that (almost) never match the model."""

    def __init__(self, vocab):
        self.vocab = vocab
        self.t = 0

    def extend(self, tokens):
        self.t += len(tokens)

    def draft(self, k):
        return [(self.t * 7919 + j) % self.vocab for j in range(k)]


class _WrongDrafter(Drafter):
    def __init__(self, vocab):
        self.vocab = vocab

    def begin(self, context, slot=None, rid=None):
        return _WrongSession(self.vocab)


def test_adaptive_k_shrinks_to_zero_on_undraftable(served):
    """On an undraftable trace (a drafter whose proposals never land),
    the adaptive engine must walk every slot's budget to 0 and ride the
    plain program — measurably fewer verify dispatches than the fixed-k
    engine on the same trace, identical outputs."""
    cfg, model, params, dec = served("glm4-9b")
    trace = lambda: _mixed_requests(cfg, lens=[6, 9], max_news=[40, 40],
                                    seed=8)

    fixed = ServeEngine(model, params, ServeConfig(
        max_batch=2, max_seq=MAX_SEQ, spec_k=4,
        drafter=_WrongDrafter(cfg.vocab_size)))
    fixed_done = fixed.serve(trace())

    adapt = ServeEngine(model, params, ServeConfig(
        max_batch=2, max_seq=MAX_SEQ, spec_k=4, spec_adaptive=True,
        drafter=_WrongDrafter(cfg.vocab_size)))
    adapt_done = adapt.serve(trace())

    for r_f, r_a in zip(sorted(fixed_done, key=lambda r: r.rid),
                        sorted(adapt_done, key=lambda r: r.rid)):
        ref = _single_stream(model, params, dec, r_f.prompt,
                             r_f.max_new_tokens)
        assert list(r_f.output) == ref
        assert list(r_a.output) == ref
    # the fixed engine verifies every step; the adaptive one only until
    # the EWMA walks k to 0 (plus sparse probes)
    assert fixed.metrics["spec_steps"] > 2 * adapt.metrics["spec_steps"]
    assert 0 in adapt.metrics.spec_k_hist        # slots really hit k=0
    assert adapt.metrics.spec_k_hist[0] > 0


def test_adaptive_k_grows_to_max_on_draftable(served):
    """On a perfectly draftable trace, budgets must grow from spec_k to
    the spec_k_max ceiling (full acceptance pushes the EWMA up)."""
    cfg, model, params, dec = served("glm4-9b")
    rng = np.random.default_rng(5)
    p = rng.integers(0, cfg.vocab_size, 7).astype(np.int32)
    p[0] = 1
    ref = _single_stream(model, params, dec, p, 40)
    engine = ServeEngine(model, params, ServeConfig(
        max_batch=1, max_seq=MAX_SEQ, spec_k=1, spec_k_max=6,
        spec_adaptive=True, drafter=_ScriptedDrafter({1: ref})))
    done = engine.serve([Request(0, p, max_new_tokens=40)])
    assert list(done[0].output) == ref
    hist = engine.metrics.spec_k_hist
    assert max(hist) == 6, hist                  # ceiling reached
    assert engine.metrics["tokens_per_step"] > 2.0


def test_serve_metrics_mapping_surface(served):
    """ServeMetrics keeps the dict surface the benches index: get/in/
    [], extras for subclass counters, and a flat to_dict for JSON."""
    from repro.runtime.serve_loop import ServeMetrics
    m = ServeMetrics()
    m["decode_steps"] += 3
    assert m.decode_steps == 3 and m["decode_steps"] == 3
    assert "slot_occupancy" in m and "nope" not in m
    assert m.get("nope", 42) == 42
    m["async_prefills"] = 2                      # unknown key -> extras
    assert m.extras == {"async_prefills": 2} and m["async_prefills"] == 2
    m.spec_k_hist[4] = 9
    d = m.to_dict()
    assert d["decode_steps"] == 3 and d["async_prefills"] == 2
    assert d["spec_k_hist"] == {4: 9} and "extras" not in d
    import json
    json.dumps(d)                                # JSON-serializable


def test_paged_spec_greedy_bitexact_and_rollback_frees(served):
    """Spec decode on the paged backend: greedy outputs stay bit-exact to
    plain decode, and pages grown ahead of the frontier for rejected draft
    positions are returned to the pool (spec rollback frees blocks)."""
    from repro.configs import CacheSpec
    from repro.runtime.serve_loop import ServeConfig

    cfg, model, params, dec = served("glm4-9b")
    engine = ServeEngine(model, params, ServeConfig(
        max_batch=2, max_seq=MAX_SEQ, spec_k=4, prefix_cache=False,
        cache=CacheSpec(paged=True, page_size=8)))
    reqs = _mixed_requests(cfg, lens=[5, 14, 9], max_news=[12, 6, 10])
    done = engine.serve(reqs)
    assert len(done) == len(reqs)
    for r in done:
        ref = _single_stream(model, params, dec, r.prompt, r.max_new_tokens)
        assert list(r.output) == ref, r.rid
    assert engine.metrics["spec_steps"] >= 0
    engine.allocator.assert_balanced()
    assert engine.allocator.used_blocks == 0
    assert (engine._tables == engine.allocator.num_blocks).all()


def test_rejection_sampling_matches_plain_distribution(served):
    """The spec acceptance rule must leave the emitted-token marginal
    exactly the plain sampling distribution p: accept the (deterministic)
    draft with probability p[d], else sample the residual.  Empirical
    check on the first emitted token against ``_dist``."""
    import types

    cfg, model, params, _ = served("glm4-9b")
    engine = ServeEngine(model, params, max_batch=2, max_seq=MAX_SEQ,
                         greedy=False, spec_k=4)
    req = Request(0, np.zeros(1, np.int32), max_new_tokens=1)
    req.temperature = 0.9
    req.top_k = 6
    rng = np.random.default_rng(11)
    slot = types.SimpleNamespace(req=req, rng=rng)
    v = 8
    rows = np.asarray(np.random.default_rng(0).normal(0, 1.5, (2, v)),
                      np.float32)
    p = engine._dist(slot, rows[0])
    draft = int(np.argsort(p)[-2])          # a plausible but not top draft
    n = 4000
    counts = np.zeros(v)
    for _ in range(n):
        out = engine._accept_sampled(slot, rows, [draft], cap=1)
        counts[out[0]] += 1
    tvd = 0.5 * np.abs(counts / n - p).sum()
    assert tvd < 0.05, (tvd, counts / n, p)


def test_sampling_rejection_fallback_deterministic(served):
    """Temperature slots take the two-phase rejection-sampling path:
    seeded runs reproduce, and temp-0 slots in the same batch stay
    bit-exact to the oracle."""
    cfg, model, params, dec = served("glm4-9b")
    outs = []
    for _ in range(2):
        engine = ServeEngine(model, params, max_batch=2, max_seq=MAX_SEQ,
                             greedy=False, spec_k=4)
        reqs = _mixed_requests(cfg, lens=[6, 8], max_news=[7, 7], seed=3)
        reqs[0].temperature = 1.0
        reqs[0].top_k = 16
        reqs[0].seed = 7
        done = engine.serve(reqs)
        outs.append({r.rid: list(r.output) for r in done})
        for r in done:
            assert all(0 <= t < cfg.vocab_size for t in r.output)
        # the two-phase path traces verify and commit as a pair (neither
        # if every step fell back to the plain program)
        assert (engine.trace_counts["verify"]
                == engine.trace_counts["commit"] <= 1)
    assert outs[0] == outs[1]
    # the temp-0 request rode the sampling batch but stays greedy-exact
    ref = _single_stream(model, params, dec, reqs[1].prompt, 7)
    assert outs[0][1] == ref
