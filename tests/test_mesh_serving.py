"""Sharded serving mesh: bit-identity, routing, prefill/decode split.

Two layers, matching how the mesh is exercisable on CPU:

  * **in-process** — the routing policy (a pure function), config
    validation, and the prefill-worker overlap contract, all on a
    1-device mesh (``MeshServeEngine(num_shards=1)`` is a legal
    degenerate mesh, so these run inside plain tier-1 too);
  * **subprocess with 8 fake devices** (``run_py`` from
    ``test_distributed.py``, ``--xla_force_host_platform_device_count``)
    — the sharded-vs-single-device bit-equality matrix across
    dense/ssm/hybrid × fp32/int8 × dense/paged, shard-aware admission
    routing under imbalance, the cross-shard token collective, and a
    snapshot taken on the mesh restoring into a *single-device* engine
    (the PR 8 chaos seam, across the mesh boundary).
"""
from __future__ import annotations

import textwrap
import threading
import time

import numpy as np
import pytest

import jax

from repro.configs import get_arch
from repro.models.model_zoo import build_model
from repro.runtime.mesh_serve import MeshServeEngine, route_free_slots
from repro.runtime.serve_loop import Request, ServeConfig, ServeEngine

from test_distributed import run_py


# ---------------------------------------------------------------------------
# Routing policy (pure function — no mesh, no engine)
# ---------------------------------------------------------------------------

class TestRouting:
    def test_empty_engine_is_index_order(self):
        assert route_free_slots([False] * 8, set(), 4) == list(range(8))

    def test_least_loaded_shard_first(self):
        # shard loads (2 slots each): s0=1, s1=0, s2=2, s3=0
        live = [True, False, False, False, True, True, False, False]
        free = route_free_slots(live, set(), 4)
        assert free == [2, 3, 6, 7, 1]

    def test_reserved_counts_as_load_and_is_excluded(self):
        live = [False] * 8
        free = route_free_slots(live, {0, 1}, 4)    # shard 0 fully pledged
        assert 0 not in free and 1 not in free
        assert free == [2, 3, 4, 5, 6, 7]

    def test_refill_stays_shard_local(self):
        # all shards equally loaded (1/2 each): a slot freed in shard 2
        # refills shard 0 first only if strictly less loaded — here loads
        # are equal, so index order keeps the freed slot in its shard
        # rotation rather than migrating ahead of it
        live = [True, False, True, False, False, True, True, False]
        free = route_free_slots(live, set(), 4)
        # every shard has load 1; ties break by slot index
        assert free == [1, 3, 4, 7]

    def test_indivisible_raises(self):
        with pytest.raises(ValueError):
            route_free_slots([False] * 6, set(), 4)


# ---------------------------------------------------------------------------
# Config / construction validation
# ---------------------------------------------------------------------------

class TestValidation:
    def test_max_batch_must_divide_shards(self):
        with pytest.raises(ValueError):
            ServeConfig(max_batch=6, num_shards=4)

    def test_num_shards_positive(self):
        with pytest.raises(ValueError):
            ServeConfig(num_shards=0)

    def test_prefill_workers_nonnegative(self):
        with pytest.raises(ValueError):
            ServeConfig(prefill_workers=-1)

    def test_more_shards_than_devices_raises(self):
        cfg = get_arch("glm4-9b").reduced()
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        n = len(jax.devices())
        with pytest.raises(ValueError, match="devices"):
            MeshServeEngine(model, params, ServeConfig(
                max_batch=8 * n, num_shards=8 * n))


# ---------------------------------------------------------------------------
# Prefill/decode split (1-device mesh: runs inside tier-1)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def glm():
    cfg = get_arch("glm4-9b").reduced()
    model = build_model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


def _mk_requests(cfg, lens, max_news, arrivals=None, seed=0):
    rng = np.random.default_rng(seed)
    arrivals = arrivals or [0.0] * len(lens)
    return [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, n)
                    .astype(np.int32), max_new_tokens=m, arrival_s=t)
            for i, (n, m, t) in enumerate(zip(lens, max_news, arrivals))]


def _outputs(done):
    return {r.rid: list(np.asarray(r.output)) for r in done}


class TestPrefillSplit:
    def test_single_shard_split_bit_identical(self, glm):
        """prefill_workers changes *when* prefill runs, never *what* it
        computes: async outputs match the inline single-device engine."""
        cfg, model, params = glm
        reqs = lambda: _mk_requests(cfg, (5, 21, 9, 13, 3, 17),
                                    (8, 4, 6, 10, 5, 7))
        ref = _outputs(ServeEngine(model, params, ServeConfig(
            max_batch=4, max_seq=64)).serve(reqs()))
        eng = MeshServeEngine(model, params, ServeConfig(
            max_batch=4, max_seq=64, num_shards=1, prefill_workers=2))
        got = _outputs(eng.serve(reqs()))
        assert got == ref
        assert eng.metrics["async_prefills"] == 6

    def test_decode_does_not_block_on_long_prompt(self, glm):
        """The split's whole point: with a slow prefill in flight, decode
        steps keep landing between the prefill submit and its admit."""
        cfg, model, params = glm
        eng = MeshServeEngine(model, params, ServeConfig(
            max_batch=2, max_seq=64, num_shards=1, prefill_workers=1))
        # make every prefill visibly slow *without* touching its result
        inner = eng._prefill
        def slow_prefill(p, inputs, lengths):
            out = jax.block_until_ready(inner(p, inputs, lengths))
            time.sleep(0.05)
            return out
        eng._prefill = slow_prefill
        # rid 0 decodes from t=0; rid 1's prompt arrives mid-decode
        reqs = _mk_requests(cfg, (5, 30), (40, 4), arrivals=(0.0, 0.02))
        done = eng.serve(reqs)
        ev = {(kind, rid): step for kind, rid, _, step in eng.events}
        submitted = ev[("prefill", 1)]
        admitted = ev[("admit", 1)]
        # decode advanced while the worker held rid 1's prefill
        assert admitted > submitted, (submitted, admitted)
        assert {r.rid for r in done} == {0, 1}
        assert all(len(r.output) == r.max_new_tokens for r in done)

    def test_drain_before_snapshot(self, glm, tmp_path):
        """snapshot() lands in-flight prefills first — no request can
        vanish into the admitted-but-unlanded window."""
        cfg, model, params = glm
        eng = MeshServeEngine(model, params, ServeConfig(
            max_batch=2, max_seq=64, num_shards=1, prefill_workers=1,
            snapshot_dir=str(tmp_path)))
        inner = eng._prefill
        def slow_prefill(p, inputs, lengths):
            time.sleep(0.03)
            return inner(p, inputs, lengths)
        eng._prefill = slow_prefill

        barrier = threading.Event()
        orig_poll = eng._poll_admissions
        def poll_then_snap(done):
            orig_poll(done)
            if eng._admissions_inflight() and not barrier.is_set():
                barrier.set()
                eng.snapshot()          # taken while a prefill is in flight
                assert not eng._admissions_inflight()
        eng._poll_admissions = poll_then_snap

        done = eng.serve(_mk_requests(cfg, (5, 9), (6, 4)))
        assert barrier.is_set(), "no in-flight window was ever observed"
        assert {r.rid for r in done} == {0, 1}
        assert all(len(r.output) == r.max_new_tokens for r in done)

    def test_paged_mode_serves_inline(self, glm):
        from repro.configs.base import CacheSpec
        cfg, model, params = glm
        eng = MeshServeEngine(model, params, ServeConfig(
            max_batch=2, max_seq=64, num_shards=1, prefill_workers=2,
            cache=CacheSpec(paged=True, page_size=8)))
        done = eng.serve(_mk_requests(cfg, (5, 9), (4, 4)))
        assert eng.metrics["async_prefills"] == 0     # documented no-op
        assert all(len(r.output) == r.max_new_tokens for r in done)


# ---------------------------------------------------------------------------
# 8 fake devices (subprocess)
# ---------------------------------------------------------------------------

_MESH_PRELUDE = textwrap.dedent("""
    import numpy as np, jax
    from repro.configs import get_arch
    from repro.configs.base import CacheSpec
    from repro.models.model_zoo import build_model
    from repro.runtime.serve_loop import Request, ServeConfig, ServeEngine
    from repro.runtime.mesh_serve import MeshServeEngine

    def requests(cfg, lens, max_news, seed=0):
        rng = np.random.default_rng(seed)
        return [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, n)
                        .astype(np.int32), max_new_tokens=m)
                for i, (n, m) in enumerate(zip(lens, max_news))]

    def outputs(done):
        return {r.rid: list(map(int, np.asarray(r.output))) for r in done}
""")

LENS = (5, 21, 9, 13, 3, 17, 7, 11, 4, 26)
NEWS = (8, 4, 6, 10, 5, 7, 3, 6, 9, 4)


class TestShardedEightDevices:
    def test_bit_equality_matrix(self):
        """Sharded (4 shards, async prefill) vs single-device outputs
        across dense/ssm/hybrid × fp32/int8 × dense/paged; one decode
        trace per engine (bucket discipline survives SPMD)."""
        out = run_py(_MESH_PRELUDE + textwrap.dedent(f"""
            MATRIX = [
                ("glm4-9b", None),
                ("rwkv6-3b", None),
                ("hymba-1.5b", None),
                ("glm4-9b", CacheSpec(dtype="int8")),
                ("glm4-9b", CacheSpec(paged=True, page_size=8)),
                ("glm4-9b", CacheSpec(dtype="int8", paged=True,
                                      page_size=8)),
            ]
            for arch, cache in MATRIX:
                cfg = get_arch(arch).reduced()
                model = build_model(cfg)
                params = model.init(jax.random.PRNGKey(0))
                ref = outputs(ServeEngine(model, params, ServeConfig(
                    max_batch=8, max_seq=64, cache=cache))
                    .serve(requests(cfg, {LENS}, {NEWS})))
                eng = MeshServeEngine(model, params, ServeConfig(
                    max_batch=8, max_seq=64, cache=cache, num_shards=4,
                    prefill_workers=2))
                got = outputs(eng.serve(requests(cfg, {LENS}, {NEWS})))
                assert got == ref, (arch, str(cache))
                assert eng.trace_counts["decode"] == 1, arch
                # the state really is distributed: some populated leaf
                # carries the mesh's data axis in its sharding
                sharded = [n for n in eng._state._fields
                           if getattr(eng._state, n) is not None
                           and "data" in str(getattr(
                               eng._state, n).sharding)]
                assert sharded, arch
                print(arch, str(cache), "ok")
            print("MATRIX_OK")
        """), timeout=560)
        assert "MATRIX_OK" in out

    def test_routing_imbalance_and_shard_telemetry(self):
        """Admissions spread over every shard; under an induced imbalance
        the next admission lands on the least-loaded shard; the
        cross-shard token collective agrees with host accounting."""
        out = run_py(_MESH_PRELUDE + textwrap.dedent("""
            cfg = get_arch("glm4-9b").reduced()
            model = build_model(cfg)
            params = model.init(jax.random.PRNGKey(0))
            eng = MeshServeEngine(model, params, ServeConfig(
                max_batch=8, max_seq=64, num_shards=4))
            # 8 simultaneous admissions fill all shards evenly
            done = eng.serve(requests(cfg, (5,) * 8, (4,) * 8))
            admits = [slot for kind, rid, slot, step in eng.events
                      if kind == "admit"]
            shards = {eng.shard_of(s) for s in admits}
            assert shards == {0, 1, 2, 3}, shards

            # induced imbalance: occupy shards 0+1 by hand, then admit
            live = [0, 1, 2, 3]
            from repro.runtime.serve_loop import _Slot, Request as Rq
            for i in live:
                eng._slots[i] = _Slot(req=Rq(100 + i, np.zeros(1, np.int32)),
                                      next_token=1, produced=0, tokens=[],
                                      rng=None, pos=3)
            free = eng._free_slots()
            assert eng.shard_of(free[0]) in (2, 3), free

            # collective telemetry == a host gather of the same rows
            # (device pos is authoritative; retired rows mask out)
            pos_host = np.asarray(eng._state.pos).astype(np.float64)
            exp = [float(pos_host[0:2].sum()), float(pos_host[2:4].sum()),
                   0.0, 0.0]
            per = eng.shard_live_tokens()
            assert per == exp, (per, exp)
            print("ROUTING_OK")
        """), timeout=420)
        assert "ROUTING_OK" in out

    def test_mesh_snapshot_restores_into_single_device_engine(self):
        """The PR 8 chaos seam across the mesh boundary: a snapshot taken
        on the sharded engine (mid-trace, injected kill) restores into a
        plain single-device engine and finishes bit-identically."""
        out = run_py(_MESH_PRELUDE + textwrap.dedent("""
            import tempfile
            from repro.parallel.fault_tolerance import WorkerKilled
            from repro.runtime.supervisor import ServeSupervisor

            cfg = get_arch("glm4-9b").reduced()
            model = build_model(cfg)
            params = model.init(jax.random.PRNGKey(0))
            lens, news = (5, 9, 13, 3, 7, 11), (10, 6, 12, 8, 5, 9)
            ref = outputs(ServeEngine(model, params, ServeConfig(
                max_batch=4, max_seq=64)).serve(requests(cfg, lens, news)))

            snap = tempfile.mkdtemp(prefix="mesh-snap-")
            def factory(i):
                if i == 0:
                    return MeshServeEngine(model, params, ServeConfig(
                        max_batch=8, max_seq=64, num_shards=4,
                        prefill_workers=2, snapshot_dir=snap,
                        snapshot_every=2, kill_at_step=4))
                return ServeEngine(model, params, ServeConfig(
                    max_batch=4, max_seq=64, snapshot_dir=snap))

            sup = ServeSupervisor(factory, max_restarts=2)
            got = outputs(sup.run(requests(cfg, lens, news)))
            assert len(sup.history) == 1
            assert got == ref
            print("CROSS_RESTORE_OK")
        """), timeout=420)
        assert "CROSS_RESTORE_OK" in out
