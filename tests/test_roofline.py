"""Roofline/cost-model tests: scan undercount verification, HLO collective
parsing, analytic-vs-HLO FLOP calibration on unrolled small configs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.analysis import roofline
from repro.analysis.costmodel import MeshSpec, param_count, step_costs
from repro.configs import ARCHS, LM_SHAPES, get_arch


def test_xla_cost_analysis_counts_scan_body_once():
    """The documented premise for using the analytic model (DESIGN.md §6)."""
    def f(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        out, _ = jax.lax.scan(body, x, w)
        return out

    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((8, 128, 128), jnp.float32)
    ca = compat.cost_analysis(jax.jit(f).lower(x, w).compile())
    one_layer = 2 * 64 * 128 * 128
    ratio = ca["flops"] / (8 * one_layer)
    assert 0.1 < ratio < 0.2  # ~1/8: body counted once


def test_hlo_collective_parser():
    hlo = """
HloModule m

%body (p: f32[8]) -> f32[8] {
  %ar = f32[1024]{0} all-reduce(%x), replica_groups={}
}

ENTRY %main () -> f32[4] {
  %ag = bf16[256,2]{1,0} all-gather(%y), dimensions={0}
  %tup = (f32[16]{0}, f32[16]{0}) all-to-all(%a, %b)
}
"""
    total, by_kind = roofline.parse_hlo_collectives(hlo, layer_trips=10)
    assert by_kind["all-reduce"] == 1024 * 4 * 10   # in body: x10
    assert by_kind["all-gather"] == 256 * 2 * 2     # entry: x1
    assert by_kind["all-to-all"] == 2 * 16 * 4
    assert total == sum(by_kind.values())


def test_analytic_flops_calibrated_against_hlo():
    """Unrolled (no layer scan) reduced dense model: analytic forward+
    backward FLOPs must match XLA cost_analysis within 2x (XLA counts some
    fusions differently, transcendentals, etc.)."""
    from repro.models.model_zoo import build_model
    cfg = get_arch("glm4-9b").reduced().scaled(
        n_layers=2, attn_impl="naive", remat=False, dtype="float32")
    model = build_model(cfg)
    params_abs = model.abstract_params()
    batch_abs = model.input_specs(4, 64, "train")

    def loss_grad(p, b):
        return jax.grad(lambda pp: model.loss(pp, b)[0])(p)

    ca = compat.cost_analysis(
        jax.jit(loss_grad).lower(params_abs, batch_abs).compile())
    hlo_flops = ca["flops"]

    import dataclasses
    shape = dataclasses.replace(LM_SHAPES["train_4k"], seq_len=64,
                                global_batch=4)
    cr = step_costs(cfg, shape, MeshSpec(data=1, model=1))
    # Note: scan undercount doesn't apply here only because layers still
    # scan... so compare per-layer-adjusted: the model scans 2 layers; HLO
    # counts 1 body. Adjust analytic to 1 layer + outside.
    # Simplest calibration: analytic must be within [0.3x, 3x] of
    # hlo_flops * n_layers-correction bound.
    lo, hi = hlo_flops * 0.5, hlo_flops * 2 * cfg.n_layers
    assert lo < cr.flops < hi, (hlo_flops, cr.flops)


def test_param_count_matches_spec_tree():
    from repro.models import spec as pspec
    from repro.models.model_zoo import build_model
    for arch in ("glm4-9b", "stablelm-12b", "qwen2.5-14b", "arctic-480b",
                 "rwkv6-3b", "hymba-1.5b"):
        cfg = get_arch(arch)
        model = build_model(cfg)
        analytic, _ = param_count(cfg)
        exact = model.n_params()
        assert abs(analytic - exact) / exact < 0.05, (arch, analytic, exact)


def test_known_param_scales():
    """Sanity anchors: the configs land near their nominal sizes."""
    from repro.models.model_zoo import build_model
    expect = {"glm4-9b": (8e9, 11e9), "qwen2.5-14b": (13e9, 16e9),
              "arctic-480b": (400e9, 520e9), "rwkv6-3b": (2.5e9, 4e9),
              "hymba-1.5b": (1.2e9, 2.2e9)}
    for arch, (lo, hi) in expect.items():
        n = build_model(get_arch(arch)).n_params()
        assert lo < n < hi, (arch, n)


def test_roofline_terms_positive_and_bottleneck_sane():
    mesh = MeshSpec(data=16, model=16)
    for arch in ARCHS:
        cfg = get_arch(arch)
        for shape in LM_SHAPES.values():
            if shape.name == "long_500k" and not cfg.supports_long_context:
                continue
            cr = step_costs(cfg, shape, mesh)
            assert cr.flops > 0 and cr.hbm_bytes > 0
            row = roofline.analyze(cfg, shape, mesh)
            assert row.bottleneck in ("compute", "memory", "collective")
            assert 0 < row.useful_ratio <= 1.5


def test_decode_is_memory_or_collective_bound():
    """Single-token decode must never be compute-bound — the classic
    bandwidth-bound regime the roofline should reproduce."""
    mesh = MeshSpec(data=16, model=16)
    cfg = get_arch("glm4-9b")
    row = roofline.analyze(cfg, LM_SHAPES["decode_32k"], mesh)
    assert row.bottleneck in ("memory", "collective")
    assert row.memory_s > row.compute_s


def test_moe_model_flops_use_active_params():
    cfg = get_arch("arctic-480b")
    total, active = param_count(cfg)
    assert active < 0.15 * total  # top-2 of 128 experts + dense residual
