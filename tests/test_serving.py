"""Scheduling invariants of the continuous-batching serve engine.

The contract under test (see ``runtime/serve_loop.py``):

  * per-request outputs are **bit-identical** to single-stream decoding —
    right-padded bucket prefill + per-slot decode changes nothing
  * retire-and-refill: a short request's slot is reused while a long one
    is still decoding (no gang drain)
  * bucketed shapes: batch-composition changes within a prompt bucket
    never retrace the jit'd prefill/decode callables (asserted via the
    engine's trace-count callbacks)
  * queue metrics (queue_wait_s, slot_occupancy) are exposed and sane
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models.model_zoo import build_model
from repro.runtime.serve_loop import (GangServeEngine, Request, ServeEngine,
                                      next_pow2)

MAX_SEQ = 64


@pytest.fixture(scope="module")
def served():
    """One model + params per family, shared across tests in this module."""
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = get_arch(arch).reduced()
            model = build_model(cfg)
            params = model.init(jax.random.PRNGKey(0))
            cache[arch] = (cfg, model, params)
        return cache[arch]

    return get


def _mixed_requests(cfg, lens, max_news, seed=0):
    rng = np.random.default_rng(seed)
    reqs = []
    for i, (n, m) in enumerate(zip(lens, max_news)):
        prompt = rng.integers(0, cfg.vocab_size, n).astype(np.int32)
        reqs.append(Request(i, prompt, max_new_tokens=m))
    return reqs


def _single_stream(model, params, prompt, max_new):
    """Greedy decode of one request through the plain (unbatched,
    unpadded) prefill/decode path — the engine's correctness oracle."""
    lg, st = model.prefill(
        params, {"tokens": jnp.asarray(prompt[None, :])},
        headroom=MAX_SEQ - len(prompt))
    cur = int(jnp.argmax(lg.reshape(1, -1), axis=-1)[0])
    seq = [cur]
    for _ in range(max_new - 1):
        lg, st = model.decode_step(
            params, st, {"tokens": jnp.asarray([[cur]], jnp.int32)})
        cur = int(jnp.argmax(lg.reshape(1, -1), axis=-1)[0])
        seq.append(cur)
    return seq


@pytest.mark.parametrize("arch", ["glm4-9b", "rwkv6-3b", "hymba-1.5b"])
def test_output_equality_with_single_stream(served, arch):
    """Continuous batching must not change a single request's tokens —
    across attention (KV cache), rwkv (recurrent) and hybrid state."""
    cfg, model, params = served(arch)
    engine = ServeEngine(model, params, max_batch=4, max_seq=MAX_SEQ)
    reqs = _mixed_requests(cfg, lens=[5, 11, 16, 3, 24, 8],
                           max_news=[4, 9, 2, 12, 1, 6])
    done = engine.serve(reqs)
    assert len(done) == len(reqs)
    for r in done:
        ref = _single_stream(model, params, r.prompt, r.max_new_tokens)
        assert list(r.output) == ref, (arch, r.rid)


def test_refill_on_retire(served):
    """A short request's slot is reused while a long one still decodes."""
    cfg, model, params = served("glm4-9b")
    engine = ServeEngine(model, params, max_batch=2, max_seq=MAX_SEQ)
    reqs = _mixed_requests(cfg, lens=[6, 7, 5], max_news=[2, 24, 2])
    done = engine.serve(reqs)
    assert len(done) == 3
    ev = {(kind, rid): (slot, step)
          for kind, rid, slot, step in engine.events}
    # r2 was admitted into the slot r0 freed...
    assert ev[("admit", 2)][0] == ev[("retire", 0)][0]
    # ...before the long request r1 retired (mid-decode refill)
    assert ev[("admit", 2)][1] < ev[("retire", 1)][1]
    long_req = next(r for r in done if r.rid == 1)
    short_req = next(r for r in done if r.rid == 2)
    assert short_req.done_at < long_req.done_at


def test_bucket_reuse_no_retrace(served):
    """Within one prompt bucket, batch-composition changes must not
    retrace prefill/decode/insert; a new bucket adds one prefill trace."""
    cfg, model, params = served("glm4-9b")
    engine = ServeEngine(model, params, max_batch=4, max_seq=MAX_SEQ,
                         min_bucket=16)
    engine.serve(_mixed_requests(cfg, lens=[5, 9], max_news=[3, 5]))
    first = dict(engine.trace_counts)
    assert first["prefill"] == 1 and first["decode"] == 1

    # different group size, lengths and budgets — same 16-token bucket
    engine.serve(_mixed_requests(cfg, lens=[3, 12, 7], max_news=[6, 2, 4],
                                 seed=1))
    assert dict(engine.trace_counts) == first, "retrace within a bucket"

    # a longer prompt crosses into the 32 bucket: exactly one new trace
    engine.serve(_mixed_requests(cfg, lens=[20], max_news=[2], seed=2))
    assert engine.trace_counts["prefill"] == first["prefill"] + 1
    assert engine.trace_counts["decode"] == first["decode"]


@pytest.mark.parametrize("arch", ["glm4-9b", "rwkv6-3b"])
def test_pad_correctness_mixed_lengths(served, arch):
    """Bucket-padded prefill with true lengths is bit-identical to the
    unpadded per-request prefill — logits and carried decode state."""
    cfg, model, params = served(arch)
    rng = np.random.default_rng(3)
    lens = [4, 10, 16, 7]
    bucket = 16
    toks = np.zeros((len(lens), bucket), np.int32)
    prompts = []
    for i, n in enumerate(lens):
        p = rng.integers(0, cfg.vocab_size, n).astype(np.int32)
        prompts.append(p)
        toks[i, :n] = p          # right-pad: real tokens first
    logits_b, st_b = model.prefill(
        params, {"tokens": jnp.asarray(toks)}, headroom=0,
        lengths=jnp.asarray(lens, jnp.int32))
    assert st_b.pos.shape == (len(lens),)
    np.testing.assert_array_equal(np.asarray(st_b.pos), lens)
    for i, p in enumerate(prompts):
        lg, st = model.prefill(params, {"tokens": jnp.asarray(p[None, :])},
                               headroom=0)
        np.testing.assert_array_equal(
            np.asarray(logits_b[i].astype(jnp.float32)).ravel(),
            np.asarray(lg[0].astype(jnp.float32)).ravel(),
            err_msg=f"{arch} row {i} (len {len(p)})")
        if cfg.family == "ssm":     # recurrent state must match exactly
            np.testing.assert_array_equal(
                np.asarray(st_b.wkv[:, i].astype(jnp.float32)),
                np.asarray(st.wkv[:, 0].astype(jnp.float32)))
            np.testing.assert_array_equal(
                np.asarray(st_b.x_prev[:, i].astype(jnp.float32)),
                np.asarray(st.x_prev[:, 0].astype(jnp.float32)))


def test_slot_update_scatter_and_sentinel(served):
    """slot_update inserts rows at slot indices and drops the sentinel."""
    cfg, model, params = served("glm4-9b")
    state = model.init_slot_state(4, MAX_SEQ)
    toks = np.ones((4, 16), np.int32)
    lengths = jnp.asarray([5, 5, 5, 5], jnp.int32)
    _, sub = model.prefill(params, {"tokens": jnp.asarray(toks)},
                           headroom=0, lengths=lengths)
    # rows 0,1 go to slots 2,0; rows 2,3 carry the drop sentinel (=4)
    state2 = model.slot_update(state, sub, jnp.asarray([2, 0, 4, 4]))
    assert state2.cache_k.shape[2] == MAX_SEQ   # bucket padded up
    np.testing.assert_array_equal(np.asarray(state2.pos), [5, 0, 5, 0])
    np.testing.assert_array_equal(
        np.asarray(state2.cache_k[:, 2, :16].astype(jnp.float32)),
        np.asarray(sub.cache_k[:, 0].astype(jnp.float32)))
    # untouched slots keep their (zero) state
    assert float(jnp.abs(state2.cache_k[:, 1].astype(jnp.float32)).sum()) == 0


def test_metrics_and_no_drops(served):
    """Queue metrics are exposed and every request completes in full."""
    cfg, model, params = served("glm4-9b")
    engine = ServeEngine(model, params, max_batch=2, max_seq=MAX_SEQ)
    reqs = _mixed_requests(cfg, lens=[5, 9, 3, 12, 6],
                           max_news=[2, 8, 3, 1, 5])
    done = engine.serve(reqs)
    assert len(done) == len(reqs)
    for r in done:
        assert len(r.output) == r.max_new_tokens
        assert r.admitted_at >= r.submitted_at
        assert r.done_at >= r.admitted_at
    m = engine.metrics
    assert m["queue_wait_s"] >= 0.0
    assert 0.0 < m["slot_occupancy"] <= 1.0
    assert m["decode_tokens"] + len(reqs) == sum(r.max_new_tokens
                                                 for r in reqs)
    # capacity violations and empty prompts raise instead of serving
    # garbage or silently dropping
    with pytest.raises(ValueError):
        engine.serve([Request(99, np.zeros(40, np.int32),
                              max_new_tokens=MAX_SEQ)])
    with pytest.raises(ValueError):
        engine.serve([Request(98, np.zeros(0, np.int32))])


def test_non_pow2_max_seq_buckets_safely(served):
    """Buckets stay pow-2 under a non-pow2 max_seq: the ssm chunked scan
    only accepts pow2-friendly lengths, so the cap must not emit e.g. 96;
    prompts beyond the largest bucket raise instead of crashing."""
    cfg, model, params = served("rwkv6-3b")
    engine = ServeEngine(model, params, max_batch=2, max_seq=96)
    with pytest.raises(ValueError):
        engine.serve([Request(0, np.ones(70, np.int32), max_new_tokens=4)])
    done = engine.serve(_mixed_requests(cfg, lens=[60], max_news=[3]))
    assert len(done) == 1 and len(done[0].output) == 3


def test_per_request_sampling_deterministic(served):
    """Per-request temperature sampling is seeded and reproducible."""
    cfg, model, params = served("glm4-9b")
    outs = []
    for _ in range(2):
        engine = ServeEngine(model, params, max_batch=2, max_seq=MAX_SEQ,
                             greedy=False)
        reqs = _mixed_requests(cfg, lens=[6, 8], max_news=[5, 5])
        for r in reqs:
            r.temperature = 1.0
            r.top_k = 16
            r.seed = 7
        done = engine.serve(reqs)
        outs.append({r.rid: list(r.output) for r in done})
        for r in done:
            assert all(0 <= t < cfg.vocab_size for t in r.output)
    assert outs[0] == outs[1]


def test_gang_engine_still_serves(served):
    """The lockstep baseline stays functional (benchmark comparability)."""
    cfg, model, params = served("glm4-9b")
    engine = GangServeEngine(model, params, max_batch=2)
    reqs = _mixed_requests(cfg, lens=[5, 9, 3], max_news=[2, 4, 3])
    done = engine.serve(reqs)
    assert len(done) == 3
    assert all(len(r.output) == r.max_new_tokens for r in done)


def test_paged_backend_matches_single_stream(served):
    """The paged block-pool backend is a pure layout change: per-request
    outputs stay bit-identical to the unbatched single-stream oracle, and
    every block returns to the free list once the trace drains (see
    tests/test_paged_cache.py for the prefix-cache contract)."""
    from repro.configs import CacheSpec
    from repro.runtime.serve_loop import ServeConfig

    cfg, model, params = served("glm4-9b")
    engine = ServeEngine(model, params, ServeConfig(
        max_batch=4, max_seq=MAX_SEQ, prefix_cache=False,
        cache=CacheSpec(paged=True, page_size=8)))
    reqs = _mixed_requests(cfg, lens=[5, 11, 16, 3, 24, 8],
                           max_news=[4, 9, 2, 12, 1, 6])
    done = engine.serve(reqs)
    assert len(done) == len(reqs)
    for r in done:
        ref = _single_stream(model, params, r.prompt, r.max_new_tokens)
        assert list(r.output) == ref, r.rid
    engine.allocator.assert_balanced()
    assert engine.allocator.used_blocks == 0
    assert (engine._tables == engine.allocator.num_blocks).all()


def test_next_pow2():
    assert [next_pow2(n) for n in (1, 2, 3, 8, 9, 31)] == [1, 2, 4, 8, 16, 32]
