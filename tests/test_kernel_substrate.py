"""Substrate + dispatch tests for kernels/common.py and repro.compat.

Deliberately hypothesis-free: this module must run even in minimal
environments where the property-test modules importorskip, so it carries
the smoke coverage for all five kernel families too.
"""
import importlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.compat as compat
import repro.kernels as K
from repro.kernels import common
from repro.kernels.cordic_act.ref import cordic_act_raw_ref
from repro.kernels.cordic_softmax.ref import cordic_softmax_raw_ref
from repro.kernels.flash_attention.ops import _exact_attention
from repro.kernels.wkv.ops import _exact_wkv
from repro.core import fixed_point as fxp


class TestBlockPicker:
    def test_largest_divisor_invariants(self):
        for n in range(1, 200):
            for cap in (1, 3, 7, 8, 100, 128, 512):
                d = common.largest_divisor(n, cap)
                assert 1 <= d <= min(cap, n) or (cap < 1 and d == 1)
                assert n % d == 0
                # maximality: nothing between d and cap divides n
                assert all(n % e for e in range(d + 1, min(cap, n) + 1))

    def test_pick_block_2d_divides(self):
        for shape in [(1, 1), (8, 8), (13, 77), (256, 300), (1000, 4096)]:
            br, bc = common.pick_block_2d("t.p2d", shape)
            assert shape[0] % br == 0 and shape[1] % bc == 0
            assert br <= 256 and bc <= 512

    def test_cache_round_trip(self):
        common.clear_block_cache()
        assert common.cached_block("t.cache", (64, 64), jnp.int32) is None
        blk = common.pick_block_2d("t.cache", (64, 64))
        assert common.cached_block("t.cache", (64, 64), jnp.int32) == blk
        # dtype and kernel name are part of the key
        assert common.cached_block("t.cache", (64, 64), jnp.float32) is None
        assert common.cached_block("other", (64, 64), jnp.int32) is None

    def test_autotune_overrides_picker(self):
        common.clear_block_cache()
        calls = []

        def run(blk):
            calls.append(blk)
            # pretend (8, 8) is fastest by sleeping on everything else
            if blk != (8, 8):
                import time
                time.sleep(0.01)
            return jnp.zeros(())

        best = common.autotune("t.tune", (64, 64), jnp.int32,
                               [(64, 64), (8, 8), (16, 16)], run, repeats=1)
        assert best == (8, 8)
        assert common.pick_block_2d("t.tune", (64, 64)) == (8, 8)

    def test_autotune_skips_failing_candidates(self):
        common.clear_block_cache()

        def run(blk):
            if blk == (4, 4):
                raise RuntimeError("vmem overflow")
            return jnp.zeros(())

        best = common.autotune("t.fail", (16, 16), jnp.int32,
                               [(4, 4), (2, 2)], run, repeats=1)
        assert best == (2, 2)

    def test_pick_block_matmul_cached(self):
        common.clear_block_cache()
        blk = common.pick_block_matmul("t.mm", 512, 512, 512)
        assert len(blk) == 3 and all(b >= 8 for b in blk)
        assert common.cached_block("t.mm", (512, 512, 512), jnp.int32) == blk


class TestRegistry:
    def test_all_five_families_registered(self):
        names = common.registered_kernels()
        for want in ("cordic_act", "cordic_mac", "cordic_softmax",
                     "flash_attention", "wkv"):
            assert want in names

    def test_spec_round_trip(self):
        spec = common.get_kernel("cordic_mac")
        assert spec.name == "cordic_mac"
        assert callable(spec.kernel) and callable(spec.ref)
        assert callable(spec.grad)

    def test_unknown_kernel_raises(self):
        with pytest.raises(KeyError, match="no kernel"):
            common.get_kernel("does_not_exist")

    def test_register_is_idempotent(self):
        before = common.get_kernel("wkv")
        importlib.reload(importlib.import_module("repro.kernels.wkv.ops"))
        after = common.get_kernel("wkv")
        assert after.name == before.name and callable(after.kernel)


class TestCompat:
    def test_shard_map_importable(self):
        from repro.compat import shard_map
        assert callable(shard_map)

    def test_prefers_stable_api_when_present(self, monkeypatch):
        sentinel = lambda *a, **k: None
        monkeypatch.setattr(jax, "shard_map", sentinel, raising=False)
        assert compat._resolve_shard_map() is sentinel

    def test_falls_back_to_experimental(self, monkeypatch):
        monkeypatch.delattr(jax, "shard_map", raising=False)
        from jax.experimental.shard_map import shard_map as exp_sm
        assert compat._resolve_shard_map() is exp_sm

    def test_check_vma_translated_for_old_api(self):
        seen = {}

        def old_sm(f, mesh=None, in_specs=None, out_specs=None,
                   check_rep=True):
            seen["check_rep"] = check_rep
            return f

        adapted = compat._adapt_shard_map(old_sm)
        adapted(lambda x: x, check_vma=False)
        assert seen["check_rep"] is False

    def test_check_vma_passthrough_for_new_api(self):
        seen = {}

        def new_sm(f, mesh=None, in_specs=None, out_specs=None,
                   check_vma=True):
            seen["check_vma"] = check_vma
            return f

        adapted = compat._adapt_shard_map(new_sm)
        assert adapted is new_sm

    def test_compiler_params_constructs(self):
        cp = common.compiler_params("parallel", "arbitrary")
        assert cp.dimension_semantics == ("parallel", "arbitrary")


class TestInterpretPolicy:
    def test_explicit_wins(self, monkeypatch):
        monkeypatch.setenv("REPRO_KERNEL_INTERPRET", "0")
        assert common.resolve_interpret(True) is True
        assert common.resolve_interpret(False) is False

    def test_env_override(self, monkeypatch):
        monkeypatch.setenv("REPRO_KERNEL_INTERPRET", "0")
        assert common.resolve_interpret(None) is False
        monkeypatch.setenv("REPRO_KERNEL_INTERPRET", "1")
        assert common.resolve_interpret(None) is True

    def test_default_interprets_off_tpu(self, monkeypatch):
        monkeypatch.delenv("REPRO_KERNEL_INTERPRET", raising=False)
        assert common.resolve_interpret(None) == (not common.on_tpu())


class TestSte:
    def test_forward_is_kernel_backward_is_exact(self):
        fwd = lambda x: jnp.round(x)          # non-differentiable forward
        f = common.ste(fwd, jnp.tanh)
        x = jnp.linspace(-2.0, 2.0, 9)
        np.testing.assert_array_equal(np.asarray(f(x)), np.asarray(fwd(x)))
        g = jax.grad(lambda v: f(v).sum())(x)
        np.testing.assert_allclose(np.asarray(g),
                                   np.asarray(1 - jnp.tanh(x) ** 2),
                                   rtol=1e-6)

    def test_multi_arg(self):
        f = common.ste(lambda a, b: jnp.round(a) @ jnp.round(b),
                       lambda a, b: a @ b)
        a = jnp.ones((3, 4)) * 1.3
        b = jnp.ones((4, 2)) * 0.7
        ga, gb = jax.grad(lambda a_, b_: f(a_, b_).sum(), argnums=(0, 1))(a, b)
        assert ga.shape == a.shape and gb.shape == b.shape


class TestFamilySmoke:
    """Numeric coverage for the dispatch path of every family, vs oracles."""

    def test_cordic_act_bit_exact_and_band(self, rng):
        fmt = fxp.FXP16
        x = jnp.array(rng.uniform(-3, 3, (16, 32)), jnp.float32)
        raw = fxp.quantize(x, fmt)
        spec = common.get_kernel("cordic_act")
        got = spec.kernel(raw, af="tanh", fmt=fmt, interpret=True)
        want = spec.ref(raw, af="tanh", fmt=fmt)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        out = K.cordic_act(x, "tanh")
        assert float(jnp.abs(out - jnp.tanh(x)).max()) < 0.05

    def test_cordic_act_ste_gradient(self, rng):
        x = jnp.array(rng.uniform(-2, 2, (8, 8)), jnp.float32)
        g = jax.grad(lambda v: K.cordic_act(v, "sigmoid").sum())(x)
        s = jax.nn.sigmoid(x)
        np.testing.assert_allclose(np.asarray(g), np.asarray(s * (1 - s)),
                                   rtol=1e-5)

    def test_cordic_softmax_bit_exact_and_normalised(self, rng):
        fmt = fxp.FXP16
        x = jnp.array(rng.normal(size=(8, 64)) * 2, jnp.float32)
        raw = fxp.quantize(x - x.max(-1, keepdims=True), fmt)
        spec = common.get_kernel("cordic_softmax")
        got = spec.kernel(raw, fmt=fmt, interpret=True)
        want = spec.ref(raw, fmt=fmt)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        s = K.cordic_softmax(x)
        assert float(jnp.abs(s.sum(-1) - 1.0).max()) < 0.05

    def test_cordic_matmul_close_and_grads(self, rng):
        x = jnp.array(rng.uniform(-1, 1, (24, 40)), jnp.float32)
        w = jnp.array(rng.uniform(-1, 1, (40, 16)), jnp.float32)
        out = K.cordic_matmul(x, w, n_stages=12)
        ref = x @ w
        scale = float(jnp.abs(ref).max()) + 1.0
        assert float(jnp.abs(out - ref).max()) / scale < 0.05
        gx, gw = jax.grad(lambda a, b: K.cordic_matmul(a, b).sum(),
                          argnums=(0, 1))(x, w)
        np.testing.assert_allclose(np.asarray(gx),
                                   np.asarray(jnp.ones((24, 16)) @ w.T),
                                   rtol=1e-5)
        assert gw.shape == w.shape

    def test_flash_attention_matches_ref(self, rng):
        q = jnp.array(rng.normal(size=(2, 16, 4, 8)), jnp.float32)
        k = jnp.array(rng.normal(size=(2, 16, 2, 8)), jnp.float32)
        v = jnp.array(rng.normal(size=(2, 16, 2, 8)), jnp.float32)
        out = K.flash_attention(q, k, v, block_q=8, block_k=8)
        ref = _exact_attention(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-5)
        g = jax.grad(lambda qq: K.flash_attention(
            qq, k, v, block_q=8, block_k=8).sum())(q)
        assert bool(jnp.isfinite(g).all())

    def test_wkv_matches_ref(self, rng):
        r = jnp.array(rng.normal(size=(2, 12, 2, 4)), jnp.float32)
        k = jnp.array(rng.normal(size=(2, 12, 2, 4)), jnp.float32)
        v = jnp.array(rng.normal(size=(2, 12, 2, 4)), jnp.float32)
        w = jnp.array(rng.uniform(0.1, 0.9, (2, 12, 2, 4)), jnp.float32)
        u = jnp.array(rng.normal(size=(2, 4)), jnp.float32)
        out = K.wkv(r, k, v, w, u, block_t=4)
        ref = _exact_wkv(r, k, v, w, u)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-4)
        g = jax.grad(lambda uu: K.wkv(r, k, v, w, uu).sum())(u)
        assert bool(jnp.isfinite(g).all())

    def test_autotuned_block_reaches_the_kernel(self, rng):
        """A cache entry installed after a first call must change the block
        the next call runs with (the pick happens outside the jit trace)."""
        from repro.kernels.cordic_act import ops as act_ops
        common.clear_block_cache()
        x = jnp.array(rng.uniform(-2, 2, (8, 16)), jnp.float32)
        out_default = K.cordic_act(x, "tanh")
        n_traces = act_ops._fwd._cache_size()
        common.set_block("cordic_act.tanh", (8, 16), jnp.int32, (2, 4))
        out_tuned = K.cordic_act(x, "tanh")
        assert act_ops._fwd._cache_size() > n_traces  # new block => retrace
        np.testing.assert_array_equal(np.asarray(out_default),
                                      np.asarray(out_tuned))
        common.clear_block_cache()

    def test_odd_shapes_dispatch(self, rng):
        """The divisor-aware picker must handle prime-ish shapes."""
        x = jnp.array(rng.uniform(-2, 2, (7, 13)), jnp.float32)
        out = K.cordic_act(x, "tanh")
        assert out.shape == (7, 13)
        s = K.cordic_softmax(jnp.array(rng.normal(size=(5, 11)), jnp.float32))
        assert float(jnp.abs(s.sum(-1) - 1.0).max()) < 0.05
