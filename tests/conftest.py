import os

# Keep tests on the single real CPU device (the 512-device override is ONLY
# for launch/dryrun.py).  Determinism + no x64 surprises.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# Hermetic block picks: a tuned table from a local `python -m
# benchmarks.tune` run (XDG default or an exported REPRO_TUNE_CACHE) must
# not leak into test assertions, so overwrite — don't setdefault — with a
# never-existing per-session path outside the source tree.  Tests of the
# disk layer monkeypatch REPRO_TUNE_CACHE themselves.
import tempfile  # noqa: E402

os.environ["REPRO_TUNE_CACHE"] = os.path.join(
    tempfile.mkdtemp(prefix="repro-test-tuned-"), "absent.json")

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture
def rng():
    return np.random.default_rng(0)
