import os

# Keep tests on the single real CPU device (the 512-device override is ONLY
# for launch/dryrun.py).  Determinism + no x64 surprises.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture
def rng():
    return np.random.default_rng(0)
