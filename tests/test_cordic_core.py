"""Unit + property tests for the CORDIC core (fixed_point, cordic modes)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests; see requirements-dev.txt
from hypothesis import given, settings, strategies as st

from repro.core import cordic, fixed_point as fxp

FMTS = [fxp.FXP8, fxp.FXP16, fxp.FXP32]


class TestFixedPoint:
    @pytest.mark.parametrize("fmt", FMTS)
    def test_roundtrip_error_bounded(self, fmt, rng):
        x = rng.uniform(fmt.min_value, fmt.max_value, (256,)).astype(np.float32)
        rt = fxp.roundtrip(jnp.array(x), fmt)
        assert float(jnp.abs(rt - x).max()) <= fmt.resolution / 2 + 1e-7

    def test_saturation(self):
        fmt = fxp.FXP8
        assert int(fxp.quantize(1e9, fmt)) == fmt.raw_max
        assert int(fxp.quantize(-1e9, fmt)) == fmt.raw_min

    @given(st.floats(-100, 100))
    @settings(max_examples=50, deadline=None)
    def test_quantize_monotone(self, v):
        fmt = fxp.FXP16
        a = int(fxp.quantize(v, fmt))
        b = int(fxp.quantize(v + 0.1, fmt))
        assert b >= a

    def test_ashr_is_floor_division(self):
        x = jnp.array([-7, -1, 0, 1, 7], jnp.int32)
        np.testing.assert_array_equal(np.asarray(fxp.ashr(x, 1)),
                                      np.floor_divide(np.asarray(x), 2))


class TestLinearMode:
    @pytest.mark.parametrize("fmt", FMTS)
    def test_mac_converges(self, fmt, rng):
        x = jnp.array(rng.uniform(-2, 2, (512,)), jnp.float32)
        w = jnp.array(rng.uniform(-1.9, 1.9, (512,)), jnp.float32)
        b = jnp.array(rng.uniform(-1, 1, (512,)), jnp.float32)
        n = fmt.frac_bits + 1
        got = cordic.mac(x, w, b, fmt, n=n)
        want = b + x * w
        # error ~ |x| * 2^-n plus accumulation of n truncations
        tol = 4.0 * (n + 2) * fmt.resolution
        assert float(jnp.abs(got - want).max()) < tol

    def test_error_decreases_with_iterations(self, rng):
        """Property from the paper's Pareto analysis: more stages => less err."""
        fmt = fxp.FXP32
        x = jnp.array(rng.uniform(-2, 2, (2048,)), jnp.float32)
        w = jnp.array(rng.uniform(-1.9, 1.9, (2048,)), jnp.float32)
        b = jnp.zeros_like(x)
        want = x * w
        errs = [float(jnp.abs(cordic.mac(x, w, b, fmt, n=n) - want).mean())
                for n in (2, 4, 8, 12)]
        assert errs[0] > errs[1] > errs[2] > errs[3]

    def test_unroll_matches_loop(self, rng):
        fmt = fxp.FXP16
        x = fxp.quantize(jnp.array(rng.uniform(-2, 2, (64,)), jnp.float32), fmt)
        y = jnp.zeros_like(x)
        z = fxp.quantize(jnp.array(rng.uniform(-1.9, 1.9, (64,)), jnp.float32), fmt)
        a = cordic.linear_rotate_raw(x, y, z, fmt, n=5, unroll=True)
        b = cordic.linear_rotate_raw(x, y, z, fmt, n=5, unroll=False)
        np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))

    @given(st.integers(-500, 500), st.integers(-480, 480))
    @settings(max_examples=100, deadline=None)
    def test_residual_bound(self, xr, zr):
        """|z| shrinks below the last angle constant => multiply error bound."""
        fmt = fxp.FXP16
        n = 8
        x = jnp.array([xr], jnp.int32)
        y = jnp.zeros_like(x)
        z = jnp.array([zr], jnp.int32)
        _, z_res = cordic.linear_rotate_raw(x, y, z, fmt, n=n)
        assert abs(int(z_res[0])) <= 2 * fxp.constant(2.0 ** (-(n - 1)), fmt) + 1


class TestHyperbolicMode:
    def test_sequence_repeats(self):
        seq = cordic.hyperbolic_sequence(16)
        assert seq[3] == seq[4] == 4
        assert 13 in seq and seq.count(13) == 2

    @pytest.mark.parametrize("n", [5, 8, 12])
    def test_cosh_sinh(self, n, rng):
        fmt = fxp.FXP32
        a = jnp.array(rng.uniform(-1.0, 1.0, (256,)), jnp.float32)
        c, s = cordic.cosh_sinh(a, fmt, n)
        tol = 4.0 * 2.0 ** (-n) + 8 * (n + 2) * fmt.resolution
        assert float(jnp.abs(c - jnp.cosh(a)).max()) < tol
        assert float(jnp.abs(s - jnp.sinh(a)).max()) < tol

    def test_exp_range_extension(self, rng):
        fmt = fxp.FXP16
        a = jnp.array(rng.uniform(-12.0, 3.0, (512,)), jnp.float32)
        e = cordic.exp_fxp(a, fmt, n=12, range_extend=True)
        rel = jnp.abs(e - jnp.exp(a)) / jnp.exp(a)
        assert float(rel.max()) < 0.05

    def test_identity_cosh2_minus_sinh2(self, rng):
        """Hyperbolic invariant survives fixed-point within tolerance."""
        fmt = fxp.FXP32
        a = jnp.array(rng.uniform(-1.0, 1.0, (128,)), jnp.float32)
        c, s = cordic.cosh_sinh(a, fmt, 14)
        assert float(jnp.abs(c * c - s * s - 1.0).max()) < 0.01


class TestDivisionMode:
    @given(st.floats(-1.8, 1.8), st.floats(0.25, 1.9))
    @settings(max_examples=100, deadline=None)
    def test_quotient(self, num, den):
        fmt = fxp.FXP16
        q = cordic.divide(jnp.array([num * den], jnp.float32),
                          jnp.array([den], jnp.float32), fmt, n=12)
        assert abs(float(q[0]) - num) < 0.02 + 4 * fmt.resolution

    def test_negative_denominator(self):
        fmt = fxp.FXP16
        q = cordic.divide(jnp.array([1.0]), jnp.array([-2.0]), fmt, n=12)
        assert abs(float(q[0]) + 0.5) < 0.01


class TestCircularMode:
    def test_cos_sin(self, rng):
        fmt = fxp.FXP32
        a = jnp.array(rng.uniform(-1.5, 1.5, (128,)), jnp.float32)
        c, s = cordic.cos_sin(a, fmt, 14)
        assert float(jnp.abs(c - jnp.cos(a)).max()) < 0.01
        assert float(jnp.abs(s - jnp.sin(a)).max()) < 0.01


class TestSqrtMode:
    def test_sqrt_native_range(self, rng):
        fmt = fxp.FXP16
        a = jnp.array(rng.uniform(0.05, 1.9, (256,)), jnp.float32)
        got = cordic.sqrt_fxp(a, fmt, n=12, range_extend=False)
        assert float(jnp.abs(got - jnp.sqrt(a)).max()) < 0.03

    def test_sqrt_range_extended(self, rng):
        fmt = fxp.FXP16
        a = jnp.array(rng.uniform(1e-3, 900.0, (512,)), jnp.float32)
        got = cordic.sqrt_fxp(a, fmt, n=12)
        rel = jnp.abs(got - jnp.sqrt(a)) / jnp.maximum(jnp.sqrt(a), 1e-6)
        assert float(rel.max()) < 0.05

    def test_sqrt_zero(self):
        assert float(cordic.sqrt_fxp(jnp.zeros(3), fxp.FXP16)[0]) == 0.0

    def test_rsqrt(self, rng):
        fmt = fxp.FXP16
        a = jnp.array(rng.uniform(0.1, 8.0, (128,)), jnp.float32)
        got = cordic.rsqrt_fxp(a, fmt, n=12)
        rel = jnp.abs(got - 1.0 / jnp.sqrt(a)) * jnp.sqrt(a)
        assert float(rel.max()) < 0.05


class TestLnMode:
    def test_ln_native(self, rng):
        fmt = fxp.FXP16
        a = jnp.array(rng.uniform(0.5, 2.0, (256,)), jnp.float32)
        got = cordic.ln_fxp(a, fmt, n=12, range_extend=False)
        assert float(jnp.abs(got - jnp.log(a)).max()) < 0.02

    def test_ln_range_extended(self, rng):
        fmt = fxp.FXP16
        a = jnp.array(rng.uniform(1e-2, 500.0, (512,)), jnp.float32)
        got = cordic.ln_fxp(a, fmt, n=12)
        assert float(jnp.abs(got - jnp.log(a)).max()) < 0.03

    def test_ln_one_is_zero(self):
        fmt = fxp.FXP16
        assert abs(float(cordic.ln_fxp(jnp.ones(2), fmt, 12)[0])) < 0.01
