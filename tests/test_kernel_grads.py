"""Fused backward kernels vs the exact float-reference VJP.

Covers the tentpole contract: for both float families the fused Pallas
backward (kernel_bwd.py, routed through common.fused_vjp) must match
jax.vjp of the float reference within family tolerances — causal and
non-causal, GQA (hq != hkv), non-divisor sequence lengths, forced small
tiles — and REPRO_FUSED_BWD=0 must fall back to the STE path.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.kernels as K
from repro.kernels import common
from repro.kernels.flash_attention.ops import _exact_attention
from repro.kernels.flash_attention.ref import attention_bwd_ref
from repro.kernels.flash_attention.kernel import flash_attention_nhd
from repro.kernels.flash_attention.kernel_bwd import flash_attention_bwd_nhd
from repro.kernels.wkv.ops import _exact_wkv
from repro.kernels.wkv.kernel import wkv_recurrence
from repro.kernels.wkv.kernel_bwd import wkv_recurrence_bwd
from repro.kernels.wkv.ref import wkv_bwd_ref


@pytest.fixture(autouse=True)
def _clean_block_cache():
    common.clear_block_cache()
    yield
    common.clear_block_cache()


def _flash_case(rng, b, s, hq, hkv, d):
    q = jnp.array(rng.normal(size=(b, s, hq, d)), jnp.float32)
    k = jnp.array(rng.normal(size=(b, s, hkv, d)), jnp.float32)
    v = jnp.array(rng.normal(size=(b, s, hkv, d)), jnp.float32)
    g = jnp.array(rng.normal(size=(b, s, hq, d)), jnp.float32)
    return q, k, v, g


class TestFlashFusedBackward:
    @pytest.mark.parametrize("shape,causal", [
        # (b, s, hq, hkv, d)
        ((2, 64, 4, 4, 16), True),      # causal, MHA
        ((2, 64, 4, 4, 16), False),     # non-causal
        ((1, 64, 8, 2, 16), True),      # GQA group=4
        ((1, 64, 4, 1, 8), True),       # MQA
        ((2, 40, 4, 2, 8), True),       # non-divisor S (40 % 128 != 0)
        ((1, 96, 2, 2, 16), False),     # non-divisor S, non-causal
    ])
    def test_matches_reference_vjp(self, shape, causal, rng):
        q, k, v, g = _flash_case(rng, *shape)
        _, vjp = jax.vjp(
            lambda a, b_, c: K.flash_attention(a, b_, c, causal=causal),
            q, k, v)
        _, ref_vjp = jax.vjp(
            lambda a, b_, c: _exact_attention(a, b_, c, causal=causal),
            q, k, v)
        for name, got, want in zip("dq dk dv".split(), vjp(g), ref_vjp(g)):
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       atol=2e-4, rtol=2e-4, err_msg=name)

    def test_forced_small_tiles(self, rng):
        """The backward tile resolves through the substrate cache, so a
        forced non-default block must still produce exact grads."""
        q, k, v, g = _flash_case(rng, 1, 96, 4, 2, 16)
        common.set_block("flash_attention.bwd", (96, 96), jnp.float32,
                         (32, 48))
        _, vjp = jax.vjp(lambda *a: K.flash_attention(*a), q, k, v)
        _, ref_vjp = jax.vjp(
            lambda *a: _exact_attention(*a, causal=True), q, k, v)
        for got, want in zip(vjp(g), ref_vjp(g)):
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       atol=2e-4, rtol=2e-4)

    def test_fused_path_resolves_bwd_block(self, rng):
        """Differentiating installs a flash_attention.bwd cache entry —
        the observable sign the fused kernels (not STE) ran."""
        q, k, v, g = _flash_case(rng, 1, 32, 2, 1, 8)
        jax.vjp(lambda *a: K.flash_attention(*a), q, k, v)[1](g)
        assert common.cached_block("flash_attention.bwd", (32, 32),
                                   jnp.float32) is not None

    def test_ste_fallback_env(self, rng, monkeypatch):
        monkeypatch.setenv("REPRO_FUSED_BWD", "0")
        q, k, v, g = _flash_case(rng, 1, 32, 4, 2, 8)
        _, vjp = jax.vjp(lambda *a: K.flash_attention(*a), q, k, v)
        _, ref_vjp = jax.vjp(
            lambda *a: _exact_attention(*a, causal=True), q, k, v)
        for got, want in zip(vjp(g), ref_vjp(g)):
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       atol=2e-4, rtol=2e-4)
        # and no backward block was resolved: the STE path really ran
        assert common.cached_block("flash_attention.bwd", (32, 32),
                                   jnp.float32) is None

    def test_lse_residual_matches_scores(self, rng):
        """The stashed LSE equals logsumexp of the scaled score rows."""
        hq, s, d = 2, 64, 16
        q = jnp.array(rng.normal(size=(hq, s, d)), jnp.float32)
        k = jnp.array(rng.normal(size=(hq, s, d)), jnp.float32)
        v = jnp.array(rng.normal(size=(hq, s, d)), jnp.float32)
        out, lse = flash_attention_nhd(q, k, v, causal=False, block_q=32,
                                       block_k=32, return_residuals=True)
        scores = jnp.einsum("hqd,hkd->hqk", q, k) / (d ** 0.5)
        want = jax.nn.logsumexp(scores, axis=-1)
        np.testing.assert_allclose(np.asarray(lse), np.asarray(want),
                                   atol=1e-5, rtol=1e-5)
        # the plain call is unchanged
        np.testing.assert_allclose(
            np.asarray(flash_attention_nhd(q, k, v, causal=False,
                                           block_q=32, block_k=32)),
            np.asarray(out), atol=1e-6)

    def test_raw_bwd_kernel_vs_ref(self, rng):
        """kernel_bwd entry point against the ref.py backward oracle."""
        hq, hkv, s, d, group = 4, 2, 64, 16, 2
        q = jnp.array(rng.normal(size=(hq, s, d)), jnp.float32)
        k = jnp.array(rng.normal(size=(hkv, s, d)), jnp.float32)
        v = jnp.array(rng.normal(size=(hkv, s, d)), jnp.float32)
        do = jnp.array(rng.normal(size=(hq, s, d)), jnp.float32)
        o, lse = flash_attention_nhd(q, k, v, causal=True, block_q=32,
                                     block_k=32, group=group,
                                     return_residuals=True)
        delta = jnp.einsum("hsd,hsd->hs", do, o)
        dq, dk, dv = flash_attention_bwd_nhd(
            q, k, v, do, lse, delta, causal=True, block_q=32, block_k=32,
            group=group)
        rdq, rdk, rdv = attention_bwd_ref(q, k, v, do, causal=True,
                                          group=group)
        np.testing.assert_allclose(np.asarray(dq), np.asarray(rdq),
                                   atol=2e-4, rtol=2e-4)
        np.testing.assert_allclose(np.asarray(dk), np.asarray(rdk),
                                   atol=2e-4, rtol=2e-4)
        np.testing.assert_allclose(np.asarray(dv), np.asarray(rdv),
                                   atol=2e-4, rtol=2e-4)


def _wkv_case(rng, b, t, h, d):
    r = jnp.array(rng.normal(size=(b, t, h, d)), jnp.float32)
    k = jnp.array(rng.normal(size=(b, t, h, d)), jnp.float32)
    v = jnp.array(rng.normal(size=(b, t, h, d)), jnp.float32)
    w = jnp.array(rng.uniform(0.1, 0.9, (b, t, h, d)), jnp.float32)
    u = jnp.array(rng.normal(size=(h, d)), jnp.float32)
    g = jnp.array(rng.normal(size=(b, t, h, d)), jnp.float32)
    return r, k, v, w, u, g


class TestWkvFusedBackward:
    @pytest.mark.parametrize("shape", [
        (2, 32, 2, 8),
        (1, 64, 4, 16),
        (1, 24, 2, 4),      # non-divisor T (24 % 64 != 0)
        (2, 40, 1, 8),      # non-divisor T, single head
    ])
    def test_matches_reference_vjp(self, shape, rng):
        r, k, v, w, u, g = _wkv_case(rng, *shape)
        _, vjp = jax.vjp(lambda *a: K.wkv(*a), r, k, v, w, u)
        _, ref_vjp = jax.vjp(_exact_wkv, r, k, v, w, u)
        for name, got, want in zip("dr dk dv dw du".split(), vjp(g),
                                   ref_vjp(g)):
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       atol=5e-4, rtol=5e-4, err_msg=name)

    def test_forced_small_time_block(self, rng):
        r, k, v, w, u, g = _wkv_case(rng, 1, 48, 2, 8)
        common.set_block("wkv.bwd", (48, 8), jnp.float32, (12, 8))
        _, vjp = jax.vjp(lambda *a: K.wkv(*a), r, k, v, w, u)
        _, ref_vjp = jax.vjp(_exact_wkv, r, k, v, w, u)
        for got, want in zip(vjp(g), ref_vjp(g)):
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       atol=5e-4, rtol=5e-4)

    def test_ste_fallback_env(self, rng, monkeypatch):
        monkeypatch.setenv("REPRO_FUSED_BWD", "0")
        r, k, v, w, u, g = _wkv_case(rng, 1, 16, 2, 4)
        _, vjp = jax.vjp(lambda *a: K.wkv(*a), r, k, v, w, u)
        _, ref_vjp = jax.vjp(_exact_wkv, r, k, v, w, u)
        for got, want in zip(vjp(g), ref_vjp(g)):
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       atol=5e-4, rtol=5e-4)
        assert common.cached_block("wkv.bwd", (16, 4), jnp.float32) is None

    def test_checkpoints_are_block_boundary_states(self, rng):
        """The residual checkpoints equal the scan states at block starts."""
        bh, t, d, bt = 2, 32, 8, 8
        r = jnp.array(rng.normal(size=(bh, t, d)), jnp.float32)
        k = jnp.array(rng.normal(size=(bh, t, d)), jnp.float32)
        v = jnp.array(rng.normal(size=(bh, t, d)), jnp.float32)
        w = jnp.array(rng.uniform(0.1, 0.9, (bh, t, d)), jnp.float32)
        u = jnp.array(rng.normal(size=(bh, d)), jnp.float32)
        _, ckpt = wkv_recurrence(r, k, v, w, u, block_t=bt,
                                 return_residuals=True)
        assert ckpt.shape == (bh, t // bt, d, d)
        # state before token 0 is zero
        np.testing.assert_allclose(np.asarray(ckpt[:, 0]), 0.0)
        # replay the recurrence to the second block boundary
        s = jnp.zeros((bh, d, d))
        for i in range(bt):
            kv = k[:, i, :, None] * v[:, i, None, :]
            s = w[:, i, :, None] * s + kv
        np.testing.assert_allclose(np.asarray(ckpt[:, 1]), np.asarray(s),
                                   atol=1e-5, rtol=1e-5)

    def test_raw_bwd_kernel_vs_ref(self, rng):
        bh, t, d, bt = 2, 32, 8, 8
        r = jnp.array(rng.normal(size=(bh, t, d)), jnp.float32)
        k = jnp.array(rng.normal(size=(bh, t, d)), jnp.float32)
        v = jnp.array(rng.normal(size=(bh, t, d)), jnp.float32)
        w = jnp.array(rng.uniform(0.1, 0.9, (bh, t, d)), jnp.float32)
        u = jnp.array(rng.normal(size=(bh, d)), jnp.float32)
        dy = jnp.array(rng.normal(size=(bh, t, d)), jnp.float32)
        _, ckpt = wkv_recurrence(r, k, v, w, u, block_t=bt,
                                 return_residuals=True)
        got = wkv_recurrence_bwd(r, k, v, w, u, dy, ckpt, block_t=bt)
        want = wkv_bwd_ref(r, k, v, w, u, dy)
        for name, g_, w_ in zip("dr dk dv dw du".split(), got, want):
            np.testing.assert_allclose(np.asarray(g_), np.asarray(w_),
                                       atol=5e-4, rtol=5e-4, err_msg=name)


class TestFusedVjpHelper:
    def test_uses_fused_pair_when_given(self):
        calls = []

        def fwd(x):
            return x * 2.0

        def fwd_res(x):
            calls.append("fwd_res")
            return x * 2.0, (x,)

        def bwd(res, g):
            calls.append("bwd")
            return (g * 3.0,)       # deliberately not the STE grad

        f = common.fused_vjp(fwd, jnp.sin, fwd_res, bwd)
        x = jnp.ones((4,))
        g = jax.grad(lambda v: f(v).sum())(x)
        np.testing.assert_allclose(np.asarray(g), 3.0)
        assert calls == ["fwd_res", "bwd"]

    def test_falls_back_to_ste_without_pair(self):
        f = common.fused_vjp(jnp.round, jnp.tanh)
        x = jnp.linspace(-2.0, 2.0, 9)
        g = jax.grad(lambda v: f(v).sum())(x)
        np.testing.assert_allclose(np.asarray(g),
                                   np.asarray(1 - jnp.tanh(x) ** 2),
                                   rtol=1e-6)

    def test_env_disables_fused_pair(self, monkeypatch):
        monkeypatch.setenv("REPRO_FUSED_BWD", "0")

        def boom(*a):
            raise AssertionError("fused pair must not run")

        f = common.fused_vjp(jnp.round, jnp.tanh, boom, boom)
        x = jnp.linspace(-2.0, 2.0, 5)
        g = jax.grad(lambda v: f(v).sum())(x)
        np.testing.assert_allclose(np.asarray(g),
                                   np.asarray(1 - jnp.tanh(x) ** 2),
                                   rtol=1e-6)

    def test_enabled_flag(self, monkeypatch):
        monkeypatch.delenv("REPRO_FUSED_BWD", raising=False)
        assert common.fused_backward_enabled()
        monkeypatch.setenv("REPRO_FUSED_BWD", "0")
        assert not common.fused_backward_enabled()
        monkeypatch.setenv("REPRO_FUSED_BWD", "1")
        assert common.fused_backward_enabled()


class TestRegistrySeam:
    def test_float_families_register_grad_kernels(self):
        assert common.get_kernel("flash_attention").grad_kernel \
            is flash_attention_bwd_nhd
        assert common.get_kernel("wkv").grad_kernel is wkv_recurrence_bwd

    def test_bwd_specs_registered_with_candidates(self):
        for name in ("flash_attention.bwd", "wkv.bwd"):
            spec = common.get_kernel(name)
            assert "backward" in spec.tags
            cands = spec.candidates((64, 64), jnp.float32)
            assert cands and all(len(c) == 2 for c in cands)

    def test_fixed_point_families_have_no_grad_kernel(self):
        for name in ("cordic_act", "cordic_mac", "cordic_softmax"):
            assert common.get_kernel(name).grad_kernel is None


class TestExplicitBlockSkipsPick:
    """Satellite: explicit blocks must bypass pick_block_* entirely (no
    cache entry is written — the observable effect of the pick)."""

    def test_flash_explicit_blocks(self, rng):
        q, k, v, _ = _flash_case(rng, 1, 32, 2, 2, 8)
        K.flash_attention(q, k, v, block_q=16, block_k=16)
        assert common.cached_block("flash_attention", (32, 32),
                                   jnp.float32) is None

    def test_wkv_explicit_block(self, rng):
        r, k, v, w, u, _ = _wkv_case(rng, 1, 16, 2, 4)
        K.wkv(r, k, v, w, u, block_t=8)
        assert common.cached_block("wkv", (16, 4), jnp.float32) is None
