"""Substrate tests: data pipeline, optimizer, checkpointing, compression."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests; see requirements-dev.txt
from hypothesis import given, settings, strategies as st

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataConfig, Prefetcher, SyntheticStream
from repro.optim import adamw
from repro.parallel import collectives


class TestData:
    def test_deterministic_across_restarts(self):
        cfg = DataConfig(vocab_size=128, seq_len=16, global_batch=4, seed=7)
        a = SyntheticStream(cfg).batch_at(13)
        b = SyntheticStream(cfg).batch_at(13)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
        np.testing.assert_array_equal(a["labels"], b["labels"])

    def test_shards_partition_batch(self):
        cfg = DataConfig(vocab_size=128, seq_len=16, global_batch=8, seed=0)
        s0 = SyntheticStream(cfg, shard=0, n_shards=2)
        s1 = SyntheticStream(cfg, shard=1, n_shards=2)
        assert s0.local_batch == 4
        a, b = s0.batch_at(0)["tokens"], s1.batch_at(0)["tokens"]
        assert not np.array_equal(a, b)

    def test_labels_are_next_token(self):
        cfg = DataConfig(vocab_size=128, seq_len=16, global_batch=2, seed=0)
        batch = SyntheticStream(cfg).batch_at(0)
        assert batch["labels"].shape == (2, 16)

    def test_frames_kind(self):
        cfg = DataConfig(vocab_size=64, seq_len=8, global_batch=2,
                         kind="frames", d_model=32)
        batch = SyntheticStream(cfg).batch_at(0)
        assert batch["frames"].shape == (2, 8, 32)

    def test_codebook_labels(self):
        cfg = DataConfig(vocab_size=64, seq_len=8, global_batch=2,
                         n_codebooks=4)
        batch = SyntheticStream(cfg).batch_at(0)
        assert batch["labels"].shape == (2, 8, 4)

    def test_prefetcher(self):
        cfg = DataConfig(vocab_size=64, seq_len=8, global_batch=2)
        pf = Prefetcher(SyntheticStream(cfg), depth=2)
        steps = [pf.next()[0] for _ in range(4)]
        pf.close()
        assert steps == [0, 1, 2, 3]


class TestAdamW:
    def _quad(self, moment_dtype):
        cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1,
                                total_steps=200, moment_dtype=moment_dtype,
                                min_lr_ratio=1.0)
        params = {"w": jnp.array([3.0, -2.0, 1.5])}
        state = adamw.init(cfg, params)
        for _ in range(150):
            grads = {"w": 2 * params["w"]}
            params, state, _ = adamw.update(cfg, grads, state, params)
        return float(jnp.abs(params["w"]).max())

    def test_fp32_converges(self):
        assert self._quad("float32") < 0.05

    def test_int8_converges(self):
        assert self._quad("int8") < 0.15

    def test_lr_schedule(self):
        cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                                min_lr_ratio=0.1)
        assert float(adamw.lr_at(cfg, jnp.int32(5))) == pytest.approx(0.5)
        assert float(adamw.lr_at(cfg, jnp.int32(100))) == pytest.approx(
            0.1, abs=1e-3)

    def test_blockwise_path_matches_direct(self):
        cfg = adamw.AdamWConfig(lr=0.01, moment_dtype="float32")
        big = jnp.ones((4, 8, 8))
        params = {"w": big}
        st1 = adamw.init(cfg, params)
        grads = {"w": jnp.full_like(big, 0.5)}
        p1, _, _ = adamw.update(cfg, grads, st1, params)
        # force scanning by lowering the threshold
        orig = adamw.update.__globals__  # noqa: F841
        import repro.optim.adamw as mod
        # call blockwise by constructing a large-leaf equivalent: instead
        # just validate small == small (blockwise requires >= 2^28 elements,
        # so assert the threshold branch exists and direct result is finite)
        assert np.isfinite(np.asarray(p1["w"])).all()

    def test_masked_update_keeps_zeros(self):
        cfg = adamw.AdamWConfig(lr=0.1)
        params = {"w": jnp.ones((4, 4))}
        masks = {"w": jnp.eye(4)}
        state = adamw.init(cfg, params)
        grads = {"w": jnp.ones((4, 4))}
        p, _, _ = adamw.update(cfg, grads, state, params, masks)
        off_diag = np.asarray(p["w"])[~np.eye(4, dtype=bool)]
        assert np.all(off_diag == 0.0)


class TestCheckpoint:
    def test_roundtrip_bf16(self):
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d, async_save=False)
            state = {"a": jnp.ones((4, 4), jnp.bfloat16) * 1.5,
                     "b": {"c": jnp.arange(5)}}
            mgr.save(3, state)
            got = mgr.restore(state)
            np.testing.assert_array_equal(np.asarray(got["a"], np.float32),
                                          np.asarray(state["a"], np.float32))
            assert got["a"].dtype == jnp.bfloat16
            np.testing.assert_array_equal(np.asarray(got["b"]["c"]),
                                          np.arange(5))

    def test_atomic_no_tmp_left(self):
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d, async_save=True)
            mgr.save(1, {"x": jnp.zeros(3)})
            mgr.wait()
            names = os.listdir(d)
            assert "step_1" in names
            assert not any(n.endswith(".tmp") for n in names)

    def test_retention(self):
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d, keep=2, async_save=False)
            for s in (1, 2, 3, 4):
                mgr.save(s, {"x": jnp.zeros(2)})
            assert mgr.all_steps() == [3, 4]

    def test_latest_and_metadata(self):
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d, async_save=False)
            mgr.save(7, {"x": jnp.zeros(2)}, metadata={"loss": 1.25})
            assert mgr.latest_step() == 7
            assert mgr.metadata()["loss"] == 1.25


class TestCompression:
    def test_roundtrip_error_bounded(self, rng):
        g = jnp.array(rng.normal(size=(32, 64)), jnp.float32)
        q, s = collectives.compress_grad(g)
        back = collectives.decompress_grad(q, s)
        row_max = np.abs(np.asarray(g)).max(-1, keepdims=True)
        assert np.all(np.abs(np.asarray(back - g)) <= row_max / 127 + 1e-7)

    def test_error_feedback_unbiased_over_time(self, rng):
        """EF compression: the running mean of decompressed gradients
        converges to the true gradient (residual carry cancels bias)."""
        g = jnp.array(rng.normal(size=(16,)), jnp.float32)
        resid = None
        total = np.zeros(16)
        n = 200
        for _ in range(n):
            comp, resid = collectives.compress_tree({"g": g}, resid)
            back = collectives.decompress_tree(comp)
            total += np.asarray(back["g"])
        err = np.abs(total / n - np.asarray(g)).max()
        assert err < 0.01

    @given(st.integers(0, 2 ** 31 - 1))
    @settings(max_examples=20, deadline=None)
    def test_compress_idempotent_scale(self, seed):
        r = np.random.default_rng(seed)
        g = jnp.array(r.normal(size=(8,)) * r.uniform(0.01, 100), jnp.float32)
        q, s = collectives.compress_grad(g)
        assert int(np.abs(np.asarray(q)).max()) <= 127
