"""Property-based numerics of the int8 per-block quantized cache.

The hypothesis layer over ``core/quant_cache.py`` — deterministic
spot-checks of the same contract live in ``tests/test_quant_cache.py``
(which runs even without hypothesis).  Three families of properties:

  * **round-trip bounds**: |x - dq(q(x))| <= scale/2 per trailing-axis
    block, over random shapes, block sizes, magnitudes and input dtypes
    (f32 / bf16 inputs — the serving cache quantizes both)
  * **scatter commutation**: quantize-then-scatter == scatter-then-
    quantize for any slot index set — the invariant ``slot_update``
    relies on to touch only the updated slot's rows and scales
  * **permutation invariance**: per-block scales depend only on the
    block's own values, so any permutation of the slot axis commutes
    with quantization bit-exactly

This module is wired into the interpret-consistency CI lane in both the
default and ``REPRO_KERNEL_INTERPRET=1`` runs: the properties are pure
jnp, so agreement across the two runs pins the quantizer itself (not
just the kernels) to one set of semantics.
"""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests; see requirements-dev.txt
from hypothesis import given, settings, strategies as st

from repro.core.quant_cache import dequantize_blocked, quantize_blocked


def _arr(rng, shape, scale, dtype):
    x = rng.normal(0.0, scale, shape).astype(np.float32)
    return jnp.asarray(x, dtype)


_dtypes = st.sampled_from([jnp.float32, jnp.bfloat16])


@given(seed=st.integers(0, 2**31 - 1),
       rows=st.integers(1, 6), cols=st.sampled_from([8, 16, 32, 64]),
       blk=st.sampled_from([None, 8, 16]),
       mag=st.floats(1e-3, 1e3), dtype=_dtypes)
@settings(max_examples=40, deadline=None)
def test_roundtrip_bound(seed, rows, cols, blk, mag, dtype):
    if blk is not None and cols % blk:
        return
    rng = np.random.default_rng(seed)
    x = _arr(rng, (rows, cols), mag, dtype)
    q, s = quantize_blocked(x, block=blk)
    assert q.dtype == jnp.int8 and s.dtype == jnp.float32
    nb = 1 if blk is None else cols // blk
    assert s.shape == (rows, nb)
    dq = np.asarray(dequantize_blocked(q, s), np.float64)
    xf = np.asarray(x, np.float64)          # bound vs what was quantized
    step = np.repeat(np.asarray(s, np.float64), cols // nb, axis=-1)
    assert np.all(np.abs(xf - dq) <= step / 2.0 + 1e-12 * mag)
    # all-zero blocks round-trip exactly (scale stored as 0, not epsilon)
    zq, zs = quantize_blocked(jnp.zeros_like(x), block=blk)
    assert np.all(np.asarray(zs) == 0.0)
    assert np.all(np.asarray(dequantize_blocked(zq, zs)) == 0.0)


@given(seed=st.integers(0, 2**31 - 1), slots=st.integers(2, 8),
       nupd=st.integers(1, 4), dtype=_dtypes)
@settings(max_examples=40, deadline=None)
def test_scatter_then_read_equals_read_then_scatter(seed, slots, nupd, dtype):
    rng = np.random.default_rng(seed)
    nupd = min(nupd, slots)
    cache = _arr(rng, (slots, 5, 16), 1.0, dtype)
    rows = _arr(rng, (nupd, 5, 16), 2.0, dtype)
    idx = jnp.asarray(rng.choice(slots, nupd, replace=False))

    qc, sc = quantize_blocked(cache)
    qr, sr = quantize_blocked(rows)
    q1, s1 = qc.at[idx].set(qr), sc.at[idx].set(sr)     # scatter quantized
    q2, s2 = quantize_blocked(cache.at[idx].set(rows))  # quantize scattered
    assert np.array_equal(np.asarray(q1), np.asarray(q2))
    assert np.array_equal(np.asarray(s1), np.asarray(s2))
    # and the reads agree bit-exactly too
    assert np.array_equal(np.asarray(dequantize_blocked(q1, s1)),
                          np.asarray(dequantize_blocked(q2, s2)))


@given(seed=st.integers(0, 2**31 - 1), slots=st.integers(2, 8),
       blk=st.sampled_from([None, 8]), dtype=_dtypes)
@settings(max_examples=40, deadline=None)
def test_permutation_invariance(seed, slots, blk, dtype):
    rng = np.random.default_rng(seed)
    x = _arr(rng, (slots, 3, 16), 1.0, dtype)
    perm = jnp.asarray(rng.permutation(slots))
    q, s = quantize_blocked(x, block=blk)
    qp, sp = quantize_blocked(x[perm], block=blk)
    assert np.array_equal(np.asarray(q[perm]), np.asarray(qp))
    assert np.array_equal(np.asarray(s[perm]), np.asarray(sp))
