"""Chaos harness: kill/restore bit-identity, backpressure, accounting.

The fault-tolerance acceptance tests for the serving stack:

  * an engine killed at an arbitrary decode step (``kill_at_step`` fault
    injection) and restored by :class:`ServeSupervisor` into a *fresh*
    engine — different ``max_batch``, a smaller paged pool — completes
    every request **bit-identically** to an uninterrupted run, across
    dense/ssm/hybrid families, fp32 and int8 caches, dense and paged
    backends, plain and speculative decode, greedy and sampled;
  * bounded-queue shedding policies and per-request deadlines terminate
    every request with an explicit status and leak no accounting
    (block-pool ``assert_balanced`` holds after restore);
  * restore re-enters through the existing jitted programs — a restored
    engine decodes with exactly one trace (bucket discipline preserved).
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_arch
from repro.configs.base import CacheSpec
from repro.models.model_zoo import build_model
from repro.parallel.fault_tolerance import WorkerKilled
from repro.runtime.serve_loop import Request, ServeConfig, ServeEngine
from repro.runtime.supervisor import ServeSupervisor

MAX_SEQ = 64
PAGE = 8


@pytest.fixture(scope="module")
def served():
    """One model + params per (family, cache format), shared per module."""
    cache = {}

    def get(arch, spec=None):
        key = (arch, spec)
        if key not in cache:
            cfg = get_arch(arch).reduced()
            if spec is not None:
                cfg = dataclasses.replace(cfg, cache=spec)
            model = build_model(cfg)
            params = model.init(jax.random.PRNGKey(0))
            cache[key] = (cfg, model, params)
        return cache[key]

    return get


def _requests(cfg, lens=(5, 9, 13, 3, 7), max_news=(10, 6, 12, 8, 5),
              temperature=0.0, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, n)
                    .astype(np.int32),
                    max_new_tokens=m, temperature=temperature,
                    top_k=12 if temperature else 0, seed=7)
            for i, (n, m) in enumerate(zip(lens, max_news))]


def _outputs(done):
    return {r.rid: (r.status, list(np.asarray(r.output)))
            for r in done}


def _assert_drained(engine):
    """No accounting leaks: every non-radix block is back in the pool."""
    if engine.allocator is not None:
        engine.allocator.assert_balanced()
        if engine.radix is not None:
            engine.radix.evict(engine.allocator.num_blocks)
        assert engine.allocator.used_blocks == 0


# ---------------------------------------------------------------------------
# Kill/restore bit-identity across the serving matrix
# ---------------------------------------------------------------------------

CHAOS_MATRIX = [
    # (arch, cache spec, spec_k, kill_at_step)
    ("glm4-9b", None, 0, 1),
    ("glm4-9b", None, 0, 5),
    ("glm4-9b", CacheSpec(dtype="int8"), 0, 4),
    ("glm4-9b", CacheSpec(paged=True, page_size=PAGE), 0, 3),
    ("glm4-9b", CacheSpec(dtype="int8", paged=True, page_size=PAGE), 0, 6),
    ("glm4-9b", None, 3, 2),
    ("rwkv6-3b", None, 0, 4),
    ("rwkv6-3b", CacheSpec(dtype="int8"), 0, 3),
    ("rwkv6-3b", CacheSpec(paged=True, page_size=PAGE), 0, 5),
    ("hymba-1.5b", None, 0, 4),
    ("hymba-1.5b", CacheSpec(dtype="int8", paged=True, page_size=PAGE),
     0, 3),
]


@pytest.mark.parametrize("arch,spec,spec_k,kill_at",
                         CHAOS_MATRIX,
                         ids=lambda v: str(v).replace(" ", ""))
def test_kill_restore_bit_identical(served, tmp_path, arch, spec, spec_k,
                                    kill_at):
    """Killed mid-trace, restored into a *smaller* fresh engine (fewer
    slots; paged: a smaller pool), every output matches the uninterrupted
    run bit for bit."""
    cfg, model, params = served(arch, spec)
    ref_eng = ServeEngine(model, params,
                          ServeConfig(max_batch=3, max_seq=MAX_SEQ,
                                      spec_k=spec_k))
    ref = _outputs(ref_eng.serve(_requests(cfg)))

    paged = spec is not None and spec.paged

    def factory(i):
        return ServeEngine(model, params, ServeConfig(
            max_batch=3 if i == 0 else 2, max_seq=MAX_SEQ, spec_k=spec_k,
            snapshot_dir=str(tmp_path), snapshot_every=2,
            kill_at_step=kill_at if i == 0 else None,
            num_blocks=(3 * MAX_SEQ // PAGE if i == 0 else 20)
            if paged else None))

    sup = ServeSupervisor(factory, max_restarts=2)
    got = _outputs(sup.run(_requests(cfg)))
    assert len(sup.history) == 1     # exactly one injected death
    assert got == ref
    _assert_drained(sup.engine)
    # liveness telemetry saw the death + respawn
    assert not sup.monitor.workers["serve"].alive
    assert sup.monitor.workers["serve-r1"].alive


def test_kill_before_first_snapshot_replays(served, tmp_path):
    """A death before any snapshot landed falls back to full replay —
    deterministic decode makes the re-run bit-identical too."""
    cfg, model, params = served("glm4-9b")
    ref_eng = ServeEngine(model, params,
                          ServeConfig(max_batch=3, max_seq=MAX_SEQ))
    ref = _outputs(ref_eng.serve(_requests(cfg)))

    def factory(i):
        return ServeEngine(model, params, ServeConfig(
            max_batch=3, max_seq=MAX_SEQ, snapshot_dir=str(tmp_path),
            snapshot_every=100,          # cadence never fires before kill
            kill_at_step=2 if i == 0 else None))

    sup = ServeSupervisor(factory)
    got = _outputs(sup.run(_requests(cfg)))
    assert got == ref
    assert sup.history[0].restored_step is None
    assert sorted(sup.history[0].replayed_rids) == [0, 1, 2, 3, 4]


def test_sampled_rng_state_restores(served, tmp_path):
    """Temperature slots resume their exact RNG stream mid-request."""
    cfg, model, params = served("glm4-9b")
    ref_eng = ServeEngine(model, params,
                          ServeConfig(max_batch=3, max_seq=MAX_SEQ,
                                      greedy=False))
    ref = _outputs(ref_eng.serve(_requests(cfg, temperature=0.9)))

    def factory(i):
        return ServeEngine(model, params, ServeConfig(
            max_batch=3, max_seq=MAX_SEQ, greedy=False,
            snapshot_dir=str(tmp_path), snapshot_every=3,
            kill_at_step=7 if i == 0 else None))

    sup = ServeSupervisor(factory)
    got = _outputs(sup.run(_requests(cfg, temperature=0.9)))
    assert got == ref
    # at least one request actually resumed mid-flight (not just replayed)
    assert sup.history[0].resumed_rids


def test_restore_does_not_retrace(served, tmp_path):
    """Bucket discipline survives restore: the respawned engine runs the
    whole resumed trace on ONE decode trace, and restores through the
    existing insert program."""
    cfg, model, params = served("glm4-9b")

    def factory(i):
        return ServeEngine(model, params, ServeConfig(
            max_batch=3, max_seq=MAX_SEQ, snapshot_dir=str(tmp_path),
            snapshot_every=2, kill_at_step=5 if i == 0 else None))

    sup = ServeSupervisor(factory)
    sup.run(_requests(cfg))
    eng = sup.engine
    assert eng.trace_counts["decode"] == 1, dict(eng.trace_counts)
    # restore rode the slot_update scatter seam (dense path), not a
    # bespoke per-restore program
    assert eng.trace_counts["insert"] >= 1


def test_double_kill_two_recoveries(served, tmp_path):
    """Two injected deaths (the second on the respawned engine) still
    finish every request bit-identically."""
    cfg, model, params = served("glm4-9b")
    ref_eng = ServeEngine(model, params,
                          ServeConfig(max_batch=3, max_seq=MAX_SEQ))
    ref = _outputs(ref_eng.serve(_requests(cfg)))

    def factory(i):
        return ServeEngine(model, params, ServeConfig(
            max_batch=3, max_seq=MAX_SEQ, snapshot_dir=str(tmp_path),
            snapshot_every=2,
            kill_at_step={0: 3, 1: 2}.get(i)))

    sup = ServeSupervisor(factory, max_restarts=3)
    got = _outputs(sup.run(_requests(cfg)))
    assert got == ref
    assert len(sup.history) == 2


def test_restart_budget_exhausted(served, tmp_path):
    cfg, model, params = served("glm4-9b")

    def factory(i):
        return ServeEngine(model, params, ServeConfig(
            max_batch=2, max_seq=MAX_SEQ, snapshot_dir=str(tmp_path),
            snapshot_every=2, kill_at_step=2))       # every incarnation dies

    sup = ServeSupervisor(factory, max_restarts=2)
    with pytest.raises(RuntimeError, match="restart budget"):
        sup.run(_requests(cfg))


# ---------------------------------------------------------------------------
# Snapshot format / compatibility validation
# ---------------------------------------------------------------------------

def test_restore_rejects_fingerprint_mismatch(served, tmp_path):
    cfg, model, params = served("glm4-9b")
    eng = ServeEngine(model, params, ServeConfig(
        max_batch=2, max_seq=MAX_SEQ, snapshot_dir=str(tmp_path)))
    eng.serve(_requests(cfg, lens=(5, 3), max_news=(4, 4)))
    eng.snapshot()

    cfg2, model2, params2 = served("rwkv6-3b")
    eng2 = ServeEngine(model2, params2, ServeConfig(
        max_batch=2, max_seq=MAX_SEQ, snapshot_dir=str(tmp_path)))
    with pytest.raises(ValueError, match="fingerprint mismatch"):
        eng2.restore_snapshot()

    # int8 vs fp32 is also a fingerprint difference — a dequantized
    # restore could not be bit-identical, so it must refuse
    cfgq, modelq, paramsq = served("glm4-9b", CacheSpec(dtype="int8"))
    engq = ServeEngine(modelq, paramsq, ServeConfig(
        max_batch=2, max_seq=MAX_SEQ, snapshot_dir=str(tmp_path)))
    with pytest.raises(ValueError, match="fingerprint mismatch"):
        engq.restore_snapshot()


def test_restore_rejects_greedy_mismatch(served, tmp_path):
    cfg, model, params = served("glm4-9b")
    eng = ServeEngine(model, params, ServeConfig(
        max_batch=2, max_seq=MAX_SEQ, snapshot_dir=str(tmp_path)))
    eng.serve(_requests(cfg, lens=(5, 3), max_news=(4, 4)))
    eng.snapshot()
    eng2 = ServeEngine(model, params, ServeConfig(
        max_batch=2, max_seq=MAX_SEQ, greedy=False,
        snapshot_dir=str(tmp_path)))
    with pytest.raises(ValueError, match="sampling mode"):
        eng2.restore_snapshot()


def test_restore_rejects_request_too_large_for_max_seq(served, tmp_path):
    cfg, model, params = served("glm4-9b")

    def factory(i):
        return ServeEngine(model, params, ServeConfig(
            max_batch=2, max_seq=MAX_SEQ, snapshot_dir=str(tmp_path),
            snapshot_every=2, kill_at_step=4 if i == 0 else None))

    eng = factory(0)
    with pytest.raises(WorkerKilled):
        eng.serve(_requests(cfg, lens=(30, 20), max_news=(20, 20)))
    small = ServeEngine(model, params, ServeConfig(
        max_batch=2, max_seq=32, snapshot_dir=str(tmp_path)))
    with pytest.raises(ValueError, match="max_seq"):
        small.restore_snapshot()


def test_snapshot_is_atomic_and_versioned(served, tmp_path):
    cfg, model, params = served("glm4-9b")
    eng = ServeEngine(model, params, ServeConfig(
        max_batch=2, max_seq=MAX_SEQ, snapshot_dir=str(tmp_path)))
    done = eng.serve(_requests(cfg, lens=(5, 3), max_news=(4, 4)))
    step = eng.snapshot()
    meta = eng._ckpt.metadata(step)
    assert meta["snapshot_version"] == 1
    assert meta["fingerprint"] == cfg.fingerprint()
    # finished outputs ride along and restore as completed
    eng2 = ServeEngine(model, params, ServeConfig(
        max_batch=2, max_seq=MAX_SEQ, snapshot_dir=str(tmp_path)))
    survivors, completed = eng2.restore_snapshot()
    assert survivors == []
    got = {r.rid: list(np.asarray(r.output)) for r in completed}
    want = {r.rid: list(np.asarray(r.output)) for r in done}
    assert got == want


# ---------------------------------------------------------------------------
# Backpressure: bounded queue, shed policies, deadlines
# ---------------------------------------------------------------------------

def _burst(cfg, budgets):
    rng = np.random.default_rng(0)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, 5)
                    .astype(np.int32), max_new_tokens=m)
            for i, m in enumerate(budgets)]


@pytest.mark.parametrize("policy", ["reject-new", "shed-oldest",
                                    "shed-lowest-budget"])
def test_shed_policies_terminal_status(served, policy):
    cfg, model, params = served("glm4-9b")
    eng = ServeEngine(model, params, ServeConfig(
        max_batch=1, max_seq=MAX_SEQ, max_queue=2,
        admission_policy=policy))
    budgets = [8, 8, 8, 2, 8, 8]
    done = eng.serve(_burst(cfg, budgets))
    assert len(done) == len(budgets)          # nobody vanishes
    shed = [r for r in done if r.status == "shed"]
    ok = [r for r in done if r.status == "done"]
    assert shed and ok
    assert eng.metrics["shed_count"] == len(shed)
    assert all(len(np.asarray(r.output)) == 0 for r in shed)
    assert all(len(np.asarray(r.output)) == r.max_new_tokens for r in ok)
    assert eng.metrics["peak_queue_depth"] <= 2
    if policy == "shed-lowest-budget":
        assert any(r.max_new_tokens == 2 for r in shed)
    # served outputs match an unbounded engine's for the same rids
    ref_eng = ServeEngine(model, params,
                          ServeConfig(max_batch=1, max_seq=MAX_SEQ))
    ref = _outputs(ref_eng.serve(_burst(cfg, budgets)))
    for r in ok:
        assert list(np.asarray(r.output)) == ref[r.rid][1]


def test_shed_policies_paged_no_leaks(served):
    cfg, model, params = served("glm4-9b",
                                CacheSpec(paged=True, page_size=PAGE))
    eng = ServeEngine(model, params, ServeConfig(
        max_batch=1, max_seq=MAX_SEQ, max_queue=1,
        admission_policy="shed-oldest", num_blocks=16))
    done = eng.serve(_burst(cfg, [6] * 5))
    assert len(done) == 5
    _assert_drained(eng)


def test_deadline_waiting_and_live(served):
    cfg, model, params = served("glm4-9b")
    eng = ServeEngine(model, params,
                      ServeConfig(max_batch=1, max_seq=MAX_SEQ))
    reqs = _burst(cfg, [6, 6, 40])
    reqs[1].deadline_s = 0.0          # expires while waiting
    done = eng.serve(reqs)
    by = {r.rid: r for r in done}
    assert by[1].status == "timeout" and len(np.asarray(by[1].output)) == 0
    assert by[0].status == "done" and by[2].status == "done"
    assert eng.metrics["timeout_count"] == 1


def test_deadline_live_graceful_retire(served):
    """A deadline expiring while the request *holds a slot* retires it
    gracefully: status "timeout", and the partial output is a bit-exact
    prefix of what an undisturbed run would have produced."""
    cfg, model, params = served("glm4-9b")
    reqs = _burst(cfg, [59])
    ref_eng = ServeEngine(model, params,
                          ServeConfig(max_batch=1, max_seq=MAX_SEQ))
    ref = list(np.asarray(ref_eng.serve(_burst(cfg, [59]))[0].output))

    eng = ServeEngine(model, params,
                      ServeConfig(max_batch=1, max_seq=MAX_SEQ))
    # long enough to survive the pre-admission sweep (~ms), short enough
    # to expire during decode (first decode step compiles, >> 0.25 s)
    reqs[0].deadline_s = 0.25
    r = eng.serve(reqs)[0]
    assert r.status == "timeout"
    out = list(np.asarray(r.output))
    assert len(out) < 59
    assert out == ref[:len(out)]
    assert eng.metrics["timeout_count"] == 1


def test_deadline_survives_snapshot(served, tmp_path):
    """deadline_s rides the snapshot: a restored request still carries
    its budget (the clock restarts at re-submission)."""
    cfg, model, params = served("glm4-9b")

    def factory(i):
        return ServeEngine(model, params, ServeConfig(
            max_batch=2, max_seq=MAX_SEQ, snapshot_dir=str(tmp_path),
            snapshot_every=2, kill_at_step=3 if i == 0 else None))

    reqs = _requests(cfg)
    for r in reqs:
        r.deadline_s = 60.0
    sup = ServeSupervisor(factory)
    done = sup.run(reqs)
    assert all(r.status == "done" for r in done)
    resumed = set(sup.history[0].resumed_rids)
    assert resumed
    assert all(r.deadline_s == 60.0 for r in done if r.rid in resumed)


def test_duplicate_rid_rejected(served):
    cfg, model, params = served("glm4-9b")
    eng = ServeEngine(model, params,
                      ServeConfig(max_batch=2, max_seq=MAX_SEQ))
    reqs = _burst(cfg, [4, 4])
    reqs[1].rid = reqs[0].rid
    with pytest.raises(ValueError, match="duplicate request id"):
        eng.serve(reqs)
