"""DA-VINCI activation tests: accuracy bands, STE gradients, reuse report."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.activations import (CordicPolicy, PAPER_FAITHFUL_POLICY,
                                    SUPPORTED_AFS, activate, reuse_report)

HQ = CordicPolicy(bits=16, n_hyperbolic=12, n_division=12)


@pytest.mark.parametrize("name", ["relu", "tanh", "sigmoid", "gelu", "selu",
                                  "swish", "exp"])
def test_matches_exact_within_band(name, rng):
    x = jnp.array(rng.uniform(-4, 4, (512,)), jnp.float32)
    got = activate(x, name, HQ)
    want = activate(x, name, None)
    scale = float(jnp.abs(want).max()) + 1.0
    assert float(jnp.abs(got - want).max()) / scale < 0.05


def test_softmax_rows_normalised(rng):
    x = jnp.array(rng.normal(size=(8, 64)) * 3, jnp.float32)
    got = activate(x, "softmax", HQ, axis=-1)
    sums = np.asarray(got.sum(-1))
    assert np.all(np.abs(sums - 1.0) < 0.08)
    # argmax preserved (what classification accuracy actually needs)
    want = jax.nn.softmax(x, axis=-1)
    assert np.array_equal(np.asarray(got.argmax(-1)), np.asarray(want.argmax(-1)))


def test_paper_faithful_policy_is_8bit_5stage():
    assert PAPER_FAITHFUL_POLICY.bits == 8
    assert PAPER_FAITHFUL_POLICY.n_linear == 5
    x = jnp.linspace(-1, 1, 65)
    got = activate(x, "sigmoid", PAPER_FAITHFUL_POLICY)
    want = jax.nn.sigmoid(x)
    # Q3.4 resolution is 1/16; the 5-stage result must sit at that floor
    # (paper's Fig 4 shows ~1e-2..1e-1 MAE at 8 bits).
    res = PAPER_FAITHFUL_POLICY.fmt.resolution
    assert float(jnp.abs(got - want).mean()) < 1.5 * res


def test_ste_gradients_are_exact_derivative(rng):
    x = jnp.array(rng.uniform(-3, 3, (64,)), jnp.float32)
    for name, dfn in [("tanh", lambda v: 1 - jnp.tanh(v) ** 2),
                      ("sigmoid", lambda v: jax.nn.sigmoid(v) * (1 - jax.nn.sigmoid(v)))]:
        g = jax.grad(lambda v: activate(v, name, HQ).sum())(x)
        np.testing.assert_allclose(np.asarray(g), np.asarray(dfn(x)),
                                   rtol=1e-5, atol=1e-5)


def test_relu_zero_negative(rng):
    x = jnp.array(rng.uniform(-4, -0.1, (64,)), jnp.float32)
    assert float(jnp.abs(activate(x, "relu", HQ)).max()) == 0.0


def test_unknown_af_raises():
    with pytest.raises(ValueError):
        activate(jnp.zeros(4), "maxout", HQ)


def test_reuse_factors_match_paper_spirit():
    r = reuse_report()
    assert r["hyperbolic_reuse"] >= 0.8   # paper: 86%
    assert r["division_reuse"] >= 0.6     # paper: 72%


def test_all_supported_afs_run(rng):
    x = jnp.array(rng.normal(size=(4, 16)), jnp.float32)
    for name in SUPPORTED_AFS:
        out = activate(x, name, HQ)
        assert out.shape == x.shape
        assert bool(jnp.all(jnp.isfinite(out)))
