"""Paged slot memory + radix prefix cache invariants.

The contract under test (see ``models/paged.py``, ``runtime/block_pool.py``
and the paged paths of ``runtime/serve_loop.py``):

  * **bit-equality**: paged serving — block-table indirection, extend
    admissions, prefix-cache reuse — never changes a single output token
    vs the dense engine (native dtype), across attention / rwkv / hybrid
    state; warm (prefix-cached) admissions equal cold ones in every
    cache dtype, including the int8 requantize-on-load path
  * **no leaks**: the block free list balances after retire-and-refill
    and speculative rollback; retired slots return every page
  * **memory scaling**: resident K/V is ``num_blocks * page_size``
    tokens — an undersized pool still serves every request (blocks
    recycle through the free list), it never silently drops one
  * **one spelling of the cache format**: ``CacheSpec`` is validated and
    exclusive with the legacy knobs; ``ServeConfig`` replaces the kwarg
    sprawl (old kwargs warn but work); ``CacheOps`` is the documented
    backend seam (dense / paged are swappable implementations)
  * **trace discipline**: paged serving keeps one jit trace per program
    shape — extend/reset/decode counters stay flat across admissions
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import CacheSpec, get_arch
from repro.models import paged as paged_mod
from repro.models.model_zoo import (DenseCacheOps, PagedCacheOps,
                                    build_model)
from repro.runtime.block_pool import BlockAllocator, RadixCache
from repro.runtime.serve_loop import Request, ServeConfig, ServeEngine

MAX_SEQ = 64
PAGE = 8
FAMILIES = ["glm4-9b", "rwkv6-3b", "hymba-1.5b"]


@pytest.fixture(scope="module")
def served():
    """One model + params per family, shared across tests in this module."""
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = get_arch(arch).reduced()
            model = build_model(cfg)
            params = model.init(jax.random.PRNGKey(0))
            cache[arch] = (cfg, model, params)
        return cache[arch]

    return get


def _prefix_requests(cfg, n, seed=0, prefix_len=17, n_prefixes=2,
                     tail_range=(3, 10), max_news=(2, 4, 7)):
    """Shared-prefix trace: few long system prompts, many short tails."""
    rng = np.random.default_rng(seed)
    prefixes = [rng.integers(0, cfg.vocab_size, prefix_len)
                for _ in range(n_prefixes)]
    reqs = []
    for i in range(n):
        tail = rng.integers(0, cfg.vocab_size,
                            int(rng.integers(*tail_range)))
        prompt = np.concatenate([prefixes[i % n_prefixes],
                                 tail]).astype(np.int32)
        reqs.append(Request(i, prompt,
                            max_new_tokens=int(max_news[i % len(max_news)])))
    return reqs


def _paged_config(**kw):
    kw.setdefault("max_batch", 4)
    kw.setdefault("max_seq", MAX_SEQ)
    kw.setdefault("cache", CacheSpec(paged=True, page_size=PAGE))
    return ServeConfig(**kw)


# -- bit-equality ------------------------------------------------------------

@pytest.mark.parametrize("arch", FAMILIES)
def test_shared_prefix_bit_identical_to_dense(served, arch):
    """Paged serving with prefix reuse is a pure memory/scheduling change:
    every output token equals the dense engine's, and a real fraction of
    prompt tokens must have come from the radix cache (not recomputed)."""
    cfg, model, params = served(arch)
    paged = ServeEngine(model, params, _paged_config())
    dense = ServeEngine(model, params, ServeConfig(max_batch=4,
                                                   max_seq=MAX_SEQ))
    reqs_p = _prefix_requests(cfg, 8)
    reqs_d = _prefix_requests(cfg, 8)
    done_p = {r.rid: list(r.output) for r in paged.serve(reqs_p)}
    done_d = {r.rid: list(r.output) for r in dense.serve(reqs_d)}
    assert done_p == done_d, arch
    assert paged.metrics["prefix_hit_tokens"] > 0, \
        "the shared prefix never hit the radix cache"
    assert paged.metrics["prefill_tokens"] < dense.metrics["prefill_tokens"]


@pytest.mark.parametrize("arch,dtype", [("glm4-9b", "native"),
                                        ("glm4-9b", "int8"),
                                        ("rwkv6-3b", "native"),
                                        ("rwkv6-3b", "int8"),
                                        ("hymba-1.5b", "int8")])
def test_warm_admission_equals_cold(served, arch, dtype):
    """Replaying the same trace against a warm radix cache must be
    deterministic, and — whenever no new quantization boundary is
    introduced — reproduce the cold run token-for-token.

    Native state and int8 *attention* caches are exact regardless of how
    much prefix matched: stored K/V pages are bit-identical to what the
    cold run wrote, and exact-f32 recurrent snapshots reload losslessly.
    int8 *recurrent* state (rwkv wkv / hybrid ssm_h) requantizes at the
    admission point, so a *longer* warm match inserts a quantization
    boundary the cold run didn't have — there only warm-vs-warm (same
    match length) is bit-exact, and that is what gets pinned.
    """
    cfg, model, params = served(arch)
    spec = CacheSpec(dtype=dtype, paged=True, page_size=PAGE)
    engine = ServeEngine(model, params, _paged_config(cache=spec))
    runs = []
    for _ in range(3):
        done = engine.serve(_prefix_requests(cfg, 6, seed=5))
        runs.append({r.rid: list(r.output) for r in done})
    assert engine.metrics["prefix_hit_tokens"] > 0
    # run 2 inserted nothing new, so runs 2 and 3 match identical page
    # counts: bit-equality holds for every dtype/family combination
    assert runs[1] == runs[2], (arch, dtype)
    quant_recurrent = dtype == "int8" and cfg.family in ("ssm", "hybrid")
    if not quant_recurrent:
        assert runs[0] == runs[1], (arch, dtype)


def test_paged_int8_matches_dense_extend(served):
    """int8 paged numerics: the reference is the *dense extend* path (a
    quantized cache makes any incremental pass attend quantized K/V,
    while one-shot prefill attends the exact values — so prefill is the
    wrong oracle).  Same suffix scored through pooled pages must match
    the dense slot layout bit-for-bit."""
    cfg, model, params = served("glm4-9b")
    q = model.with_cache_spec(CacheSpec(dtype="int8"))
    qp = model.with_cache_spec(CacheSpec(dtype="int8", paged=True,
                                         page_size=PAGE))
    rng = np.random.default_rng(7)
    prompt = rng.integers(0, cfg.vocab_size, 13).astype(np.int32)
    toks = jnp.asarray(prompt[None, :])
    adv = np.array([len(prompt)], np.int32)

    st_d = q.init_slot_state(1, MAX_SEQ)
    lg_d, st_d, rec = q.verify_step(params, st_d, {"tokens": toks})
    st_d = q.spec_commit(st_d, rec, adv)

    ops = qp.cache_ops(num_blocks=MAX_SEQ // PAGE)
    st_p = ops.init_slot_state(1, MAX_SEQ)
    tables = np.arange(MAX_SEQ // PAGE, dtype=np.int32)[None, :]
    st_p = st_p._replace(block_tables=jnp.asarray(tables))
    lg_p, st_p, rec = qp.verify_step(params, st_p, {"tokens": toks})
    st_p = ops.spec_commit(st_p, rec, adv)
    np.testing.assert_array_equal(np.asarray(lg_d, np.float32),
                                  np.asarray(lg_p, np.float32))

    for _ in range(4):
        t = jnp.asarray([[int(jnp.argmax(lg_d[0, -1]))]], jnp.int32)
        lg_d, st_d = q.decode_step(params, st_d, {"tokens": t})
        lg_p, st_p = qp.decode_step(params, st_p, {"tokens": t})
        np.testing.assert_array_equal(np.asarray(lg_d, np.float32),
                                      np.asarray(lg_p, np.float32))


# -- block accounting --------------------------------------------------------

def _radix_block_count(radix):
    count = 0

    def walk(node):
        nonlocal count
        for c in node.children.values():
            if c.block is not None:
                count += 1
            walk(c)
    walk(radix.root)
    return count


def test_free_list_never_leaks(served):
    """After every request retires, only the radix cache may hold blocks
    — across plain decode, speculative rollback, and a refill run."""
    cfg, model, params = served("glm4-9b")
    engine = ServeEngine(model, params, _paged_config(max_batch=2,
                                                      spec_k=3))
    for seed in (0, 1):      # second run refills over a warm engine
        done = engine.serve(_prefix_requests(cfg, 6, seed=seed))
        assert len(done) == 6
        engine.allocator.assert_balanced()
        sentinel = engine.ops.num_blocks
        assert (engine._tables == sentinel).all(), \
            "a retired slot kept table entries"
        assert engine.allocator.used_blocks == \
            _radix_block_count(engine.radix)

    # without the prefix cache nothing may survive the trace at all
    bare = ServeEngine(model, params, _paged_config(prefix_cache=False))
    bare.serve(_prefix_requests(cfg, 5))
    bare.allocator.assert_balanced()
    assert bare.allocator.used_blocks == 0


def test_block_allocator_guards():
    alloc = BlockAllocator(2)
    a = alloc.alloc()
    alloc.free(a)
    with pytest.raises(ValueError, match="double free"):
        alloc.free(a)
    with pytest.raises(ValueError, match="dead block"):
        alloc.ref(a)
    b = alloc.alloc()
    alloc.ref(b)
    alloc.free(b)
    assert alloc.refcount(b) == 1      # still held by the second ref
    alloc.assert_balanced()


def test_radix_match_leaves_a_suffix_token():
    """A full-prompt match must still leave >= 1 token to compute (the
    extend pass has to produce the prompt's next-token logits)."""
    alloc = BlockAllocator(8)
    radix = RadixCache(alloc, page_size=4)
    toks = np.arange(8, dtype=np.int32)
    blocks = [alloc.alloc(), alloc.alloc()]
    radix.insert(toks, 8, blocks)
    m, nodes = radix.match(toks)
    assert m == 4 and len(nodes) == 1      # page 2 would leave no suffix
    m, nodes = radix.match(np.arange(9, dtype=np.int32))
    assert m == 8 and len(nodes) == 2


def test_memory_scales_with_live_tokens(served):
    """An undersized pool (far below max_batch * max_seq worth of pages)
    still serves the whole trace — blocks recycle at retire — and the
    resident pool is the allocation, not the dense worst case."""
    cfg, model, params = served("glm4-9b")
    num_blocks = 12           # vs 2 * 64/8 = 16 for full occupancy
    engine = ServeEngine(model, params,
                         _paged_config(max_batch=2, num_blocks=num_blocks))
    done = engine.serve(_prefix_requests(cfg, 10, seed=2))
    assert len(done) == 10, "undersized pool dropped requests"
    assert engine._state.cache_k.shape[1] == num_blocks
    dense_tokens = 2 * MAX_SEQ
    assert num_blocks * PAGE < dense_tokens
    assert engine.metrics["peak_blocks"] <= num_blocks


# -- API surface -------------------------------------------------------------

def test_cache_spec_validation():
    with pytest.raises(ValueError, match="dtype"):
        CacheSpec(dtype="fp8")
    with pytest.raises(ValueError, match="block"):
        CacheSpec(dtype="int8", block=0)
    with pytest.raises(ValueError, match="fxp8"):
        CacheSpec(dtype="fxp8", paged=True)
    assert CacheSpec(dtype="int8").quantized
    assert not CacheSpec().quantized


def test_cache_spec_excludes_legacy_knobs():
    cfg = get_arch("glm4-9b").reduced()
    mixed = dataclasses.replace(cfg, cache=CacheSpec(dtype="int8"),
                                cache_quant="int8")
    with pytest.raises(ValueError, match="legacy spelling"):
        mixed.cache_spec()
    # with_cache_spec clears the legacy knobs, so no conflict survives
    m = build_model(cfg).with_cache_dtype("int8")
    m2 = m.with_cache_spec(CacheSpec(dtype="int8", paged=True,
                                     page_size=PAGE))
    assert m2.cfg.cache_quant == "none"
    assert m2.cfg.cache_spec().paged


def test_serve_config_replaces_kwargs(served):
    cfg, model, params = served("glm4-9b")
    with pytest.raises(ValueError, match="exactly one"):
        ServeConfig(cache=CacheSpec(dtype="int8"), cache_dtype="int8")
    with pytest.raises(ValueError, match="max_batch"):
        ServeConfig(max_batch=0)
    with pytest.raises(TypeError, match="not both"):
        ServeEngine(model, params, ServeConfig(), max_batch=2)
    # the legacy kwarg spelling still works, with a deprecation warning
    with pytest.warns(DeprecationWarning, match="ServeConfig"):
        eng = ServeEngine(model, params, max_batch=2, max_seq=MAX_SEQ)
    assert eng.max_batch == 2 and not eng.paged


def test_cache_ops_backends(served):
    cfg, model, params = served("glm4-9b")
    assert isinstance(model.cache_ops(), DenseCacheOps)
    pm = model.with_cache_spec(CacheSpec(paged=True, page_size=PAGE))
    with pytest.raises(ValueError, match="num_blocks"):
        pm.cache_ops()
    ops = pm.cache_ops(num_blocks=4)
    assert isinstance(ops, PagedCacheOps) and ops.paged
    with pytest.raises(NotImplementedError, match="extend in place"):
        ops.slot_update(None, None, None)
    with pytest.raises(ValueError, match="multiple"):
        paged_mod.init_paged_slot_state(pm.cfg, 2, 30, 4, PAGE)
    # pool memory is num_blocks pages, not max_batch * max_seq
    st = ops.init_slot_state(4, MAX_SEQ, abstract=True)
    assert st.cache_k.shape[1] == 4 and st.cache_k.shape[2] == PAGE
    assert st.block_tables.shape == (4, MAX_SEQ // PAGE)


def test_paged_trace_discipline(served):
    """Admission-composition changes must not retrace the paged programs:
    one reset trace, one extend trace per suffix bucket, one decode."""
    cfg, model, params = served("glm4-9b")
    engine = ServeEngine(model, params, _paged_config(min_bucket=16))
    # 7 requests -> a cold first group (32-token suffix bucket) and warm
    # refill groups (16-token bucket): both extend shapes get traced
    engine.serve(_prefix_requests(cfg, 7, seed=0))
    first = dict(engine.trace_counts)
    assert first["reset"] == 1 and first["decode"] == 1
    assert first["extend"] == 2
    # fresh prefixes, different group sizes / tails / budgets — the same
    # two suffix buckets, so not a single new trace
    engine.serve(_prefix_requests(cfg, 7, seed=9))
    assert dict(engine.trace_counts) == first, "retrace within a bucket"
