"""AdamW with optionally int8-quantized moments (the paper's quantization
co-design applied to optimizer state — what lets arctic-480b's optimizer fit
the 16 GB/chip HBM budget; see DESIGN.md §Memory).

Moments are stored per-parameter as int8 raw + per-slice fp32 absmax scales
(block size = last axis), dequantized on the fly inside the update.  The
estimator is error-compensated by re-quantizing *after* the moment update,
so quantization noise does not accumulate as drift.

Also provides:
  * decoupled weight decay, bias-corrected betas,
  * global-norm clipping,
  * pruning-mask-aware updates (pruned weights stay exactly zero).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    moment_dtype: str = "float32"      # float32 | int8
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


class QMoment(NamedTuple):
    """int8 moment + per-row scale.  Second moments are stored in sqrt
    space (quantized sqrt(v)): the compressed dynamic range plus a half-ulp
    dequantization floor keeps 1/sqrt(v) bounded when tiny entries would
    otherwise quantize to exactly zero.  Whether a moment is sqrt-space is
    positional (m vs v), not stored, so the pytree stays trace-friendly."""
    q: Array
    scale: Array


def _quantize_moment(m: Array, sqrt_space: bool = False) -> QMoment:
    v = jnp.sqrt(jnp.maximum(m, 0.0)) if sqrt_space else m
    if v.ndim == 0:
        amax = jnp.abs(v)
    else:
        amax = jnp.max(jnp.abs(v), axis=-1, keepdims=True)
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(v / scale), -127, 127).astype(jnp.int8)
    return QMoment(q, scale.astype(jnp.float32))


def _dequantize_moment(qm: QMoment, sqrt_space: bool = False) -> Array:
    v = qm.q.astype(jnp.float32)
    if sqrt_space:
        # half-ulp floor: a stored zero means "below scale/2", not 0 —
        # bounds the rsqrt without inflating eps for healthy entries.
        v = jnp.maximum(jnp.abs(v), 0.5) * qm.scale
        return v * v
    return v * qm.scale


class AdamWState(NamedTuple):
    step: Array
    m: Any
    v: Any


def lr_at(cfg: AdamWConfig, step: Array) -> Array:
    """Linear warmup + cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    t = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    t = jnp.clip(t, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * t))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init(cfg: AdamWConfig, params) -> AdamWState:
    def zero_like(sqrt_space):
        def f(p):
            z = jnp.zeros(p.shape, jnp.float32)
            if cfg.moment_dtype == "int8":
                return _quantize_moment(z, sqrt_space)
            return z
        return f
    m = jax.tree_util.tree_map(zero_like(False), params)
    v = jax.tree_util.tree_map(zero_like(True), params)
    return AdamWState(jnp.zeros((), jnp.int32), m, v)


def global_norm(tree) -> Array:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(sum(leaves))


def update(cfg: AdamWConfig, grads, state: AdamWState, params,
           masks=None) -> Tuple[Any, AdamWState, Dict[str, Array]]:
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    if cfg.clip_norm is not None:
        # scale applied per-block inside the update (no f32 grad tree copy)
        gscale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    else:
        gscale = jnp.float32(1.0)

    lr = lr_at(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)
    quant = cfg.moment_dtype == "int8"

    def upd_block(p, g, m, v, mask):
        g = g.astype(jnp.float32) * gscale
        m_f = _dequantize_moment(m, False) if quant else m
        v_f = _dequantize_moment(v, True) if quant else v
        m_f = b1 * m_f + (1 - b1) * g
        v_f = b2 * v_f + (1 - b2) * g * g
        mhat = m_f / c1
        vhat = v_f / c2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * delta
        if mask is not None:
            new_p = new_p * mask
        new_m = _quantize_moment(m_f, False) if quant else m_f
        new_v = _quantize_moment(v_f, True) if quant else v_f
        return new_p.astype(p.dtype), new_m, new_v

    # Blockwise update for huge stacked leaves (arctic's (L, E, D, F)
    # expert slabs): scanning the leading axis keeps the f32 dequant/
    # requant temporaries at 1/L of the tensor instead of ~6 whole-tensor
    # f32 copies — the dominant train-step memory term without it.
    BLOCK_SCAN_MIN = 1 << 28  # elements

    def upd(p, g, m, v, mask):
        if p.ndim >= 3 and p.size >= BLOCK_SCAN_MIN and mask is None:
            def body(_, xs):
                return None, upd_block(*xs, None)
            _, out = jax.lax.scan(body, None, (p, g, m, v))
            return out
        return upd_block(p, g, m, v, mask)

    if masks is None:
        masks = jax.tree_util.tree_map(lambda _: None, params)

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    flat_mask = treedef.flatten_up_to(masks)
    out = [upd(p, g, m, v, mk) for p, g, m, v, mk in
           zip(flat_p, flat_g, flat_m, flat_v, flat_mask)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(step, new_m, new_v), metrics
