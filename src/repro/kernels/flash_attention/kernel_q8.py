"""Pallas TPU kernel: causal flash attention over an int8 quantized cache.

The serving counterpart of ``kernel.py``: K/V arrive as int8 with one
float32 scale per (kv head, position) vector — the per-block quantized
cache format of :mod:`repro.core.quant_cache` — and are dequantized
**inside the kernel**, per K-tile, in VMEM.  The HBM traffic for the K/V
sweep (the decode/verify bottleneck) drops ~4x vs f32 / ~2x vs bf16; the
online-softmax math itself is unchanged f32, so the only divergence from
the float kernel is the cache round-trip the caller already accepted.

Same grid (heads, q_blocks, k_blocks) and output-stationary m/l/acc
discipline as ``_flash_kernel``; GQA again rides on the K/V index maps.
Forward-only: the quantized cache is a serving artifact, nothing
differentiates through it.

TPU note: int8 VMEM tiles want (32, 128) multiples — production shapes
(Sk >= 128, d a lane multiple) satisfy this; tiny smoke shapes run in
interpret mode anyway (see ``common.resolve_interpret``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import common

NEG_INF = -1e30


def _flash_q8_kernel(q_ref, k_ref, v_ref, ks_ref, vs_ref, o_ref,
                     m_scr, l_scr, acc_scr, *, bq: int, bk: int,
                     scale: float, causal: bool, nk: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = iq * bq
    k_start = ik * bk
    live = jnp.logical_or(not causal,
                          k_start <= q_start + bq - 1)

    @pl.when(live)
    def _step():
        q = q_ref[0].astype(jnp.float32)                   # (bq, d)
        # in-VMEM dequant: one f32 scale per cached vector (row)
        k = k_ref[0].astype(jnp.float32) * ks_ref[0][:, None]   # (bk, d)
        v = v_ref[0].astype(jnp.float32) * vs_ref[0][:, None]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale    # (bq, bk)
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_prev * alpha + jnp.sum(p, axis=-1)
        m_scr[...] = m_new
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ik == nk - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention_q8_nhd(q: jax.Array, k: jax.Array, v: jax.Array,
                           k_scale: jax.Array, v_scale: jax.Array, *,
                           causal: bool = True, block_q: int = 128,
                           block_k: int = 128, group: int = 1,
                           interpret: bool = True) -> jax.Array:
    """q: (Hq, Sq, d) float; k/v: (Hkv, Sk, d) int8 with per-vector
    float32 scales (Hkv, Sk); Hq = group * Hkv.  Returns (Hq, Sq, d) in
    q's dtype.  Sq/Sk must tile by the blocks (clamped to divisors)."""
    hq, sq, d = q.shape
    hkv, sk, _ = k.shape
    assert hq == group * hkv, (hq, hkv, group)
    assert k.dtype == jnp.int8 and v.dtype == jnp.int8, (k.dtype, v.dtype)
    bq = common.largest_divisor(sq, block_q)
    bk = common.largest_divisor(sk, block_k)
    nk = sk // bk
    grid = (hq, sq // bq, nk)
    kernel = functools.partial(_flash_q8_kernel, bq=bq, bk=bk,
                               scale=1.0 / (d ** 0.5), causal=causal, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, d), lambda h, i, j, g=group: (h // g, j, 0)),
            pl.BlockSpec((1, bk, d), lambda h, i, j, g=group: (h // g, j, 0)),
            pl.BlockSpec((1, bk), lambda h, i, j, g=group: (h // g, j)),
            pl.BlockSpec((1, bk), lambda h, i, j, g=group: (h // g, j)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        compiler_params=common.compiler_params("parallel", "parallel",
                                               "arbitrary"),
        interpret=interpret,
    )(q, k, v, k_scale.astype(jnp.float32), v_scale.astype(jnp.float32))
