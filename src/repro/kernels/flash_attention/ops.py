"""Jit'd public wrapper: (B, S, H, d) GQA frontend for the flash kernel."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import common
from repro.kernels.flash_attention.kernel import flash_attention_nhd
from repro.kernels.flash_attention.kernel_bwd import flash_attention_bwd_nhd
from repro.kernels.flash_attention.kernel_q8 import flash_attention_q8_nhd
from repro.kernels.flash_attention.ref import (attention_bwd_ref,
                                               attention_nhd_ref,
                                               attention_q8_nhd_ref)


def _to_hsd(x):
    return x.transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def _fwd(q, k, v, causal: bool, block_q: int, block_k: int, interpret: bool):
    b, sq, hq, d = q.shape
    _, sk, hkv, _ = k.shape
    group = hq // hkv
    return jax.vmap(
        lambda qq, kk, vv: flash_attention_nhd(
            qq, kk, vv, causal=causal, block_q=block_q, block_k=block_k,
            group=group, interpret=interpret)
    )(_to_hsd(q), _to_hsd(k), _to_hsd(v)).transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def _fwd_res(q, k, v, causal: bool, block_q: int, block_k: int,
             interpret: bool):
    """Forward also emitting the per-row LSE residual, (B, Hq, Sq) f32."""
    b, sq, hq, d = q.shape
    _, sk, hkv, _ = k.shape
    group = hq // hkv
    out, lse = jax.vmap(
        lambda qq, kk, vv: flash_attention_nhd(
            qq, kk, vv, causal=causal, block_q=block_q, block_k=block_k,
            group=group, interpret=interpret, return_residuals=True)
    )(_to_hsd(q), _to_hsd(k), _to_hsd(v))
    return out.transpose(0, 2, 1, 3), lse


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def _bwd_impl(q, k, v, o, lse, do, causal: bool, block_q: int, block_k: int,
              interpret: bool):
    """Fused backward on the public layout; cotangents in primal dtypes."""
    b, sq, hq, d = q.shape
    _, sk, hkv, _ = k.shape
    group = hq // hkv
    # softmax-VJP correction term, one float per row: O(S d) jnp work.
    delta = jnp.einsum("bshd,bshd->bhs", do.astype(jnp.float32),
                       o.astype(jnp.float32))
    dq, dk, dv = jax.vmap(
        lambda qq, kk, vv, dd, ll, de: flash_attention_bwd_nhd(
            qq, kk, vv, dd, ll, de, causal=causal, block_q=block_q,
            block_k=block_k, group=group, interpret=interpret)
    )(_to_hsd(q), _to_hsd(k), _to_hsd(v), _to_hsd(do), lse, delta)
    return (dq.transpose(0, 2, 1, 3).astype(q.dtype),
            dk.transpose(0, 2, 1, 3).astype(k.dtype),
            dv.transpose(0, 2, 1, 3).astype(v.dtype))


def _exact_attention(q, k, v, *, causal: bool):
    """Materialised-scores float reference on the (B, S, H, d) layout —
    the STE backward (exact attention VJP, O(S^2) memory)."""
    group = q.shape[2] // k.shape[2]
    return jax.vmap(
        lambda qq, kk, vv: attention_nhd_ref(qq, kk, vv, causal=causal,
                                             group=group)
    )(_to_hsd(q), _to_hsd(k), _to_hsd(v)).transpose(0, 2, 1, 3)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, block_q: Optional[int] = None,
                    block_k: Optional[int] = None,
                    interpret: Optional[bool] = None) -> jax.Array:
    """q: (B, Sq, Hq, d); k/v: (B, Sk, Hkv, d).  Returns (B, Sq, Hq, d).

    ``block_q``/``block_k`` default through the substrate cache keyed on
    (Sq, Sk) — tuned-table entries apply; the heuristic matches the old
    fixed 128 default (the kernel clamps to a divisor either way).  The
    pick happens outside the jitted forward so tuned entries retrace, and
    is skipped entirely when both blocks are passed explicitly.

    Differentiable: the backward pass is the fused recompute kernel pair
    in ``kernel_bwd.py`` (its tiles resolve through the substrate under
    the ``flash_attention.bwd`` key), or the exact VJP of the materialised
    float reference when ``REPRO_FUSED_BWD=0``.
    """
    interpret = common.resolve_interpret(interpret)
    if block_q is None or block_k is None:
        bq, bk = common.pick_block_2d("flash_attention",
                                      (q.shape[1], k.shape[1]), q.dtype,
                                      max_rows=128, max_cols=128)
        block_q = block_q if block_q is not None else bq
        block_k = block_k if block_k is not None else bk
    fwd = functools.partial(_fwd, causal=causal, block_q=block_q,
                            block_k=block_k, interpret=interpret)
    grad = functools.partial(_exact_attention, causal=causal)
    fwd_res = bwd = None
    if common.fused_backward_enabled():
        # The backward keeps the head axis whole inside the tile, so the
        # (hq, bq, bk) score tensor bounds the tile on TPU; off-TPU the
        # interpreter wants the fewest grid steps it can get.
        cap = 128 if common.on_tpu() else 512
        bq_b, bk_b = common.pick_block_2d("flash_attention.bwd",
                                          (q.shape[1], k.shape[1]), q.dtype,
                                          max_rows=cap, max_cols=cap)

        def fwd_res(q_, k_, v_):
            out, lse = _fwd_res(q_, k_, v_, causal, block_q, block_k,
                                interpret)
            return out, (q_, k_, v_, out, lse)

        def bwd(res, g):
            q_, k_, v_, o_, lse = res
            return _bwd_impl(q_, k_, v_, o_, lse, g, causal, bq_b, bk_b,
                             interpret)

    return common.fused_vjp(fwd, grad, fwd_res, bwd)(q, k, v)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def _fwd_q8(q, k, v, k_scale, v_scale, causal: bool, block_q: int,
            block_k: int, interpret: bool):
    b, sq, hq, d = q.shape
    _, sk, hkv, _ = k.shape
    group = hq // hkv
    return jax.vmap(
        lambda qq, kk, vv, ks, vs: flash_attention_q8_nhd(
            qq, kk, vv, ks, vs, causal=causal, block_q=block_q,
            block_k=block_k, group=group, interpret=interpret)
    )(_to_hsd(q), _to_hsd(k), _to_hsd(v),
      k_scale.transpose(0, 2, 1), v_scale.transpose(0, 2, 1)
      ).transpose(0, 2, 1, 3)


def flash_attention_q8(q: jax.Array, k: jax.Array, v: jax.Array,
                       k_scale: jax.Array, v_scale: jax.Array, *,
                       causal: bool = True, block_q: Optional[int] = None,
                       block_k: Optional[int] = None,
                       interpret: Optional[bool] = None) -> jax.Array:
    """Quantized-cache attention.  q: (B, Sq, Hq, d) float; k/v:
    (B, Sk, Hkv, d) int8 with per-vector float32 scales (B, Sk, Hkv) —
    the layout :func:`repro.core.quant_cache.quantize_blocked` yields on
    the serving KV cache (scales squeezed to drop the block axis).

    Blocks resolve through the substrate under the ``flash_attention.q8``
    key (int8 dtype) — tuned independently of the float forward, since
    the best K tile shifts when the K/V stream is 4x narrower.
    Forward-only: the quantized cache is never differentiated through.
    """
    interpret = common.resolve_interpret(interpret)
    if block_q is None or block_k is None:
        bq, bk = common.pick_block_2d("flash_attention.q8",
                                      (q.shape[1], k.shape[1]), k.dtype,
                                      max_rows=128, max_cols=128)
        block_q = block_q if block_q is not None else bq
        block_k = block_k if block_k is not None else bk
    return _fwd_q8(q, k, v, k_scale, v_scale, causal=causal,
                   block_q=block_q, block_k=block_k, interpret=interpret)


def _candidates(shape, dtype):
    """(block_q, block_k) candidates for the (Sq, Sk) key: divisors keep
    the kernel's own clamp a no-op, so the measured block is the run
    block."""
    sq, sk = shape
    return tuple((bq, bk)
                 for bq in common.divisor_candidates(sq, 256, 3)
                 for bk in common.divisor_candidates(sk, 256, 3))


def _bwd_candidates(shape, dtype):
    """Backward tiles for the same (Sq, Sk) key.  The sweep spans small
    tiles (VMEM-bound: the passes hold an all-heads (hq, bq, bk) score
    tensor) through large ones (interpret-mode-bound: grid-step count);
    candidates that overflow VMEM on device are skipped by autotune."""
    sq, sk = shape
    return tuple((bq, bk)
                 for bq in common.divisor_candidates(sq, 512, 3)
                 for bk in common.divisor_candidates(sk, 512, 3))


common.register(common.KernelSpec(
    name="flash_attention", kernel=flash_attention_nhd,
    ref=attention_nhd_ref, grad=_exact_attention,
    grad_kernel=flash_attention_bwd_nhd,
    candidates=_candidates, tags=("float", "attention")))

# Backward tiles tune independently of the forward's: same cache-key
# shape, own registry entry so `benchmarks.tune` sweeps it.
common.register(common.KernelSpec(
    name="flash_attention.bwd", kernel=flash_attention_bwd_nhd,
    ref=attention_bwd_ref, candidates=_bwd_candidates,
    tags=("float", "attention", "backward")))

# Quantized-cache forward: same (Sq, Sk) cache-key shape, int8 dtype key,
# own registry entry so `benchmarks.tune` sweeps its tiles separately.
common.register(common.KernelSpec(
    name="flash_attention.q8", kernel=flash_attention_q8_nhd,
    ref=attention_q8_nhd_ref, candidates=_candidates,
    tags=("int8", "attention", "serving")))
