"""Jit'd public wrapper: (B, S, H, d) GQA frontend for the flash kernel."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import common
from repro.kernels.flash_attention.kernel import flash_attention_nhd
from repro.kernels.flash_attention.ref import attention_nhd_ref


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def _fwd(q, k, v, causal: bool, block_q: int, block_k: int, interpret: bool):
    b, sq, hq, d = q.shape
    _, sk, hkv, _ = k.shape
    group = hq // hkv
    return jax.vmap(
        lambda qq, kk, vv: flash_attention_nhd(
            qq, kk, vv, causal=causal, block_q=block_q, block_k=block_k,
            group=group, interpret=interpret)
    )(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
      v.transpose(0, 2, 1, 3)).transpose(0, 2, 1, 3)


def _exact_attention(q, k, v, *, causal: bool):
    """Materialised-scores float reference on the (B, S, H, d) layout —
    the STE backward (exact attention VJP, O(S^2) memory)."""
    group = q.shape[2] // k.shape[2]
    return jax.vmap(
        lambda qq, kk, vv: attention_nhd_ref(qq, kk, vv, causal=causal,
                                             group=group)
    )(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
      v.transpose(0, 2, 1, 3)).transpose(0, 2, 1, 3)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, block_q: Optional[int] = None,
                    block_k: Optional[int] = None,
                    interpret: Optional[bool] = None) -> jax.Array:
    """q: (B, Sq, Hq, d); k/v: (B, Sk, Hkv, d).  Returns (B, Sq, Hq, d).

    ``block_q``/``block_k`` default through the substrate cache keyed on
    (Sq, Sk) — tuned-table entries apply; the heuristic matches the old
    fixed 128 default (the kernel clamps to a divisor either way).  The
    pick happens outside the jitted forward so tuned entries retrace.
    """
    interpret = common.resolve_interpret(interpret)
    if block_q is None or block_k is None:
        bq, bk = common.pick_block_2d("flash_attention",
                                      (q.shape[1], k.shape[1]), q.dtype,
                                      max_rows=128, max_cols=128)
        block_q = block_q if block_q is not None else bq
        block_k = block_k if block_k is not None else bk
    f = common.ste(
        functools.partial(_fwd, causal=causal, block_q=block_q,
                          block_k=block_k, interpret=interpret),
        functools.partial(_exact_attention, causal=causal))
    return f(q, k, v)


def _candidates(shape, dtype):
    """(block_q, block_k) candidates for the (Sq, Sk) key: divisors keep
    the kernel's own clamp a no-op, so the measured block is the run
    block."""
    sq, sk = shape
    return tuple((bq, bk)
                 for bq in common.divisor_candidates(sq, 256, 3)
                 for bk in common.divisor_candidates(sk, 256, 3))


common.register(common.KernelSpec(
    name="flash_attention", kernel=flash_attention_nhd,
    ref=attention_nhd_ref, grad=_exact_attention,
    candidates=_candidates, tags=("float", "attention")))
