"""Jit'd public wrapper: (B, S, H, d) GQA frontend for the flash kernel."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_nhd

_ON_TPU = any(d.platform == "tpu" for d in jax.devices())


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, block_q: int = 128,
                    block_k: int = 128,
                    interpret: Optional[bool] = None) -> jax.Array:
    """q: (B, Sq, Hq, d); k/v: (B, Sk, Hkv, d).  Returns (B, Sq, Hq, d)."""
    if interpret is None:
        interpret = not _ON_TPU
    b, sq, hq, d = q.shape
    _, sk, hkv, _ = k.shape
    group = hq // hkv
    qn = q.transpose(0, 2, 1, 3).reshape(b * hq, sq, d)
    kn = k.transpose(0, 2, 1, 3).reshape(b * hkv, sk, d)
    vn = v.transpose(0, 2, 1, 3).reshape(b * hkv, sk, d)
    out = jax.vmap(
        lambda qq, kk, vv: flash_attention_nhd(
            qq, kk, vv, causal=causal, block_q=block_q, block_k=block_k,
            group=group, interpret=interpret)
    )(qn.reshape(b, hq, sq, d), kn.reshape(b, hkv, sk, d),
      vn.reshape(b, hkv, sk, d))
    return out.transpose(0, 2, 1, 3)
