"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_nhd_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True, group: int = 1) -> jax.Array:
    """Materialised-scores reference.  q (Hq,Sq,d); k/v (Hkv,Sk,d)."""
    hq, sq, d = q.shape
    hkv, sk, _ = k.shape
    kk = jnp.repeat(k, group, axis=0)
    vv = jnp.repeat(v, group, axis=0)
    s = jnp.einsum("hqd,hkd->hqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) / (d ** 0.5)
    if causal:
        mask = jnp.tril(jnp.ones((sq, sk), bool), k=sk - sq)
        s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("hqk,hkd->hqd", p,
                      vv.astype(jnp.float32)).astype(q.dtype)


def attention_q8_nhd_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         k_scale: jax.Array, v_scale: jax.Array, *,
                         causal: bool = True, group: int = 1) -> jax.Array:
    """Oracle for the quantized-cache kernel: dequantize (one float32
    scale per (kv head, position) vector), then the float reference.
    k/v (Hkv,Sk,d) int8; scales (Hkv,Sk)."""
    kk = k.astype(jnp.float32) * k_scale.astype(jnp.float32)[..., None]
    vv = v.astype(jnp.float32) * v_scale.astype(jnp.float32)[..., None]
    return attention_nhd_ref(q, kk, vv, causal=causal, group=group)


def attention_bwd_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                      do: jax.Array, *, causal: bool = True,
                      group: int = 1):
    """Exact (dq, dk, dv) via autodiff of the materialised reference —
    the oracle for the fused backward kernels."""
    _, vjp = jax.vjp(
        lambda q_, k_, v_: attention_nhd_ref(q_, k_, v_, causal=causal,
                                             group=group), q, k, v)
    return vjp(do)
