"""Pallas TPU kernel: causal flash attention (online softmax).

Beyond-paper perf layer for prefill_32k: never materialises the (S x S)
score matrix.  Grid (heads, q_blocks, k_blocks) with the K axis innermost;
the output tile plus running (max, sum) statistics stay pinned in VMEM
scratch across the K sweep — the same output-stationary discipline as the
paper's SYCore, applied to attention.

Causally-dead (q_block, k_block) pairs are skipped with ``pl.when`` (the
scheduler-level analogue of CAESAR's zero-skip gating).

GQA is handled in ops.py via the K/V BlockSpec index map (q head h reads
kv head h // group) — no materialised head replication.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import common

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *rest, bq: int, bk: int,
                  scale: float, causal: bool, nk: int, with_lse: bool):
    if with_lse:
        lse_ref, m_scr, l_scr, acc_scr = rest
    else:
        lse_ref, (m_scr, l_scr, acc_scr) = None, rest
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = iq * bq
    k_start = ik * bk
    live = jnp.logical_or(not causal,
                          k_start <= q_start + bq - 1)

    @pl.when(live)
    def _step():
        q = q_ref[0].astype(jnp.float32)              # (bq, d)
        k = k_ref[0].astype(jnp.float32)              # (bk, d)
        v = v_ref[0].astype(jnp.float32)              # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (bq, bk)
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_prev * alpha + jnp.sum(p, axis=-1)
        m_scr[...] = m_new
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ik == nk - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)
        if with_lse:
            # Per-row log-sum-exp of the scaled scores: the O(S) residual
            # the fused backward recomputes score tiles against.
            lse_ref[0] = m_scr[...] + jnp.log(denom)


def flash_attention_nhd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, block_q: int = 128,
                        block_k: int = 128, group: int = 1,
                        interpret: bool = True,
                        return_residuals: bool = False):
    """q: (Hq, Sq, d); k/v: (Hkv, Sk, d) with Hq = group * Hkv.

    Returns (Hq, Sq, d) in q's dtype.  Sq/Sk must tile by the blocks.
    With ``return_residuals`` also returns the per-row log-sum-exp of the
    scaled scores, shape (Hq, Sq) float32 — the O(S) residual the fused
    backward (see ``kernel_bwd.py``) recomputes score tiles against.
    """
    hq, sq, d = q.shape
    hkv, sk, _ = k.shape
    assert hq == group * hkv, (hq, hkv, group)
    bq = common.largest_divisor(sq, block_q)
    bk = common.largest_divisor(sk, block_k)
    nk = sk // bk
    grid = (hq, sq // bq, nk)
    kernel = functools.partial(_flash_kernel, bq=bq, bk=bk,
                               scale=1.0 / (d ** 0.5), causal=causal, nk=nk,
                               with_lse=return_residuals)
    out_specs = pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0))
    out_shape = jax.ShapeDtypeStruct((hq, sq, d), q.dtype)
    if return_residuals:
        out_specs = [out_specs,
                     pl.BlockSpec((1, bq), lambda h, i, j: (h, i))]
        out_shape = [out_shape,
                     jax.ShapeDtypeStruct((hq, sq), jnp.float32)]
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, d), lambda h, i, j, g=group: (h // g, j, 0)),
            pl.BlockSpec((1, bk, d), lambda h, i, j, g=group: (h // g, j, 0)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        compiler_params=common.compiler_params("parallel", "parallel",
                                               "arbitrary"),
        interpret=interpret,
    )(q, k, v)
