"""Pallas TPU kernels: fused flash-attention backward (recompute scheme).

The forward stashes one float per row — the log-sum-exp of the scaled
scores (``return_residuals=True`` in ``kernel.py``) — and the backward
rebuilds each probability tile on the fly as

    p = exp(q k^T * scale - lse)

instead of differentiating through a materialised (S x S) score matrix.
O(S) residual memory where the STE fallback pays O(S^2): the same
trade-cheap-recompute-for-expensive-storage move the paper's engines make
in hardware.

Two passes, both tiled and both skipping causally-dead tiles, and both
keeping the **head axis whole inside the block**: the grid runs over
sequence tiles only, and every contraction is one hkv-batched
``dot_general`` across all heads — fewer grid steps, fuller MXU shapes,
and the GQA group-sum falls out of the contraction instead of a
wrapper-side reduction:

  * **dQ** — grid (q_blocks, k_blocks), K innermost; the (Hq, bq, d) dQ
    tile accumulates in VMEM scratch across the K sweep
    (output-stationary).
  * **dK/dV** — grid (k_blocks, q_blocks), Q innermost; the (Hkv, bk, d)
    dK and dV tiles accumulate across the Q sweep, summing each group of
    q heads into its kv head inside the contraction.

Both consume ``delta = rowsum(dO * O)`` (the softmax-VJP correction term),
computed once in jnp by the wrapper — O(S d) work, no kernel needed.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import common
from repro.kernels.flash_attention.kernel import NEG_INF


def _tile_grads(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                q_start, k_start, *, bq, bk, scale, causal, group):
    """Recompute p and ds for one (all-heads, bq, bk) tile pair.

    Returns (p, ds, q_r, k, do_r) with p/ds shaped (hkv, g, bq, bk) and
    q_r/do_r (hkv, g, bq, d) — everything the two passes contract from.
    """
    hq = q_ref.shape[0]
    hkv = hq // group
    d = q_ref.shape[-1]
    q_r = q_ref[...].astype(jnp.float32).reshape(hkv, group, bq, d)
    do_r = do_ref[...].astype(jnp.float32).reshape(hkv, group, bq, d)
    k = k_ref[...].astype(jnp.float32)                 # (hkv, bk, d)
    v = v_ref[...].astype(jnp.float32)
    lse = lse_ref[...].reshape(hkv, group, bq)
    delta = delta_ref[...].reshape(hkv, group, bq)
    s = jax.lax.dot_general(                           # (hkv, g, bq, bk)
        q_r, k, (((3,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32) * scale
    if causal:
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where((qpos >= kpos)[None, None], s, NEG_INF)
    p = jnp.exp(s - lse[..., None])
    dp = jax.lax.dot_general(                          # dO V^T
        do_r, v, (((3,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)
    ds = p * (dp - delta[..., None]) * scale
    return p, ds, q_r, k, do_r


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
               acc_scr, *, bq: int, bk: int, scale: float, causal: bool,
               group: int, nk: int):
    iq = pl.program_id(0)
    ik = pl.program_id(1)

    @pl.when(ik == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = iq * bq
    k_start = ik * bk
    live = jnp.logical_or(not causal, k_start <= q_start + bq - 1)

    @pl.when(live)
    def _step():
        _, ds, _, k, _ = _tile_grads(
            q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
            q_start, k_start, bq=bq, bk=bk, scale=scale, causal=causal,
            group=group)
        dq = jax.lax.dot_general(                       # dS K: (hkv,g,bq,d)
            ds, k, (((3,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)
        acc_scr[...] += dq.reshape(acc_scr.shape)

    @pl.when(ik == nk - 1)
    def _finish():
        dq_ref[...] = acc_scr[...].astype(dq_ref.dtype)


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                dk_ref, dv_ref, dk_scr, dv_scr, *, bq: int, bk: int,
                scale: float, causal: bool, group: int, nq: int):
    ij = pl.program_id(0)   # k block
    iq = pl.program_id(1)   # q block (innermost, sequential)

    @pl.when(iq == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    q_start = iq * bq
    k_start = ij * bk
    live = jnp.logical_or(not causal, q_start + bq - 1 >= k_start)

    @pl.when(live)
    def _step():
        p, ds, q_r, _, do_r = _tile_grads(
            q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
            q_start, k_start, bq=bq, bk=bk, scale=scale, causal=causal,
            group=group)
        # Contract over (group, bq): the GQA group-sum happens here.
        dv_scr[...] += jax.lax.dot_general(             # P^T dO: (hkv,bk,d)
            p, do_r, (((1, 2), (1, 2)), ((0,), (0,))),
            preferred_element_type=jnp.float32)
        dk_scr[...] += jax.lax.dot_general(             # dS^T Q: (hkv,bk,d)
            ds, q_r, (((1, 2), (1, 2)), ((0,), (0,))),
            preferred_element_type=jnp.float32)

    @pl.when(iq == nq - 1)
    def _finish():
        dk_ref[...] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[...] = dv_scr[...].astype(dv_ref.dtype)


def flash_attention_bwd_nhd(q: jax.Array, k: jax.Array, v: jax.Array,
                            do: jax.Array, lse: jax.Array, delta: jax.Array,
                            *, causal: bool = True, block_q: int = 128,
                            block_k: int = 128, group: int = 1,
                            interpret: bool = True
                            ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Fused backward on the (H, S, d) layout.

    q/do: (Hq, Sq, d); k/v: (Hkv, Sk, d); lse/delta: (Hq, Sq) float32.
    Returns float32 (dq (Hq, Sq, d), dk (Hkv, Sk, d), dv (Hkv, Sk, d)) —
    dk/dv are already group-summed to kv heads.
    """
    hq, sq, d = q.shape
    hkv, sk, _ = k.shape
    assert hq == group * hkv, (hq, hkv, group)
    bq = common.largest_divisor(sq, block_q)
    bk = common.largest_divisor(sk, block_k)
    nq = sq // bq
    nk = sk // bk
    scale = 1.0 / (d ** 0.5)

    q_spec = pl.BlockSpec((hq, bq, d), lambda i, j: (0, i, 0))
    kv_spec = pl.BlockSpec((hkv, bk, d), lambda i, j: (0, j, 0))
    row_spec = pl.BlockSpec((hq, bq), lambda i, j: (0, i))

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, bq=bq, bk=bk, scale=scale,
                          causal=causal, group=group, nk=nk),
        grid=(nq, nk),
        in_specs=[q_spec, kv_spec, kv_spec, q_spec, row_spec, row_spec],
        out_specs=pl.BlockSpec((hq, bq, d), lambda i, j: (0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((hq, sq, d), jnp.float32),
        scratch_shapes=[pltpu.VMEM((hq, bq, d), jnp.float32)],
        compiler_params=common.compiler_params("parallel", "arbitrary"),
        interpret=interpret,
    )(q, k, v, do, lse, delta)

    # Same maps with the (k block, q block) grid order of the dK/dV pass.
    q_spec2 = pl.BlockSpec((hq, bq, d), lambda j, i: (0, i, 0))
    kv_spec2 = pl.BlockSpec((hkv, bk, d), lambda j, i: (0, j, 0))
    row_spec2 = pl.BlockSpec((hq, bq), lambda j, i: (0, i))
    dkv_out = pl.BlockSpec((hkv, bk, d), lambda j, i: (0, j, 0))

    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, bq=bq, bk=bk, scale=scale,
                          causal=causal, group=group, nq=nq),
        grid=(nk, nq),
        in_specs=[q_spec2, kv_spec2, kv_spec2, q_spec2, row_spec2, row_spec2],
        out_specs=[dkv_out, dkv_out],
        out_shape=[jax.ShapeDtypeStruct((hkv, sk, d), jnp.float32),
                   jax.ShapeDtypeStruct((hkv, sk, d), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((hkv, bk, d), jnp.float32),
                        pltpu.VMEM((hkv, bk, d), jnp.float32)],
        compiler_params=common.compiler_params("parallel", "arbitrary"),
        interpret=interpret,
    )(q, k, v, do, lse, delta)
    return dq, dk, dv
