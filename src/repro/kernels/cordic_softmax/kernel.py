"""Pallas TPU kernel: CORDIC SoftMax (paper §2.3 FIFO flow, blocked rows).

Per row block (the RPE's SoftMax FIFO):
  1. integer max-subtraction (keeps every exponent argument <= 0, so the
     fixed-point FIFO cannot overflow — our stability adaptation),
  2. hyperbolic-stage exponentials with ln2 barrel-shift range extension,
  3. running int32 sum (the FIFO accumulator),
  4. division-stage normalisation of every entry by the sum,
  5. zero-skip gating: underflowed exponentials bypass the divider
     (CAESAR sparsity co-design) instead of emitting the 1-ulp floor.

The whole datapath runs at Q(frac+G) internal precision (guard bits — the
paper's 2N+K AF precision) and rounds back at the output latch.  Bit-exact
vs :mod:`repro.kernels.cordic_softmax.ref`.  Rows are blocked on the grid;
the feature axis stays whole inside VMEM (true to the FIFO, which holds the
full SoftMax window).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import cordic, fixed_point as fxp
from repro.core.fixed_point import FxpFormat
from repro.kernels import common
from repro.kernels.cordic_act.kernel import (EXP_ARG_CLAMP, GUARD_BITS,
                                             _divide, _exp_neg, _round_back)


def _softmax_kernel(x_ref, o_ref, *, fmt: FxpFormat, n_hyp: int, n_div: int,
                    guard: int):
    fb = fmt.frac_bits + guard
    a = jnp.left_shift(x_ref[...], guard)            # (br, C) Q(fb)
    clamp = jnp.int32(fxp.constant_raw(EXP_ARG_CLAMP, fb))
    m = jnp.max(a, axis=-1, keepdims=True)
    e = _exp_neg(jnp.maximum(a - m, -clamp), fb, n_hyp)   # <= 1.0 in Q(fb)
    tot = jnp.sum(e, axis=-1, keepdims=True)              # FIFO accumulator
    tot = jnp.maximum(tot, jnp.int32(1))                  # all-underflow guard
    q = _divide(e, jnp.broadcast_to(tot, e.shape), fb, n_div)
    q = jnp.where(e == 0, jnp.int32(0), q)                # zero-skip gating
    o_ref[...] = _round_back(q, guard)


def cordic_softmax_raw(x_raw: jax.Array, *, fmt: FxpFormat,
                       n_hyp: int = cordic.N_HYPERBOLIC_STAGES,
                       n_div: int = cordic.N_DIVISION_STAGES,
                       guard: int = GUARD_BITS,
                       block_rows: int = 128,
                       interpret: bool = True) -> jax.Array:
    assert fmt.frac_bits + guard <= 12, "internal precision capped at Q12"
    r, c = x_raw.shape
    br = common.largest_divisor(r, block_rows)
    kernel = functools.partial(_softmax_kernel, fmt=fmt, n_hyp=n_hyp,
                               n_div=n_div, guard=guard)
    return pl.pallas_call(
        kernel,
        grid=(r // br,),
        in_specs=[pl.BlockSpec((br, c), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, c), jnp.int32),
        compiler_params=common.compiler_params("parallel"),
        interpret=interpret,
    )(x_raw)
