"""Pure-jnp oracle for the CORDIC SoftMax kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import cordic, fixed_point as fxp
from repro.core.fixed_point import FxpFormat
from repro.kernels.cordic_act.ref import (EXP_ARG_CLAMP, GUARD_BITS,
                                          _divide_ref, _round_back_ref,
                                          exp_neg_raw_ref)


def cordic_softmax_raw_ref(x_raw: jax.Array, *, fmt: FxpFormat,
                           n_hyp: int = cordic.N_HYPERBOLIC_STAGES,
                           n_div: int = cordic.N_DIVISION_STAGES,
                           guard: int = GUARD_BITS) -> jax.Array:
    fb = fmt.frac_bits + guard
    a = jnp.left_shift(x_raw.astype(jnp.int32), guard)
    clamp = jnp.int32(fxp.constant_raw(EXP_ARG_CLAMP, fb))
    m = jnp.max(a, axis=-1, keepdims=True)
    e = exp_neg_raw_ref(jnp.maximum(a - m, -clamp), fb, n_hyp)
    tot = jnp.maximum(jnp.sum(e, axis=-1, keepdims=True), jnp.int32(1))
    q = _divide_ref(e, jnp.broadcast_to(tot, e.shape), fb, n_div)
    q = jnp.where(e == 0, jnp.int32(0), q)
    return _round_back_ref(q, guard)
