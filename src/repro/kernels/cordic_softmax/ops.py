"""Jit'd public wrapper for the CORDIC SoftMax kernel (float frontend)."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import cordic, fixed_point as fxp
from repro.core.fixed_point import FxpFormat
from repro.kernels.cordic_softmax.kernel import cordic_softmax_raw

_ON_TPU = any(d.platform == "tpu" for d in jax.devices())


@functools.partial(jax.jit, static_argnames=("fmt", "n_hyp", "n_div",
                                             "guard", "interpret"))
def _fwd(x, fmt: FxpFormat, n_hyp: int, n_div: int, guard: int,
         interpret: bool):
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    # Pre-scale into fmt range: softmax(x) == softmax(x - max) and the
    # kernel re-subtracts its own integer max, so only quantization of the
    # *differences* matters; clamp keeps huge logits finite in fmt.
    x2 = x2 - jax.lax.stop_gradient(jnp.max(x2, axis=-1, keepdims=True))
    raw = fxp.quantize(x2, fmt)
    out = cordic_softmax_raw(raw, fmt=fmt, n_hyp=n_hyp, n_div=n_div,
                             guard=guard, interpret=interpret)
    return fxp.dequantize(out, fmt).reshape(shape).astype(x.dtype)


def cordic_softmax(x: jax.Array, *, fmt: FxpFormat = fxp.FXP16,
                   n_hyp: int = cordic.N_HYPERBOLIC_STAGES,
                   n_div: Optional[int] = None, guard: int = 4,
                   interpret: Optional[bool] = None) -> jax.Array:
    """Row softmax through the RPE FIFO datapath, STE gradients."""
    if interpret is None:
        interpret = not _ON_TPU
    if n_div is None:
        n_div = max(cordic.N_DIVISION_STAGES, fmt.frac_bits + guard)

    @jax.custom_vjp
    def f(v):
        return _fwd(v, fmt, n_hyp, n_div, guard, interpret)

    def fwd(v):
        return f(v), v

    def bwd(v, g):
        _, vjp = jax.vjp(lambda t: jax.nn.softmax(t, axis=-1), v)
        return vjp(g)

    f.defvjp(fwd, bwd)
    return f(x)
