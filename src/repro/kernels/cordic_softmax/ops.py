"""Jit'd public wrapper for the CORDIC SoftMax kernel (float frontend)."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import cordic, fixed_point as fxp
from repro.core.fixed_point import FxpFormat
from repro.kernels import common
from repro.kernels.cordic_softmax.kernel import cordic_softmax_raw
from repro.kernels.cordic_softmax.ref import cordic_softmax_raw_ref


@functools.partial(jax.jit, static_argnames=("fmt", "n_hyp", "n_div",
                                             "guard", "block_rows",
                                             "interpret"))
def _fwd(x, fmt: FxpFormat, n_hyp: int, n_div: int, guard: int,
         block_rows: int, interpret: bool):
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    # Pre-scale into fmt range: softmax(x) == softmax(x - max) and the
    # kernel re-subtracts its own integer max, so only quantization of the
    # *differences* matters; clamp keeps huge logits finite in fmt.
    x2 = x2 - jax.lax.stop_gradient(jnp.max(x2, axis=-1, keepdims=True))
    raw = fxp.quantize(x2, fmt)
    out = cordic_softmax_raw(raw, fmt=fmt, n_hyp=n_hyp, n_div=n_div,
                             guard=guard, block_rows=block_rows,
                             interpret=interpret)
    return fxp.dequantize(out, fmt).reshape(shape).astype(x.dtype)


def _exact_softmax(x: jax.Array) -> jax.Array:
    return jax.nn.softmax(x, axis=-1)


def cordic_softmax(x: jax.Array, *, fmt: FxpFormat = fxp.FXP16,
                   n_hyp: int = cordic.N_HYPERBOLIC_STAGES,
                   n_div: Optional[int] = None, guard: int = 4,
                   interpret: Optional[bool] = None) -> jax.Array:
    """Row softmax through the RPE FIFO datapath, STE gradients."""
    interpret = common.resolve_interpret(interpret)
    if n_div is None:
        n_div = max(cordic.N_DIVISION_STAGES, fmt.frac_bits + guard)
    # Pick the block OUTSIDE the jitted forward so autotuned cache entries
    # take effect (a lookup inside _fwd would be frozen into its trace).
    x2_shape = (x.size // x.shape[-1], x.shape[-1])
    block_rows = common.pick_block_rows("cordic_softmax", x2_shape, jnp.int32)
    f = common.ste(
        functools.partial(_fwd, fmt=fmt, n_hyp=n_hyp, n_div=n_div,
                          guard=guard, block_rows=block_rows,
                          interpret=interpret),
        _exact_softmax)
    return f(x)


def _candidates(shape, dtype):
    """Legal (rows, cols) tiles: the feature axis stays whole (the kernel
    reduces over it), so only the row-block varies, over divisors."""
    r, c = shape
    return tuple((br, c) for br in common.divisor_candidates(r, 128, 4))


common.register(common.KernelSpec(
    name="cordic_softmax", kernel=cordic_softmax_raw,
    ref=cordic_softmax_raw_ref, grad=_exact_softmax,
    candidates=_candidates, tags=("fixed-point", "rowwise")))
