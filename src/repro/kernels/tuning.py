"""Persistent tuned-block table: the retained-configuration layer.

The paper's SYCore earns its throughput by *configuring* the RPE array per
workload; the software analogue is the per-(kernel, shape, dtype) block
cache in :mod:`repro.kernels.common`.  That cache is in-process only —
every serving boot would re-derive (or never measure) its tiles.  This
module persists measured winners to disk so tuning is paid once per
(jax version, platform) and every later process boots warm:

  * **format** — one JSON document: a ``version`` stamp plus an
    ``entries`` list of ``{kernel, shape, dtype, block}`` records, keyed
    exactly like the in-process cache.
  * **versioning** — the stamp is (schema int, jax version, platform).
    A table written by a different jax release or for a different
    accelerator is *stale*: :func:`load` silently discards it, because a
    block measured under another compiler/backend is at best noise and at
    worst illegal.
  * **location** — ``REPRO_TUNE_CACHE`` if set, else the XDG cache dir
    (``$XDG_CACHE_HOME/repro/tuned_blocks.json``, defaulting to
    ``~/.cache/repro``).
  * **robustness** — a corrupt or truncated file loads as an empty table
    (serving must never fail on a bad cache); :func:`save` writes
    atomically (tmp + rename) and by default merges with the valid
    entries already on disk, so concurrent tuners lose at most a race,
    never the file.

Producers: ``benchmarks/tune_bench.py`` (the sweep CLI) and any direct
:func:`repro.kernels.common.autotune` caller that snapshots its winners.
Consumer: the three-level lookup in ``common.pick_block_*`` (in-process →
this table → heuristic) and ``runtime/serve_loop.py``'s warm boot.

Kept dependency-light (jax + stdlib only) so :mod:`repro.kernels.common`
can import it without cycles.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax

# Bump when the on-disk layout changes; old files are then ignored.
SCHEMA_VERSION = 1

# Same key structure as common._BLOCK_CACHE.
Key = Tuple[str, Tuple[int, ...], str]
Table = Dict[Key, Tuple[int, ...]]

_ENV_VAR = "REPRO_TUNE_CACHE"


def _platform() -> str:
    """Primary accelerator platform (duplicated from common to avoid a
    cycle; both resolve to jax.devices)."""
    try:
        return jax.devices()[0].platform
    except RuntimeError:
        return "cpu"


def version_stamp() -> Dict[str, Any]:
    """The validity domain of a tuned table."""
    return {
        "schema": SCHEMA_VERSION,
        "jax": jax.__version__,
        "platform": _platform(),
    }


def default_path() -> str:
    """``REPRO_TUNE_CACHE`` if set, else the XDG cache location."""
    env = os.environ.get(_ENV_VAR)
    if env:
        return env
    xdg = os.environ.get("XDG_CACHE_HOME") or os.path.join(
        os.path.expanduser("~"), ".cache")
    return os.path.join(xdg, "repro", "tuned_blocks.json")


def _entry_to_key(entry: Any) -> Optional[Tuple[Key, Tuple[int, ...]]]:
    """Validate one on-disk record; None if malformed (skipped, not fatal)."""
    if not isinstance(entry, dict):
        return None
    kernel = entry.get("kernel")
    shape = entry.get("shape")
    dtype = entry.get("dtype")
    block = entry.get("block")
    if not (isinstance(kernel, str) and isinstance(dtype, str)
            and isinstance(shape, (list, tuple))
            and isinstance(block, (list, tuple)) and block):
        return None
    try:
        key = (kernel, tuple(int(s) for s in shape), dtype)
        val = tuple(int(b) for b in block)
    except (TypeError, ValueError):
        return None
    if any(b < 1 for b in val):
        return None
    return key, val


def load(path: Optional[str] = None) -> Table:
    """Read the tuned table; {} on missing, corrupt or stale-version files.

    Never raises on bad content: a cache must degrade to "no cache".
    """
    path = path or default_path()
    try:
        with open(path, "r", encoding="utf-8") as f:
            doc = json.load(f)
    except (OSError, ValueError):
        return {}
    if not isinstance(doc, dict):
        return {}
    if doc.get("version") != version_stamp():
        return {}  # stale: different schema, jax release, or platform
    table: Table = {}
    for entry in doc.get("entries") or []:
        kv = _entry_to_key(entry)
        if kv is not None:
            table[kv[0]] = kv[1]
    return table


def save(table: Table, path: Optional[str] = None,
         merge: bool = True) -> str:
    """Write ``table`` (atomically); returns the path written.

    With ``merge`` (default), valid same-version entries already on disk
    are kept and ``table`` overrides on key collisions — so incremental
    tuning runs accumulate instead of clobbering each other.  A stale or
    corrupt existing file contributes nothing and is replaced.
    """
    path = path or default_path()
    merged: Table = load(path) if merge else {}
    merged.update(table)
    doc = {
        "version": version_stamp(),
        "entries": [
            {"kernel": k[0], "shape": list(k[1]), "dtype": k[2],
             "block": list(v)}
            for k, v in sorted(merged.items())
        ],
    }
    parent = os.path.dirname(os.path.abspath(path))
    os.makedirs(parent, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=parent, suffix=".tmp")
    try:
        with os.fdopen(fd, "w", encoding="utf-8") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
    return path
