"""Shared kernel substrate: the one dispatch layer under all five families.

The paper's RPE is a *single* reconfigurable datapath that serves MAC,
tanh/sigmoid, and SoftMax workloads; this module is the software analogue.
Every kernel family (``cordic_act``, ``cordic_mac``, ``cordic_softmax``,
``flash_attention``, ``wkv``) routes its public wrapper through here for:

  * **platform policy** — :func:`platform` / :func:`on_tpu` /
    :func:`resolve_interpret`: Pallas kernels compile on TPU and run in
    interpret mode everywhere else (the CPU fallback), overridable with
    ``REPRO_KERNEL_INTERPRET=0|1``.
  * **compiler params** — :func:`compiler_params` wraps the
    CompilerParams/TPUCompilerParams rename (see :mod:`repro.compat`).
  * **block sizing** — :func:`largest_divisor` / :func:`pick_block_2d` /
    :func:`pick_block_matmul`, all answering through a three-level lookup:
    the in-process per-(kernel, shape, dtype) cache (which
    :func:`autotune` overwrites with measured winners), then the
    persistent tuned table from :mod:`repro.kernels.tuning`, then the
    shape heuristic.
  * **registry** — :class:`KernelSpec` maps a family name to its raw Pallas
    entry point, its bit/numeric oracle from ``ref.py``, the float
    function whose exact VJP is the STE backward pass, and (for families
    that have one) the fused Pallas backward entry point.
  * **gradients** — :func:`ste` packages the straight-through custom_vjp
    pattern (quantized forward, exact float backward) that every family
    used to hand-roll; :func:`fused_vjp` generalises it to a fused Pallas
    backward kernel when the family registers one
    (``REPRO_FUSED_BWD=0`` forces the STE fallback).

Adding a new family?  Read ``docs/KERNELS.md``.
"""
from __future__ import annotations

import dataclasses
import functools
import os
import time
from typing import Any, Callable, Dict, Iterable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro import compat
from repro.core.caesar import pick_block_shape
from repro.kernels import tuning

# ---------------------------------------------------------------------------
# Platform policy
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def platform() -> str:
    """Primary accelerator platform: 'tpu', 'gpu' or 'cpu'."""
    try:
        return jax.devices()[0].platform
    except RuntimeError:
        return "cpu"


def on_tpu() -> bool:
    return platform() == "tpu"


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """The CPU-fallback policy shared by every family.

    Explicit ``interpret=`` wins; else ``REPRO_KERNEL_INTERPRET=0|1`` (force
    compile under a TPU simulator / force interpret while debugging on
    device); else interpret everywhere except real TPUs.
    """
    if interpret is not None:
        return interpret
    env = os.environ.get("REPRO_KERNEL_INTERPRET")
    if env is not None:
        return env.lower() not in ("0", "false", "no")
    return not on_tpu()


def compiler_params(*dimension_semantics: str):
    """TPU compiler params across the CompilerParams rename."""
    return compat.TPUCompilerParams(
        dimension_semantics=tuple(dimension_semantics))


# ---------------------------------------------------------------------------
# Block sizing + autotune cache
# ---------------------------------------------------------------------------

# (kernel name, shape tuple, dtype name) -> chosen block tuple
_BLOCK_CACHE: Dict[Tuple[str, Tuple[int, ...], str], Tuple[int, ...]] = {}

# Lazily-loaded snapshot of the on-disk tuned table (None = not loaded yet).
# Consulted by the pick_block_* helpers between the in-process cache and
# the heuristic: in-process beats disk beats heuristic.
_DISK_TABLE: Optional[Dict[Tuple[str, Tuple[int, ...], str],
                           Tuple[int, ...]]] = None


def _cache_key(kernel: str, shape: Sequence[int], dtype: Any
               ) -> Tuple[str, Tuple[int, ...], str]:
    return (kernel, tuple(int(s) for s in shape), jnp.dtype(dtype).name)


def clear_block_cache() -> None:
    _BLOCK_CACHE.clear()


def cached_block(kernel: str, shape: Sequence[int], dtype: Any
                 ) -> Optional[Tuple[int, ...]]:
    return _BLOCK_CACHE.get(_cache_key(kernel, shape, dtype))


def set_block(kernel: str, shape: Sequence[int], dtype: Any,
              block: Sequence[int]) -> None:
    _BLOCK_CACHE[_cache_key(kernel, shape, dtype)] = tuple(block)


def block_cache_snapshot() -> Dict[Tuple[str, Tuple[int, ...], str],
                                   Tuple[int, ...]]:
    """Copy of the in-process cache (what a tuner would persist)."""
    return dict(_BLOCK_CACHE)


def load_tuned_table(path: Optional[str] = None) -> int:
    """(Re)load the persistent tuned table; returns the entry count.

    Called eagerly by serving so boots are warm; the pick_block_* helpers
    also trigger a lazy load on first miss, so calling this is an
    optimisation, never a requirement.  A missing/stale/corrupt table
    loads as empty (see :mod:`repro.kernels.tuning`).
    """
    global _DISK_TABLE
    _DISK_TABLE = tuning.load(path)
    return len(_DISK_TABLE)


def reset_disk_table() -> None:
    """Forget the loaded tuned table (next lookup re-reads; test seam)."""
    global _DISK_TABLE
    _DISK_TABLE = None


def _disk_block(kernel: str, shape: Sequence[int], dtype: Any
                ) -> Optional[Tuple[int, ...]]:
    global _DISK_TABLE
    if _DISK_TABLE is None:
        _DISK_TABLE = tuning.load()
    return _DISK_TABLE.get(_cache_key(kernel, shape, dtype))


def _lookup(kernel: str, shape: Sequence[int], dtype: Any
            ) -> Optional[Tuple[int, ...]]:
    """Levels 1+2 of the lookup: in-process cache, then disk table.

    A disk hit is promoted into the in-process cache, so later
    ``set_block``/``autotune`` results still take precedence over it.
    """
    hit = cached_block(kernel, shape, dtype)
    if hit is not None:
        return hit
    hit = _disk_block(kernel, shape, dtype)
    if hit is not None:
        set_block(kernel, shape, dtype, hit)
    return hit


def largest_divisor(n: int, cap: int) -> int:
    """Largest d with 1 <= d <= cap and n % d == 0."""
    d = max(1, min(int(cap), int(n)))
    while n % d:
        d -= 1
    return d


def divisor_candidates(n: int, cap: int, limit: int = 4) -> Tuple[int, ...]:
    """Up to ``limit`` distinct divisors of ``n`` that are <= ``cap``,
    largest first.  The building block for ``KernelSpec.candidates``
    hooks of kernels whose tiles must divide the array."""
    out = []
    cap = min(int(cap), int(n))
    while len(out) < limit:
        d = largest_divisor(n, cap)
        out.append(d)
        if d == 1:
            break
        cap = d - 1
    return tuple(out)


def pick_block_2d(kernel: str, shape: Tuple[int, int], dtype: Any = jnp.int32,
                  max_rows: int = 256, max_cols: int = 512) -> Tuple[int, int]:
    """Divisor-aware (rows, cols) tile for an elementwise/row-wise kernel.

    Pallas BlockSpecs here require tiles that divide the array exactly, so
    both sides shrink to the largest divisor under the cap.  Three-level
    lookup: the in-process cache (where :func:`autotune` winners land),
    then the persistent tuned table, then this heuristic.
    """
    hit = _lookup(kernel, shape, dtype)
    if hit is not None:
        return hit  # type: ignore[return-value]
    r, c = shape
    block = (largest_divisor(r, max_rows), largest_divisor(c, max_cols))
    set_block(kernel, shape, dtype, block)
    return block


def pick_block_rows(kernel: str, shape: Tuple[int, int],
                    dtype: Any = jnp.int32, max_rows: int = 128) -> int:
    """Row-block for kernels that keep the feature axis whole (softmax)."""
    hit = _lookup(kernel, shape, dtype)
    if hit is not None:
        return hit[0]
    br = largest_divisor(shape[0], max_rows)
    set_block(kernel, shape, dtype, (br, shape[1]))
    return br


def pick_block_matmul(kernel: str, m: int, n: int, k: int,
                      dtype: Any = jnp.int32, max_block: int = 256
                      ) -> Tuple[int, int, int]:
    """(bm, bn, bk) for an output-stationary matmul via the CAESAR
    VMEM-budget model (callers pad, so the block need not divide)."""
    hit = _lookup(kernel, (m, n, k), dtype)
    if hit is not None:
        return hit  # type: ignore[return-value]
    block = pick_block_shape(m, n, k,
                             bytes_per_el=jnp.dtype(dtype).itemsize,
                             max_block=max_block)
    set_block(kernel, (m, n, k), dtype, block)
    return block


def autotune(kernel: str, shape: Sequence[int], dtype: Any,
             candidates: Iterable[Sequence[int]],
             run: Callable[[Tuple[int, ...]], Any],
             repeats: int = 3) -> Tuple[int, ...]:
    """Measure ``run(block)`` per candidate; cache and return the winner.

    Each candidate gets one untimed call (compile/warmup) and ``repeats``
    timed calls, each blocked on individually — under jax's async dispatch,
    blocking only on the last result would let earlier calls overlap the
    timer and skew per-candidate numbers.  Candidates that raise (e.g.
    VMEM overflow on device) are skipped; ``KeyboardInterrupt`` is not
    swallowed.  The winner lands in the block cache under
    (kernel, shape, dtype), so the ``pick_block_*`` helpers serve it to
    every later trace of the same problem.
    """
    best: Optional[Tuple[int, ...]] = None
    best_t = float("inf")
    for cand in candidates:
        blk = tuple(int(b) for b in cand)
        try:
            jax.block_until_ready(run(blk))
            t0 = time.perf_counter()
            for _ in range(repeats):
                jax.block_until_ready(run(blk))
            dt = (time.perf_counter() - t0) / max(1, repeats)
        except KeyboardInterrupt:
            raise
        except Exception:
            continue
        if dt < best_t:
            best, best_t = blk, dt
    if best is None:
        raise ValueError(f"autotune({kernel!r}): no candidate ran")
    set_block(kernel, shape, dtype, best)
    return best


# ---------------------------------------------------------------------------
# Kernel registry
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """One kernel family, as the substrate sees it.

    kernel: the raw Pallas entry point (tiled, takes ``interpret=``).
    ref:    the oracle from the family's ``ref.py`` — bit-exact for the
            fixed-point families, float-allclose for flash/wkv.
    grad:   float function whose exact VJP is the backward pass (STE);
            None for forward-only families.
    grad_kernel: the raw fused Pallas backward entry point (tiled, takes
            ``interpret=``), consuming the residuals the forward emits
            under ``return_residuals=True``.  None = the family trains
            through the STE fallback only.
    candidates: ``candidates(shape, dtype) -> iterable of block tuples``
            — the family's legal tile candidates for the cache-key shape
            its wrapper uses, enumerated for :func:`autotune` /
            ``benchmarks.tune``.  None = family is not tunable.
            Backward tiles get their own registry entry (a ``<family>.bwd``
            spec) so the sweep tunes them independently.
    tags:   free-form labels ("fixed-point", "attention", ...).
    """
    name: str
    kernel: Callable[..., Any]
    ref: Callable[..., Any]
    grad: Optional[Callable[..., Any]] = None
    grad_kernel: Optional[Callable[..., Any]] = None
    candidates: Optional[Callable[..., Tuple[Tuple[int, ...], ...]]] = None
    tags: Tuple[str, ...] = ()


_REGISTRY: Dict[str, KernelSpec] = {}


def register(spec: KernelSpec) -> KernelSpec:
    """Idempotent by name (module re-imports re-register the same spec)."""
    _REGISTRY[spec.name] = spec
    return spec


def get_kernel(name: str) -> KernelSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"no kernel {name!r} registered; known: {registered_kernels()} "
            "(import repro.kernels to populate the registry)") from None


def registered_kernels() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


# ---------------------------------------------------------------------------
# Straight-through gradients
# ---------------------------------------------------------------------------


def ste(fwd: Callable[..., jax.Array],
        grad: Callable[..., jax.Array]) -> Callable[..., jax.Array]:
    """custom_vjp wrapper: quantized forward, exact float backward.

    ``fwd`` runs the (non-differentiable) kernel; the backward pass is the
    exact VJP of ``grad`` evaluated at the primal inputs — straight-through
    estimation.  All static configuration must already be bound into both
    callables; the returned function takes arrays only.
    """

    @jax.custom_vjp
    def f(*args):
        return fwd(*args)

    def f_fwd(*args):
        return fwd(*args), args

    def f_bwd(args, g):
        _, vjp = jax.vjp(grad, *args)
        return vjp(g)

    f.defvjp(f_fwd, f_bwd)
    return f


# ---------------------------------------------------------------------------
# Fused backward kernels
# ---------------------------------------------------------------------------


def fused_backward_enabled() -> bool:
    """Global switch for the fused Pallas backward passes.

    On by default; ``REPRO_FUSED_BWD=0`` forces every family back onto the
    STE fallback (the exact VJP of the float reference) — the escape hatch
    while debugging a backward kernel on device.
    """
    env = os.environ.get("REPRO_FUSED_BWD")
    if env is None:
        return True
    return env.lower() not in ("0", "false", "no")


def fused_vjp(fwd: Callable[..., jax.Array],
              grad: Callable[..., jax.Array],
              fwd_res: Optional[Callable[..., Any]] = None,
              bwd: Optional[Callable[..., Any]] = None
              ) -> Callable[..., jax.Array]:
    """custom_vjp wrapper generalising :func:`ste` to fused backwards.

    ``fwd`` runs the kernel; when the family registers a fused backward
    pair — ``fwd_res(*args) -> (out, residuals)`` (the kernel forward also
    emitting its O(S) residuals) and ``bwd(residuals, g) -> cotangents`` —
    differentiation goes through it.  Without the pair, or with
    ``REPRO_FUSED_BWD=0``, this *is* :func:`ste`: quantized/kernel forward,
    exact float backward via ``grad``.  As with ``ste``, all static
    configuration must already be bound in; the callables take arrays only.
    """
    if fwd_res is None or bwd is None or not fused_backward_enabled():
        return ste(fwd, grad)

    @jax.custom_vjp
    def f(*args):
        return fwd(*args)

    def f_fwd(*args):
        return fwd_res(*args)

    def f_bwd(res, g):
        return bwd(res, g)

    f.defvjp(f_fwd, f_bwd)
    return f
