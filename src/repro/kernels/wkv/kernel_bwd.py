"""Pallas TPU kernel: fused wkv backward (reverse-time recurrence).

Forward per token (state S (dk, dv), per-channel decay w):

    y_t = r_t (S_t + diag(u) k_t v_t^T)      S_{t+1} = diag(w_t) S_t + k_t v_t^T

The backward runs time *in reverse*, carrying the state adjoint
A_t = dL/dS_t across blocks in VMEM scratch:

    A_t = diag(w_t) A_{t+1} + r_t dy_t^T                (A after last token = 0)
    dr_t = S_t dy_t + u ⊙ k_t (v_t·dy_t)
    dk_t = r_t ⊙ u (v_t·dy_t) + A_{t+1} v_t
    dv_t = (Σ_j r_j u_j k_j) dy_t + A_{t+1}^T k_t
    dw_t = rowsum(A_{t+1} ⊙ S_t)
    du  += r_t ⊙ k_t (v_t·dy_t)

The forward states S_t it needs are *recomputed* inside each time block
from the per-block checkpoints the forward emits under
``return_residuals=True`` (kernel.py) — O(T/bt) checkpointed states
instead of the O(T) a scan-based VJP stashes.  Grid (BH, T/bt) with the
time axis sequential and **reversed through the index maps**: grid step i
processes time block nt-1-i.  du accumulates into a per-(BH) output block
revisited across the whole sweep.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import common


def _wkv_bwd_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, dy_ref, c_ref,
                    dr_ref, dk_ref, dv_ref, dw_ref, du_ref, a_scr, *,
                    bt: int):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        a_scr[...] = jnp.zeros_like(a_scr)   # A after the final token
        du_ref[...] = jnp.zeros_like(du_ref)

    r = r_ref[0].astype(jnp.float32)    # (bt, dk)
    k = k_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)    # (bt, dv)
    dy = dy_ref[0].astype(jnp.float32)  # (bt, dv)
    u = u_ref[0][0].astype(jnp.float32)  # (dk,) broadcast row
    dk_dim, dv_dim = r.shape[1], v.shape[1]

    # Recompute the in-block forward states from the block checkpoint:
    # states[i] = S before token i of this block.
    def fstep(i, carry):
        s, states = carry
        states = jax.lax.dynamic_update_slice(states, s[None], (i, 0, 0))
        kv = k[i][:, None] * v[i][None, :]
        return w[i][:, None] * s + kv, states

    _, states = jax.lax.fori_loop(
        0, bt, fstep,
        (c_ref[0, 0], jnp.zeros((bt, dk_dim, dv_dim), jnp.float32)))

    def bstep(j, carry):
        a, drb, dkb, dvb, dwb, du = carry    # a = A_{t+1} for token t below
        i = bt - 1 - j
        s_i = jax.lax.dynamic_slice(states, (i, 0, 0),
                                    (1, dk_dim, dv_dim))[0]
        r_i, k_i, w_i, v_i, dy_i = r[i], k[i], w[i], v[i], dy[i]
        vdy = jnp.sum(v_i * dy_i)
        dr_i = (s_i @ dy_i[:, None])[:, 0] + u * k_i * vdy
        du = du + r_i * k_i * vdy
        dk_i = r_i * u * vdy + (a @ v_i[:, None])[:, 0]
        dv_i = jnp.sum(r_i * u * k_i) * dy_i + (k_i[None, :] @ a)[0]
        dw_i = jnp.sum(a * s_i, axis=1)
        a = w_i[:, None] * a + r_i[:, None] * dy_i[None, :]
        upd = jax.lax.dynamic_update_slice_in_dim
        return (a, upd(drb, dr_i[None], i, 0), upd(dkb, dk_i[None], i, 0),
                upd(dvb, dv_i[None], i, 0), upd(dwb, dw_i[None], i, 0), du)

    zk = jnp.zeros((bt, dk_dim), jnp.float32)
    zv = jnp.zeros((bt, dv_dim), jnp.float32)
    a_fin, drb, dkb, dvb, dwb, du = jax.lax.fori_loop(
        0, bt, bstep,
        (a_scr[...], zk, zk, zv, zk, jnp.zeros((dk_dim,), jnp.float32)))
    a_scr[...] = a_fin
    dr_ref[0] = drb.astype(dr_ref.dtype)
    dk_ref[0] = dkb.astype(dk_ref.dtype)
    dv_ref[0] = dvb.astype(dv_ref.dtype)
    dw_ref[0] = dwb.astype(dw_ref.dtype)
    du_ref[0] += du


def wkv_recurrence_bwd(r: jax.Array, k: jax.Array, v: jax.Array,
                       w: jax.Array, u: jax.Array, dy: jax.Array,
                       ckpt: jax.Array, *, block_t: int = 64,
                       interpret: bool = True
                       ) -> Tuple[jax.Array, jax.Array, jax.Array,
                                  jax.Array, jax.Array]:
    """Fused backward on the (BH, T, d) layout, all outputs float32.

    r/k/w: (BH, T, dk); v/dy: (BH, T, dv); u: (BH, dk); ckpt: the
    (BH, T/bt, dk, dv) block-boundary states from the forward's
    ``return_residuals=True`` run — **block_t must match that run's** so
    the checkpoints align.  Returns (dr, dk, dv, dw, du) with du (BH, dk).
    """
    bh, t, dk = r.shape
    dv = v.shape[-1]
    bt = common.largest_divisor(t, block_t)
    nt = t // bt
    assert ckpt.shape == (bh, nt, dk, dv), (ckpt.shape, (bh, nt, dk, dv))

    # Reverse time through the index maps: grid step i -> block nt-1-i.
    def rev(b, i, nt=nt):
        return (b, nt - 1 - i, 0)

    tk_spec = pl.BlockSpec((1, bt, dk), rev)
    tv_spec = pl.BlockSpec((1, bt, dv), rev)
    shapes = [jax.ShapeDtypeStruct((bh, t, dk), jnp.float32),
              jax.ShapeDtypeStruct((bh, t, dk), jnp.float32),
              jax.ShapeDtypeStruct((bh, t, dv), jnp.float32),
              jax.ShapeDtypeStruct((bh, t, dk), jnp.float32),
              jax.ShapeDtypeStruct((bh, dk), jnp.float32)]
    return pl.pallas_call(
        functools.partial(_wkv_bwd_kernel, bt=bt),
        grid=(bh, nt),
        in_specs=[
            tk_spec, tk_spec, tv_spec, tk_spec,
            pl.BlockSpec((1, 1, dk), lambda b, i: (b, 0, 0)),
            tv_spec,
            pl.BlockSpec((1, 1, dk, dv),
                         lambda b, i, nt=nt: (b, nt - 1 - i, 0, 0)),
        ],
        out_specs=[tk_spec, tk_spec, tv_spec, tk_spec,
                   pl.BlockSpec((1, dk), lambda b, i: (b, 0))],
        out_shape=shapes,
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        compiler_params=common.compiler_params("parallel", "arbitrary"),
        interpret=interpret,
    )(r, k, v, w, u.reshape(bh, 1, dk), dy, ckpt)
