"""Pallas TPU kernel: wkv recurrence over an int8 quantized state.

The serving counterpart of ``kernel.py``: the (dk x dv) state enters as
int8 with one float32 scale per dk row (the per-block format of
:mod:`repro.core.quant_cache`, block = the value axis), is dequantized
into the VMEM scratch once at the start of the sweep, carried there in
f32 across all T steps, and re-quantized **in-kernel** on the last grid
step.  One int8 round-trip per kernel call — identical numerics to the
jnp serving path, which also round-trips the state through int8 exactly
once per dispatched step (``models/transformer.py::decode_step``).

Same grid (batch*heads, T/bt) and sequential-time discipline as
``_wkv_kernel``.  Forward-only: a serving artifact, never differentiated.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import common

_TINY = 1e-30


def _wkv_q8_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, s0s_ref,
                   o_ref, sq_ref, ss_ref, s_scr, *, bt: int, nt: int):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        # dequant the incoming int8 state once; f32 thereafter
        s_scr[...] = (s0_ref[0].astype(jnp.float32)
                      * s0s_ref[0][:, None])

    r = r_ref[0].astype(jnp.float32)   # (bt, dk)
    k = k_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)   # (bt, dv)
    u = u_ref[0].astype(jnp.float32)   # (1, dk) broadcast row

    def step(i, carry):
        s, out = carry
        kv = k[i][:, None] * v[i][None, :]              # (dk, dv)
        y = (r[i] * u[0])[None, :] @ kv + r[i][None, :] @ s
        out = jax.lax.dynamic_update_slice_in_dim(out, y, i, axis=0)
        s = w[i][:, None] * s + kv
        return s, out

    s0 = s_scr[...]
    out0 = jnp.zeros((bt, v.shape[1]), jnp.float32)
    s_fin, out = jax.lax.fori_loop(0, bt, step, (s0, out0))
    s_scr[...] = s_fin
    o_ref[0] = out.astype(o_ref.dtype)

    @pl.when(pl.program_id(1) == nt - 1)
    def _finish():
        # requantize: same ops as core.quant_cache.quantize_blocked with
        # the value axis as the block (one scale per dk row)
        s = s_scr[...]
        sc = jnp.max(jnp.abs(s), axis=1) * (1.0 / 127.0)       # (dk,)
        q = jnp.clip(jnp.round(s / jnp.maximum(sc, _TINY)[:, None]),
                     -127.0, 127.0)
        sq_ref[0] = q.astype(jnp.int8)
        ss_ref[0] = sc


def wkv_recurrence_q8(r: jax.Array, k: jax.Array, v: jax.Array,
                      w: jax.Array, u: jax.Array, s0: jax.Array,
                      s0_scale: jax.Array, *, block_t: int = 64,
                      interpret: bool = True):
    """r/k/w: (BH, T, dk); v: (BH, T, dv); u: (BH, dk); s0: (BH, dk, dv)
    int8 with per-row float32 scales (BH, dk).

    Returns ``(out (BH, T, dv), s_fin int8 (BH, dk, dv), s_scale float32
    (BH, dk))`` — the state after all T steps, requantized in-kernel.
    T must tile by block_t.
    """
    bh, t, dk = r.shape
    dv = v.shape[-1]
    assert s0.dtype == jnp.int8, s0.dtype
    bt = common.largest_divisor(t, block_t)
    nt = t // bt
    kernel = functools.partial(_wkv_q8_kernel, bt=bt, nt=nt)
    return pl.pallas_call(
        kernel,
        grid=(bh, nt),
        in_specs=[
            pl.BlockSpec((1, bt, dk), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, bt, dk), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, bt, dv), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, bt, dk), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, 1, dk), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, dk, dv), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, dk), lambda b, i: (b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bt, dv), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, dk, dv), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, dk), lambda b, i: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, t, dv), r.dtype),
            jax.ShapeDtypeStruct((bh, dk, dv), jnp.int8),
            jax.ShapeDtypeStruct((bh, dk), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        compiler_params=common.compiler_params("parallel", "arbitrary"),
        interpret=interpret,
    )(r, k, v, w, u.reshape(bh, 1, dk), s0,
      s0_scale.astype(jnp.float32))
