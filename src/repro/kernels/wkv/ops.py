"""Jit'd public wrapper for the wkv kernel: (B, T, H, dk) frontend."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.wkv.kernel import wkv_recurrence

_ON_TPU = any(d.platform == "tpu" for d in jax.devices())


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def wkv(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
        u: jax.Array, *, block_t: int = 64,
        interpret: Optional[bool] = None) -> jax.Array:
    """r/k/v/w: (B, T, H, d); u: (H, d).  Returns (B, T, H, d)."""
    if interpret is None:
        interpret = not _ON_TPU
    b, t, h, d = r.shape
    def flat(x):
        return x.transpose(0, 2, 1, 3).reshape(b * h, t, d)
    uu = jnp.tile(u[None], (b, 1, 1)).reshape(b * h, d)
    out = wkv_recurrence(flat(r), flat(k), flat(v), flat(w), uu,
                         block_t=block_t, interpret=interpret)
    return out.reshape(b, h, t, d).transpose(0, 2, 1, 3)
