"""Jit'd public wrapper for the wkv kernel: (B, T, H, dk) frontend."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import common
from repro.kernels.wkv.kernel import wkv_recurrence
from repro.kernels.wkv.ref import wkv_recurrence_ref


def _flat(x):
    b, t, h, d = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b * h, t, d)


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def _fwd(r, k, v, w, u, block_t: int, interpret: bool):
    b, t, h, d = r.shape
    uu = jnp.tile(u[None], (b, 1, 1)).reshape(b * h, d)
    out = wkv_recurrence(_flat(r), _flat(k), _flat(v), _flat(w), uu,
                         block_t=block_t, interpret=interpret)
    return out.reshape(b, h, t, d).transpose(0, 2, 1, 3)


def _exact_wkv(r, k, v, w, u):
    """Float scan reference on the (B, T, H, d) layout — the STE backward."""
    b, t, h, d = r.shape
    uu = jnp.tile(u[None], (b, 1, 1)).reshape(b * h, d)
    out = wkv_recurrence_ref(_flat(r), _flat(k), _flat(v), _flat(w), uu)
    return out.reshape(b, h, t, d).transpose(0, 2, 1, 3)


def wkv(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
        u: jax.Array, *, block_t: Optional[int] = None,
        interpret: Optional[bool] = None) -> jax.Array:
    """r/k/v/w: (B, T, H, d); u: (H, d).  Returns (B, T, H, d).

    ``block_t`` defaults through the substrate cache keyed on (T, d) —
    tuned-table entries apply; the heuristic matches the old fixed 64
    default (the kernel clamps to a divisor of T either way)."""
    interpret = common.resolve_interpret(interpret)
    if block_t is None:
        block_t = common.pick_block_rows("wkv", (r.shape[1], r.shape[3]),
                                         r.dtype, max_rows=64)
    f = common.ste(
        functools.partial(_fwd, block_t=block_t, interpret=interpret),
        _exact_wkv)
    return f(r, k, v, w, u)


def _candidates(shape, dtype):
    """(block_t, d) candidates for the (T, d) key: the time axis is the
    only tunable dimension (sequential sweep); it must divide T."""
    t, d = shape
    return tuple((bt, d) for bt in common.divisor_candidates(t, 128, 4))


common.register(common.KernelSpec(
    name="wkv", kernel=wkv_recurrence, ref=wkv_recurrence_ref,
    grad=_exact_wkv, candidates=_candidates, tags=("float", "recurrent")))
