"""Jit'd public wrapper for the wkv kernel: (B, T, H, dk) frontend."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import common
from repro.kernels.wkv.kernel import wkv_recurrence
from repro.kernels.wkv.kernel_bwd import wkv_recurrence_bwd
from repro.kernels.wkv.kernel_q8 import wkv_recurrence_q8
from repro.kernels.wkv.ref import wkv_bwd_ref, wkv_q8_ref, wkv_recurrence_ref


def _flat(x):
    b, t, h, d = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b * h, t, d)


def _unflat(x, b, h):
    bh, t, d = x.shape
    return x.reshape(b, h, t, d).transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def _fwd(r, k, v, w, u, block_t: int, interpret: bool):
    b, t, h, d = r.shape
    uu = jnp.tile(u[None], (b, 1, 1)).reshape(b * h, d)
    out = wkv_recurrence(_flat(r), _flat(k), _flat(v), _flat(w), uu,
                         block_t=block_t, interpret=interpret)
    return _unflat(out, b, h)


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def _fwd_res(r, k, v, w, u, block_t: int, interpret: bool):
    """Forward also emitting block-boundary state checkpoints."""
    b, t, h, d = r.shape
    uu = jnp.tile(u[None], (b, 1, 1)).reshape(b * h, d)
    out, ckpt = wkv_recurrence(_flat(r), _flat(k), _flat(v), _flat(w), uu,
                               block_t=block_t, interpret=interpret,
                               return_residuals=True)
    return _unflat(out, b, h), ckpt


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def _bwd_impl(r, k, v, w, u, ckpt, dy, block_t: int, interpret: bool):
    """Fused backward on the public layout; cotangents in primal dtypes."""
    b, t, h, d = r.shape
    uu = jnp.tile(u[None], (b, 1, 1)).reshape(b * h, d)
    dr, dk, dv, dw, du = wkv_recurrence_bwd(
        _flat(r), _flat(k), _flat(v), _flat(w), uu, _flat(dy), ckpt,
        block_t=block_t, interpret=interpret)
    return (_unflat(dr, b, h).astype(r.dtype),
            _unflat(dk, b, h).astype(k.dtype),
            _unflat(dv, b, h).astype(v.dtype),
            _unflat(dw, b, h).astype(w.dtype),
            du.reshape(b, h, d).sum(0).astype(u.dtype))


def bwd_block_cap(d: int, on_tpu: Optional[bool] = None) -> int:
    """Heuristic cap for the training-path time block.

    The backward stashes block_t recomputed (dk, dv) states at once, so
    the cap bounds that buffer: ~1 MB on TPU VMEM, ~4 MB in interpret
    mode (where fewer grid steps win).  Shared with benchmarks so
    reported residual-memory estimates match the blocks that actually
    ran.
    """
    if on_tpu is None:
        on_tpu = common.on_tpu()
    budget = (1 << 18) if on_tpu else (1 << 20)
    return max(16, min(512, budget // max(1, d * d)))


def _exact_wkv(r, k, v, w, u):
    """Float scan reference on the (B, T, H, d) layout — the STE backward."""
    b, t, h, d = r.shape
    uu = jnp.tile(u[None], (b, 1, 1)).reshape(b * h, d)
    out = wkv_recurrence_ref(_flat(r), _flat(k), _flat(v), _flat(w), uu)
    return _unflat(out, b, h)


def wkv(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
        u: jax.Array, *, block_t: Optional[int] = None,
        interpret: Optional[bool] = None) -> jax.Array:
    """r/k/v/w: (B, T, H, d); u: (H, d).  Returns (B, T, H, d).

    ``block_t`` defaults through the substrate cache keyed on (T, d) —
    tuned-table entries apply; the heuristic matches the old fixed 64
    default (the kernel clamps to a divisor of T either way); the pick is
    skipped when the block is passed explicitly.

    Differentiable: the backward pass is the fused reverse-time kernel in
    ``kernel_bwd.py``, restarted from per-block state checkpoints the
    forward emits.  Checkpoint spacing must match the backward's time
    block, so under differentiation both passes run with the block
    resolved under the ``wkv.bwd`` substrate key (tuned independently of
    the inference-path ``wkv`` key).  ``REPRO_FUSED_BWD=0`` falls back to
    the exact VJP of the float scan reference.
    """
    interpret = common.resolve_interpret(interpret)
    if block_t is None:
        block_t = common.pick_block_rows("wkv", (r.shape[1], r.shape[3]),
                                         r.dtype, max_rows=64)
    fwd = functools.partial(_fwd, block_t=block_t, interpret=interpret)
    fwd_res = bwd = None
    if common.fused_backward_enabled():
        bt_b = common.pick_block_rows("wkv.bwd", (r.shape[1], r.shape[3]),
                                      r.dtype,
                                      max_rows=bwd_block_cap(r.shape[3]))

        def fwd_res(r_, k_, v_, w_, u_):
            out, ckpt = _fwd_res(r_, k_, v_, w_, u_, bt_b, interpret)
            return out, (r_, k_, v_, w_, u_, ckpt)

        def bwd(res, g):
            r_, k_, v_, w_, u_, ckpt = res
            return _bwd_impl(r_, k_, v_, w_, u_, ckpt, g, bt_b, interpret)

    return common.fused_vjp(fwd, _exact_wkv, fwd_res, bwd)(r, k, v, w, u)


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def _fwd_q8(r, k, v, w, u, state, state_scale, block_t: int,
            interpret: bool):
    b, t, h, d = r.shape
    dk, dv = state.shape[-2:]
    uu = jnp.tile(u[None], (b, 1, 1)).reshape(b * h, d)
    out, s_fin, s_scale = wkv_recurrence_q8(
        _flat(r), _flat(k), _flat(v), _flat(w), uu,
        state.reshape(b * h, dk, dv), state_scale.reshape(b * h, dk),
        block_t=block_t, interpret=interpret)
    return (_unflat(out, b, h), s_fin.reshape(b, h, dk, dv),
            s_scale.reshape(b, h, dk))


def wkv_q8(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
           u: jax.Array, state: jax.Array, state_scale: jax.Array, *,
           block_t: Optional[int] = None,
           interpret: Optional[bool] = None):
    """Quantized-state wkv.  r/k/v/w: (B, T, H, d); u: (H, d); state:
    (B, H, dk, dv) int8 with per-row float32 scales (B, H, dk) — the
    serving slot state's wkv/wkv_scale leaves for one layer.

    Returns ``(out (B, T, H, dv), state int8, state_scale)`` — the state
    after the T steps, requantized in-kernel (one int8 round-trip per
    call, matching the jnp serving path).  Blocks resolve under the
    ``wkv.q8`` substrate key.  Forward-only.
    """
    interpret = common.resolve_interpret(interpret)
    if block_t is None:
        block_t = common.pick_block_rows("wkv.q8",
                                         (r.shape[1], r.shape[3]),
                                         state.dtype, max_rows=64)
    return _fwd_q8(r, k, v, w, u, state, state_scale, block_t=block_t,
                   interpret=interpret)


def _candidates(shape, dtype):
    """(block_t, d) candidates for the (T, d) key: the time axis is the
    only tunable dimension (sequential sweep); it must divide T."""
    t, d = shape
    return tuple((bt, d) for bt in common.divisor_candidates(t, 128, 4))


def _bwd_candidates(shape, dtype):
    """Backward time blocks for the same (T, d) key.  The backward holds
    bt recomputed (dk, dv) states in VMEM at once, so small blocks bound
    VMEM (device) and large ones bound grid steps (interpret); autotune
    skips candidates that overflow on device."""
    t, d = shape
    return tuple((bt, d) for bt in common.divisor_candidates(t, 512, 4))


common.register(common.KernelSpec(
    name="wkv", kernel=wkv_recurrence, ref=wkv_recurrence_ref,
    grad=_exact_wkv, grad_kernel=wkv_recurrence_bwd,
    candidates=_candidates, tags=("float", "recurrent")))

# Training-path time block (shared by the residual forward and the
# reverse sweep): own registry entry so `benchmarks.tune` sweeps it.
common.register(common.KernelSpec(
    name="wkv.bwd", kernel=wkv_recurrence_bwd, ref=wkv_bwd_ref,
    candidates=_bwd_candidates, tags=("float", "recurrent", "backward")))

# Quantized-state forward: same (T, d) cache-key shape, int8 dtype key,
# own registry entry so `benchmarks.tune` sweeps its time block.
common.register(common.KernelSpec(
    name="wkv.q8", kernel=wkv_recurrence_q8, ref=wkv_q8_ref,
    candidates=_candidates, tags=("int8", "recurrent", "serving")))
