"""Pallas TPU kernel: RWKV6 (Finch) wkv recurrence.

The attention-free mixer's hotspot: per head, a (dk x dv) state S updated
per token with data-dependent per-channel decay,

    out_t = r_t · (S + (u ⊙ k_t) v_tᵀ)
    S    <- diag(w_t) S + k_t v_tᵀ

Grid (batch*heads, T/bt) with the time axis sequential ("arbitrary"); the
state S lives in VMEM scratch across the whole sweep — the recurrent
analogue of the SYCore output-stationary discipline (state stays, tokens
stream).  Inside a block the bt steps run as an unrolled/fori loop of
rank-1 updates on the VPU.

Bit-comparable (f32) to :mod:`repro.kernels.wkv.ref`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import common


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, *rest, bt: int,
                with_ckpt: bool):
    if with_ckpt:
        c_ref, (s_scr,) = rest[0], rest[1:]
    else:
        c_ref, (s_scr,) = None, rest

    @pl.when(pl.program_id(1) == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    if with_ckpt:
        # State at this block's start: the checkpoint the reverse-time
        # backward (kernel_bwd.py) restarts its in-block recompute from.
        c_ref[0, 0] = s_scr[...]

    r = r_ref[0].astype(jnp.float32)   # (bt, dk)
    k = k_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)   # (bt, dv)
    u = u_ref[0].astype(jnp.float32)   # (1, dk) broadcast row

    def step(i, carry):
        s, out = carry
        kv = k[i][:, None] * v[i][None, :]              # (dk, dv)
        y = (r[i] * u[0])[None, :] @ kv + r[i][None, :] @ s
        out = jax.lax.dynamic_update_slice_in_dim(out, y, i, axis=0)
        s = w[i][:, None] * s + kv
        return s, out

    s0 = s_scr[...]
    out0 = jnp.zeros((bt, v.shape[1]), jnp.float32)
    s_fin, out = jax.lax.fori_loop(0, bt, step, (s0, out0))
    s_scr[...] = s_fin
    o_ref[0] = out.astype(o_ref.dtype)


def wkv_recurrence(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
                   u: jax.Array, *, block_t: int = 64,
                   interpret: bool = True,
                   return_residuals: bool = False):
    """r/k/w: (BH, T, dk); v: (BH, T, dv); u: (BH, dk).  -> (BH, T, dv).

    T must tile by block_t; state starts at zero (training semantics — the
    decode path carries S explicitly in jnp, see models/ssm.py).
    With ``return_residuals`` also returns the per-block-boundary state
    checkpoints, (BH, T/bt, dk, dv) float32 — O(T/bt) states instead of
    the O(T) a scan-based VJP would stash; the backward recomputes the
    in-block states from them.
    """
    bh, t, dk = r.shape
    dv = v.shape[-1]
    bt = common.largest_divisor(t, block_t)
    grid = (bh, t // bt)
    kernel = functools.partial(_wkv_kernel, bt=bt,
                               with_ckpt=return_residuals)
    out_specs = pl.BlockSpec((1, bt, dv), lambda b, i: (b, i, 0))
    out_shape = jax.ShapeDtypeStruct((bh, t, dv), r.dtype)
    if return_residuals:
        out_specs = [out_specs,
                     pl.BlockSpec((1, 1, dk, dv), lambda b, i: (b, i, 0, 0))]
        out_shape = [out_shape,
                     jax.ShapeDtypeStruct((bh, t // bt, dk, dv),
                                          jnp.float32)]
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bt, dk), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, bt, dk), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, bt, dv), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, bt, dk), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, 1, dk), lambda b, i: (b, 0, 0)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        compiler_params=common.compiler_params("parallel", "arbitrary"),
        interpret=interpret,
    )(r, k, v, w, u.reshape(bh, 1, dk))
