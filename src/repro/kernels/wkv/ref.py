"""Pure-jnp oracle for the wkv kernel (scan formulation)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv_recurrence_ref(r: jax.Array, k: jax.Array, v: jax.Array,
                       w: jax.Array, u: jax.Array) -> jax.Array:
    """r/k/w (BH,T,dk); v (BH,T,dv); u (BH,dk) -> (BH,T,dv), f32 math."""
    bh, t, dk = r.shape
    dv = v.shape[-1]

    def one(r1, k1, v1, w1, u1):
        def step(s, xs):
            rt, kt, vt, wt = xs
            kv = kt[:, None] * vt[None, :]
            y = (rt * u1) @ kv + rt @ s
            s = wt[:, None] * s + kv
            return s, y

        _, out = jax.lax.scan(step, jnp.zeros((dk, dv), jnp.float32),
                              (r1.astype(jnp.float32),
                               k1.astype(jnp.float32),
                               v1.astype(jnp.float32),
                               w1.astype(jnp.float32)))
        return out

    return jax.vmap(one)(r, k, v, w, u.astype(jnp.float32)).astype(r.dtype)


def wkv_q8_ref(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
               u: jax.Array, s0: jax.Array, s0_scale: jax.Array):
    """Oracle for the quantized-state kernel: dequantize the int8 state
    (one float32 scale per dk row), run the f32 scan from it, requantize
    the final state the same way.  Returns (out, s_fin int8, s_scale)."""

    def one(r1, k1, v1, w1, u1, s1):
        def step(s, xs):
            rt, kt, vt, wt = xs
            kv = kt[:, None] * vt[None, :]
            y = (rt * u1) @ kv + rt @ s
            s = wt[:, None] * s + kv
            return s, y

        return jax.lax.scan(step, s1,
                            (r1.astype(jnp.float32),
                             k1.astype(jnp.float32),
                             v1.astype(jnp.float32),
                             w1.astype(jnp.float32)))

    s_init = s0.astype(jnp.float32) * s0_scale.astype(jnp.float32)[..., None]
    s_fin, out = jax.vmap(one)(r, k, v, w, u.astype(jnp.float32), s_init)
    sc = jnp.max(jnp.abs(s_fin), axis=-1) * (1.0 / 127.0)
    q = jnp.clip(jnp.round(s_fin / jnp.maximum(sc, 1e-30)[..., None]),
                 -127.0, 127.0)
    return out.astype(r.dtype), q.astype(jnp.int8), sc


def wkv_bwd_ref(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
                u: jax.Array, dy: jax.Array):
    """Exact (dr, dk, dv, dw, du) via autodiff of the scan reference —
    the oracle for the fused backward kernel."""
    _, vjp = jax.vjp(wkv_recurrence_ref, r, k, v, w, u)
    return vjp(dy)
