"""Pallas kernel families, all dispatching through the shared substrate.

Importing this package registers every family in
:mod:`repro.kernels.common`'s :class:`~repro.kernels.common.KernelSpec`
registry and re-exports the public float-frontend ops.  Each family lives
in its own subpackage as ``kernel.py`` (raw Pallas entry point) +
``ref.py`` (oracle) + ``ops.py`` (jit'd wrapper) — the contract is
documented in ``docs/KERNELS.md``.
"""
from repro.kernels.common import (KernelSpec, get_kernel, register,
                                  registered_kernels)
from repro.kernels.cordic_act.ops import cordic_act
from repro.kernels.cordic_mac.ops import cordic_matmul
from repro.kernels.cordic_softmax.ops import cordic_softmax
from repro.kernels.flash_attention.ops import (flash_attention,
                                               flash_attention_q8)
from repro.kernels.wkv.ops import wkv, wkv_q8

__all__ = [
    "KernelSpec", "get_kernel", "register", "registered_kernels",
    "cordic_act", "cordic_matmul", "cordic_softmax", "flash_attention",
    "flash_attention_q8", "wkv", "wkv_q8",
]
