"""Pure-jnp oracle for the CORDIC activation kernel.

Composes the identical integer recurrences (same constants, same shift
schedule, same guard-bit rounding) in plain jnp — no Pallas — so the kernel
can be asserted bit-exact.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core import cordic, fixed_point as fxp
from repro.core.fixed_point import FxpFormat

LN2 = math.log(2.0)
GUARD_BITS = 4
EXP_ARG_CLAMP = 30.0


def _hyperbolic_ref(z, fb: int, n: int):
    inv_gain = jnp.int32(fxp.constant_raw(1.0 / cordic.hyperbolic_gain(n), fb))
    x = jnp.full_like(z, inv_gain)
    y = jnp.zeros_like(z)
    for shift in cordic.hyperbolic_sequence(n):
        e_i = jnp.int32(fxp.constant_raw(math.atanh(2.0 ** (-shift)), fb))
        delta = jnp.where(z >= 0, jnp.int32(1), jnp.int32(-1))
        x, y, z = (x + delta * jnp.right_shift(y, shift),
                   y + delta * jnp.right_shift(x, shift),
                   z - delta * e_i)
    return x, y


def _divide_ref(y, x, fb: int, n: int):
    q = jnp.zeros_like(y)
    for i in range(n):
        e_i = jnp.int32(fxp.constant_raw(2.0 ** (-i), fb))
        delta = jnp.where(y >= 0, jnp.int32(1), jnp.int32(-1))
        y = y - delta * jnp.right_shift(x, i)
        q = q + delta * e_i
    return q


def exp_neg_raw_ref(a, fb: int, n_hyp: int):
    inv_ln2 = jnp.int32(fxp.constant_raw(1.0 / LN2, fb))
    ln2 = jnp.int32(fxp.constant_raw(LN2, fb))
    t = a * inv_ln2
    k = jnp.right_shift(t + (jnp.int32(1) << (2 * fb - 1)), 2 * fb)
    r = a - k * ln2
    c, s = _hyperbolic_ref(r, fb, n_hyp)
    return jnp.right_shift(c + s, jnp.clip(-k, 0, 31))


def _round_back_ref(v, guard: int):
    return jnp.right_shift(v + (jnp.int32(1) << (guard - 1)), guard)


def cordic_act_raw_ref(x_raw: jax.Array, *, af: str, fmt: FxpFormat,
                       n_hyp: int = cordic.N_HYPERBOLIC_STAGES,
                       n_div: int = cordic.N_DIVISION_STAGES,
                       guard: int = GUARD_BITS) -> jax.Array:
    fb = fmt.frac_bits + guard
    a = jnp.left_shift(x_raw.astype(jnp.int32), guard)
    one = jnp.int32(1) << fb
    clamp = jnp.int32(fxp.constant_raw(EXP_ARG_CLAMP, fb))
    if af == "exp":
        a = jnp.clip(a, -clamp, jnp.int32(0))
        return _round_back_ref(exp_neg_raw_ref(a, fb, n_hyp), guard)
    if af == "tanh":
        cap = jnp.int32(fxp.constant_raw(
            min(4.0, fmt.max_value / 2.0 - fmt.resolution), fb))
        a_abs = jnp.minimum(jnp.abs(a), cap)
        e2a = exp_neg_raw_ref(-(a_abs + a_abs), fb, n_hyp)
        q = _divide_ref(e2a - one, e2a + one, fb, n_div)
        return _round_back_ref(jnp.where(a >= 0, -q, q), guard)
    if af == "sigmoid":
        e = exp_neg_raw_ref(jnp.maximum(-jnp.abs(a), -clamp), fb, n_hyp)
        q = _divide_ref(jnp.full_like(a, one), one + e, fb, n_div)
        return _round_back_ref(jnp.where(a >= 0, q, one - q), guard)
    raise ValueError(f"unsupported AF {af!r}")
