"""Jit'd public wrapper for the CORDIC activation kernel (float frontend)."""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import cordic, fixed_point as fxp
from repro.core.fixed_point import FxpFormat
from repro.kernels.cordic_act.kernel import cordic_act_raw

_ON_TPU = any(d.platform == "tpu" for d in jax.devices())

_EXACT = {"tanh": jnp.tanh, "sigmoid": jax.nn.sigmoid, "exp": jnp.exp}


def _pick_block(r: int, c: int) -> Tuple[int, int]:
    br = r if r < 256 else 256
    bc = c if c < 512 else 512
    # shrink to divisors
    while r % br:
        br -= 1
    while c % bc:
        bc -= 1
    return br, bc


@functools.partial(jax.jit, static_argnames=("af", "fmt", "n_hyp", "n_div",
                                             "guard", "interpret"))
def _fwd(x, af: str, fmt: FxpFormat, n_hyp: int, n_div: int, guard: int,
         interpret: bool):
    shape = x.shape
    x2 = x.reshape(-1, shape[-1]) if x.ndim != 2 else x
    raw = fxp.quantize(x2, fmt)
    out = cordic_act_raw(raw, af=af, fmt=fmt, n_hyp=n_hyp, n_div=n_div,
                         guard=guard, block=_pick_block(*x2.shape),
                         interpret=interpret)
    return fxp.dequantize(out, fmt).reshape(shape).astype(x.dtype)


def cordic_act(x: jax.Array, af: str, *, fmt: FxpFormat = fxp.FXP16,
               n_hyp: int = cordic.N_HYPERBOLIC_STAGES,
               n_div: Optional[int] = None, guard: int = 4,
               interpret: Optional[bool] = None) -> jax.Array:
    """tanh/sigmoid/exp through the DA-VINCI kernel, STE gradients."""
    if interpret is None:
        interpret = not _ON_TPU
    if n_div is None:
        n_div = max(cordic.N_DIVISION_STAGES, fmt.frac_bits + guard)

    @jax.custom_vjp
    def f(v):
        return _fwd(v, af, fmt, n_hyp, n_div, guard, interpret)

    def fwd(v):
        return f(v), v

    def bwd(v, g):
        _, vjp = jax.vjp(_EXACT[af], v)
        return vjp(g)

    f.defvjp(fwd, bwd)
    return f(x)
