"""Jit'd public wrapper for the CORDIC activation kernel (float frontend)."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import cordic, fixed_point as fxp
from repro.core.fixed_point import FxpFormat
from repro.kernels import common
from repro.kernels.cordic_act.kernel import cordic_act_raw
from repro.kernels.cordic_act.ref import cordic_act_raw_ref

_EXACT = {"tanh": jnp.tanh, "sigmoid": jax.nn.sigmoid, "exp": jnp.exp}


@functools.partial(jax.jit, static_argnames=("af", "fmt", "n_hyp", "n_div",
                                             "guard", "block", "interpret"))
def _fwd(x, af: str, fmt: FxpFormat, n_hyp: int, n_div: int, guard: int,
         block, interpret: bool):
    shape = x.shape
    x2 = x.reshape(-1, shape[-1]) if x.ndim != 2 else x
    raw = fxp.quantize(x2, fmt)
    out = cordic_act_raw(raw, af=af, fmt=fmt, n_hyp=n_hyp, n_div=n_div,
                         guard=guard, block=block, interpret=interpret)
    return fxp.dequantize(out, fmt).reshape(shape).astype(x.dtype)


def cordic_act(x: jax.Array, af: str, *, fmt: FxpFormat = fxp.FXP16,
               n_hyp: int = cordic.N_HYPERBOLIC_STAGES,
               n_div: Optional[int] = None, guard: int = 4,
               interpret: Optional[bool] = None) -> jax.Array:
    """tanh/sigmoid/exp through the DA-VINCI kernel, STE gradients."""
    if af not in _EXACT:
        raise ValueError(f"unsupported af {af!r}; kernel AFs: "
                         f"{sorted(_EXACT)} (composites like gelu live in "
                         "core/activations.py)")
    interpret = common.resolve_interpret(interpret)
    if n_div is None:
        n_div = max(cordic.N_DIVISION_STAGES, fmt.frac_bits + guard)
    # Pick the block OUTSIDE the jitted forward so autotuned cache entries
    # take effect (a lookup inside _fwd would be frozen into its trace).
    x2_shape = (x.size // x.shape[-1], x.shape[-1])
    block = common.pick_block_2d(f"cordic_act.{af}", x2_shape, jnp.int32)
    f = common.ste(
        functools.partial(_fwd, af=af, fmt=fmt, n_hyp=n_hyp, n_div=n_div,
                          guard=guard, block=block, interpret=interpret),
        _EXACT[af])
    return f(x)


def _exact_act(x: jax.Array, *, af: str) -> jax.Array:
    return _EXACT[af](x)


def _candidates(shape, dtype):
    """Legal (rows, cols) tiles for the flattened 2-d input: the Pallas
    BlockSpec requires exact division, so candidates are divisor pairs
    under the elementwise caps.  Cache keys are per-AF
    (``cordic_act.tanh`` etc.) but legality depends only on the shape."""
    r, c = shape
    return tuple((br, bc)
                 for br in common.divisor_candidates(r, 256, 3)
                 for bc in common.divisor_candidates(c, 512, 3))


common.register(common.KernelSpec(
    name="cordic_act", kernel=cordic_act_raw, ref=cordic_act_raw_ref,
    grad=_exact_act, candidates=_candidates,
    tags=("fixed-point", "elementwise")))
