"""Pallas TPU kernel: DA-VINCI activation datapath (hyperbolic + division).

Elementwise tanh / sigmoid / exp on raw int32 fixed-point tiles, computed
exactly as the RPE's iterative stages do it:

  * G guard bits of internal precision (the paper's "2N+K" AF input
    precision, §1.1) — inputs are up-shifted by G, iterated at
    frac_bits+G, and rounded back at the output latch,
  * hyperbolic micro-rotations -> cosh, sinh,
  * integer ln2 range extension (a = k*ln2 + r; barrel shift by k) — our
    TPU-side fidelity adaptation, see DESIGN.md §Hardware-adaptation,
  * division micro-iterations for the tanh/sigmoid quotients,
  * range-extended tanh identity tanh(-|a|) = (e^{-2|a|}-1)/(e^{-2|a|}+1).

Bit-exact against :mod:`repro.kernels.cordic_act.ref`, which composes the
same recurrences in plain jnp.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import cordic, fixed_point as fxp
from repro.core.fixed_point import FxpFormat
from repro.kernels import common

LN2 = math.log(2.0)
GUARD_BITS = 4
# |a| clamp before the k-extraction multiply so Q(2*fb) products fit int32.
EXP_ARG_CLAMP = 30.0


# ---------------------------------------------------------------------------
# Integer building blocks — all operate at internal precision Q(fb)
# ---------------------------------------------------------------------------

def _hyperbolic(z, fb: int, n: int):
    """Unrolled hyperbolic rotation at Q(fb): returns (cosh_raw, sinh_raw)."""
    inv_gain = jnp.int32(fxp.constant_raw(1.0 / cordic.hyperbolic_gain(n), fb))
    x = jnp.full_like(z, inv_gain)
    y = jnp.zeros_like(z)
    for shift in cordic.hyperbolic_sequence(n):
        e_i = jnp.int32(fxp.constant_raw(math.atanh(2.0 ** (-shift)), fb))
        delta = jnp.where(z >= 0, jnp.int32(1), jnp.int32(-1))
        x, y, z = (x + delta * jnp.right_shift(y, shift),
                   y + delta * jnp.right_shift(x, shift),
                   z - delta * e_i)
    return x, y


def _divide(y, x, fb: int, n: int):
    """Unrolled linear vectoring at Q(fb): quotient y/x (x > 0, |y/x| < 2)."""
    q = jnp.zeros_like(y)
    for i in range(n):
        e_i = jnp.int32(fxp.constant_raw(2.0 ** (-i), fb))
        delta = jnp.where(y >= 0, jnp.int32(1), jnp.int32(-1))
        y = y - delta * jnp.right_shift(x, i)
        q = q + delta * e_i
    return q


def _exp_neg(a, fb: int, n_hyp: int):
    """e^a for a <= 0 at Q(fb) via integer ln2 range extension.

    k = round(a/ln2) (<= 0), r = a - k*ln2, e^a = (cosh r + sinh r) >> -k.
    Callers must clamp a >= -EXP_ARG_CLAMP so the Q(2*fb) product fits int32
    (requires fb <= 12).
    """
    inv_ln2 = jnp.int32(fxp.constant_raw(1.0 / LN2, fb))
    ln2 = jnp.int32(fxp.constant_raw(LN2, fb))
    t = a * inv_ln2                       # Q(2*fb) product
    k = jnp.right_shift(t + (jnp.int32(1) << (2 * fb - 1)), 2 * fb)
    r = a - k * ln2
    c, s = _hyperbolic(r, fb, n_hyp)
    return jnp.right_shift(c + s, jnp.clip(-k, 0, 31))


def _round_back(v, guard: int):
    """Round from Q(frac+guard) back to Q(frac) — the output latch."""
    return jnp.right_shift(v + (jnp.int32(1) << (guard - 1)), guard)


# ---------------------------------------------------------------------------
# Kernel body
# ---------------------------------------------------------------------------

def _act_kernel(x_ref, o_ref, *, af: str, fmt: FxpFormat, n_hyp: int,
                n_div: int, guard: int):
    fb = fmt.frac_bits + guard
    a = jnp.left_shift(x_ref[...], guard)            # Q(fb)
    one = jnp.int32(1) << fb
    clamp = jnp.int32(fxp.constant_raw(EXP_ARG_CLAMP, fb))

    if af == "exp":
        # decode paths feed max-subtracted (<= 0) arguments
        a = jnp.clip(a, -clamp, jnp.int32(0))
        o_ref[...] = _round_back(_exp_neg(a, fb, n_hyp), guard)
    elif af == "tanh":
        # tanh(-|a|) = (e^{-2|a|}-1)/(e^{-2|a|}+1), mirrored by sign.
        cap = jnp.int32(fxp.constant_raw(
            min(4.0, fmt.max_value / 2.0 - fmt.resolution), fb))
        a_abs = jnp.minimum(jnp.abs(a), cap)
        e2a = _exp_neg(-(a_abs + a_abs), fb, n_hyp)
        q = _divide(e2a - one, e2a + one, fb, n_div)  # in (-1, 0]
        o_ref[...] = _round_back(jnp.where(a >= 0, -q, q), guard)
    elif af == "sigmoid":
        e = _exp_neg(jnp.maximum(-jnp.abs(a), -clamp), fb, n_hyp)
        q = _divide(jnp.full_like(a, one), one + e, fb, n_div)
        o_ref[...] = _round_back(jnp.where(a >= 0, q, one - q), guard)
    else:
        raise ValueError(f"unsupported kernel AF {af!r}")


def cordic_act_raw(x_raw: jax.Array, *, af: str, fmt: FxpFormat,
                   n_hyp: int = cordic.N_HYPERBOLIC_STAGES,
                   n_div: int = cordic.N_DIVISION_STAGES,
                   guard: int = GUARD_BITS,
                   block: tuple[int, int] = (256, 256),
                   interpret: bool = True) -> jax.Array:
    """Elementwise CORDIC AF on a 2D raw-int32 array (tiles must divide)."""
    assert fmt.frac_bits + guard <= 12, (
        "internal precision capped at Q12 for int32 headroom in the "
        "ln2-extraction multiply")
    r, c = x_raw.shape
    br, bc = min(block[0], r), min(block[1], c)
    assert r % br == 0 and c % bc == 0
    kernel = functools.partial(_act_kernel, af=af, fmt=fmt, n_hyp=n_hyp,
                               n_div=n_div, guard=guard)
    return pl.pallas_call(
        kernel,
        grid=(r // br, c // bc),
        in_specs=[pl.BlockSpec((br, bc), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r, c), jnp.int32),
        compiler_params=common.compiler_params("parallel", "parallel"),
        interpret=interpret,
    )(x_raw)
