"""Pallas TPU kernel: output-stationary CORDIC matmul (SYCore on the VPU).

Dataflow = the paper's SYCore: the output tile is pinned in VMEM (the
"output-stationary partial sums"), K-slices of inputs and weights stream
through, and every scalar multiply is the RPE's n-stage linear-CORDIC
shift-add recurrence:

    for stage i in 0..n-1:
        delta = sign(z)            # z: weight residual
        y    += delta * (x >> i)   # arithmetic shift + add
        z    -= delta * 2^-i

All arithmetic is on raw int32 fixed-point words, so the kernel is
bit-exact against :mod:`repro.kernels.cordic_mac.ref` (which reduces the
same recurrence to a sum of signed-digit matmuls).

Grid: (M/bm, N/bn, K/bk) with the K axis innermost ("arbitrary"), so each
(i, j) output tile sees its K-slices back-to-back and accumulates in place —
exactly one output-stationary pass of the systolic array per tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import fixed_point as fxp
from repro.core.fixed_point import FxpFormat
from repro.kernels import common


def _mac_kernel(x_ref, w_ref, out_ref, *, n_stages: int, fmt: FxpFormat,
                bk: int):
    """One grid step: out_tile += CORDIC(x_tile @ w_tile)."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...]            # (bm, bk) int32 raw
    w = w_ref[...]            # (bk, bn) int32 raw
    acc = out_ref[...]        # (bm, bn) int32 raw — the stationary tile

    # Angle constants E_i = 2^-i in fmt (hard-wired per pipeline stage).
    e_consts = [jnp.int32(fxp.constant(2.0 ** (-i), fmt)) for i in range(n_stages)]

    def k_step(kk, acc):
        # One weight row enters the array; delta is a pure function of the
        # evolving weight residual, shared across the whole input column.
        xc = jax.lax.dynamic_slice_in_dim(x, kk, 1, axis=1)        # (bm, 1)
        z = jax.lax.dynamic_slice_in_dim(w, kk, 1, axis=0)         # (1, bn)
        for i in range(n_stages):
            delta = jnp.where(z >= 0, jnp.int32(1), jnp.int32(-1))  # (1, bn)
            acc = acc + delta * jnp.right_shift(xc, i)              # (bm, bn)
            z = z - delta * e_consts[i]
        return acc

    acc = jax.lax.fori_loop(0, bk, k_step, acc)
    out_ref[...] = acc


def cordic_matmul_raw(x_raw: jax.Array, w_raw: jax.Array, *,
                      fmt: FxpFormat, n_stages: int,
                      block: tuple[int, int, int] = (128, 128, 128),
                      interpret: bool = True) -> jax.Array:
    """Raw int32 CORDIC matmul via pallas_call.  Shapes must tile evenly."""
    m, k = x_raw.shape
    k2, n = w_raw.shape
    assert k == k2, (x_raw.shape, w_raw.shape)
    bm, bn, bk = block
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        f"shape ({m},{k},{n}) must tile by {block}; ops.py pads for you")

    grid = (m // bm, n // bn, k // bk)
    kernel = functools.partial(_mac_kernel, n_stages=n_stages, fmt=fmt, bk=bk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
            pl.BlockSpec((bk, bn), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        compiler_params=common.compiler_params("parallel", "parallel",
                                               "arbitrary"),
        interpret=interpret,
    )(x_raw, w_raw)
