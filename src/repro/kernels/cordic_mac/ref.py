"""Pure-jnp oracle for the CORDIC matmul kernel.

Identity used: the n-stage linear-CORDIC multiply-accumulate

    y[m,n] = sum_k sum_i delta_i[k,n] * (x[m,k] >> i)

commutes (integer adds are associative), so the whole matmul is a sum of n
*signed-digit matmuls*:

    Y = sum_i  shift_i(X) @ Delta_i,      Delta_i in {-1,+1}^{KxN}

where Delta_i is the stage-i sign plane of the weight residual recurrence —
a pure function of W, precomputable offline.  This is bit-exact w.r.t. the
hardware recurrence (and is itself the TPU-native "CORDIC on the MXU"
formulation discussed in DESIGN.md: n int matmuls against sign planes).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import fixed_point as fxp
from repro.core.fixed_point import FxpFormat


def weight_sign_planes(w_raw: jax.Array, fmt: FxpFormat, n_stages: int
                       ) -> jax.Array:
    """Delta_i planes, shape (n_stages, K, N), values in {-1, +1} (int32)."""
    z = w_raw.astype(jnp.int32)
    planes = []
    for i in range(n_stages):
        delta = jnp.where(z >= 0, jnp.int32(1), jnp.int32(-1))
        planes.append(delta)
        z = z - delta * jnp.int32(fxp.constant(2.0 ** (-i), fmt))
    return jnp.stack(planes)


def cordic_matmul_raw_ref(x_raw: jax.Array, w_raw: jax.Array, *,
                          fmt: FxpFormat, n_stages: int) -> jax.Array:
    x_raw = x_raw.astype(jnp.int32)
    planes = weight_sign_planes(w_raw, fmt, n_stages)
    out = jnp.zeros((x_raw.shape[0], w_raw.shape[1]), jnp.int32)
    for i in range(n_stages):
        xs = jnp.right_shift(x_raw, i)
        out = out + jax.lax.dot_general(
            xs, planes[i],
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)
    return out


def cordic_matmul_ref(x: jax.Array, w: jax.Array, *, fmt: FxpFormat,
                      n_stages: int) -> jax.Array:
    """Float frontend: quantize -> raw matmul -> dequantize."""
    x_raw = fxp.quantize(x, fmt)
    w_raw = fxp.quantize(w, fmt)
    out_raw = cordic_matmul_raw_ref(x_raw, w_raw, fmt=fmt, n_stages=n_stages)
    return fxp.dequantize(out_raw, fmt)


def effective_weight(w: jax.Array, fmt: FxpFormat, n_stages: int
                     ) -> jax.Array:
    """The signed-digit value the CORDIC recurrence effectively multiplies
    by: w_eff = sum_i delta_i * 2^-i.  Useful for error analysis — the MAC's
    multiplicative error is exactly (w_eff - w), independent of x up to the
    per-stage truncation of x (captured only by the full recurrence)."""
    w_raw = fxp.quantize(w, fmt)
    planes = weight_sign_planes(w_raw, fmt, n_stages)
    coeffs = jnp.asarray([2.0 ** (-i) for i in range(n_stages)], jnp.float32)
    return jnp.tensordot(coeffs, planes.astype(jnp.float32), axes=1)
