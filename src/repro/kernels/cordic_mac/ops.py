"""Jit'd public wrapper for the CORDIC matmul kernel.

Handles quantization, CAESAR block-shape selection, padding to tile
boundaries, interpret-mode fallback on CPU, and an STE backward pass so the
op is usable inside training graphs.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import fixed_point as fxp
from repro.core.fixed_point import FxpFormat
from repro.kernels import common
from repro.kernels.cordic_mac.kernel import cordic_matmul_raw
from repro.kernels.cordic_mac.ref import cordic_matmul_raw_ref


def _pad_to(a: jax.Array, m0: int, m1: int) -> jax.Array:
    p0 = (-a.shape[0]) % m0
    p1 = (-a.shape[1]) % m1
    if p0 or p1:
        a = jnp.pad(a, ((0, p0), (0, p1)))
    return a


@functools.partial(jax.jit, static_argnames=("fmt", "n_stages", "block",
                                             "interpret"))
def _fwd(x, w, fmt: FxpFormat, n_stages: int,
         block: Tuple[int, int, int], interpret: bool):
    m, k = x.shape
    n = w.shape[1]
    x_raw = _pad_to(fxp.quantize(x, fmt), block[0], block[2])
    w_raw = _pad_to(fxp.quantize(w, fmt), block[2], block[1])
    out_raw = cordic_matmul_raw(x_raw, w_raw, fmt=fmt, n_stages=n_stages,
                                block=block, interpret=interpret)
    return fxp.dequantize(out_raw[:m, :n], fmt)


def _exact_matmul(x: jax.Array, w: jax.Array) -> jax.Array:
    return x @ w


def cordic_matmul(x: jax.Array, w: jax.Array, *, fmt: FxpFormat = fxp.FXP16,
                  n_stages: int = 5,
                  block: Optional[Tuple[int, int, int]] = None,
                  interpret: Optional[bool] = None) -> jax.Array:
    """``x @ w`` through the RPE's 5-stage linear CORDIC (paper §2.2).

    Differentiable via straight-through estimation: forward is the
    bit-accurate systolic kernel, backward is the exact matmul VJP.
    """
    interpret = common.resolve_interpret(interpret)
    if block is None:
        m, k = x.shape
        n = w.shape[1]
        # int32 raw words => 4 bytes/element in VMEM.
        block = common.pick_block_matmul("cordic_mac", m, n, k,
                                         dtype=jnp.int32, max_block=256)
    f = common.ste(
        functools.partial(_fwd, fmt=fmt, n_stages=n_stages, block=block,
                          interpret=interpret),
        _exact_matmul)
    return f(x, w)


def _candidates(shape, dtype):
    """(bm, bn, bk) candidates for the (m, n, k) problem.  The wrapper
    pads, so blocks need not divide — candidates are the CAESAR
    VMEM-model pick plus square-ish power-of-two tiles clamped to the
    padded problem (>= 8 keeps the sublane tile legal on TPU)."""
    m, n, k = shape

    def clamp(dim: int, b: int) -> int:
        ceil_pow2 = 1 << (max(1, dim) - 1).bit_length()
        return max(8, min(b, ceil_pow2))

    caesar = common.pick_block_shape(
        m, n, k, bytes_per_el=jnp.dtype(dtype).itemsize, max_block=256)
    cands = [tuple(caesar)]
    for b in (64, 128, 256):
        cand = (clamp(m, b), clamp(n, b), clamp(k, b))
        if cand not in cands:
            cands.append(cand)
    return tuple(cands)


common.register(common.KernelSpec(
    name="cordic_mac", kernel=cordic_matmul_raw, ref=cordic_matmul_raw_ref,
    grad=_exact_matmul, candidates=_candidates,
    tags=("fixed-point", "matmul")))
