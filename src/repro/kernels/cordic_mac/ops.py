"""Jit'd public wrapper for the CORDIC matmul kernel.

Handles quantization, CAESAR block-shape selection, padding to tile
boundaries, interpret-mode fallback on CPU, and an STE backward pass so the
op is usable inside training graphs.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import fixed_point as fxp
from repro.core.caesar import pick_block_shape
from repro.core.fixed_point import FxpFormat
from repro.kernels.cordic_mac.kernel import cordic_matmul_raw

_ON_TPU = any(d.platform == "tpu" for d in jax.devices())


def _pad_to(a: jax.Array, m0: int, m1: int) -> jax.Array:
    p0 = (-a.shape[0]) % m0
    p1 = (-a.shape[1]) % m1
    if p0 or p1:
        a = jnp.pad(a, ((0, p0), (0, p1)))
    return a


@functools.partial(jax.jit, static_argnames=("fmt", "n_stages", "block",
                                             "interpret"))
def _fwd(x, w, fmt: FxpFormat, n_stages: int,
         block: Tuple[int, int, int], interpret: bool):
    m, k = x.shape
    n = w.shape[1]
    x_raw = _pad_to(fxp.quantize(x, fmt), block[0], block[2])
    w_raw = _pad_to(fxp.quantize(w, fmt), block[2], block[1])
    out_raw = cordic_matmul_raw(x_raw, w_raw, fmt=fmt, n_stages=n_stages,
                                block=block, interpret=interpret)
    return fxp.dequantize(out_raw[:m, :n], fmt)


def cordic_matmul(x: jax.Array, w: jax.Array, *, fmt: FxpFormat = fxp.FXP16,
                  n_stages: int = 5,
                  block: Optional[Tuple[int, int, int]] = None,
                  interpret: Optional[bool] = None) -> jax.Array:
    """``x @ w`` through the RPE's 5-stage linear CORDIC (paper §2.2).

    Differentiable via straight-through estimation: forward is the
    bit-accurate systolic kernel, backward is the exact matmul VJP.
    """
    if interpret is None:
        interpret = not _ON_TPU
    if block is None:
        m, k = x.shape
        n = w.shape[1]
        # int32 raw words => 4 bytes/element in VMEM.
        block = pick_block_shape(m, n, k, bytes_per_el=4, max_block=256)

    @jax.custom_vjp
    def f(x_, w_):
        return _fwd(x_, w_, fmt, n_stages, block, interpret)

    def fwd(x_, w_):
        return f(x_, w_), (x_, w_)

    def bwd(res, g):
        x_, w_ = res
        return (g @ w_.T).astype(x_.dtype), (x_.T @ g).astype(w_.dtype)

    f.defvjp(fwd, bwd)
    return f(x, w)
