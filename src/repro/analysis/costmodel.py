"""Analytic per-device cost model: FLOPs, HBM bytes, collective bytes.

Why analytic: XLA's ``cost_analysis()`` counts while-loop bodies ONCE
(verified in tests/test_roofline.py), and every production config here runs
its layers — and its attention/SSM chunks — under ``lax.scan``.  The
compiled artifact therefore under-counts by ~n_layers x n_chunks.  Since we
own the model code, the analytic count is exact for the matmul-dominated
terms; tests calibrate it against ``cost_analysis`` on unrolled small
configs.

Sharding-aware: a dimension is divided by a mesh-axis size only when the
rule engine would actually shard it (divisibility), mirroring
:mod:`repro.parallel.sharding`.

All quantities are PER DEVICE PER STEP.  Collective bytes are what crosses
this device's links (ring terms: all-reduce 2(n-1)/n, gather/scatter
(n-1)/n of the payload).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Tuple

from repro.configs.base import ArchConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class MeshSpec:
    data: int
    model: int
    pod: int = 1

    @property
    def n_chips(self) -> int:
        return self.data * self.model * self.pod

    @property
    def dp(self) -> int:      # total data-parallel ways (batch divides this)
        return self.data * self.pod


@dataclasses.dataclass
class CostReport:
    flops: float                 # per device
    hbm_bytes: float             # per device
    collective_bytes: float      # per device, on-link
    breakdown: Dict[str, float]
    model_flops: float           # 6*N*D (dense) / 6*N_active*D (MoE), global
    params_bytes_per_chip: float


def _eff(n: int, ways: int) -> float:
    """Divide only if the rule engine would shard (divisibility)."""
    return n / ways if (ways > 1 and n % ways == 0) else float(n)


def _dtype_bytes(cfg: ArchConfig) -> int:
    return 2 if cfg.dtype == "bfloat16" else 4


def param_count(cfg: ArchConfig) -> Tuple[float, float]:
    """(total, active) parameter counts."""
    D, F, L, V = cfg.d_model, cfg.d_ff, cfg.n_layers, cfg.vocab_size
    dh, Hq, Hkv = cfg.head_dim_, cfg.n_heads, cfg.n_kv_heads
    n = 0.0
    if cfg.input_kind == "tokens":
        n += V * D
    else:
        n += D * D
    n += D * (cfg.n_codebooks or 1) * V     # lm head
    per_layer = 0.0
    if cfg.family != "ssm":
        per_layer += D * (Hq + 2 * Hkv) * dh + Hq * dh * D
    if cfg.family in ("dense", "audio", "vlm", "hybrid") or cfg.dense_residual:
        per_layer += 3 * D * F
    moe_per_layer = 0.0
    if cfg.n_experts:
        moe_per_layer = 3 * cfg.n_experts * D * cfg.moe_d_ff + D * cfg.n_experts
        per_layer += moe_per_layer
    if cfg.family == "ssm":
        per_layer += 5 * D * D          # wr wk wv wg wo
        per_layer += 2 * D * F + D * D  # channel mix
        per_layer += 2 * 64 * D         # decay lora
    if cfg.family == "hybrid":
        per_layer += 2 * D * D + D * (2 * cfg.ssm_state + 1) + D * D  # mamba
    total = n + L * per_layer
    active = total
    if cfg.n_experts:
        active_moe = 3 * cfg.top_k * D * cfg.moe_d_ff + D * cfg.n_experts
        active = total - L * moe_per_layer + L * active_moe
    return total, active


def step_costs(cfg: ArchConfig, shape: ShapeConfig, mesh: MeshSpec,
               optimizer_bytes_per_param: float = 8.0) -> CostReport:
    """Per-device roofline quantities for one (train|prefill|decode) step."""
    D, F, L = cfg.d_model, cfg.d_ff, cfg.n_layers
    dh, Hq, Hkv = cfg.head_dim_, cfg.n_heads, cfg.n_kv_heads
    V = cfg.vocab_size * (cfg.n_codebooks or 1)
    tp, dp = mesh.model, mesh.dp
    if cfg.exec_policy.moe_pure_dp:
        # pure-DP profile: the whole mesh is data-parallel, no TP axes
        tp, dp = 1, mesh.n_chips
    dt = _dtype_bytes(cfg)
    kv_dt = 1 if cfg.kv_cache_bits == 8 else dt

    B = shape.global_batch
    S = 1 if shape.is_decode else shape.seq_len
    ctx = shape.seq_len                       # context length attended over
    # per-device tokens (batch shards over dp when divisible)
    T = (_eff(B, dp)) * S
    bk = {}

    train = shape.kind == "train"
    bwd_mult = 3.0 if train else 1.0          # fwd + ~2x bwd

    # ---- linear algebra ----------------------------------------------------
    if cfg.family != "ssm":
        qf = 2 * T * D * _eff(Hq * dh, tp)
        kvf = 2 * 2 * T * D * _eff(Hkv * dh, tp)
        of = 2 * T * _eff(Hq * dh, tp) * D
        bk["qkvo"] = (qf + kvf + of) * L * bwd_mult
        # attention: causal halves the averaged context for full-seq
        # passes.  Compute shards over tp via heads when aligned, else via
        # the query-sequence dim (verified against compiled HLO: scores
        # dots carry S/tp query rows when heads don't divide).
        if shape.is_decode:
            eff_ctx = min(ctx, cfg.sliding_window) if (
                cfg.sliding_window and cfg.supports_long_context
                and ctx > 65536) else ctx
            # decode: the cache seq dim is sharded over tp (dryrun
            # _STATE_AXES), so per-device context shards too
            att = 2 * 2 * T * _eff(eff_ctx, tp) * Hq * dh
        else:
            if Hq % tp == 0:
                att = 2 * 2 * T * (ctx / 2) * (Hq / tp) * dh
            elif S % tp == 0 and tp > 1:
                att = 2 * 2 * (T / tp) * (ctx / 2) * Hq * dh
            else:
                att = 2 * 2 * T * (ctx / 2) * Hq * dh
        bk["attention"] = att * L * bwd_mult
    if cfg.family in ("dense", "audio", "vlm", "hybrid") or cfg.dense_residual:
        bk["ffn"] = 3 * 2 * T * D * _eff(F, tp) * L * bwd_mult
    if cfg.n_experts:
        cf = cfg.capacity_factor
        tok = T * cfg.top_k * cf
        bk["moe_ffn"] = 3 * 2 * tok * D * _eff(cfg.moe_d_ff, tp) * L * bwd_mult
        bk["router"] = 2 * T * D * cfg.n_experts * L * bwd_mult
    if cfg.family == "ssm":
        bk["rwkv_proj"] = 5 * 2 * T * D * _eff(D, tp) * L * bwd_mult
        bk["rwkv_rec"] = 8 * T * D * dh * L * bwd_mult
        bk["rwkv_cm"] = (2 * 2 * T * D * _eff(F, tp) +
                         2 * T * D * _eff(D, tp)) * L * bwd_mult
    if cfg.family == "hybrid":
        N = cfg.ssm_state
        bk["mamba"] = ((2 * T * D * _eff(2 * D, tp)) +
                       (2 * T * cfg.ssm_conv * D) +
                       (2 * T * D * (2 * N + 1)) +
                       (6 * T * D * N) +
                       (2 * T * D * _eff(D, tp))) * L * bwd_mult
    bk["lm_head"] = 2 * T * D * _eff(V, tp) * (bwd_mult if train else
                                               (1.0 if not shape.is_decode
                                                else 1.0))
    flops = sum(bk.values())

    # ---- parameters & optimizer --------------------------------------------
    total_p, active_p = param_count(cfg)
    # params shard over tp (and experts additionally over dp via expert_mlp
    # fallback only when tp can't take them; approximate: /n_chips for MoE
    # expert slabs when both axes divide, else /tp).
    if cfg.n_experts and cfg.n_experts % tp == 0:
        params_dev = total_p / tp
    else:
        params_dev = total_p / tp
    params_bytes = params_dev * dt
    opt_bytes = params_dev * (optimizer_bytes_per_param if train else 0.0)

    # ---- HBM bytes ----------------------------------------------------------
    act_unit = T * D * dt
    weight_reads = params_bytes * (3.0 if train else 1.0)
    act_traffic = act_unit * 12 * L * (2.0 if train else 1.0)
    hbm = weight_reads + act_traffic + opt_bytes * (1.0 if train else 0.0)
    if shape.is_decode and cfg.family != "ssm":
        cache_len = min(ctx, cfg.sliding_window) if (
            cfg.sliding_window and cfg.supports_long_context
            and ctx > 65536) else ctx
        # cache sequence dim shards over tp (launch/dryrun _STATE_AXES)
        kv_dev = (L * _eff(B, dp) * _eff(cache_len, tp) *
                  Hkv * dh * 2 * kv_dt)
        hbm += kv_dev  # full cache streamed once per decoded token
        bk["kv_cache_bytes"] = kv_dev
    if cfg.family in ("ssm", "hybrid") and shape.is_decode:
        hbm += L * _eff(B, dp) * (Hq * dh * dh if cfg.family == "ssm"
                                  else D * cfg.ssm_state) * 4

    # ---- collectives ---------------------------------------------------------
    coll = 0.0
    ar = lambda payload, n: 2 * payload * (n - 1) / n if n > 1 else 0.0
    # TP activation all-reduces: 2/layer fwd (+2 bwd when training)
    n_ar = (4 if train else 2) * L
    if tp > 1 and cfg.family != "ssm":
        coll += n_ar * ar(act_unit, tp)
    if tp > 1 and cfg.family == "ssm":
        coll += n_ar * ar(act_unit, tp)
    # vocab-sharded logits: logsumexp partial reduction (fp32 scalars/token)
    if tp > 1:
        coll += ar(T * 4, tp) * 2
    # MoE (shard_map, see models/moe.py): tokens NEVER cross devices in
    # either mode — each (data, model) device routes its local tokens.
    # What crosses:
    #   * the output psum over model (activation-sized, fwd; bwd is a
    #     broadcast) in both EP and expert-TP modes,
    #   * EP+FSDP: the expert-weight all-gathers (fwd + recompute in bwd)
    #     and the grad reduce-scatter back.
    # (The earlier dispatch-crossing model over-counted granite 5.3x —
    # refuted against HLO-parsed collectives; see EXPERIMENTS.md §Perf.)
    if cfg.n_experts and tp > 1:
        coll += ar(T * D * dt, tp) / 2 * L * (2.0 if train else 1.0)
        if cfg.fuse_moe_ffn_ar and cfg.dense_residual:
            # dense-residual FFN shares the MoE psum: one fwd AR saved/layer
            coll -= ar(T * D * dt, tp) / 2 * L * (1.0 if train else 1.0)
        ep_mode = cfg.n_experts % tp == 0
        fsdp_ways = mesh.dp
        expert_bytes = 3 * (cfg.n_experts / tp) * D * cfg.moe_d_ff * dt
        big = cfg.n_experts * D * cfg.moe_d_ff * cfg.n_layers > 4e9
        if ep_mode and big and cfg.moe_d_ff % fsdp_ways == 0:
            gather = expert_bytes * (fsdp_ways - 1) / fsdp_ways
            if cfg.exec_policy.fsdp_int8_gather:
                gather *= (0.5 if dt == 2 else 0.25)  # FxP8 transport
            # fwd gather + bwd re-gather (remat) + grad reduce-scatter
            coll += gather * (3.0 if train else 1.0) * L
    # DP gradient all-reduce (hierarchical on multi-pod: intra-pod RS/AG at
    # full shard size + inter-pod AR at 1/data the bytes)
    if train and dp > 1:
        grad_bytes = params_dev * dt
        if mesh.pod > 1:
            coll += ar(grad_bytes, mesh.data)
            coll += ar(grad_bytes / mesh.data, mesh.pod)
        else:
            coll += ar(grad_bytes, mesh.data)
        bk["dp_grad_bytes"] = grad_bytes
    mf = 6 * active_p * (B * shape.seq_len) if train else \
        2 * active_p * (B * S)
    return CostReport(flops=flops, hbm_bytes=hbm, collective_bytes=coll,
                      breakdown=bk, model_flops=mf,
                      params_bytes_per_chip=params_bytes + opt_bytes)
