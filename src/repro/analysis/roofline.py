"""Roofline analysis: three terms per (arch x shape x mesh).

    compute    = FLOPs / (peak_FLOP/s)            [per device]
    memory     = HBM bytes / HBM_bw               [per device]
    collective = on-link collective bytes / link_bw

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI.  (The spec's "X / (chips x peak)" form uses global sums; we work with
per-device quantities, which is the same number.)

Two sources feed the report:
  * the ANALYTIC model (:mod:`repro.analysis.costmodel`) — primary, because
    XLA cost_analysis counts scan bodies once (see costmodel docstring),
  * the COMPILED artifact — memory_analysis (fits / doesn't), raw
    cost_analysis, and HLO-parsed collective bytes with a while-body
    trip-count correction (collectives in non-entry computations are
    multiplied by n_layers, since every collective in these models lives in
    the layer scan body).
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.analysis.costmodel import CostReport, MeshSpec, step_costs
from repro.configs.base import ArchConfig, ShapeConfig

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s
LINK_BW = 50e9               # bytes/s per ICI link
HBM_PER_CHIP = 16 * 2 ** 30  # v5e

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
                "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8, "s16": 2,
                "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1}

_COLLECTIVE_OP_RE = re.compile(
    r"=\s*(.*?)\s*(all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def parse_hlo_collectives(hlo_text: str, layer_trips: int = 1
                          ) -> Tuple[float, Dict[str, float]]:
    """Sum collective payload bytes from a post-SPMD HLO module.

    Shapes in the partitioned module are already per-device; result shapes
    (including tuple results) are summed per op.  Ops found in non-entry
    computations (while bodies — the layer scan) are multiplied by
    ``layer_trips``.  ``*-done`` halves of async pairs are not double
    counted (only ``*-start``/sync forms match).
    """
    by_kind: Dict[str, float] = {}
    total = 0.0
    in_entry = False
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if stripped.startswith("ENTRY"):
            in_entry = True
            continue
        if line and not line[0].isspace() and line.rstrip().endswith("{"):
            in_entry = stripped.startswith("ENTRY")
        m = _COLLECTIVE_OP_RE.search(line)
        if not m:
            continue
        shapes_str, kind = m.groups()
        payload = 0
        for dtype, dims in _SHAPE_RE.findall(shapes_str):
            nbytes = _DTYPE_BYTES.get(dtype, 4)
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            payload += n * nbytes
        mult = 1 if in_entry else layer_trips
        by_kind[kind] = by_kind.get(kind, 0.0) + payload * mult
        total += payload * mult
    return total, by_kind


_CONVERT_RE = re.compile(r"=\s*f32\[([\d,]+)\][^=]*\bconvert\(")


def cpu_upcast_correction(hlo_text: str, param_shard_shapes) -> float:
    """Estimate bytes of XLA:CPU's bf16->f32 weight upcasts.

    The CPU backend cannot execute bf16 dots natively, so it converts
    weight operands to f32 — and loop-invariant code motion hoists those
    converts out of the layer scan, holding a whole f32 copy of every
    stacked weight.  TPU executes bf16 on the MXU directly, so these
    buffers do not exist on the target.  We count each distinct f32
    convert whose shape matches a per-device weight shard, bounded by the
    number of leaves with that shape.

    param_shard_shapes: list of per-device weight shard shape tuples.
    """
    from collections import Counter
    shape_counts = Counter(tuple(s) for s in param_shard_shapes)
    seen = Counter()
    for m in _CONVERT_RE.finditer(hlo_text):
        dims = tuple(int(d) for d in m.group(1).split(",") if d)
        if dims in shape_counts:
            seen[dims] += 1
    bytes_total = 0.0
    for dims, cnt in seen.items():
        n = min(cnt, shape_counts[dims])
        numel = 1
        for d in dims:
            numel *= d
        bytes_total += 4.0 * numel * n
    return bytes_total


@dataclasses.dataclass
class RooflineRow:
    arch: str
    shape: str
    mesh: str
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float
    hlo_flops_raw: Optional[float]
    analytic_flops: float
    useful_ratio: float
    bytes_per_device: Optional[float]
    fits_hbm: Optional[bool]
    hlo_collective_bytes: Optional[float]
    cpu_upcast_bytes: float = 0.0
    note: str = ""

    @property
    def step_time_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """Useful-model-compute time / bound step time (the score)."""
        n_chips = 1  # per-device accounting throughout
        ideal = self.model_flops_per_dev / PEAK_FLOPS
        return ideal / self.step_time_s if self.step_time_s else 0.0

    @property
    def model_flops_per_dev(self) -> float:
        return self.model_flops / self._chips

    _chips: int = 1

    def as_dict(self):
        d = dataclasses.asdict(self)
        d.update(step_time_s=self.step_time_s,
                 roofline_fraction=self.roofline_fraction)
        return d


def analyze(cfg: ArchConfig, shape: ShapeConfig, mesh: MeshSpec,
            memory_analysis=None, cost_analysis=None,
            hlo_text: Optional[str] = None, note: str = "",
            param_shard_shapes=None) -> RooflineRow:
    cr = step_costs(cfg, shape, mesh)
    # the paper's FxP8 path runs matmuls on the MXU int8 datapath: 2x bf16
    # peak (394 TOPS on v5e)
    peak = PEAK_FLOPS * (2.0 if cfg.exec_policy.matmul == "fxp8" else 1.0)
    compute_s = cr.flops / peak
    memory_s = cr.hbm_bytes / HBM_BW
    coll_s = cr.collective_bytes / LINK_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": coll_s}
    bottleneck = max(terms, key=terms.get)

    hlo_flops = None
    if cost_analysis:
        hlo_flops = float(cost_analysis.get("flops", 0.0))
    bytes_dev = None
    fits = None
    if memory_analysis is not None:
        try:
            bytes_dev = float(
                memory_analysis.temp_size_in_bytes
                + memory_analysis.argument_size_in_bytes
                + memory_analysis.output_size_in_bytes
                - memory_analysis.alias_size_in_bytes)
        except AttributeError:
            bytes_dev = None
        if bytes_dev is not None:
            fits = bytes_dev <= HBM_PER_CHIP
    hlo_coll = None
    upcast = 0.0
    if hlo_text is not None:
        hlo_coll, _ = parse_hlo_collectives(hlo_text, cfg.n_layers)
        if param_shard_shapes:
            upcast = cpu_upcast_correction(hlo_text, param_shard_shapes)
            if bytes_dev is not None:
                bytes_dev = max(bytes_dev - upcast, 0.0)
                fits = bytes_dev <= HBM_PER_CHIP

    row = RooflineRow(
        arch=cfg.name, shape=shape.name,
        mesh=f"{mesh.pod}x{mesh.data}x{mesh.model}" if mesh.pod > 1
        else f"{mesh.data}x{mesh.model}",
        compute_s=compute_s, memory_s=memory_s, collective_s=coll_s,
        bottleneck=bottleneck, model_flops=cr.model_flops,
        hlo_flops_raw=hlo_flops, analytic_flops=cr.flops,
        useful_ratio=(cr.model_flops / mesh.n_chips) / max(cr.flops, 1.0),
        bytes_per_device=bytes_dev, fits_hbm=fits,
        hlo_collective_bytes=hlo_coll, cpu_upcast_bytes=upcast, note=note)
    row._chips = mesh.n_chips
    return row


def table(rows: List[RooflineRow]) -> str:
    hdr = ("arch,shape,mesh,compute_s,memory_s,collective_s,bottleneck,"
           "roofline_frac,useful_ratio,bytes_per_dev_GB,fits,note")
    lines = [hdr]
    for r in rows:
        gb = "" if r.bytes_per_device is None else \
            f"{r.bytes_per_device / 2**30:.2f}"
        lines.append(
            f"{r.arch},{r.shape},{r.mesh},{r.compute_s:.4e},"
            f"{r.memory_s:.4e},{r.collective_s:.4e},{r.bottleneck},"
            f"{r.roofline_fraction:.3f},{r.useful_ratio:.3f},{gb},"
            f"{r.fits_hbm},{r.note}")
    return "\n".join(lines)
