"""Generate the EXPERIMENTS.md roofline tables from dry-run JSONL records.

Usage: PYTHONPATH=src python -m repro.analysis.report dryrun_baseline.jsonl
Prints the §Roofline markdown table (stored analytic terms as compiled,
plus current-model re-derivation for comparison).
"""
from __future__ import annotations

import json
import sys
from typing import Dict, List

from repro.analysis.costmodel import MeshSpec, step_costs
from repro.analysis.roofline import HBM_PER_CHIP, LINK_BW, PEAK_FLOPS, analyze
from repro.configs import LM_SHAPES, get_arch


def load(path: str) -> List[dict]:
    return [json.loads(l) for l in open(path)]


def mesh_spec_of(tag: str) -> MeshSpec:
    parts = [int(x) for x in tag.split("x")]
    if len(parts) == 3:
        return MeshSpec(pod=parts[0], data=parts[1], model=parts[2])
    return MeshSpec(data=parts[0], model=parts[1])


def markdown_table(rows: List[dict], mesh_filter: str = "16x16") -> str:
    hdr = ("| arch | shape | compute_s | memory_s | collective_s | "
           "bottleneck | roofline | useful | GB/dev | fits |\n"
           "|---|---|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in rows:
        if r["status"] == "skipped":
            if r["mesh"] == mesh_filter or (mesh_filter == "16x16" and
                                            r["mesh"] == "16x16"):
                lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                             f"skipped | — | — | — | — |")
            continue
        if r["mesh"] != mesh_filter:
            continue
        gb = "" if r.get("bytes_per_device") is None else \
            f"{r['bytes_per_device'] / 2 ** 30:.1f}"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
            f"{r['bottleneck']} | {r['roofline_fraction']:.3f} | "
            f"{r['useful_ratio']:.2f} | {gb} | {r['fits_hbm']} |")
    return "\n".join(lines)


def main(argv=None):
    path = (argv or sys.argv[1:])[0] if (argv or sys.argv[1:]) else \
        "dryrun_baseline.jsonl"
    rows = load(path)
    ok = [r for r in rows if r["status"] in ("ok", "skipped")]
    print("## Single-pod (16x16)\n")
    print(markdown_table(ok, "16x16"))
    print("\n## Multi-pod (2x16x16)\n")
    print(markdown_table(ok, "2x16x16"))


if __name__ == "__main__":
    main()
