"""Version shims for the jax APIs this repo depends on.

The repo targets the Pallas/TPU toolchain, whose public surface has moved
between jax releases.  Everything that is version-sensitive resolves here,
once, so kernels and models import stable names:

  * ``shard_map`` — promoted out of ``jax.experimental`` in newer jax;
    we try ``jax.shard_map`` first, then fall back to
    ``jax.experimental.shard_map.shard_map``.  The replication-check
    kwarg also renamed (``check_rep`` -> ``check_vma``); callers write
    the new name and the shim translates for old jax.
  * ``TPUCompilerParams`` — renamed to ``pltpu.CompilerParams`` in newer
    jax; older releases only have ``pltpu.TPUCompilerParams``.
  * ``cost_analysis`` — ``Compiled.cost_analysis()`` returns a one-element
    list of dicts on older jax, a plain dict on newer; normalise to dict.

Keep this module dependency-free (jax only) so it can be imported from
anywhere in the tree without cycles.
"""
from __future__ import annotations


def _resolve_shard_map():
    """Prefer the stable ``jax.shard_map``, fall back to experimental."""
    import jax

    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm
    from jax.experimental.shard_map import shard_map as sm
    return sm


def _adapt_shard_map(sm):
    """Translate the ``check_vma`` kwarg for jax that only knows
    ``check_rep`` (or neither)."""
    import functools
    import inspect

    try:
        params = inspect.signature(sm).parameters
    except (TypeError, ValueError):
        return sm
    if "check_vma" in params:
        return sm

    @functools.wraps(sm)
    def wrapper(*args, **kwargs):
        if "check_vma" in kwargs:
            val = kwargs.pop("check_vma")
            if "check_rep" in params:
                kwargs["check_rep"] = val
        return sm(*args, **kwargs)

    return wrapper


def _resolve_tpu_compiler_params():
    """``pltpu.CompilerParams`` (new name) or ``pltpu.TPUCompilerParams``."""
    from jax.experimental.pallas import tpu as pltpu

    cp = getattr(pltpu, "CompilerParams", None)
    if cp is not None:
        return cp
    return pltpu.TPUCompilerParams


def cost_analysis(compiled) -> dict:
    """``compiled.cost_analysis()`` as a dict across jax versions."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca


shard_map = _adapt_shard_map(_resolve_shard_map())
TPUCompilerParams = _resolve_tpu_compiler_params()

__all__ = ["shard_map", "TPUCompilerParams", "cost_analysis"]
