"""Deterministic synthetic data pipeline.

Production-shaped: sharded per host, stateless (step -> batch is a pure
function of (seed, step), so restarts and elastic re-scales replay exactly
the same stream), with background prefetch.  The token generator produces a
mixture of Zipfian unigrams and copy/induction spans so language-model
training exhibits learnable structure (loss decreases measurably within a
few hundred steps).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    kind: str = "tokens"          # tokens | frames
    d_model: int = 0              # for frame stubs
    n_codebooks: int = 0
    zipf_alpha: float = 1.2
    copy_fraction: float = 0.3    # fraction of positions in copy spans


def _zipf_probs(vocab: int, alpha: float) -> np.ndarray:
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    p = ranks ** (-alpha)
    return p / p.sum()


class SyntheticStream:
    """step -> batch, deterministic; shard-aware for multi-host."""

    def __init__(self, cfg: DataConfig, shard: int = 0, n_shards: int = 1):
        assert cfg.global_batch % n_shards == 0
        self.cfg = cfg
        self.shard = shard
        self.n_shards = n_shards
        self.local_batch = cfg.global_batch // n_shards
        self._probs = _zipf_probs(cfg.vocab_size, cfg.zipf_alpha)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, self.shard]))
        b, s = self.local_batch, cfg.seq_len
        toks = rng.choice(cfg.vocab_size, size=(b, s + 1), p=self._probs)
        # copy spans: induction structure the model can learn
        n_copy = int(cfg.copy_fraction * s) // 2
        if n_copy > 4:
            for i in range(b):
                start = rng.integers(0, s - 2 * n_copy)
                src = toks[i, start:start + n_copy]
                toks[i, start + n_copy:start + 2 * n_copy] = src
        toks = toks.astype(np.int32)
        if cfg.kind == "frames":
            frames = rng.standard_normal((b, s, cfg.d_model)).astype(np.float32)
            batch = {"frames": frames}
        else:
            batch = {"tokens": toks[:, :s]}
        labels = toks[:, 1:s + 1]
        if cfg.n_codebooks:
            labels = np.stack([(labels + k) % cfg.vocab_size
                               for k in range(cfg.n_codebooks)], axis=-1)
        batch["labels"] = labels
        return batch

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Background thread keeping ``depth`` batches ready (overlaps host data
    generation with device compute)."""

    def __init__(self, stream: SyntheticStream, depth: int = 2,
                 start_step: int = 0):
        self._stream = stream
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            batch = self._stream.batch_at(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def next(self):
        step, batch = self._q.get()
        return step, batch

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2.0)


def stream_for_model(model, shape, seed: int = 0, shard: int = 0,
                     n_shards: int = 1) -> SyntheticStream:
    cfg = model.cfg
    return SyntheticStream(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=shape.seq_len,
        global_batch=shape.global_batch, seed=seed,
        kind=cfg.input_kind if cfg.input_kind == "frames" else "tokens",
        d_model=cfg.d_model, n_codebooks=cfg.n_codebooks),
        shard=shard, n_shards=n_shards)
