"""Production mesh construction.

A function (not a module-level constant) so importing never touches jax
device state.  Single pod: 16x16 = 256 chips (data, model).  Multi-pod:
2x16x16 = 512 chips (pod, data, model) — the pod axis is a second
data-parallel dimension with thin inter-pod links, which the gradient
reduction treats hierarchically (see parallel/collectives.py).
"""
from __future__ import annotations

import jax

from repro.analysis.costmodel import MeshSpec


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (elastic re-scale / tests)."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def mesh_spec(mesh) -> MeshSpec:
    s = dict(zip(mesh.axis_names, mesh.devices.shape))
    return MeshSpec(data=s.get("data", 1), model=s.get("model", 1),
                    pod=s.get("pod", 1))
