"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch glm4-9b --steps 100 \
        --reduced --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

``--reduced`` runs the smoke-scale config of the same family (CPU-sized);
without it the full assigned config is built (requires real accelerators).
``--cordic`` switches every matmul/AF onto the paper's FxP8 + DA-VINCI
execution policy.  ``--fault-at N`` injects a crash to exercise
checkpoint/restart (the supervisor restores and resumes).
"""
from __future__ import annotations

import argparse

from repro.configs import CORDIC_EXEC, get_arch
from repro.configs.base import LM_SHAPES
from repro.data.pipeline import stream_for_model
from repro.models.model_zoo import build_model
from repro.optim.adamw import AdamWConfig
from repro.runtime.train_loop import TrainConfig, Trainer


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k", choices=list(LM_SHAPES))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--cordic", action="store_true",
                    help="paper-faithful FxP8 + DA-VINCI execution")
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--int8-moments", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--fault-at", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    shape = LM_SHAPES[args.shape]
    if args.batch or args.seq:
        import dataclasses
        shape = dataclasses.replace(
            shape, global_batch=args.batch or shape.global_batch,
            seq_len=args.seq or shape.seq_len)
    stream = stream_for_model(model, shape, seed=args.seed)
    tcfg = TrainConfig(
        optimizer=AdamWConfig(
            lr=args.lr, total_steps=args.steps,
            warmup_steps=max(args.steps // 20, 1),
            moment_dtype="int8" if args.int8_moments else "float32"),
        grad_accum=args.grad_accum,
        grad_compression=args.grad_compression,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every)
    pol = CORDIC_EXEC if args.cordic else None
    trainer = Trainer(model, tcfg, stream, pol=pol)
    print(f"# {cfg.name}: {model.n_params():,} params "
          f"({model.n_active_params():,} active), exec="
          f"{(pol or cfg.exec_policy).tag()}")
    try:
        out = trainer.run(args.steps, seed=args.seed, fault_at=args.fault_at)
    except RuntimeError as e:
        if "injected fault" in str(e) and args.ckpt_dir:
            print(f"# fault: {e}; restarting from checkpoint")
            trainer = Trainer(model, tcfg, stream, pol=pol)
            out = trainer.run(args.steps, seed=args.seed)
        else:
            raise
    for step, loss in out["losses"]:
        print(f"step {step:5d}  loss {loss:.4f}")
    print(f"# wall {out['wall_s']:.1f}s  final loss {out['final_loss']:.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
