import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Multi-pod dry-run: lower + compile every (architecture x input-shape) cell
# on the production meshes with ShapeDtypeStruct inputs (zero allocation),
# then extract memory_analysis / cost_analysis / HLO collectives for the
# roofline report.
#
# Usage:
#   python -m repro.launch.dryrun --arch glm4-9b --shape train_4k
#   python -m repro.launch.dryrun --all --multi-pod both --out dryrun.jsonl

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as PS  # noqa: E402

from repro import compat  # noqa: E402
from repro.analysis import roofline  # noqa: E402
from repro.analysis.costmodel import MeshSpec  # noqa: E402
from repro.configs import ARCHS, LM_SHAPES, get_arch, shape_applicable  # noqa: E402
from repro.launch.mesh import make_production_mesh, mesh_spec  # noqa: E402
from repro.models import spec as pspec  # noqa: E402
from repro.models import transformer as T  # noqa: E402
from repro.models.model_zoo import build_model  # noqa: E402
from repro.optim import adamw  # noqa: E402
from repro.parallel import sharding as shd  # noqa: E402


def batch_shardings(mesh, specs, batch_axes=("pod", "data")):
    axes = tuple(a for a in batch_axes if a in mesh.shape)
    bspec = axes if len(axes) > 1 else (axes[0] if axes else None)

    def one(sds):
        b = sds.shape[0]
        n = 1
        for a in (axes if isinstance(bspec, tuple) else
                  ((bspec,) if bspec else ())):
            n *= mesh.shape[a]
        first = bspec if (n > 1 and b % n == 0) else None
        return NamedSharding(mesh, PS(first, *([None] * (len(sds.shape) - 1))))
    return jax.tree_util.tree_map(one, specs)


_STATE_AXES = {
    # cache sequence dim shards over model ("seq" rule): none of the
    # assigned archs can shard kv heads over tp=16, and a replicated 32k
    # cache is the decode memory bottleneck (see EXPERIMENTS.md #Perf).
    "cache_k": ("layers", "batch", "seq", "kv_heads", None),
    "cache_v": ("layers", "batch", "seq", "kv_heads", None),
    "pos": (),
    "x_prev": ("layers", "batch", None),
    "cm_prev": ("layers", "batch", None),
    "wkv": ("layers", "batch", "heads", None, None),
    "conv_tail": ("layers", "batch", None, None),
    "ssm_h": ("layers", "batch", None, "state"),
}


def decode_state_shardings(state, mesh):
    out = {}
    for name, val in state._asdict().items():
        if val is None:
            out[name] = None
            continue
        axes = _STATE_AXES[name][:len(val.shape)]
        out[name] = NamedSharding(mesh, shd.spec_for(val.shape, axes, mesh))
    return type(state)(**out)


def opt_shardings(spec_tree, mesh, moment_dtype: str, rules=None):
    p_sh = shd.tree_shardings(spec_tree, mesh, rules)

    def moment(psh, p):
        if moment_dtype != "int8":
            return psh
        scale_axes = tuple(p.axes[:-1]) + (None,) if p.axes else ()
        scale_shape = tuple(p.shape[:-1]) + (1,) if p.shape else ()
        if not p.shape:
            return adamw.QMoment(psh, NamedSharding(mesh, PS()))
        return adamw.QMoment(
            NamedSharding(mesh, shd.spec_for(p.shape, p.axes, mesh, rules)),
            NamedSharding(mesh, shd.spec_for(scale_shape, scale_axes, mesh,
                                             rules)))

    m = jax.tree_util.tree_map(moment, p_sh, pspec.tree_map_specs(
        lambda p: p, spec_tree), is_leaf=lambda x: isinstance(x, NamedSharding))
    return adamw.AdamWState(NamedSharding(mesh, PS()), m, m)


def abstract_opt_state(spec_tree, moment_dtype: str):
    def mom(p):
        if moment_dtype == "int8":
            scale_shape = tuple(p.shape[:-1]) + (1,) if p.shape else ()
            return adamw.QMoment(
                jax.ShapeDtypeStruct(p.shape, jnp.int8),
                jax.ShapeDtypeStruct(scale_shape, jnp.float32))
        return jax.ShapeDtypeStruct(p.shape, jnp.float32)
    m = pspec.tree_map_specs(mom, spec_tree)
    return adamw.AdamWState(jax.ShapeDtypeStruct((), jnp.int32), m, m)


# ---------------------------------------------------------------------------
# Optimized variants (the #Perf hillclimbs; see EXPERIMENTS.md)
# ---------------------------------------------------------------------------

def _variants():
    from repro.configs.base import BF16_EXEC
    from repro.parallel.sharding import PURE_DP_RULES, ZERO1_OPT_RULES
    return {
        # glm4 decode: FxP8 KV cache (+ the already-default seq-sharded
        # cache) — the paper's quantization applied to the decode memory
        # bottleneck.
        "kv8": dict(arch_overrides=dict(kv_cache_bits=8)),
        # arctic train: fuse dense-residual FFN into the MoE psum + FxP8
        # FSDP weight-gather transport.
        "moefuse": dict(arch_overrides=dict(
            fuse_moe_ffn_ar=True,
            exec_policy=dataclasses.replace(BF16_EXEC,
                                            fsdp_int8_gather=True))),
        # granite train: pure-DP profile (batch over all 256/512 chips,
        # weights replicated, ZeRO-1 int8 moments over the mesh).
        # paper-faithful FxP8 execution: every projection on the MXU int8
        # path (the production mapping of the 5-stage CORDIC MAC).
        "fxp8": dict(arch_overrides=dict(
            exec_policy=dataclasses.replace(BF16_EXEC, matmul="fxp8"))),
        "puredp": dict(arch_overrides=dict(
            exec_policy=dataclasses.replace(BF16_EXEC, moe_pure_dp=True)),
            param_rules=PURE_DP_RULES, opt_rules=ZERO1_OPT_RULES,
            batch_axes=("pod", "data", "model")),
    }


def build_step(arch_name: str, shape_name: str, mesh,
               moment_dtype: str = None, arch_overrides: dict = None,
               param_rules=None, opt_rules=None, batch_axes=None):
    """Returns (jitted fn, abstract args tuple) for one cell."""
    cfg = get_arch(arch_name)
    if arch_overrides:
        cfg = cfg.scaled(**arch_overrides)
    shape = LM_SHAPES[shape_name]
    model = build_model(cfg)
    spec_tree = model.params_spec()
    if moment_dtype is None:
        # quantization co-design default: int8 Adam moments everywhere
        # (arctic's 469B expert slab requires it; the others gain headroom)
        moment_dtype = "int8"
    ocfg = adamw.AdamWConfig(moment_dtype=moment_dtype)

    params_abs = model.abstract_params()
    p_sh = shd.tree_shardings(spec_tree, mesh, param_rules)
    batch_axes = batch_axes or ("pod", "data")
    dp = 1
    for a in batch_axes:
        if a in mesh.shape:
            dp *= mesh.shape[a]

    if shape.kind == "train":
        batch_abs = model.input_specs(shape.global_batch, shape.seq_len,
                                      "train")
        opt_abs = abstract_opt_state(spec_tree, moment_dtype)
        # Production memory recipe (CAESAR quantization co-design, see
        # DESIGN.md §Memory): microbatch so each device sees <= 8192 tokens
        # per backward pass; accumulate grads in bf16; int8 Adam moments.
        tokens_dev = (shape.global_batch // dp
                      if shape.global_batch % dp == 0
                      else shape.global_batch) * shape.seq_len
        accum = max(1, tokens_dev // 8192)
        while shape.global_batch % accum or \
                (shape.global_batch // accum) % min(dp, shape.global_batch):
            accum //= 2
        accum = max(accum, 1)

        def train_step(params, opt_state, batch):
            mb = shape.global_batch // accum

            def micro(i, carry):
                gsum, lsum = carry
                mbatch = jax.tree_util.tree_map(
                    lambda x: jax.lax.dynamic_slice_in_dim(
                        x, i * mb, mb, axis=0), batch)
                (l, _), g = jax.value_and_grad(
                    lambda p: model.loss(p, mbatch), has_aux=True)(params)
                gsum = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(a.dtype), gsum, g)
                return gsum, lsum + l

            if accum > 1:
                zeros = jax.tree_util.tree_map(
                    lambda p: jnp.zeros(p.shape, p.dtype), params)
                grads, lsum = jax.lax.fori_loop(
                    0, accum, micro, (zeros, jnp.float32(0.0)))
                grads = jax.tree_util.tree_map(
                    lambda g: g / accum, grads)
                loss = lsum / accum
            else:
                (loss, _), grads = jax.value_and_grad(
                    lambda p: model.loss(p, batch), has_aux=True)(params)
            new_p, new_o, om = adamw.update(ocfg, grads, opt_state, params)
            return new_p, new_o, {"loss": loss, **om}

        fn = jax.jit(
            train_step,
            in_shardings=(p_sh,
                          opt_shardings(spec_tree, mesh, moment_dtype,
                                        opt_rules or param_rules),
                          batch_shardings(mesh, batch_abs, batch_axes)),
            donate_argnums=(0, 1))
        return fn, (params_abs, opt_abs, batch_abs)

    if shape.kind == "prefill":
        batch_abs = model.input_specs(shape.global_batch, shape.seq_len,
                                      "prefill")

        def prefill_step(params, batch):
            return model.prefill(params, batch)

        fn = jax.jit(prefill_step,
                     in_shardings=(p_sh, batch_shardings(mesh, batch_abs,
                                                         batch_axes)))
        return fn, (params_abs, batch_abs)

    # decode
    batch_abs = model.input_specs(shape.global_batch, shape.seq_len, "decode")
    state_abs = model.init_decode_state(shape.global_batch, shape.seq_len,
                                        abstract=True)
    st_sh = decode_state_shardings(state_abs, mesh)

    def serve_step(params, state, batch):
        return model.decode_step(params, state, batch)

    fn = jax.jit(serve_step,
                 in_shardings=(p_sh, st_sh,
                               batch_shardings(mesh, batch_abs, batch_axes)),
                 donate_argnums=(1,))
    return fn, (params_abs, state_abs, batch_abs)


def run_cell(arch_name: str, shape_name: str, multi_pod: bool,
             with_hlo: bool = True, variant: str = None) -> dict:
    cfg = get_arch(arch_name)
    vkw = dict(_variants()[variant]) if variant else {}
    arch_overrides = vkw.pop("arch_overrides", None)
    if arch_overrides:
        cfg = cfg.scaled(**arch_overrides)
    shape = LM_SHAPES[shape_name]
    ok, reason = shape_applicable(cfg, shape)
    mesh_tag = "2x16x16" if multi_pod else "16x16"
    if not ok:
        return {"arch": arch_name, "shape": shape_name, "mesh": mesh_tag,
                "status": "skipped", "reason": reason}
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules_ctx = (shd.use_rules(vkw["param_rules"]) if
                 vkw.get("param_rules") else None)
    try:
        with mesh:
            import contextlib
            with (rules_ctx or contextlib.nullcontext()):
                fn, args = build_step(arch_name, shape_name, mesh,
                                      arch_overrides=arch_overrides, **vkw)
                lowered = fn.lower(*args)
                compiled = lowered.compile()
            mem = compiled.memory_analysis()
            cost = compat.cost_analysis(compiled)
            hlo_text = compiled.as_text() if with_hlo else None
    except Exception as e:
        return {"arch": arch_name, "shape": shape_name, "mesh": mesh_tag,
                "status": "error", "error": f"{type(e).__name__}: {e}",
                "trace": traceback.format_exc()[-2000:]}
    # per-device weight shard shapes (for the CPU f32-upcast correction)
    model = build_model(cfg)  # cfg includes variant overrides
    spec_tree = model.params_spec()
    shard_shapes = []
    for p in jax.tree_util.tree_leaves(
            pspec.tree_map_specs(lambda q: q, spec_tree),
            is_leaf=pspec.is_spec):
        if not isinstance(p, pspec.P) or len(p.shape) < 2:
            continue
        ps = shd.spec_for(p.shape, p.axes, mesh)
        shp = list(p.shape)
        for i, entry in enumerate(ps):
            if entry is None:
                continue
            axes_ = entry if isinstance(entry, tuple) else (entry,)
            n = 1
            for a in axes_:
                n *= mesh.shape[a]
            shp[i] //= n
        shard_shapes.append(tuple(shp))
    row = roofline.analyze(cfg, shape, mesh_spec(mesh), mem, cost, hlo_text,
                           param_shard_shapes=shard_shapes)
    rec = row.as_dict()
    rec.update({"status": "ok", "compile_s": round(time.time() - t0, 1),
                "variant": variant or "baseline"})
    rec.pop("note", None)
    # memory_analysis detail
    try:
        rec["mem_args_GB"] = mem.argument_size_in_bytes / 2 ** 30
        rec["mem_temp_GB"] = mem.temp_size_in_bytes / 2 ** 30
        rec["mem_out_GB"] = mem.output_size_in_bytes / 2 ** 30
    except AttributeError:
        pass
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", choices=["off", "on", "both"],
                    default="off")
    ap.add_argument("--no-hlo", action="store_true",
                    help="skip HLO text extraction (faster)")
    ap.add_argument("--out", default=None, help="append JSONL here")
    ap.add_argument("--variant", default=None,
                    help="optimized variant: kv8 | moefuse | puredp")
    args = ap.parse_args(argv)

    cells = []
    archs = sorted(ARCHS) if (args.all or not args.arch) else [args.arch]
    shapes = (list(LM_SHAPES) if (args.all or not args.shape)
              else [args.shape])
    pods = {"off": [False], "on": [True], "both": [False, True]}[
        args.multi_pod]
    for mp in pods:
        for a in archs:
            for s in shapes:
                cells.append((a, s, mp))

    out_f = open(args.out, "a") if args.out else None
    n_ok = n_err = n_skip = 0
    for a, s, mp in cells:
        rec = run_cell(a, s, mp, with_hlo=not args.no_hlo,
                       variant=args.variant)
        status = rec["status"]
        n_ok += status == "ok"
        n_err += status == "error"
        n_skip += status == "skipped"
        line = json.dumps(rec, default=float)
        print(line, flush=True)
        if out_f:
            out_f.write(line + "\n")
            out_f.flush()
    print(f"# done: {n_ok} ok, {n_skip} skipped, {n_err} errors",
          file=sys.stderr)
    if out_f:
        out_f.close()
    return 1 if n_err else 0


if __name__ == "__main__":
    sys.exit(main())
