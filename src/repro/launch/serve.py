"""Serving launcher: batched requests through the continuous-batching engine.

    PYTHONPATH=src python -m repro.launch.serve --arch glm4-9b --reduced \
        --requests 8 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.models.model_zoo import build_model
from repro.runtime.serve_loop import (GangServeEngine, Request, ServeConfig,
                                      ServeEngine)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--gang", action="store_true",
                    help="use the old lockstep scheduler")
    ServeConfig.add_args(ap)           # the shared engine flag set
    args = ap.parse_args(argv)
    ServeConfig.check_args(ap, args, gang=args.gang)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))

    def make_engine(incarnation=0):
        # only the first incarnation carries the injected fault: the
        # respawn must run the trace to completion
        config = ServeConfig.from_args(args, incarnation=incarnation)
        if args.mesh_shards:
            from repro.runtime.mesh_serve import MeshServeEngine
            return MeshServeEngine(model, params, config)
        return ServeEngine(model, params, config)

    if args.gang:
        engine = GangServeEngine(model, params, max_batch=args.max_batch,
                                 max_seq=args.max_seq)
    else:
        engine = make_engine()
    rng = np.random.default_rng(args.seed)
    reqs = []
    for i in range(args.requests):
        n = int(rng.integers(4, 24))
        if cfg.input_kind == "tokens":
            prompt = rng.integers(0, cfg.vocab_size, n).astype(np.int32)
        else:
            prompt = rng.standard_normal((n, cfg.d_model)).astype(np.float32)
        reqs.append(Request(i, prompt, max_new_tokens=args.max_new))
    t0 = time.time()
    if args.kill_at_step is not None:
        from repro.runtime.supervisor import ServeSupervisor
        sup = ServeSupervisor(make_engine)
        done = sup.run(reqs)
        engine = sup.engine
        for h in sup.history:
            print(f"# chaos: restart {h.restart} restored step "
                  f"{h.restored_step}; resumed {h.resumed_rids}, "
                  f"replayed {h.replayed_rids}, recovered "
                  f"{h.recovered_rids}")
    else:
        done = engine.serve(reqs)
    dt = time.time() - t0
    for r in done:
        print(f"req {r.rid}: prompt {len(r.prompt)} toks -> "
              f"{list(r.output[:8])}{'...' if len(r.output) > 8 else ''} "
              f"({(r.done_at - r.submitted_at) * 1e3:.0f} ms)")
    tput = sum(len(r.output) for r in done) / dt
    print(f"# {engine.metrics['prefill_tokens']} prefill toks, "
          f"{engine.metrics['decode_tokens']} decode toks, "
          f"{tput:.1f} tok/s")
    if not args.gang:
        print(f"# queue wait {engine.metrics['queue_wait_s'] * 1e3:.0f}ms, "
              f"slot occupancy {engine.metrics['slot_occupancy']:.0%}")
    if args.paged:
        print(f"# paged: prefix hits "
              f"{engine.metrics['prefix_hit_tokens']:.0f} tok, peak "
              f"blocks {engine.metrics['peak_blocks']:.0f}")
    if args.mesh_shards:
        print(f"# mesh: {engine.n_shards} shards, loads "
              f"{engine.shard_loads()}, "
              f"{engine.metrics['async_prefills']:.0f} async prefills, "
              f"{engine.metrics['overlap_steps']:.0f} overlapped steps")
    if args.spec:
        print(f"# spec ({args.drafter or 'ngram'}): acceptance "
              f"{engine.metrics['spec_acceptance']:.0%}, "
              f"{engine.metrics['tokens_per_step']:.2f} tokens/step over "
              f"{engine.metrics['decode_steps']:.0f} steps, "
              f"k hist {dict(sorted(engine.metrics.spec_k_hist.items()))}")
        if args.drafter == "draft_model":
            print(f"# drafter tiers: {engine.metrics['model_drafts']:.0f} "
                  f"model, {engine.metrics['fallback_drafts']:.0f} "
                  f"fallback dispatches")
    if args.snapshot_dir:
        print(f"# snapshots: {engine.metrics['snapshots']:.0f} taken "
              f"({engine.metrics['snapshot_s'] * 1e3:.0f} ms total), "
              f"restore {engine.metrics['restore_s'] * 1e3:.0f} ms")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
