"""Paged slot memory: a shared fixed-size block pool + per-slot tables.

The dense serving layout gives every decode slot its own
``max_seq``-long K/V cache, so memory is ``max_batch * max_seq``
regardless of how many tokens are actually live.  The paged layout keeps
**one pool per cache family**

    pool:        (L, num_blocks, page_size, Hkv, dh)
    block_table: (B, max_seq // page_size) int32   # logical page -> block

and every slot addresses its cache through its block-table row: logical
position ``t`` lives at ``(table[b, t // page], t % page)``.  Blocks are
allocated lazily as a slot's write frontier crosses page boundaries and
returned to a free list on retire (``runtime/block_pool.py`` owns the
host-side accounting), so resident cache memory scales with live tokens
— and **full pages are shareable**: a radix prefix cache can point many
slots' tables at one physical block, because sharing is only ever of
full pages strictly behind every reader's write frontier (writes land in
private frontier pages, so shared blocks are immutable by construction;
no copy-on-write pass is ever needed).

Unallocated table entries hold the sentinel ``num_blocks``; reads clamp
(jax gather semantics) into harmless in-pool garbage that the decode age
mask excludes, and writes through the sentinel drop (``mode="drop"``) —
the same discipline the dense path uses for admission padding.

The quantized cache mode composes: int8 pools carry per-page scale pools
``(L, num_blocks, page_size, Hkv, 1)`` with identical tables, so the
quantization granularity (one scale per written vector) aligns with the
paging granularity by construction and shared pages carry their scales
with them.

Recurrent state (rwkv/mamba) is O(1) per slot and stays dense per-slot
exactly as in :class:`~repro.models.transformer.DecodeState`; the field
names match so :func:`~repro.models.transformer.spec_commit` and the
engine's scatter seams work on either state type unchanged.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.quant_cache import quantize_blocked

Array = jax.Array


class PagedDecodeState(NamedTuple):
    """Slot decode state with pooled K/V (see module docstring).

    Field names deliberately mirror :class:`DecodeState` — ``pos`` and
    the recurrent fields are identical, only the K/V (+scale) layout and
    the extra ``block_tables`` differ.
    """
    cache_k: Optional[Array] = None     # (L, N, page, Hkv, dh) pool
    cache_v: Optional[Array] = None
    block_tables: Optional[Array] = None  # (B, P) int32; N = unallocated
    pos: Optional[Array] = None         # (B,) per-slot tokens seen
    # ssm / hybrid (dense per-slot, as in DecodeState)
    x_prev: Optional[Array] = None
    cm_prev: Optional[Array] = None
    wkv: Optional[Array] = None
    conv_tail: Optional[Array] = None
    ssm_h: Optional[Array] = None
    # per-page int8 scale pools (CacheSpec.dtype == "int8" only)
    scale_k: Optional[Array] = None     # (L, N, page, Hkv, 1)
    scale_v: Optional[Array] = None
    wkv_scale: Optional[Array] = None
    ssm_scale: Optional[Array] = None


def init_paged_slot_state(cfg: ArchConfig, max_batch: int, max_seq: int,
                          num_blocks: int, page_size: int,
                          abstract: bool = False,
                          shardings=None) -> PagedDecodeState:
    """Pool-backed slot state for ``max_batch`` persistent decode slots.

    ``num_blocks`` bounds resident cache memory (``num_blocks *
    page_size`` tokens across *all* slots, vs the dense layout's
    ``max_batch * max_seq``); ``max_seq`` remains each slot's logical
    capacity (the block-table width).  All tables start fully
    unallocated (sentinel ``num_blocks``).  ``shardings`` (a
    ``PagedDecodeState`` of ``Optional[NamedSharding]``) places each leaf
    on a serving mesh at construction — the pool leaves shard over the
    blocks axis, the per-slot leaves over the slot batch.
    """
    from repro.models import transformer as T   # late: avoid import cycle

    if max_seq % page_size != 0:
        raise ValueError(f"max_seq {max_seq} must be a multiple of "
                         f"page_size {page_size}")
    if num_blocks < 1:
        raise ValueError(f"num_blocks must be >= 1, got {num_blocks}")
    spec = cfg.cache_spec()
    if spec.dtype == "fxp8":
        raise ValueError("paged caches do not support the legacy "
                         "fixed-scale fxp8 format")
    if cfg.family != "ssm" and cfg.sliding_window and \
            cfg.supports_long_context and max_seq > 65536:
        raise ValueError(
            "paged slot memory addresses caches linearly; the long_500k "
            "ring-cache configuration is not supported (ROADMAP: ring "
            "verify/paging is an open item)")

    # Recurrent fields + per-row pos come straight from the dense slot
    # init; only the K/V (+scale) leaves are re-laid-out as pools.
    dense = T.init_slot_state(cfg, max_batch, max_seq, abstract)
    fields: Dict[str, Any] = {
        name: getattr(dense, name)
        for name in ("pos", "x_prev", "cm_prev", "wkv", "conv_tail",
                     "ssm_h", "wkv_scale", "ssm_scale")
    }
    mk = (jax.ShapeDtypeStruct if abstract
          else (lambda sh, d: jnp.zeros(sh, d)))
    if cfg.family != "ssm":
        Lr, dh = cfg.n_layers, cfg.head_dim_
        kv_dt = dense.cache_k.dtype
        fields["cache_k"] = mk((Lr, num_blocks, page_size, cfg.n_kv_heads,
                                dh), kv_dt)
        fields["cache_v"] = mk((Lr, num_blocks, page_size, cfg.n_kv_heads,
                                dh), kv_dt)
        if spec.quantized:
            fields["scale_k"] = mk((Lr, num_blocks, page_size,
                                    cfg.n_kv_heads, 1), jnp.float32)
            fields["scale_v"] = mk((Lr, num_blocks, page_size,
                                    cfg.n_kv_heads, 1), jnp.float32)
    P = max_seq // page_size
    fields["block_tables"] = (
        jax.ShapeDtypeStruct((max_batch, P), jnp.int32) if abstract
        else jnp.full((max_batch, P), num_blocks, jnp.int32))
    st = PagedDecodeState(**fields)
    if shardings is not None and not abstract:
        from repro.models.model_zoo import place_slot_state   # late: cycle
        st = place_slot_state(st, shardings)
    return st


# Recurrent fields an admission scatter may load from a prefix-cache
# snapshot (exact f32 host copies; quantized state re-quantizes on load).
_REC_SNAPSHOT = ("x_prev", "cm_prev", "wkv", "conv_tail", "ssm_h")
_SCALE_FOR = {"wkv": "wkv_scale", "ssm_h": "ssm_scale"}


def slot_extract(state: PagedDecodeState, slots: Array) -> PagedDecodeState:
    """Gather the per-slot (non-pooled) leaves at slot indices.

    The paged snapshot seam: ``pos`` and the dense-per-slot recurrent
    leaves (raw dtype — int8 state and its scale leaves verbatim) come
    back shaped ``(L, G, ...)``; the K/V pools and block tables are left
    ``None`` because they are not per-slot arrays — the engine snapshots
    the block-table rows (host-authoritative) plus only the pool blocks
    those rows reference.
    """
    slots = jnp.asarray(slots, jnp.int32)
    out: Dict[str, Any] = {name: None for name in PagedDecodeState._fields}
    out["pos"] = state.pos[slots]
    for name in _REC_SNAPSHOT + ("wkv_scale", "ssm_scale"):
        leaf = getattr(state, name)
        if leaf is not None:
            out[name] = leaf[:, slots]
    return PagedDecodeState(**out)


def slot_restore(state, slots: Array, pos_values: Array,
                 rec: Dict[str, Array]):
    """Raw-dtype restore of per-slot ``pos`` + recurrent leaves.

    Unlike :func:`slot_reset` — whose ``rec`` is an exact-f32 prefix
    snapshot that int8 states *re-quantize* on load — ``rec`` here holds
    leaves already in their storage dtype (int8 state plus its scale
    leaves as separate entries), written back verbatim: a restored
    request must resume **bit-identically**, so the round trip through a
    snapshot can never be dequant/requant.  Works on either state layout
    (dense ``DecodeState`` or :class:`PagedDecodeState`); out-of-range
    slot indices drop, as everywhere on the scatter seam.
    """
    slots = jnp.asarray(slots, jnp.int32)
    out: Dict[str, Any] = {
        "pos": state.pos.at[slots].set(
            jnp.asarray(pos_values, state.pos.dtype), mode="drop")}
    for name, src in rec.items():
        tgt = getattr(state, name)
        if tgt is None:
            raise ValueError(f"slot_restore: state has no leaf {name!r}")
        out[name] = tgt.at[:, slots].set(jnp.asarray(src, tgt.dtype),
                                         mode="drop")
    return state._replace(**out)


def slot_reset(state: PagedDecodeState, slots: Array, pos_values: Array,
               rec: Optional[Dict[str, Array]] = None) -> PagedDecodeState:
    """Reset admitted slots: per-row ``pos`` plus recurrent-state loads.

    ``slots`` (G,) target slot indices (out-of-range = drop sentinel, as
    in :func:`~repro.models.transformer.slot_update`); ``pos_values``
    (G,) the committed position each slot resumes from (the matched
    prefix length, 0 for a cold admission).  ``rec`` maps recurrent
    field names to (L, G, ...) exact-f32 snapshots from the radix cache;
    omitted fields reset to zero (the cold boundary state).  K/V pools
    and block tables are untouched — tables are host-owned and pool
    writes happen in the extend pass that follows.
    """
    slots = jnp.asarray(slots, jnp.int32)
    rec = rec or {}
    out: Dict[str, Any] = {
        "pos": state.pos.at[slots].set(
            jnp.asarray(pos_values, state.pos.dtype), mode="drop")}
    for name in _REC_SNAPSHOT:
        tgt = getattr(state, name)
        if tgt is None:
            continue
        src = rec.get(name)
        if src is None:
            src = jnp.zeros((tgt.shape[0], slots.shape[0])
                            + tgt.shape[2:], jnp.float32)
        src = jnp.asarray(src, jnp.float32)
        if tgt.dtype == jnp.int8:
            q, s = quantize_blocked(src)
            out[name] = tgt.at[:, slots].set(q, mode="drop")
            sname = _SCALE_FOR[name]
            out[sname] = getattr(state, sname).at[:, slots].set(
                s[..., None] if s.ndim + 1 == getattr(state, sname).ndim
                else s, mode="drop")
        else:
            out[name] = tgt.at[:, slots].set(src.astype(tgt.dtype),
                                             mode="drop")
    return state._replace(**out)
