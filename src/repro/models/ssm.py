"""Attention-free sequence mixers: RWKV6 (Finch) and a Mamba-style
selective SSM (the hybrid branch of hymba).

Both are implemented as chunked scans: an outer ``lax.scan`` over time
chunks carries the recurrent state (which is also exactly the decode-time
state — long_500k decode is O(1) per step), and the inner chunk is a short
unrolled recurrence.  Sequence length therefore never enters the memory
footprint beyond one chunk of activations.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ExecutionPolicy
from repro.models import layers as L
from repro.parallel.sharding import constrain

Array = jax.Array


# ---------------------------------------------------------------------------
# RWKV6 time-mix (Finch: data-dependent per-channel decay)
# ---------------------------------------------------------------------------

class Rwkv6Params(NamedTuple):
    mu: Array        # (5, D) token-shift lerp factors for r,k,v,w,g
    w0: Array        # (D,) decay base
    w_lora_a: Array  # (D, 64) data-dependent decay LoRA
    w_lora_b: Array  # (64, D)
    bonus: Array     # (H, dk) the "u" current-token bonus
    wr: Array        # (D, D)
    wk: Array        # (D, D)
    wv: Array        # (D, D)
    wg: Array        # (D, D)
    wo: Array        # (D, D)
    ln_w: Array      # (D,) per-head group-norm gain


def _token_shift(x: Array, x_prev: Array) -> Array:
    """shifted[t] = x[t-1]; position 0 sees the carried boundary token."""
    return jnp.concatenate([x_prev[:, None, :], x[:, :-1, :]], axis=1)


def _last_valid(x: Array, lengths) -> Array:
    """x[:, n-1, :] per row — the boundary token carried into decode.

    With ``lengths=None`` (unpadded sequences) this is just ``x[:, -1]``;
    for right-padded serving prefill it gathers each row's last *real*
    position so the carried token-shift state matches single-stream decode.
    """
    if lengths is None:
        return x[:, -1, :]
    idx = (lengths - 1).astype(jnp.int32)[:, None, None]
    return jnp.take_along_axis(x, jnp.broadcast_to(
        idx, (x.shape[0], 1, x.shape[2])), axis=1)[:, 0, :]


def rwkv6_timemix(x: Array, p: Rwkv6Params, cfg: ArchConfig,
                  pol: ExecutionPolicy, state: Tuple[Array, Array],
                  mask: Array = None, lengths: Array = None,
                  return_states: bool = False):
    """x: (B, T, D).  state = (x_boundary (B, D), S (B, H, dk, dv)).

    Returns (out (B,T,D), new state).  wkv recurrence per head:
        out_t = (r_t ( S + (u*k_t) v_t^T )) ; S <- diag(w_t) S + k_t v_t^T

    ``mask`` (B, T) marks real tokens in a right-padded batch: pad steps
    carry S through unchanged (decay forced to 1, k to 0), so the carried
    state is bit-identical to running the unpadded sequence; ``lengths``
    picks each row's last real token for the token-shift boundary.

    ``return_states`` appends a third result: the wkv state *after every
    step*, (B, T, H, dk, dv) float32 — the per-position checkpoints a
    speculative ``verify_step`` rolls back to when drafts are rejected.
    Only sensible for short T (the verify window).
    """
    b, t, d = x.shape
    h = cfg.n_heads
    dk = d // h
    x_prev, s0 = state
    xs = _token_shift(x, x_prev)

    mixed = [x + (xs - x) * p.mu[i].astype(x.dtype) for i in range(5)]
    xr, xk, xv, xw, xg = mixed
    r = L.dense(xr, p.wr, pol).reshape(b, t, h, dk)
    k = L.dense(xk, p.wk, pol).reshape(b, t, h, dk)
    v = L.dense(xv, p.wv, pol).reshape(b, t, h, dk)
    g = L.dense(xg, p.wg, pol)
    # data-dependent decay (Finch): w = exp(-exp(w0 + lora(xw)))
    dd = jnp.tanh(xw.astype(jnp.float32) @ p.w_lora_a) @ p.w_lora_b
    logw = -jnp.exp(jnp.clip(p.w0.astype(jnp.float32) + dd, -8.0, 2.0))
    w = jnp.exp(logw).reshape(b, t, h, dk)                     # decay in (0,1)
    u = p.bonus.astype(jnp.float32)                            # (H, dk)
    if mask is not None:
        # pad steps are state no-ops: S <- 1*S + 0*v^T (exact)
        m = mask[:, :, None, None]
        w = jnp.where(m, w, jnp.ones((), w.dtype))
        k = jnp.where(m, k, jnp.zeros((), k.dtype))

    if t == 1:
        # decode/verify fast path: one recurrence step, no chunk
        # scaffolding (same primitive ops and casts as the scanned step
        # below — bit-identical, just without the length-1 scans)
        r1, k1, v1, w1 = (a[:, 0].astype(jnp.float32) for a in (r, k, v, w))
        S = s0.astype(jnp.float32)
        kv = k1[..., :, None] * v1[..., None, :]               # (B,H,dk,dv)
        out = jnp.einsum("bhk,bhkv->bhv", r1, S + u[..., None] * kv)[:, None]
        S = w1[..., None] * S + kv
        res = _timemix_out(out, x, g, p, pol, lengths, S)
        return res + (S[:, None],) if return_states else res

    chunk = max(1, min(64, t))
    assert t % chunk == 0
    n_chunks = t // chunk

    def scan_chunk(S, xs_c):
        r_c, k_c, v_c, w_c = xs_c  # (chunk, B, H, dk)

        def step(S, xs_t):
            r_t, k_t, v_t, w_t = (a.astype(jnp.float32) for a in xs_t)
            kv = k_t[..., :, None] * v_t[..., None, :]          # (B,H,dk,dv)
            out_t = jnp.einsum("bhk,bhkv->bhv", r_t, S + u[..., None] * kv)
            S = w_t[..., None] * S + kv
            ys = (S, out_t) if return_states else out_t
            return S, ys

        S, ys_c = jax.lax.scan(step, S, (r_c, k_c, v_c, w_c))
        return S, ys_c

    def to_chunks(a):  # (B,T,H,dk) -> (n_chunks, chunk, B, H, dk)
        return a.transpose(1, 0, 2, 3).reshape(n_chunks, chunk, b, h, dk)

    S, ys = jax.lax.scan(scan_chunk, s0.astype(jnp.float32),
                         (to_chunks(r), to_chunks(k), to_chunks(v),
                          to_chunks(w)))
    s_steps, out = ys if return_states else (None, ys)
    out = out.reshape(t, b, h, dk).transpose(1, 0, 2, 3)        # (B,T,H,dk)
    res = _timemix_out(out, x, g, p, pol, lengths, S)
    if return_states:  # (n_chunks, chunk, B, ...) -> (B, T, ...)
        s_steps = jnp.moveaxis(s_steps.reshape((t,) + s_steps.shape[2:]),
                               0, 1)
        return res + (s_steps,)
    return res


def _timemix_out(out: Array, x: Array, g: Array, p: Rwkv6Params,
                 pol: ExecutionPolicy, lengths, S: Array
                 ) -> Tuple[Array, Tuple[Array, Array]]:
    """Shared timemix epilogue: per-head group norm, gate, out proj."""
    b, t, d = x.shape
    mean = out.mean(-1, keepdims=True)
    var = out.var(-1, keepdims=True)
    out = (out - mean) * jax.lax.rsqrt(var + 64e-5)
    out = out.reshape(b, t, d) * p.ln_w.astype(jnp.float32)
    out = (out.astype(x.dtype) * L.af(g, "silu", pol))
    out = L.dense(out, p.wo, pol)
    return out, (_last_valid(x, lengths), S)


class Rwkv6ChannelParams(NamedTuple):
    mu_k: Array   # (D,)
    mu_r: Array   # (D,)
    wk: Array     # (D, F)
    wv: Array     # (F, D)
    wr: Array     # (D, D)


def rwkv6_channelmix(x: Array, p: Rwkv6ChannelParams, cfg: ArchConfig,
                     pol: ExecutionPolicy, x_prev: Array,
                     lengths: Array = None) -> Tuple[Array, Array]:
    xs = _token_shift(x, x_prev)
    xk = x + (xs - x) * p.mu_k.astype(x.dtype)
    xr = x + (xs - x) * p.mu_r.astype(x.dtype)
    k = L.af(L.dense(xk, p.wk, pol), "relu", pol)
    k = k * k                                        # squared ReLU
    kv = L.dense(k, p.wv, pol)
    r = L.af(L.dense(xr, p.wr, pol), "sigmoid", pol)
    return r * kv, _last_valid(x, lengths)


# ---------------------------------------------------------------------------
# Mamba-style selective SSM (hymba's parallel head branch)
# ---------------------------------------------------------------------------

class MambaParams(NamedTuple):
    w_in: Array      # (D, 2*Di)  -> x, z gate
    conv_w: Array    # (K, Di) depthwise causal conv
    w_bc: Array      # (Di, 2*N + 1) -> B, C, dt
    a_log: Array     # (Di, N)
    d_skip: Array    # (Di,)
    w_out: Array     # (Di, D)


def mamba_mix(x: Array, p: MambaParams, cfg: ArchConfig,
              pol: ExecutionPolicy, state: Tuple[Array, Array],
              mask: Array = None, lengths: Array = None,
              return_states: bool = False):
    """x: (B,T,D).  state = (conv tail (B, K-1, Di), h (B, Di, N)).

    ``mask``/``lengths`` as in :func:`rwkv6_timemix`: pad steps of a
    right-padded batch are forced to state no-ops (decay 1, drive 0) and
    the carried conv tail is gathered at each row's last real positions.

    ``return_states`` appends a third result ``(tails (B,T,K-1,Di),
    hs (B,T,Di,N))``: the conv tail and ssm state *after every step* —
    speculative verify checkpoints; short T only.
    """
    b, t, d = x.shape
    n = cfg.ssm_state
    conv_tail, h0 = state
    di = p.conv_w.shape[1]

    xz = L.dense(x, p.w_in, pol)
    # keep the mamba branch in the residual stream's (batch, seq) layout —
    # without this XLA reshards (B,T,2D) between the mlp- and seq-sharded
    # layouts every layer (hymba's 18x collective inflation, see
    # EXPERIMENTS.md #Perf)
    xz = constrain(xz, ("batch", "seq", None))
    xi, z = jnp.split(xz, 2, axis=-1)                # (B,T,Di)

    # depthwise causal conv via the carried tail
    kk = p.conv_w.shape[0]
    xi_pad = jnp.concatenate([conv_tail.astype(xi.dtype), xi], axis=1)
    conv = sum(xi_pad[:, i:i + t, :] * p.conv_w[i].astype(xi.dtype)
               for i in range(kk))
    conv = L.af(conv, "silu", pol)
    if kk == 1:
        new_tail = conv_tail
    elif lengths is None:
        new_tail = xi_pad[:, t:t + kk - 1, :]
    else:
        # last kk-1 *real* inputs per row: xi_pad cols [n, n + kk - 1)
        idx = lengths.astype(jnp.int32)[:, None] + jnp.arange(kk - 1)[None]
        new_tail = jnp.take_along_axis(xi_pad, idx[..., None], axis=1)

    bc = L.dense(conv, p.w_bc, pol).astype(jnp.float32)
    b_t, c_t, dt = bc[..., :n], bc[..., n:2 * n], bc[..., -1:]
    dt = jax.nn.softplus(dt)                          # (B,T,1)
    a = -jnp.exp(p.a_log.astype(jnp.float32))         # (Di,N)
    # dt (B,T,1) broadcasts over channels: decay (B,T,Di,N)
    decay = jnp.exp(dt[..., None] * a[None, None, :, :])
    drive = (dt[..., None] * b_t[:, :, None, :]) * conv.astype(
        jnp.float32)[..., None]                       # (B,T,Di,N)
    if mask is not None:
        # pad steps are state no-ops: h <- 1*h + 0 (exact)
        m = mask[:, :, None, None]
        decay = jnp.where(m, decay, jnp.ones((), decay.dtype))
        drive = jnp.where(m, drive, jnp.zeros((), drive.dtype))

    def step_tails():
        # conv-tail checkpoint after step j+1 = the last K-1 conv inputs
        # seen up to and including position j (sliding windows of xi_pad)
        return jnp.stack([xi_pad[:, j + 1:j + kk, :] for j in range(t)],
                         axis=1)                      # (B,T,K-1,Di)

    if t == 1:
        # decode/verify fast path: one recurrence step, no chunk
        # scaffolding (same ops as the scanned step — bit-identical)
        h = decay[:, 0] * h0.astype(jnp.float32) + drive[:, 0]  # (B,Di,N)
        y = jnp.einsum("bdn,bn->bd", h, c_t[:, 0])[:, None]     # (B,1,Di)
        y = y + conv.astype(jnp.float32) * p.d_skip.astype(jnp.float32)
        y = y.astype(x.dtype) * L.af(z, "silu", pol)
        out = L.dense(y, p.w_out, pol), (new_tail, h)
        return out + ((new_tail[:, None], h[:, None]),) if return_states \
            else out

    chunk = max(1, min(64, t))
    assert t % chunk == 0
    n_chunks = t // chunk

    def to_chunks(arr):  # (B,T,Di,N) -> (n_chunks, chunk, B, Di, N)
        return arr.transpose(1, 0, 2, 3).reshape(n_chunks, chunk, b, di, n)

    def scan_chunk(h, xs_c):
        dec_c, drv_c, c_c = xs_c

        def step(h, xs_t):
            dec_t, drv_t, c_tt = xs_t
            h = dec_t * h + drv_t                    # (B,Di,N)
            y_t = jnp.einsum("bdn,bn->bd", h, c_tt)
            ys = (h, y_t) if return_states else y_t
            return h, ys

        h, ys_c = jax.lax.scan(step, h, (dec_c, drv_c, c_c))
        return h, ys_c

    c_chunks = c_t.transpose(1, 0, 2).reshape(n_chunks, chunk, b, n)
    h, ys = jax.lax.scan(scan_chunk, h0.astype(jnp.float32),
                         (to_chunks(decay), to_chunks(drive), c_chunks))
    h_steps, y = ys if return_states else (None, ys)
    y = y.reshape(t, b, di).transpose(1, 0, 2)
    y = y + conv.astype(jnp.float32) * p.d_skip.astype(jnp.float32)
    y = y.astype(x.dtype) * L.af(z, "silu", pol)
    out = L.dense(y, p.w_out, pol), (new_tail, h)
    if return_states:
        h_steps = jnp.moveaxis(h_steps.reshape((t,) + h_steps.shape[2:]),
                               0, 1)                 # (B,T,Di,N)
        return out + ((step_tails(), h_steps),)
    return out
