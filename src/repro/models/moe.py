"""Mixture-of-Experts FFN with capacity-based dispatch (GShard-style).

Two execution paths:

* **Sharded (mesh active)** — an explicit ``shard_map`` over (data, model):
  GSPMD cannot partition the dispatch scatter/gather along the batch dim
  (it materialises the global (B, S*k, D) gather — 56 GB/device for
  arctic), so we make the parallelism explicit instead:

    - **EP mode** (E % model == 0, arctic): experts split over the model
      axis; each (data, model) device routes its local tokens to its local
      experts and the partial outputs psum over model.  Expert FFN weights
      optionally keep an extra FSDP shard over data (arctic's 469B slab)
      and are all-gathered at use.
    - **expert-TP mode** (otherwise, granite's 40 experts): every model
      shard holds all experts with a 1/model slice of the FFN width; the
      F-contraction makes outputs partial sums, combined by the same psum.

* **Local (no mesh)** — plain capacity-based scatter dispatch (smoke tests,
  single-device training); numerically equivalent (tests assert it).

Aux load-balance loss follows Switch Transformer (mean gate * mean load).
"""
from __future__ import annotations

import math
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as PS

from repro.compat import shard_map
from repro.configs.base import ArchConfig, ExecutionPolicy
from repro.models import layers as L
from repro.parallel.sharding import constrain, get_abstract_mesh

Array = jax.Array

# expert-weight FSDP threshold (total expert params)
FSDP_MIN_PARAMS = 4e9


class MoEParams(NamedTuple):
    w_router: Array           # (D, E)
    w_gate: Array             # (E, D, F)
    w_up: Array               # (E, D, F)
    w_down: Array             # (E, F, D)


def capacity(cfg: ArchConfig, tokens_per_group: int) -> int:
    c = int(math.ceil(tokens_per_group * cfg.top_k * cfg.capacity_factor
                      / cfg.n_experts))
    return max(c, cfg.top_k)


def moe_ffn(x: Array, p: MoEParams, cfg: ArchConfig, pol: ExecutionPolicy,
            ffn=None) -> Tuple[Array, Array]:
    """x: (B, S, D) -> (out (B, S, D), aux_loss scalar).

    ``ffn``: optional (w_gate, w_up, w_down) of a dense-residual FFN to be
    computed *inside* the sharded region and combined in the same psum as
    the MoE output — one all-reduce per layer instead of two (§Perf).
    """
    mesh = get_abstract_mesh()
    if (mesh is not None and not mesh.empty and "model" in mesh.shape
            and mesh.shape.get("model", 1) > 1
            and x.shape[0] % mesh.shape.get("data", 1) == 0):
        if pol.moe_pure_dp and x.shape[0] % _total_devices(mesh) == 0:
            return _moe_ffn_pure_dp(x, p, cfg, pol, mesh, ffn)
        return _moe_ffn_sharded(x, p, cfg, pol, mesh, ffn)
    out, aux = _moe_ffn_local(x, p, cfg, pol)
    if ffn is not None:
        out = out + L.swiglu(x, ffn[0], ffn[1], ffn[2], pol, cfg.activation)
    return out, aux


def _total_devices(mesh) -> int:
    n = 1
    for v in mesh.shape.values():
        n *= v
    return n


def _moe_ffn_local(x: Array, p: MoEParams, cfg: ArchConfig,
                   pol: ExecutionPolicy) -> Tuple[Array, Array]:
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    c = capacity(cfg, s)

    logits = L.dense(x, p.w_router, pol).astype(jnp.float32)   # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)             # (B,S,k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # Switch-style aux loss: fraction routed * mean prob per expert.
    density = jnp.mean(
        jax.nn.one_hot(expert_idx[..., 0], e, dtype=jnp.float32), axis=(0, 1))
    density_prob = jnp.mean(probs, axis=(0, 1))
    aux = jnp.sum(density * density_prob) * e

    # Position of each (token, k) entry within its expert, per group (=seq).
    flat_idx = expert_idx.reshape(b, s * k)                     # (B, S*k)
    onehot = jax.nn.one_hot(flat_idx, e, dtype=jnp.int32)       # (B, S*k, E)
    pos_in_e = jnp.cumsum(onehot, axis=1) * onehot              # 1-based
    position = jnp.sum(pos_in_e, axis=-1) - 1                   # (B, S*k)
    keep = position < c

    token_of = jnp.broadcast_to(jnp.arange(s)[None, :, None],
                                (b, s, k)).reshape(b, s * k)

    # Scatter tokens into the expert slab (dropped entries write to a
    # garbage slot c which we slice off).
    slot = jnp.where(keep, position, c)
    x_flat = x  # (B, S, D)
    src = jnp.take_along_axis(
        x_flat, token_of[..., None], axis=1)                    # (B,S*k,D)
    slab = jnp.zeros((b, e, c + 1, d), x.dtype)
    slab = slab.at[jnp.arange(b)[:, None], flat_idx, slot].add(src)
    slab = slab[:, :, :c, :]                                    # (B,E,C,D)
    slab = constrain(slab, ("batch", "experts", None, None))

    # Batched expert SwiGLU.
    def emm(t, w):  # (B,E,C,*) x (E,*,*)
        return jnp.einsum("becd,edf->becf", t, w.astype(t.dtype))

    h = L.af(emm(slab, p.w_gate), cfg.activation, pol) * emm(slab, p.w_up)
    h = constrain(h, ("batch", "experts", None, "expert_mlp"))
    y = jnp.einsum("becf,efd->becd", h, p.w_down.astype(h.dtype))
    y = constrain(y, ("batch", "experts", None, None))

    # Combine: gather each kept entry back and weight by its gate.
    y_pad = jnp.concatenate([y, jnp.zeros((b, e, 1, d), y.dtype)], axis=2)
    gathered = y_pad[jnp.arange(b)[:, None], flat_idx, slot]    # (B,S*k,D)
    gathered = gathered * (gate_vals.reshape(b, s * k)[..., None]
                           * keep[..., None]).astype(gathered.dtype)
    out = gathered.reshape(b, s, k, d).sum(axis=2)
    return out.astype(x.dtype), aux


# ---------------------------------------------------------------------------
# Explicit shard_map path (production meshes)
# ---------------------------------------------------------------------------

def _dispatch_local(x2, probs, e_lo, e_count, e_total, k, c, act_dtype):
    """Local capacity dispatch for experts [e_lo, e_lo+e_count).

    x2: (T, D) local tokens; probs: (T, E) router probabilities.
    Returns (slab (e_count, C, D), flat_idx, slot, gates, keep).
    """
    t, d = x2.shape
    gate_vals, expert_idx = jax.lax.top_k(probs, k)          # (T, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)
    flat_idx = expert_idx.reshape(t * k)
    local = jnp.logical_and(flat_idx >= e_lo, flat_idx < e_lo + e_count)
    local_e = jnp.where(local, flat_idx - e_lo, e_count)     # garbage bucket
    onehot = jax.nn.one_hot(local_e, e_count + 1, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) * onehot
    slot = jnp.sum(pos, axis=-1) - 1                         # (T*k,)
    keep = jnp.logical_and(local, slot < c)
    slot = jnp.where(keep, slot, c)
    token_of = jnp.repeat(jnp.arange(t), k)
    slab = jnp.zeros((e_count + 1, c + 1, d), act_dtype)
    slab = slab.at[local_e, slot].add(x2[token_of].astype(act_dtype))
    return (slab[:e_count, :c], flat_idx, local_e, slot, gate_vals, keep,
            token_of)


def _quantize_transport(w):
    """FxP8 transport for FSDP gathers (per-[e,d]-row absmax scales)."""
    amax = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(w.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _moe_ffn_sharded(x: Array, p: MoEParams, cfg: ArchConfig,
                     pol: ExecutionPolicy, mesh, ffn=None
                     ) -> Tuple[Array, Array]:
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    m = mesh.shape["model"]
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.shape
                    and b % mesh.shape[a] == 0)
    # batch split over every usable DP axis
    dp = 1
    for a in dp_axes:
        dp *= mesh.shape[a]
    ep_mode = (e % m == 0)
    fm = cfg.moe_d_ff
    # FSDP shard of the expert FFN width over every DP axis (arctic's 469B
    # slab spreads over all 256/512 chips; gathered at use).  Gather order
    # permutes F consistently for w_gate/w_up/w_down, and F is contracted
    # between them, so any reassembly order is numerically exact.
    fsdp_axes = tuple(a for a in ("data", "pod") if a in mesh.shape)
    fsdp_ways = 1
    for a in fsdp_axes:
        fsdp_ways *= mesh.shape[a]
    fsdp = ep_mode and fsdp_axes and fm % fsdp_ways == 0 and \
        (e * d * fm * cfg.n_layers) > FSDP_MIN_PARAMS
    tp_f = (not ep_mode) and fm % m == 0

    t_loc = (b // dp) * s
    # local capacity: expected local tokens per expert, with headroom
    c = max(k, int(math.ceil(t_loc * k * cfg.capacity_factor / e)))

    bspec = dp_axes if len(dp_axes) > 1 else (dp_axes[0] if dp_axes else None)
    x_spec = PS(bspec, None, None)
    fspec = (fsdp_axes if len(fsdp_axes) > 1 else fsdp_axes[0]) if fsdp else None
    if ep_mode:
        w_spec = PS("model", None, fspec) if fsdp else PS("model", None, None)
        wd_spec = PS("model", fspec, None) if fsdp else PS("model", None, None)
    else:
        w_spec = PS(None, None, "model") if tp_f else PS(None, None, None)
        wd_spec = PS(None, "model", None) if tp_f else PS(None, None, None)

    def f(xb, wr, wg, wu, wd, *ffn_w):
        bl = xb.shape[0]
        x2 = xb.reshape(bl * s, d)
        logits = (x2 @ wr.astype(x2.dtype)).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        # aux loss from local tokens (identical across model shards)
        top1 = jnp.argmax(probs, axis=-1)
        density = jnp.mean(jax.nn.one_hot(top1, e, dtype=jnp.float32), 0)
        aux = jnp.sum(density * jnp.mean(probs, axis=0)) * e
        if dp_axes:
            aux = jax.lax.pmean(aux, dp_axes)

        if ep_mode:
            e_loc = e // m
            e_lo = jax.lax.axis_index("model") * e_loc
            if fsdp:
                if pol.fsdp_int8_gather:
                    # FxP8 transport (CAESAR co-design on collectives):
                    # quantize the local F-shard, gather int8 payload AND
                    # per-shard scales, dequantize segment-wise — link
                    # bytes halve vs bf16 (scales are negligible).
                    ways = 1
                    for a in fsdp_axes:
                        ways *= mesh.shape[a]

                    def gq_last(w):
                        # w (E, D, Fs): scales per (e, d) row of this shard
                        q, sc = _quantize_transport(w)
                        qg = jax.lax.all_gather(q, fsdp_axes, axis=2,
                                                tiled=True)       # (E,D,F)
                        sg = jax.lax.all_gather(sc, fsdp_axes, axis=2,
                                                tiled=True)       # (E,D,ways)
                        eh, dh_, fs = q.shape
                        out = (qg.reshape(eh, dh_, ways, fs).astype(
                            jnp.float32) * sg[..., :, None])
                        return out.reshape(eh, dh_, ways * fs).astype(w.dtype)

                    def gq_mid(w):
                        # w (E, Fs, D): scales per (e, f) row
                        q, sc = _quantize_transport(w)
                        qg = jax.lax.all_gather(q, fsdp_axes, axis=1,
                                                tiled=True)       # (E,F,D)
                        sg = jax.lax.all_gather(sc, fsdp_axes, axis=1,
                                                tiled=True)       # (E,F,1)
                        return (qg.astype(jnp.float32) * sg).astype(w.dtype)

                    wg_l = gq_last(wg)
                    wu_l = gq_last(wu)
                    wd_l = gq_mid(wd)
                else:
                    wg_l = jax.lax.all_gather(wg, fsdp_axes, axis=2,
                                              tiled=True)
                    wu_l = jax.lax.all_gather(wu, fsdp_axes, axis=2,
                                              tiled=True)
                    wd_l = jax.lax.all_gather(wd, fsdp_axes, axis=1,
                                              tiled=True)
            else:
                wg_l, wu_l, wd_l = wg, wu, wd
        else:
            e_loc, e_lo = e, 0
            wg_l, wu_l, wd_l = wg, wu, wd

        slab, flat_idx, local_e, slot, gates, keep, token_of = \
            _dispatch_local(x2, probs, e_lo, e_loc, e, k, c, xb.dtype)

        h = L.af(jnp.einsum("ecd,edf->ecf", slab, wg_l.astype(slab.dtype)),
                 cfg.activation, pol) * jnp.einsum(
            "ecd,edf->ecf", slab, wu_l.astype(slab.dtype))
        y = jnp.einsum("ecf,efd->ecd", h, wd_l.astype(h.dtype))

        # combine: gather back, weight by gate, scatter-add per token
        y_pad = jnp.pad(y, ((0, 1), (0, 1), (0, 0)))
        vals = y_pad[jnp.minimum(local_e, e_loc), slot]      # (T*k, D)
        w_gate_val = (gates.reshape(-1) * keep).astype(vals.dtype)
        vals = vals * w_gate_val[:, None]
        out = jnp.zeros((bl * s, d), vals.dtype).at[token_of].add(vals)
        if ffn_w:
            # dense-residual FFN fused into the same psum: its w_down
            # contraction is over the model-sharded F, so its local output
            # is a partial sum exactly like the MoE output.
            fg, fu, fd = ffn_w
            h2 = L.af(x2 @ fg.astype(x2.dtype), cfg.activation, pol) * (
                x2 @ fu.astype(x2.dtype))
            out = out + (h2 @ fd.astype(h2.dtype)).astype(out.dtype)
        if ep_mode or tp_f or ffn_w:
            out = jax.lax.psum(out, "model")
        return out.reshape(bl, s, d).astype(xb.dtype), aux

    ffn_args = ()
    ffn_specs = ()
    if ffn is not None:
        # dense FFN weights are "mlp"-sharded over model (column/row)
        ffn_args = (ffn[0], ffn[1], ffn[2])
        ffn_specs = (PS(None, "model"), PS(None, "model"), PS("model", None))
    out, aux = shard_map(
        f, mesh=mesh,
        in_specs=(x_spec, PS(), w_spec, w_spec, wd_spec) + ffn_specs,
        out_specs=(x_spec, PS()),
        check_vma=False,
    )(x, p.w_router, p.w_gate, p.w_up, p.w_down, *ffn_args)
    return out, aux


def _moe_ffn_pure_dp(x: Array, p: MoEParams, cfg: ArchConfig,
                     pol: ExecutionPolicy, mesh, ffn=None
                     ) -> Tuple[Array, Array]:
    """Whole-mesh data parallelism for small MoEs (granite at tp=16 is
    communication-bound: E=40 can't shard over 16 and the psum dominates).
    Batch shards over every axis; experts replicated; zero collectives in
    the layer body."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    axes = tuple(a for a in ("pod", "data", "model") if a in mesh.shape)
    dp = 1
    for a in axes:
        dp *= mesh.shape[a]
    t_loc = (b // dp) * s
    c = max(k, int(math.ceil(t_loc * k * cfg.capacity_factor / e)))
    x_spec = PS(axes, None, None)

    def f(xb, wr, wg, wu, wd, *ffn_w):
        bl = xb.shape[0]
        x2 = xb.reshape(bl * s, d)
        logits = (x2 @ wr.astype(x2.dtype)).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        top1 = jnp.argmax(probs, axis=-1)
        density = jnp.mean(jax.nn.one_hot(top1, e, dtype=jnp.float32), 0)
        aux = jax.lax.pmean(
            jnp.sum(density * jnp.mean(probs, axis=0)) * e, axes)
        slab, flat_idx, local_e, slot, gates, keep, token_of = \
            _dispatch_local(x2, probs, 0, e, e, k, c, xb.dtype)
        h = L.af(jnp.einsum("ecd,edf->ecf", slab, wg.astype(slab.dtype)),
                 cfg.activation, pol) * jnp.einsum(
            "ecd,edf->ecf", slab, wu.astype(slab.dtype))
        y = jnp.einsum("ecf,efd->ecd", h, wd.astype(h.dtype))
        y_pad = jnp.pad(y, ((0, 1), (0, 1), (0, 0)))
        vals = y_pad[jnp.minimum(local_e, e), slot]
        vals = vals * (gates.reshape(-1) * keep).astype(vals.dtype)[:, None]
        out = jnp.zeros((bl * s, d), vals.dtype).at[token_of].add(vals)
        if ffn_w:
            fg, fu, fd = ffn_w
            h2 = L.af(x2 @ fg.astype(x2.dtype), cfg.activation, pol) * (
                x2 @ fu.astype(x2.dtype))
            out = out + (h2 @ fd.astype(h2.dtype)).astype(out.dtype)
        return out.reshape(bl, s, d).astype(xb.dtype), aux

    ffn_args = () if ffn is None else (ffn[0], ffn[1], ffn[2])
    ffn_specs = () if ffn is None else (PS(), PS(), PS())
    out, aux = shard_map(
        f, mesh=mesh,
        in_specs=(x_spec, PS(), PS(), PS(), PS()) + ffn_specs,
        out_specs=(x_spec, PS()),
        check_vma=False,
    )(x, p.w_router, p.w_gate, p.w_up, p.w_down, *ffn_args)
    return out, aux
