"""Common model layers, all routed through the ExecutionPolicy so the
paper's CORDIC datapath (FxP8 MAC + DA-VINCI AFs) is a first-class
execution mode for every architecture."""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.configs.base import ExecutionPolicy
from repro.core.activations import activate
from repro.core.quantization import QuantPolicy, quantized_dense
from repro.parallel.sharding import constrain

Array = jax.Array


def dense(x: Array, w: Array, policy: ExecutionPolicy,
          bias: Optional[Array] = None) -> Array:
    """Matmul through the policy-selected datapath."""
    if policy.matmul == "bf16":
        out = x @ w.astype(x.dtype)
    elif policy.matmul == "fxp8":
        out = quantized_dense(x, w, policy.quant)
    elif policy.matmul == "fxp8_weight":
        out = quantized_dense(x, w, QuantPolicy(act_bits=None))
    elif policy.matmul == "cordic_kernel":
        from repro.kernels.cordic_mac.ops import cordic_matmul
        x2 = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
        out = cordic_matmul(x2, w.astype(jnp.float32))
        out = out.reshape(*x.shape[:-1], w.shape[-1]).astype(x.dtype)
    else:
        raise ValueError(f"unknown matmul mode {policy.matmul!r}")
    if bias is not None:
        out = out + bias.astype(out.dtype)
    return out


def af(x: Array, name: str, policy: ExecutionPolicy, axis: int = -1) -> Array:
    """Activation through DA-VINCI when the policy enables CORDIC AFs.

    The CORDIC path computes in f32 (dequantized fixed point); cast back so
    residual-stream dtypes are stable under any policy."""
    return activate(x, name, policy.af, axis=axis).astype(x.dtype)


def softmax(x: Array, policy: ExecutionPolicy, axis: int = -1) -> Array:
    if policy.softmax_cordic and policy.af is not None:
        return activate(x, "softmax", policy.af, axis=axis).astype(x.dtype)
    return jax.nn.softmax(x, axis=axis)


def rms_norm(x: Array, gamma: Array, eps: float = 1e-5) -> Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * gamma.astype(x.dtype)


def rope_angles(positions: Array, head_dim: int, theta: float) -> Array:
    """(..., head_dim/2) rotary angles for integer positions."""
    inv_freq = 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                           dtype=jnp.float32) / head_dim))
    return positions.astype(jnp.float32)[..., None] * inv_freq


def apply_rope(x: Array, angles: Array) -> Array:
    """x: (..., S, H, D); angles: (..., S, D/2) broadcast over heads."""
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    sin = sin[..., None, :].astype(x.dtype)   # add head axis
    cos = cos[..., None, :].astype(x.dtype)
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def swiglu(x: Array, w_gate: Array, w_up: Array, w_down: Array,
           policy: ExecutionPolicy, act: str = "silu") -> Array:
    g = dense(x, w_gate, policy)
    u = dense(x, w_up, policy)
    h = af(g, act, policy) * u
    h = constrain(h, ("batch", "seq", "mlp"))
    return dense(h, w_down, policy)


def embedding_lookup(tokens: Array, table: Array) -> Array:
    return jnp.take(table, tokens, axis=0)


def cross_entropy(logits: Array, labels: Array,
                  mask: Optional[Array] = None) -> Array:
    """Mean CE over valid positions; logits (..., V) may be vocab-sharded."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
