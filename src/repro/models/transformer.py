"""The unified LM: dense / MoE / SSM / hybrid / audio / vlm families.

One blocks-scanned decoder whose per-layer mixer is selected by the family:
  dense|audio|vlm : GQA attention
  moe             : GQA attention + (dense residual?) MoE FFN
  ssm             : RWKV6 time-mix + channel-mix (attention-free)
  hybrid          : parallel GQA-attention + Mamba heads (hymba), fused by
                    per-branch normalisation then mean

Layers are stacked along a leading "layers" axis and executed with
``jax.lax.scan`` so the 40-48 layer production configs compile as a single
block.  Per-layer heterogeneity (hymba's sliding-window vs global layers)
rides along as a scanned per-layer window scalar.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ExecutionPolicy
from repro.core.quant_cache import dequantize_blocked, quantize_blocked
from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as M
from repro.models import spec as pspec
from repro.models import ssm as S
from repro.models.spec import P
from repro.parallel.sharding import constrain

Array = jax.Array


def _dt(cfg: ArchConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------

def params_spec(cfg: ArchConfig) -> Dict[str, Any]:
    """Declaration tree for the whole model (stacked layers)."""
    Lr, D, dh = cfg.n_layers, cfg.d_model, cfg.head_dim_
    Hq, Hkv, F = cfg.n_heads, cfg.n_kv_heads, cfg.d_ff
    dt = _dt(cfg)

    def ly(*shape, axes, **kw):
        return P((Lr,) + shape, ("layers",) + axes, dtype=dt, **kw)

    tree: Dict[str, Any] = {}
    if cfg.input_kind == "tokens":
        tree["embed"] = P((cfg.vocab_size, D), ("vocab", "embed"), dtype=dt)
    else:
        # modality stub: frames arrive pre-embedded; a small adapter remains
        tree["frame_adapter"] = P((D, D), ("embed", "qkv"), dtype=dt,
                                  init="scaled")
    tree["ln_f"] = P((D,), ("embed",), init="ones")
    if cfg.n_codebooks:
        tree["lm_head"] = P((D, cfg.n_codebooks * cfg.vocab_size),
                            ("embed", "vocab"), dtype=dt, init="scaled")
    else:
        tree["lm_head"] = P((D, cfg.vocab_size), ("embed", "vocab"),
                            dtype=dt, init="scaled")

    blk: Dict[str, Any] = {"ln1": ly(D, axes=("embed",), init="ones"),
                           "ln2": ly(D, axes=("embed",), init="ones")}

    if cfg.family != "ssm":
        attn = {
            "wq": ly(D, Hq * dh, axes=("embed", "heads"), init="scaled"),
            "wk": ly(D, Hkv * dh, axes=("embed", "kv_heads"), init="scaled"),
            "wv": ly(D, Hkv * dh, axes=("embed", "kv_heads"), init="scaled"),
            "wo": ly(Hq * dh, D, axes=("heads", "embed"), init="scaled"),
        }
        if cfg.qkv_bias:
            attn["bq"] = ly(Hq * dh, axes=("heads",), init="zeros")
            attn["bk"] = ly(Hkv * dh, axes=("kv_heads",), init="zeros")
            attn["bv"] = ly(Hkv * dh, axes=("kv_heads",), init="zeros")
        blk["attn"] = attn

    if cfg.family in ("dense", "audio", "vlm", "hybrid"):
        blk["ffn"] = {
            "w_gate": ly(D, F, axes=("embed", "mlp"), init="scaled"),
            "w_up": ly(D, F, axes=("embed", "mlp"), init="scaled"),
            "w_down": ly(F, D, axes=("mlp", "embed"), init="scaled"),
        }
    if cfg.family == "moe":
        E, Fm = cfg.n_experts, cfg.moe_d_ff
        blk["moe"] = {
            "w_router": ly(D, E, axes=("embed", None), init="scaled"),
            "w_gate": ly(E, D, Fm, axes=("experts", "embed", "expert_mlp"),
                         init="scaled"),
            "w_up": ly(E, D, Fm, axes=("experts", "embed", "expert_mlp"),
                       init="scaled"),
            "w_down": ly(E, Fm, D, axes=("experts", "expert_mlp", "embed"),
                         init="scaled"),
        }
        if cfg.dense_residual:
            blk["ffn"] = {
                "w_gate": ly(D, F, axes=("embed", "mlp"), init="scaled"),
                "w_up": ly(D, F, axes=("embed", "mlp"), init="scaled"),
                "w_down": ly(F, D, axes=("mlp", "embed"), init="scaled"),
            }
    if cfg.family == "ssm":
        H = cfg.n_heads
        blk["tm"] = {
            "mu": ly(5, D, axes=(None, "embed"), init="zeros"),
            "w0": ly(D, axes=("embed",), init="zeros"),
            "w_lora_a": ly(D, 64, axes=("embed", None), init="scaled"),
            "w_lora_b": ly(64, D, axes=(None, "embed"), init="scaled"),
            "bonus": ly(H, dh, axes=("heads", None), init="zeros"),
            "wr": ly(D, D, axes=("embed", "heads"), init="scaled"),
            "wk": ly(D, D, axes=("embed", "heads"), init="scaled"),
            "wv": ly(D, D, axes=("embed", "heads"), init="scaled"),
            "wg": ly(D, D, axes=("embed", "heads"), init="scaled"),
            "wo": ly(D, D, axes=("heads", "embed"), init="scaled"),
            "ln_w": ly(D, axes=("embed",), init="ones"),
        }
        blk["cm"] = {
            "mu_k": ly(D, axes=("embed",), init="zeros"),
            "mu_r": ly(D, axes=("embed",), init="zeros"),
            "wk": ly(D, F, axes=("embed", "mlp"), init="scaled"),
            "wv": ly(F, D, axes=("mlp", "embed"), init="scaled"),
            "wr": ly(D, D, axes=("embed", "qkv"), init="scaled"),
        }
        del blk["ln2"]  # channel-mix has its own pre-norm
        blk["ln2"] = ly(D, axes=("embed",), init="ones")
    if cfg.family == "hybrid":
        Di = D  # mamba inner width = d_model (hymba parallel heads)
        N = cfg.ssm_state
        blk["mamba"] = {
            "w_in": ly(D, 2 * Di, axes=("embed", "mlp"), init="scaled"),
            "conv_w": ly(cfg.ssm_conv, Di, axes=(None, "embed"),
                         init="scaled"),
            "w_bc": ly(Di, 2 * N + 1, axes=("embed", None), init="scaled"),
            "a_log": ly(Di, N, axes=("embed", "state"), init="zeros"),
            "d_skip": ly(Di, axes=("embed",), init="ones"),
            "w_out": ly(Di, D, axes=("mlp", "embed"), init="scaled"),
        }
        blk["norm_attn"] = ly(dh * cfg.n_heads, axes=("heads",), init="ones")
        blk["norm_ssm"] = ly(D, axes=("embed",), init="ones")
    tree["blocks"] = blk
    return tree


def layer_windows(cfg: ArchConfig, seq_len: int) -> np.ndarray:
    """Per-layer attention window (scanned alongside params)."""
    full = 2 ** 30
    if cfg.sliding_window <= 0:
        return np.full((cfg.n_layers,), full, np.int32)
    w = np.full((cfg.n_layers,), cfg.sliding_window, np.int32)
    if cfg.global_attn_every > 0 and seq_len <= 65536:
        # periodic global layers (hymba); in long_500k mode every layer is
        # windowed to keep the cache sub-quadratic (see DESIGN.md).
        w[::cfg.global_attn_every] = full
        w[-1] = full
    return w


# ---------------------------------------------------------------------------
# Block forward (training / prefill)
# ---------------------------------------------------------------------------

def _attn_params(bp: Dict[str, Array], cfg: ArchConfig) -> A.AttnParams:
    return A.AttnParams(bp["attn"]["wq"], bp["attn"]["wk"], bp["attn"]["wv"],
                        bp["attn"]["wo"], bp["attn"].get("bq"),
                        bp["attn"].get("bk"), bp["attn"].get("bv"))


def block_forward(x: Array, bp: Dict[str, Any], cfg: ArchConfig,
                  pol: ExecutionPolicy, positions: Array, window: Array,
                  ) -> Tuple[Array, Array]:
    """One decoder block (full-sequence). Returns (x, aux_loss)."""
    aux = jnp.float32(0.0)
    if cfg.family == "ssm":
        h = L.rms_norm(x, bp["ln1"], cfg.norm_eps)
        b, t, d = h.shape
        dk = d // cfg.n_heads
        st = (jnp.zeros((b, d), h.dtype),
              jnp.zeros((b, cfg.n_heads, dk, dk), jnp.float32))
        tm_out, _ = S.rwkv6_timemix(h, S.Rwkv6Params(**bp["tm"]), cfg, pol, st)
        x = x + tm_out
        h = L.rms_norm(x, bp["ln2"], cfg.norm_eps)
        cm_out, _ = S.rwkv6_channelmix(h, S.Rwkv6ChannelParams(**bp["cm"]),
                                       cfg, pol, jnp.zeros((b, d), h.dtype))
        return x + cm_out, aux

    h = L.rms_norm(x, bp["ln1"], cfg.norm_eps)
    q, k, v = A.qkv(h, _attn_params(bp, cfg), cfg, pol, positions)
    ctx = A.attention(q, k, v, cfg, pol, positions, positions, window)
    attn_out = L.dense(ctx.reshape(*x.shape[:2], -1), bp["attn"]["wo"], pol)

    if cfg.family == "hybrid":
        b, t, d = h.shape
        st = (jnp.zeros((b, cfg.ssm_conv - 1, d), h.dtype),
              jnp.zeros((b, d, cfg.ssm_state), jnp.float32))
        ssm_out, _ = S.mamba_mix(h, S.MambaParams(**bp["mamba"]), cfg, pol, st)
        # hymba fusion: normalise each branch, then average
        attn_out = L.rms_norm(attn_out, bp["norm_attn"], cfg.norm_eps)
        ssm_out = L.rms_norm(ssm_out, bp["norm_ssm"], cfg.norm_eps)
        x = x + 0.5 * (attn_out + ssm_out)
    else:
        x = x + attn_out
    x = constrain(x, ("batch", "seq", "embed"))

    h = L.rms_norm(x, bp["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        fused = (cfg.fuse_moe_ffn_ar and cfg.dense_residual)
        ffn_w = (bp["ffn"]["w_gate"], bp["ffn"]["w_up"],
                 bp["ffn"]["w_down"]) if fused else None
        moe_out, aux = M.moe_ffn(h, M.MoEParams(**bp["moe"]), cfg, pol,
                                 ffn=ffn_w)
        if cfg.dense_residual and not fused:
            moe_out = moe_out + L.swiglu(h, bp["ffn"]["w_gate"],
                                         bp["ffn"]["w_up"],
                                         bp["ffn"]["w_down"], pol,
                                         cfg.activation)
        x = x + moe_out
    else:
        x = x + L.swiglu(h, bp["ffn"]["w_gate"], bp["ffn"]["w_up"],
                         bp["ffn"]["w_down"], pol, cfg.activation)
    return constrain(x, ("batch", "seq", "embed")), aux


def forward(params: Dict[str, Any], batch: Dict[str, Array],
            cfg: ArchConfig, pol: Optional[ExecutionPolicy] = None) -> Array:
    """Full-sequence forward -> logits.

    batch: {"tokens": (B,S) int32} or {"frames": (B,S,D)} for stub
    frontends.
    """
    pol = pol or cfg.exec_policy
    if cfg.input_kind == "tokens":
        x = L.embedding_lookup(batch["tokens"], params["embed"])
    else:
        x = batch["frames"].astype(_dt(cfg)) @ params["frame_adapter"]
    x = constrain(x, ("batch", "seq", "embed"))
    b, s = x.shape[:2]
    positions = jnp.arange(s, dtype=jnp.int32)
    windows = jnp.asarray(layer_windows(cfg, s))

    def body(carry, xs):
        x, aux = carry
        bp, win = xs
        x, a = block_forward(x, bp, cfg, pol, positions, win)
        return (x, aux + a), None

    block_fn = body
    if cfg.remat:
        block_fn = jax.checkpoint(body, prevent_cse=False)
    (x, aux), _ = jax.lax.scan(block_fn, (x, jnp.float32(0.0)),
                               (params["blocks"], windows))
    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = L.dense(x, params["lm_head"], pol)
    if cfg.n_codebooks:
        logits = logits.reshape(b, s, cfg.n_codebooks, cfg.vocab_size)
    return logits


def loss_fn(params, batch, cfg: ArchConfig,
            pol: Optional[ExecutionPolicy] = None) -> Tuple[Array, Dict]:
    pol = pol or cfg.exec_policy
    if cfg.input_kind == "tokens":
        x = L.embedding_lookup(batch["tokens"], params["embed"])
    else:
        x = batch["frames"].astype(_dt(cfg)) @ params["frame_adapter"]
    x = constrain(x, ("batch", "seq", "embed"))
    b, s = x.shape[:2]
    positions = jnp.arange(s, dtype=jnp.int32)
    windows = jnp.asarray(layer_windows(cfg, s))

    def body(carry, xs):
        xc, aux = carry
        bp, win = xs
        xc, a = block_forward(xc, bp, cfg, pol, positions, win)
        return (xc, aux + a), None

    block_fn = jax.checkpoint(body, prevent_cse=False) if cfg.remat else body
    (x, aux), _ = jax.lax.scan(block_fn, (x, jnp.float32(0.0)),
                               (params["blocks"], windows))
    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = L.dense(x, params["lm_head"], pol)
    if cfg.n_codebooks:
        logits = logits.reshape(b, s, cfg.n_codebooks, cfg.vocab_size)
    ce = L.cross_entropy(logits, batch["labels"], batch.get("mask"))
    total = ce + 0.01 * aux / max(cfg.n_layers, 1)
    return total, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# Serving: prefill + single-token decode with stacked per-layer caches
# ---------------------------------------------------------------------------

class DecodeState(NamedTuple):
    """Stacked (n_layers leading dim) recurrent state for every family.

    The ``*scale*`` fields carry the per-block float32 scales of the
    quantized cache mode (``cfg.cache_quant == "int8"``, see
    :mod:`repro.core.quant_cache`); they stay ``None`` otherwise.
    """
    cache_k: Optional[Array] = None     # (L,B,S,Hkv,dh)
    cache_v: Optional[Array] = None
    pos: Optional[Array] = None         # scalar int32 tokens-seen
    # ssm / hybrid
    x_prev: Optional[Array] = None      # (L,B,D) rwkv token-shift boundary
    cm_prev: Optional[Array] = None     # (L,B,D) rwkv channel-mix boundary
    wkv: Optional[Array] = None         # (L,B,H,dk,dk) rwkv state
    conv_tail: Optional[Array] = None   # (L,B,K-1,Di) mamba conv tail
    ssm_h: Optional[Array] = None       # (L,B,Di,N) mamba state
    # per-block int8 cache scales (cache_quant="int8" only)
    scale_k: Optional[Array] = None     # (L,B,S,Hkv,1)
    scale_v: Optional[Array] = None     # (L,B,S,Hkv,1)
    wkv_scale: Optional[Array] = None   # (L,B,H,dk,1)
    ssm_scale: Optional[Array] = None   # (L,B,Di,1)


def _cache_quant(cfg: ArchConfig) -> bool:
    """Whether the per-block int8 serving-cache format is active.

    Delegates to :meth:`ArchConfig.cache_spec` — the one resolver for the
    cache format — so unknown ``cache_quant`` strings and the
    int8-vs-fxp8 mutual exclusion raise here exactly as before.
    """
    return cfg.cache_spec().quantized


def init_decode_state(cfg: ArchConfig, batch: int, max_seq: int,
                      abstract: bool = False) -> DecodeState:
    Lr, D, dh = cfg.n_layers, cfg.d_model, cfg.head_dim_
    dt = _dt(cfg)
    spec = cfg.cache_spec()
    qc = spec.quantized
    kv_dt = jnp.int8 if spec.dtype in ("int8", "fxp8") else dt
    mk = (jax.ShapeDtypeStruct if abstract
          else (lambda sh, d: jnp.zeros(sh, d)))
    fields: Dict[str, Any] = {"pos": (jax.ShapeDtypeStruct((), jnp.int32)
                                      if abstract else jnp.zeros((), jnp.int32))}
    if cfg.family != "ssm":
        cache_len = max_seq
        if cfg.sliding_window and cfg.supports_long_context and \
                max_seq > 65536:
            cache_len = cfg.sliding_window  # long_500k: ring cache only
        fields["cache_k"] = mk((Lr, batch, cache_len, cfg.n_kv_heads, dh),
                               kv_dt)
        fields["cache_v"] = mk((Lr, batch, cache_len, cfg.n_kv_heads, dh),
                               kv_dt)
        if qc:
            fields["scale_k"] = mk((Lr, batch, cache_len, cfg.n_kv_heads, 1),
                                   jnp.float32)
            fields["scale_v"] = mk((Lr, batch, cache_len, cfg.n_kv_heads, 1),
                                   jnp.float32)
    if cfg.family == "ssm":
        fields["x_prev"] = mk((Lr, batch, D), dt)
        fields["cm_prev"] = mk((Lr, batch, D), dt)
        # quantized mode stores the O(1) recurrent state itself as int8;
        # the tiny token-shift boundaries (x_prev/cm_prev) stay exact
        fields["wkv"] = mk((Lr, batch, cfg.n_heads, dh, dh),
                           jnp.int8 if qc else jnp.float32)
        if qc:
            fields["wkv_scale"] = mk((Lr, batch, cfg.n_heads, dh, 1),
                                     jnp.float32)
    if cfg.family == "hybrid":
        fields["conv_tail"] = mk((Lr, batch, cfg.ssm_conv - 1, D), dt)
        fields["ssm_h"] = mk((Lr, batch, D, cfg.ssm_state),
                             jnp.int8 if qc else jnp.float32)
        if qc:
            fields["ssm_scale"] = mk((Lr, batch, D, 1), jnp.float32)
    return DecodeState(**fields)


def decode_step(params: Dict[str, Any], state: DecodeState,
                batch: Dict[str, Array], cfg: ArchConfig,
                pol: Optional[ExecutionPolicy] = None
                ) -> Tuple[Array, DecodeState]:
    """One new token for every sequence. batch: {"tokens": (B,1)} or
    {"frames": (B,1,D)}.  Returns (logits, new state)."""
    pol = pol or cfg.exec_policy
    if cfg.input_kind == "tokens":
        x = L.embedding_lookup(batch["tokens"], params["embed"])
    else:
        x = batch["frames"].astype(_dt(cfg)) @ params["frame_adapter"]
    b = x.shape[0]
    pos = state.pos
    per_row = jnp.ndim(pos) == 1            # serving slots: own pos per row
    paged = getattr(state, "block_tables", None) is not None
    if state.cache_k is not None:
        cache_len = state.cache_k.shape[2]
        if paged:   # pool (L,N,page,...): logical capacity is the table's
            cache_len = state.block_tables.shape[1] * cache_len
        if cfg.sliding_window and cache_len <= cfg.sliding_window:
            # ring cache (long_500k): every layer is windowed
            windows = jnp.full((cfg.n_layers,), cfg.sliding_window, jnp.int32)
        else:
            windows = jnp.asarray(layer_windows(cfg, cache_len))
    else:
        windows = jnp.asarray(layer_windows(cfg, 4096))

    qc = _cache_quant(cfg)

    def body(x, xs):
        if cfg.family == "ssm":
            if qc:
                bp, xp, cp, wkv_q, wkv_s = xs
                # dequant -> exact f32 recurrence step -> requant: the
                # O(1) state round-trips through int8 once per token
                wkv = dequantize_blocked(wkv_q, wkv_s)
            else:
                bp, xp, cp, wkv = xs
            h = L.rms_norm(x, bp["ln1"], cfg.norm_eps)
            tm_out, (xp2, wkv2) = S.rwkv6_timemix(
                h, S.Rwkv6Params(**bp["tm"]), cfg, pol, (xp, wkv))
            x = x + tm_out
            h = L.rms_norm(x, bp["ln2"], cfg.norm_eps)
            cm_out, cp2 = S.rwkv6_channelmix(
                h, S.Rwkv6ChannelParams(**bp["cm"]), cfg, pol, cp)
            if qc:
                wkv2, wkv2_s = quantize_blocked(wkv2)
                return x + cm_out, (xp2, cp2, wkv2, wkv2_s)
            return x + cm_out, (xp2, cp2, wkv2)

        bp, ck, cv = xs[0], xs[1], xs[2]
        if qc:
            sk_, sv_, win = xs[3], xs[4], xs[5]
            extra = xs[6:]
        else:
            win = xs[3]
            extra = xs[4:]
        h = L.rms_norm(x, bp["ln1"], cfg.norm_eps)
        positions = (pos[:, None].astype(jnp.int32) if per_row
                     else jnp.full((1,), pos, jnp.int32))
        q, k, v = A.qkv(h, _attn_params(bp, cfg), cfg, pol, positions)
        if paged:
            if qc:
                ctx, ck2, cv2, sk2, sv2 = A.paged_decode_attention(
                    q, k, v, ck, cv, state.block_tables, pos, cfg, pol,
                    win, scale_k=sk_, scale_v=sv_)
                new_caches = (ck2, cv2, sk2, sv2)
            else:
                ctx, ck2, cv2 = A.paged_decode_attention(
                    q, k, v, ck, cv, state.block_tables, pos, cfg, pol,
                    win)
                new_caches = (ck2, cv2)
        elif qc:
            ctx, ck2, cv2, sk2, sv2 = A.decode_attention(
                q, k, v, ck, cv, pos, cfg, pol, win,
                scale_k=sk_, scale_v=sv_)
            new_caches = (ck2, cv2, sk2, sv2)
        else:
            ctx, ck2, cv2 = A.decode_attention(q, k, v, ck, cv, pos, cfg,
                                               pol, win)
            new_caches = (ck2, cv2)
        attn_out = L.dense(ctx.reshape(b, 1, -1), bp["attn"]["wo"], pol)
        new_extra = ()
        if cfg.family == "hybrid":
            if qc:
                tail, hq_, hs_ = extra
                hprev = dequantize_blocked(hq_, hs_)
            else:
                tail, hprev = extra
            ssm_out, (tail2, h2) = S.mamba_mix(
                h, S.MambaParams(**bp["mamba"]), cfg, pol, (tail, hprev))
            attn_out = L.rms_norm(attn_out, bp["norm_attn"], cfg.norm_eps)
            ssm_out = L.rms_norm(ssm_out, bp["norm_ssm"], cfg.norm_eps)
            x = x + 0.5 * (attn_out + ssm_out)
            if qc:
                h2, h2_s = quantize_blocked(h2)
                new_extra = (tail2, h2, h2_s)
            else:
                new_extra = (tail2, h2)
        else:
            x = x + attn_out
        h = L.rms_norm(x, bp["ln2"], cfg.norm_eps)
        if cfg.family == "moe":
            moe_out, _ = M.moe_ffn(h, M.MoEParams(**bp["moe"]), cfg, pol)
            if cfg.dense_residual:
                moe_out = moe_out + L.swiglu(h, bp["ffn"]["w_gate"],
                                             bp["ffn"]["w_up"],
                                             bp["ffn"]["w_down"], pol,
                                             cfg.activation)
            x = x + moe_out
        else:
            x = x + L.swiglu(h, bp["ffn"]["w_gate"], bp["ffn"]["w_up"],
                             bp["ffn"]["w_down"], pol, cfg.activation)
        return x, new_caches + new_extra

    if cfg.family == "ssm":
        if qc:
            x, (xp, cp, wkv, wkv_s) = jax.lax.scan(
                body, x, (params["blocks"], state.x_prev, state.cm_prev,
                          state.wkv, state.wkv_scale))
            new_state = state._replace(x_prev=xp, cm_prev=cp, wkv=wkv,
                                       wkv_scale=wkv_s, pos=pos + 1)
        else:
            x, (xp, cp, wkv) = jax.lax.scan(
                body, x, (params["blocks"], state.x_prev, state.cm_prev,
                          state.wkv))
            new_state = state._replace(x_prev=xp, cm_prev=cp, wkv=wkv,
                                       pos=pos + 1)
    elif cfg.family == "hybrid":
        if qc:
            x, (ck, cv, sk, sv, tail, hh, hs) = jax.lax.scan(
                body, x, (params["blocks"], state.cache_k, state.cache_v,
                          state.scale_k, state.scale_v, windows,
                          state.conv_tail, state.ssm_h, state.ssm_scale))
            new_state = state._replace(cache_k=ck, cache_v=cv, scale_k=sk,
                                       scale_v=sv, conv_tail=tail, ssm_h=hh,
                                       ssm_scale=hs, pos=pos + 1)
        else:
            x, (ck, cv, tail, hh) = jax.lax.scan(
                body, x, (params["blocks"], state.cache_k, state.cache_v,
                          windows, state.conv_tail, state.ssm_h))
            new_state = state._replace(cache_k=ck, cache_v=cv,
                                       conv_tail=tail, ssm_h=hh, pos=pos + 1)
    else:
        if qc:
            x, (ck, cv, sk, sv) = jax.lax.scan(
                body, x, (params["blocks"], state.cache_k, state.cache_v,
                          state.scale_k, state.scale_v, windows))
            new_state = state._replace(cache_k=ck, cache_v=cv, scale_k=sk,
                                       scale_v=sv, pos=pos + 1)
        else:
            x, (ck, cv) = jax.lax.scan(
                body, x, (params["blocks"], state.cache_k, state.cache_v,
                          windows))
            new_state = state._replace(cache_k=ck, cache_v=cv, pos=pos + 1)

    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = L.dense(x, params["lm_head"], pol)
    if cfg.n_codebooks:
        logits = logits.reshape(b, 1, cfg.n_codebooks, cfg.vocab_size)
    return logits, new_state


def prefill(params, batch, cfg: ArchConfig,
            pol: Optional[ExecutionPolicy] = None,
            headroom: int = 64,
            lengths: Optional[Array] = None) -> Tuple[Array, DecodeState]:
    """Full-sequence forward that also populates the decode state.

    For attention families the per-layer K/V are written into a cache with
    ``headroom`` extra decode slots (prefill_32k lowers this path);
    recurrent families fold the sequence into their O(1) state.

    ``lengths`` (B,) marks each row's true prompt length in a batch whose
    prompts are **right-padded** to a common bucket (the serving engine's
    shape buckets): causal attention already ignores the trailing pads for
    the real positions, recurrent state updates are masked to no-ops on pad
    steps, the returned logits are each row's *last real* position, and
    ``state.pos`` comes back per-row — ready for
    :func:`slot_update`/:func:`decode_step` with per-slot positions.
    Outputs for the real tokens are bit-identical to the unpadded run.
    """
    pol = pol or cfg.exec_policy
    if cfg.input_kind == "tokens":
        x = L.embedding_lookup(batch["tokens"], params["embed"])
    else:
        x = batch["frames"].astype(_dt(cfg)) @ params["frame_adapter"]
    b, s = x.shape[:2]
    positions = jnp.arange(s, dtype=jnp.int32)
    windows = jnp.asarray(layer_windows(cfg, s))
    state = init_decode_state(cfg, b, s + headroom)
    mask = (None if lengths is None
            else jnp.arange(s)[None, :] < lengths[:, None])

    def body(carry, xs):
        x = carry
        if cfg.family == "ssm":
            bp = xs
            h = L.rms_norm(x, bp["ln1"], cfg.norm_eps)
            dk = cfg.d_model // cfg.n_heads
            st = (jnp.zeros((b, cfg.d_model), h.dtype),
                  jnp.zeros((b, cfg.n_heads, dk, dk), jnp.float32))
            tm_out, (xp, wkv) = S.rwkv6_timemix(
                h, S.Rwkv6Params(**bp["tm"]), cfg, pol, st,
                mask=mask, lengths=lengths)
            x = x + tm_out
            h = L.rms_norm(x, bp["ln2"], cfg.norm_eps)
            cm_out, cp = S.rwkv6_channelmix(
                h, S.Rwkv6ChannelParams(**bp["cm"]), cfg, pol,
                jnp.zeros((b, cfg.d_model), h.dtype), lengths=lengths)
            return x + cm_out, (xp, cp, wkv)

        bp, win = xs
        h = L.rms_norm(x, bp["ln1"], cfg.norm_eps)
        q, k, v = A.qkv(h, _attn_params(bp, cfg), cfg, pol, positions)
        ctx = A.attention(q, k, v, cfg, pol, positions, positions, win)
        attn_out = L.dense(ctx.reshape(b, s, -1), bp["attn"]["wo"], pol)
        ys_extra = ()
        if cfg.family == "hybrid":
            st = (jnp.zeros((b, cfg.ssm_conv - 1, cfg.d_model), h.dtype),
                  jnp.zeros((b, cfg.d_model, cfg.ssm_state), jnp.float32))
            ssm_out, (tail, hh) = S.mamba_mix(
                h, S.MambaParams(**bp["mamba"]), cfg, pol, st,
                mask=mask, lengths=lengths)
            attn_out = L.rms_norm(attn_out, bp["norm_attn"], cfg.norm_eps)
            ssm_out = L.rms_norm(ssm_out, bp["norm_ssm"], cfg.norm_eps)
            x = x + 0.5 * (attn_out + ssm_out)
            ys_extra = (tail, hh)
        else:
            x = x + attn_out
        h = L.rms_norm(x, bp["ln2"], cfg.norm_eps)
        if cfg.family == "moe":
            moe_out, _ = M.moe_ffn(h, M.MoEParams(**bp["moe"]), cfg, pol)
            if cfg.dense_residual:
                moe_out = moe_out + L.swiglu(h, bp["ffn"]["w_gate"],
                                             bp["ffn"]["w_up"],
                                             bp["ffn"]["w_down"], pol,
                                             cfg.activation)
            x = x + moe_out
        else:
            x = x + L.swiglu(h, bp["ffn"]["w_gate"], bp["ffn"]["w_up"],
                             bp["ffn"]["w_down"], pol, cfg.activation)
        return x, (k, v) + ys_extra

    qc = _cache_quant(cfg)

    def pad_seq(t):
        # zero-pad along the sequence axis up to the slot cache length
        tgt = state.cache_k.shape[2]
        if t.shape[2] != tgt:
            t = jnp.pad(t, ((0, 0), (0, 0), (0, tgt - t.shape[2]))
                        + ((0, 0),) * (t.ndim - 3))
        return t

    def pad_cache(t):
        # write the prefilled K/V into slots [0, s); headroom slots stay 0.
        # The cache lives seq-sharded over the model axis (the decode
        # memory-term fix) regardless of how the per-layer k/v were laid
        # out during the forward pass.  Already-int8 inputs (the per-block
        # quantized mode quantizes before padding) must not re-quantize
        # through the legacy fixed-scale path.
        if state.cache_k.dtype == jnp.int8 and t.dtype != jnp.int8:
            t = A.quantize_kv(t)
        return constrain(pad_seq(t),
                         ("layers", "batch", "seq", "kv_heads", None))

    pos = (jnp.int32(s) if lengths is None else lengths.astype(jnp.int32))
    if cfg.family == "ssm":
        x, (xp, cp, wkv) = jax.lax.scan(body, x, params["blocks"])
        if qc:
            wkv, wkv_s = quantize_blocked(wkv)
            state = state._replace(x_prev=xp, cm_prev=cp, wkv=wkv,
                                   wkv_scale=wkv_s, pos=pos)
        else:
            state = state._replace(x_prev=xp, cm_prev=cp, wkv=wkv, pos=pos)
    elif cfg.family == "hybrid":
        x, (ks, vs, tails, hs) = jax.lax.scan(body, x,
                                              (params["blocks"], windows))
        if qc:
            ks, ks_s = quantize_blocked(ks)
            vs, vs_s = quantize_blocked(vs)
            hs, hs_s = quantize_blocked(hs)
            state = state._replace(cache_k=pad_cache(ks),
                                   cache_v=pad_cache(vs),
                                   scale_k=pad_seq(ks_s),
                                   scale_v=pad_seq(vs_s),
                                   conv_tail=tails, ssm_h=hs,
                                   ssm_scale=hs_s, pos=pos)
        else:
            state = state._replace(cache_k=pad_cache(ks),
                                   cache_v=pad_cache(vs),
                                   conv_tail=tails, ssm_h=hs, pos=pos)
    else:
        x, (ks, vs) = jax.lax.scan(body, x, (params["blocks"], windows))
        if qc:
            ks, ks_s = quantize_blocked(ks)
            vs, vs_s = quantize_blocked(vs)
            state = state._replace(cache_k=pad_cache(ks),
                                   cache_v=pad_cache(vs),
                                   scale_k=pad_seq(ks_s),
                                   scale_v=pad_seq(vs_s), pos=pos)
        else:
            state = state._replace(cache_k=pad_cache(ks),
                                   cache_v=pad_cache(vs), pos=pos)

    if lengths is None:
        x_last = x[:, -1:, :]
    else:                       # each row's last *real* position
        idx = (lengths - 1).astype(jnp.int32)[:, None, None]
        x_last = jnp.take_along_axis(
            x, jnp.broadcast_to(idx, (b, 1, x.shape[-1])), axis=1)
    x_last = L.rms_norm(x_last, params["ln_f"], cfg.norm_eps)
    logits = L.dense(x_last, params["lm_head"], pol)
    return logits, state


# ---------------------------------------------------------------------------
# Serving slots: per-slot state insertion (the continuous-batching seam)
# ---------------------------------------------------------------------------

def init_slot_state(cfg: ArchConfig, max_batch: int, max_seq: int,
                    abstract: bool = False) -> DecodeState:
    """Decode state for ``max_batch`` persistent serving slots.

    Identical to :func:`init_decode_state` except ``pos`` is a ``(B,)``
    vector — every slot tracks its own tokens-seen counter, so slots
    prefilled at different times (and lengths) can decode in one batch.
    """
    st = init_decode_state(cfg, max_batch, max_seq, abstract)
    pos = (jax.ShapeDtypeStruct((max_batch,), jnp.int32) if abstract
           else jnp.zeros((max_batch,), jnp.int32))
    return st._replace(pos=pos)


def slot_update(state: DecodeState, sub: DecodeState, slots: Array
                ) -> DecodeState:
    """Scatter ``sub``'s per-request state into ``state`` at slot indices.

    ``state`` is the engine's persistent slot state (``pos`` per-row, from
    :func:`init_slot_state`); ``sub`` is a fresh prefill over a (possibly
    smaller, bucket-padded) batch; ``slots`` (B_sub,) maps each ``sub`` row
    to a target slot.  Out-of-range slot indices (>= max_batch) are
    dropped — the engine pads admission groups with a sentinel so one
    traced program covers every group size.  K/V caches shorter than the
    slot cache (prompt buckets < max_seq) are zero-padded along the
    sequence axis; every state family (attention KV, rwkv wkv/token-shift,
    mamba conv/ssm) scatters along its batch axis (axis 1 under the
    stacked layers axis).
    """
    slots = jnp.asarray(slots, jnp.int32)
    out: Dict[str, Any] = {}
    for name in DecodeState._fields:
        tgt = getattr(state, name)
        src = getattr(sub, name)
        if tgt is None or src is None:
            out[name] = tgt
            continue
        if name == "pos":
            src = jnp.broadcast_to(src.astype(tgt.dtype), slots.shape)
            out[name] = tgt.at[slots].set(src, mode="drop")
            continue
        if name in ("cache_k", "cache_v", "scale_k", "scale_v") \
                and src.shape[2] != tgt.shape[2]:
            grow = tgt.shape[2] - src.shape[2]
            if grow < 0:
                raise ValueError(
                    f"prefill cache ({src.shape[2]}) exceeds slot cache "
                    f"({tgt.shape[2]}); raise the engine's max_seq")
            src = jnp.pad(src, [(0, 0), (0, 0), (0, grow)]
                          + [(0, 0)] * (src.ndim - 3))
        out[name] = tgt.at[:, slots].set(src.astype(tgt.dtype), mode="drop")
    return DecodeState(**out)


def slot_extract(state: DecodeState, slots: Array) -> DecodeState:
    """Gather per-slot state rows at slot indices — the inverse of
    :func:`slot_update`, and the serving snapshot's extract seam.

    ``slots`` (G,) picks rows along the batch axis (axis 1 under the
    stacked layers axis; axis 0 for ``pos``) of every present leaf; the
    result is a sub-state shaped exactly like a prefill's output for G
    requests, so ``slot_update(state, slot_extract(state, slots), slots)``
    is an identity and a snapshot restores through the same scatter that
    admissions use.  Leaves come back **raw** (int8 cache leaves and
    their scale leaves verbatim) — restore must be bit-identical, never a
    dequant/requant round trip.
    """
    slots = jnp.asarray(slots, jnp.int32)
    out: Dict[str, Any] = {}
    for name in DecodeState._fields:
        leaf = getattr(state, name)
        if leaf is None:
            out[name] = None
        elif name == "pos":
            out[name] = leaf[slots]
        else:
            out[name] = leaf[:, slots]
    return DecodeState(**out)


# ---------------------------------------------------------------------------
# Speculative decode: k+1-position verify with variable per-row commit
# ---------------------------------------------------------------------------

# Recurrent DecodeState fields that must roll back when drafted tokens are
# rejected (everything O(1)-per-slot; the K/V caches never roll back — a
# rejected write sits at a position > the committed ``pos`` and is invalid
# by the age mask until the real token at that position overwrites it).
REC_FIELDS = ("x_prev", "cm_prev", "wkv", "conv_tail", "ssm_h")

# quantized-cache mode: the rec fields that live as int8 and the scale
# field each one re-derives at spec_commit time
_SCALE_FOR = {"wkv": "wkv_scale", "ssm_h": "ssm_scale"}

# ring-cache verify: rec_stack keys carrying the raw evicted K/V columns
# (L, B, K, ...) that spec_commit restores for rejected candidates, and
# the cache field each one restores into
_RING_KEYS = ("ring_k", "ring_v", "ring_sk", "ring_sv")
_RING_FIELD = {"ring_k": "cache_k", "ring_v": "cache_v",
               "ring_sk": "scale_k", "ring_sv": "scale_v"}


def verify_step(params: Dict[str, Any], state: DecodeState,
                batch: Dict[str, Array], cfg: ArchConfig,
                pol: Optional[ExecutionPolicy] = None
                ) -> Tuple[Array, DecodeState, Dict[str, Array]]:
    """Score ``K = k+1`` candidate positions per row in **one pass**.

    ``batch = {"tokens": (B, K)}`` — column 0 is each row's committed next
    token, columns 1..k the drafter's proposals.  The whole window runs
    through the layer stack as a short sequence (weights read once — the
    speculative-decode win), with per-query masking in
    :func:`~repro.models.attention.verify_attention` and per-step
    recurrent-state checkpoints from the ssm/mamba scans, so
    ``logits[:, j]`` equals what ``decode_step`` would return after
    feeding columns ``0..j`` one at a time (asserted bit-exactly by
    ``tests/test_spec_decode.py`` across every stateful family).

    Returns ``(logits (B, K, V), state, rec_stack)``:

    * ``state``: K/V caches hold all K candidate writes (positions
      ``pos..pos+K-1``; linear caches drop writes past the cache end,
      ring caches — allocations smaller than the stream, the long_500k
      preset — wrap them, with the pre-write entry still readable by
      earlier queries; see
      :func:`~repro.models.attention.verify_attention`) and ``pos`` is
      *unchanged* — nothing is committed yet.  A rejected write sits past
      the committed ``pos`` (or, on a ring, at a slot the real token will
      ring-write again on commit) and stays invisible until overwritten.
    * ``rec_stack``: per-step checkpoints of the recurrent fields
      (:data:`REC_FIELDS`), leading axis ``K+1`` where index ``j`` is the
      state after ``j`` accepted steps (0 = pre-verify).  Feed it to
      :func:`spec_commit` with the host's per-row accepted counts.
    """
    pol = pol or cfg.exec_policy
    if cfg.input_kind != "tokens":
        raise ValueError("speculative verify needs token inputs; frame "
                         "frontends have no draftable vocabulary")
    x = L.embedding_lookup(batch["tokens"], params["embed"])
    b, kq = x.shape[:2]
    pos = state.pos
    per_row = jnp.ndim(pos) == 1
    offs = jnp.arange(kq, dtype=jnp.int32)
    positions = (pos[:, None].astype(jnp.int32) + offs[None, :] if per_row
                 else pos.astype(jnp.int32) + offs)
    paged = getattr(state, "block_tables", None) is not None
    ring = False
    if state.cache_k is not None:
        cache_len = state.cache_k.shape[2]
        if paged:   # pool (L,N,page,...): logical capacity is the table's
            cache_len = state.block_tables.shape[1] * cache_len
        if cfg.sliding_window and cache_len <= cfg.sliding_window:
            windows = jnp.full((cfg.n_layers,), cfg.sliding_window,
                               jnp.int32)
            # the cache really is a ring (long_500k: allocation is the
            # window, the stream is longer): candidate writes must wrap.
            # Paged caches are linear by construction, never a ring.
            ring = not paged
        else:
            windows = jnp.asarray(layer_windows(cfg, cache_len))
    else:
        windows = jnp.asarray(layer_windows(cfg, 4096))

    qc = _cache_quant(cfg)

    def body(x, xs):
        if cfg.family == "ssm":
            if qc:
                bp, xp, cp, wkv_q, wkv_s = xs
                wkv = dequantize_blocked(wkv_q, wkv_s)
            else:
                bp, xp, cp, wkv = xs
            h = L.rms_norm(x, bp["ln1"], cfg.norm_eps)
            tm_out, (xp2, wkv2), wkv_steps = S.rwkv6_timemix(
                h, S.Rwkv6Params(**bp["tm"]), cfg, pol, (xp, wkv),
                return_states=True)
            x = x + tm_out
            h2 = L.rms_norm(x, bp["ln2"], cfg.norm_eps)
            cm_out, cp2 = S.rwkv6_channelmix(
                h2, S.Rwkv6ChannelParams(**bp["cm"]), cfg, pol, cp)
            # token-shift checkpoints after step j+1 are the mixer inputs
            # themselves: h[:, j] / h2[:, j]
            if qc:
                # requantized placeholder keeps the returned pytree's
                # dtypes stable; spec_commit overwrites it from the exact
                # f32 checkpoints anyway
                wkv2, wkv2_s = quantize_blocked(wkv2)
                return x + cm_out, (h, h2, wkv_steps, xp2, cp2, wkv2,
                                    wkv2_s)
            return x + cm_out, (h, h2, wkv_steps, xp2, cp2, wkv2)

        bp, ck, cv = xs[0], xs[1], xs[2]
        if qc:
            sk_, sv_, win = xs[3], xs[4], xs[5]
            extra = xs[6:]
        else:
            win = xs[3]
            extra = xs[4:]
        h = L.rms_norm(x, bp["ln1"], cfg.norm_eps)
        q, k, v = A.qkv(h, _attn_params(bp, cfg), cfg, pol, positions)
        if paged:
            ev = ()
            if qc:
                ctx, ck2, cv2, sk2, sv2 = A.paged_verify_attention(
                    q, k, v, ck, cv, state.block_tables, pos, cfg, pol,
                    win, scale_k=sk_, scale_v=sv_)
                new_caches = (ck2, cv2, sk2, sv2)
            else:
                ctx, ck2, cv2 = A.paged_verify_attention(
                    q, k, v, ck, cv, state.block_tables, pos, cfg, pol,
                    win)
                new_caches = (ck2, cv2)
        elif qc:
            if ring:
                ctx, ck2, cv2, sk2, sv2, ev = A.verify_attention(
                    q, k, v, ck, cv, pos, cfg, pol, win,
                    scale_k=sk_, scale_v=sv_, ring=True)
            else:
                ctx, ck2, cv2, sk2, sv2 = A.verify_attention(
                    q, k, v, ck, cv, pos, cfg, pol, win,
                    scale_k=sk_, scale_v=sv_)
                ev = ()
            new_caches = (ck2, cv2, sk2, sv2)
        else:
            if ring:
                ctx, ck2, cv2, ev = A.verify_attention(
                    q, k, v, ck, cv, pos, cfg, pol, win, ring=True)
            else:
                ctx, ck2, cv2 = A.verify_attention(q, k, v, ck, cv, pos,
                                                   cfg, pol, win)
                ev = ()
            new_caches = (ck2, cv2)
        attn_out = L.dense(ctx.reshape(b, kq, -1), bp["attn"]["wo"], pol)
        new_extra = ()
        if cfg.family == "hybrid":
            if qc:
                tail, hq_, hs_ = extra
                hprev = dequantize_blocked(hq_, hs_)
            else:
                tail, hprev = extra
            ssm_out, (tail2, h2), (tail_steps, h_steps) = S.mamba_mix(
                h, S.MambaParams(**bp["mamba"]), cfg, pol, (tail, hprev),
                return_states=True)
            attn_out = L.rms_norm(attn_out, bp["norm_attn"], cfg.norm_eps)
            ssm_out = L.rms_norm(ssm_out, bp["norm_ssm"], cfg.norm_eps)
            x = x + 0.5 * (attn_out + ssm_out)
            if qc:
                h2, h2_s = quantize_blocked(h2)
                new_extra = (tail2, h2, h2_s, tail_steps, h_steps)
            else:
                new_extra = (tail2, h2, tail_steps, h_steps)
        else:
            x = x + attn_out
        h = L.rms_norm(x, bp["ln2"], cfg.norm_eps)
        if cfg.family == "moe":
            moe_out, _ = M.moe_ffn(h, M.MoEParams(**bp["moe"]), cfg, pol)
            if cfg.dense_residual:
                moe_out = moe_out + L.swiglu(h, bp["ffn"]["w_gate"],
                                             bp["ffn"]["w_up"],
                                             bp["ffn"]["w_down"], pol,
                                             cfg.activation)
            x = x + moe_out
        else:
            x = x + L.swiglu(h, bp["ffn"]["w_gate"], bp["ffn"]["w_up"],
                             bp["ffn"]["w_down"], pol, cfg.activation)
        return x, new_caches + new_extra + ev

    def stack(pre, steps):
        # steps (L, B, K, ...) stacked by the layer scan -> checkpoint
        # layout (K+1, L, B, ...): index j = state after j steps
        return jnp.concatenate([pre[None],
                                jnp.moveaxis(steps, 2, 0).astype(pre.dtype)])

    rec_stack: Dict[str, Array] = {}
    if cfg.family == "ssm":
        if qc:
            x, (xp_steps, cp_steps, wkv_steps, xp, cp, wkv,
                wkv_s) = jax.lax.scan(
                body, x, (params["blocks"], state.x_prev, state.cm_prev,
                          state.wkv, state.wkv_scale))
            new_state = state._replace(x_prev=xp, cm_prev=cp, wkv=wkv,
                                       wkv_scale=wkv_s)
            wkv_pre = dequantize_blocked(state.wkv, state.wkv_scale)
        else:
            x, (xp_steps, cp_steps, wkv_steps, xp, cp, wkv) = jax.lax.scan(
                body, x, (params["blocks"], state.x_prev, state.cm_prev,
                          state.wkv))
            new_state = state._replace(x_prev=xp, cm_prev=cp, wkv=wkv)
            wkv_pre = state.wkv
        # checkpoints stay exact f32: quantization (if any) happens only
        # at spec_commit, on the state actually committed
        rec_stack = {"x_prev": stack(state.x_prev, xp_steps),
                     "cm_prev": stack(state.cm_prev, cp_steps),
                     "wkv": stack(wkv_pre, wkv_steps)}
    elif cfg.family == "hybrid":
        if qc:
            x, (ck, cv, sk, sv, tail, hh, hh_s, tail_steps, h_steps,
                *ring_ev) = jax.lax.scan(
                body, x, (params["blocks"], state.cache_k, state.cache_v,
                          state.scale_k, state.scale_v, windows,
                          state.conv_tail, state.ssm_h, state.ssm_scale))
            new_state = state._replace(cache_k=ck, cache_v=cv, scale_k=sk,
                                       scale_v=sv, conv_tail=tail, ssm_h=hh,
                                       ssm_scale=hh_s)
            h_pre = dequantize_blocked(state.ssm_h, state.ssm_scale)
        else:
            x, (ck, cv, tail, hh, tail_steps, h_steps,
                *ring_ev) = jax.lax.scan(
                body, x, (params["blocks"], state.cache_k, state.cache_v,
                          windows, state.conv_tail, state.ssm_h))
            new_state = state._replace(cache_k=ck, cache_v=cv,
                                       conv_tail=tail, ssm_h=hh)
            h_pre = state.ssm_h
        rec_stack = {"conv_tail": stack(state.conv_tail, tail_steps),
                     "ssm_h": stack(h_pre, h_steps)}
        rec_stack.update(zip(_RING_KEYS, ring_ev))
    else:
        if qc:
            x, (ck, cv, sk, sv, *ring_ev) = jax.lax.scan(
                body, x, (params["blocks"], state.cache_k, state.cache_v,
                          state.scale_k, state.scale_v, windows))
            new_state = state._replace(cache_k=ck, cache_v=cv, scale_k=sk,
                                       scale_v=sv)
        else:
            x, (ck, cv, *ring_ev) = jax.lax.scan(
                body, x, (params["blocks"], state.cache_k, state.cache_v,
                          windows))
            new_state = state._replace(cache_k=ck, cache_v=cv)
        rec_stack.update(zip(_RING_KEYS, ring_ev))

    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = L.dense(x, params["lm_head"], pol)
    if cfg.n_codebooks:
        logits = logits.reshape(b, kq, cfg.n_codebooks, cfg.vocab_size)
    return logits, new_state, rec_stack


def verify_commit_greedy(params: Dict[str, Any], state: DecodeState,
                         batch: Dict[str, Array], caps: Array,
                         cfg: ArchConfig,
                         pol: Optional[ExecutionPolicy] = None
                         ) -> Tuple[Array, Array, DecodeState]:
    """Fused greedy speculative step: verify, accept, commit — one program.

    Greedy acceptance needs no host round trip: draft ``j`` is accepted
    iff ``argmax(logits[:, j]) == tokens[:, j+1]``, so the longest
    matching prefix, the budget clamp and the state commit all run on
    device and the host pulls a single ``(B, K)`` int array per engine
    step (the two-phase :func:`verify_step` + :func:`spec_commit` path
    remains for sampling, whose rejection test is host-side).

    ``caps`` (B,) int32 — per-row ceiling on *accepted drafts* (min of
    real draft count and remaining budget - 1); ``-1`` marks a row that
    must not advance at all (an empty serving slot).

    Returns ``(ids (B, K) greedy targets, advance (B,), new state)`` with
    ``advance = min(matched, caps) + 1`` (0 for capped-out rows) already
    committed into ``pos`` and the recurrent state.
    """
    logits, st, rec_stack = verify_step(params, state, batch, cfg, pol)
    ids = jnp.argmax(logits, axis=-1)
    toks = batch["tokens"]
    match = (ids[:, :-1] == toks[:, 1:]).astype(jnp.int32)
    matched = jnp.sum(jnp.cumprod(match, axis=1), axis=1)     # prefix len
    advance = jnp.maximum(jnp.minimum(matched, caps) + 1, 0)
    return ids, advance, spec_commit(st, rec_stack, advance)


def spec_commit(state: DecodeState, rec_stack: Dict[str, Array],
                advance: Array) -> DecodeState:
    """Commit a verify call: advance each row by its accepted length.

    ``advance`` — int32 ``(B,)`` (or scalar for single-stream state) in
    ``[0..K]``: the number of verified tokens the host accepted per row
    (accepted drafts + 1, or 0 for rows that must not move — e.g. empty
    serving slots).  ``pos`` advances by it and every recurrent field is
    gathered from its ``rec_stack`` checkpoint at that index — the rollback
    for rejected tokens.  Linear K/V caches pass through: rejected writes
    sit past the committed ``pos`` and stay masked until overwritten.  On
    a ring cache the rejected candidates' wrapped writes evicted live
    history, so ``rec_stack`` additionally carries the raw evicted columns
    (:data:`_RING_KEYS`) and the commit scatters them back into every slot
    past each row's accepted prefix.
    """
    advance = jnp.asarray(advance, jnp.int32)
    ring_cols = {k: rec_stack[k] for k in _RING_KEYS if k in rec_stack}
    rec_stack = {k: v for k, v in rec_stack.items() if k not in ring_cols}
    out: Dict[str, Any] = {"pos": state.pos + advance.astype(state.pos.dtype)}
    for name, ev in ring_cols.items():            # ev (L, B, K, ...)
        cache = getattr(state, _RING_FIELD[name])
        nb, kq = ev.shape[1], ev.shape[2]
        s_max = cache.shape[2]
        offs = jnp.arange(kq, dtype=jnp.int32)
        if jnp.ndim(advance) == 0:
            slots = jnp.mod(state.pos.astype(jnp.int32) + offs, s_max)
            rej = offs >= advance                              # (K,)
            cur = cache[:, :, slots]                           # (L,B,K,...)
            sel = rej.reshape((1, 1, kq) + (1,) * (ev.ndim - 3))
            out[_RING_FIELD[name]] = cache.at[:, :, slots].set(
                jnp.where(sel, ev, cur))
        else:
            posv = jnp.broadcast_to(state.pos, (nb,)).astype(jnp.int32)
            slots = jnp.mod(posv[:, None] + offs[None, :], s_max)  # (B,K)
            rej = offs[None, :] >= advance[:, None]                # (B,K)
            rows = jnp.arange(nb)[:, None]
            cur = cache[:, rows, slots]                        # (L,B,K,...)
            sel = rej.reshape((1, nb, kq) + (1,) * (ev.ndim - 3))
            out[_RING_FIELD[name]] = cache.at[:, rows, slots].set(
                jnp.where(sel, ev, cur))
    for name, stack in rec_stack.items():         # stack (K+1, L, B, ...)
        if jnp.ndim(advance) == 0:
            picked = stack[advance]
        else:
            # picked[l, b] = stack[advance[b], l, b]
            picked = jax.vmap(lambda s, a: s[a], in_axes=(2, 0),
                              out_axes=1)(stack, advance)
        cur = getattr(state, name)
        if cur is not None and cur.dtype == jnp.int8:
            # quantize-on-commit: checkpoints are exact f32, the committed
            # int8 state is quantized exactly once per accepted prefix
            out[name], out[_SCALE_FOR[name]] = quantize_blocked(picked)
        else:
            out[name] = picked
    return state._replace(**out)
