"""Model facade: one object per architecture tying config -> functions."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ExecutionPolicy
from repro.models import spec as pspec
from repro.models import transformer as T

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig

    # -- parameters ---------------------------------------------------------
    def params_spec(self):
        return T.params_spec(self.cfg)

    def init(self, key: jax.Array):
        return pspec.materialize(self.params_spec(), key)

    def abstract_params(self):
        return pspec.abstract(self.params_spec())

    def n_params(self) -> int:
        return pspec.n_params(self.params_spec())

    def n_active_params(self) -> int:
        """Active parameters per token (MoE discounts inactive experts)."""
        cfg = self.cfg
        total = self.n_params()
        if not cfg.n_experts:
            return total
        per_expert = 3 * cfg.d_model * cfg.moe_d_ff * cfg.n_layers
        inactive = per_expert * (cfg.n_experts - cfg.top_k)
        return total - inactive

    # -- cache format --------------------------------------------------------
    def with_cache_dtype(self, cache_dtype: Optional[str]) -> "Model":
        """Same architecture with the serving-cache storage format swapped.

        ``"int8"`` turns on the per-block-scaled quantized caches
        (:mod:`repro.core.quant_cache`); ``None`` or a float name keeps
        full-precision caches.  Parameter shapes/specs are unchanged —
        only ``init_decode_state``/``init_slot_state`` layouts and the
        decode read/write paths differ.
        """
        if cache_dtype in (None, "none", "float", "fp32", "fp16", "bf16"):
            return self
        if cache_dtype == "int8":
            if self.cfg.cache_quant == "int8":
                return self
            return Model(dataclasses.replace(self.cfg, cache_quant="int8"))
        raise ValueError(f"unknown cache_dtype {cache_dtype!r}; expected "
                         f"'int8', a float dtype name, or None")

    # -- compute ------------------------------------------------------------
    def forward(self, params, batch, pol: Optional[ExecutionPolicy] = None):
        return T.forward(params, batch, self.cfg, pol)

    def loss(self, params, batch, pol: Optional[ExecutionPolicy] = None):
        return T.loss_fn(params, batch, self.cfg, pol)

    def prefill(self, params, batch, pol: Optional[ExecutionPolicy] = None,
                headroom: int = 64, lengths=None):
        """``lengths`` (B,): true prompt lengths of a right-padded batch
        (serving shape buckets); see :func:`repro.models.transformer.prefill`."""
        return T.prefill(params, batch, self.cfg, pol, headroom=headroom,
                         lengths=lengths)

    def decode_step(self, params, state, batch,
                    pol: Optional[ExecutionPolicy] = None):
        return T.decode_step(params, state, batch, self.cfg, pol)

    def init_decode_state(self, batch: int, max_seq: int,
                          abstract: bool = False):
        return T.init_decode_state(self.cfg, batch, max_seq, abstract)

    # -- speculative decode --------------------------------------------------
    def verify_step(self, params, state, batch,
                    pol: Optional[ExecutionPolicy] = None):
        """Score k+1 drafted positions per row in one call.

        Returns ``(logits (B,K,V), state, rec_stack)``; commit the host's
        per-row accepted lengths with :meth:`spec_commit`.  The scan body
        is the exact single-token decode computation, so greedy outputs
        are bit-identical to plain :meth:`decode_step` chains.
        """
        return T.verify_step(params, state, batch, self.cfg, pol)

    def spec_commit(self, state, rec_stack, advance):
        """Advance per-row ``pos`` by the accepted length (0..k+1) and roll
        recurrent state back to the matching verify checkpoint."""
        return T.spec_commit(state, rec_stack, advance)

    def verify_commit_greedy(self, params, state, batch, caps,
                             pol: Optional[ExecutionPolicy] = None):
        """Fused greedy spec step: verify + longest-prefix accept + commit
        in one program; returns ``(ids, advance, state)``."""
        return T.verify_commit_greedy(params, state, batch, caps, self.cfg,
                                      pol)

    # -- serving slots (continuous batching) --------------------------------
    def init_slot_state(self, max_batch: int, max_seq: int,
                        abstract: bool = False):
        """Persistent decode-slot state with a per-slot ``pos`` vector."""
        return T.init_slot_state(self.cfg, max_batch, max_seq, abstract)

    def slot_update(self, state, sub, slots):
        """Insert a prefill's per-request state into decode slots.

        The state-scatter seam of the continuous-batching engine: works for
        attention KV caches and recurrent (rwkv/mamba) state alike.  Slot
        indices >= max_batch are dropped (admission-group padding).
        """
        return T.slot_update(state, sub, slots)

    # -- inputs -------------------------------------------------------------
    def input_specs(self, batch: int, seq: int, kind: str = "train"
                    ) -> Dict[str, jax.ShapeDtypeStruct]:
        """ShapeDtypeStruct stand-ins for every model input (dry-run)."""
        cfg = self.cfg
        d = {}
        s = seq if kind != "decode" else 1
        if cfg.input_kind == "tokens":
            d["tokens"] = jax.ShapeDtypeStruct((batch, s), jnp.int32)
        else:
            d["frames"] = jax.ShapeDtypeStruct((batch, s, cfg.d_model),
                                               jnp.bfloat16)
        if kind == "train":
            if cfg.n_codebooks:
                d["labels"] = jax.ShapeDtypeStruct((batch, s, cfg.n_codebooks),
                                                   jnp.int32)
            else:
                d["labels"] = jax.ShapeDtypeStruct((batch, s), jnp.int32)
        return d

    def make_batch(self, key, batch: int, seq: int, kind: str = "train"):
        """Concrete random batch matching input_specs (smoke tests)."""
        cfg = self.cfg
        specs = self.input_specs(batch, seq, kind)
        out = {}
        for name, sds in specs.items():
            if sds.dtype == jnp.int32:
                key, k = jax.random.split(key)
                out[name] = jax.random.randint(k, sds.shape, 0,
                                               cfg.vocab_size, jnp.int32)
            else:
                key, k = jax.random.split(key)
                out[name] = jax.random.normal(k, sds.shape, jnp.float32
                                              ).astype(sds.dtype)
        return out


def build_model(cfg: ArchConfig) -> Model:
    return Model(cfg)
