"""Model facade: one object per architecture tying config -> functions."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Protocol, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, CacheSpec, ExecutionPolicy
from repro.models import paged as PG
from repro.models import spec as pspec
from repro.models import transformer as T

Array = jax.Array


# ---------------------------------------------------------------------------
# CacheOps: the slot-cache backend protocol (dense / paged)
# ---------------------------------------------------------------------------

def place_slot_state(state, shardings):
    """Place each populated slot-state leaf with its sharding.

    ``shardings`` is a matching namedtuple of ``Optional[NamedSharding]``
    (see :func:`repro.parallel.sharding.slot_state_shardings`) or ``None``
    for default placement.  Leaves whose sharding is ``None`` are left
    where they are — the serving-mesh constructors hand every populated
    leaf a sharding, so this is the single-device no-op path.
    """
    if shardings is None:
        return state
    placed = {}
    for name in state._fields:
        leaf = getattr(state, name)
        sh = getattr(shardings, name)
        placed[name] = (leaf if leaf is None or sh is None
                        else jax.device_put(leaf, sh))
    return type(state)(**placed)


class CacheOps(Protocol):
    """The serving engine's slot-cache seam, as an explicit protocol.

    A backend owns the *layout* of per-slot decode state and the three
    operations the engine drives it through; the model's compute
    functions (``decode_step``/``verify_step``) dispatch on the state
    type they are handed, so swapping backends never touches the engine's
    jitted programs beyond their (cached) input shapes.

    ``init_slot_state(max_batch, max_seq, abstract=False, shardings=None)``
        Allocate the persistent slot state (per-slot ``pos`` vector).
        ``shardings`` (a matching namedtuple of ``NamedSharding``, see
        :func:`repro.parallel.sharding.slot_state_shardings`) places each
        leaf on a serving mesh at construction — the mesh engine's
        sharded allocation path.

    ``slot_update(state, sub, slots)``
        Prefill-admission scatter: insert a bucketed group-prefill's
        per-request state at slot indices (>= max_batch drops).  The
        dense backend's admission path; the paged backend — whose
        admissions *extend in place* through the block tables
        (``slot_reset`` + ``Model.verify_step`` + ``spec_commit``) —
        raises ``NotImplementedError`` here by design.

    ``slot_reset(state, slots, pos_values, rec=None)``
        Extend-admission reset: point admitted slots at their resume
        position (0 cold, or a radix-cache prefix length) and load/zero
        the recurrent fields.  Works on either layout.

    ``spec_commit(state, rec_stack, advance)``
        Commit a verify pass: advance per-row ``pos`` by the accepted
        length and roll recurrent state back to its checkpoint.  Also the
        second half of a paged admission (``advance = suffix lengths``).

    ``slot_extract(state, slots)``
        Snapshot gather — the scatter seam read in reverse.  Returns the
        per-slot leaves at slot indices in their **raw storage dtype**
        (int8 state and its scale leaves verbatim), because a restored
        request must resume bit-identically.  The paged backend returns
        only ``pos`` + recurrent leaves; pool pages travel via the
        host-side block tables.

    ``slot_restore(state, slots, pos_values, rec)``
        Raw-dtype restore of per-slot ``pos`` + recurrent leaves — the
        write half of the snapshot seam.  Unlike ``slot_reset`` (whose
        ``rec`` is exact-f32 and re-quantizes on load), leaves land
        verbatim.

    ``paged`` / ``spec`` describe the backend for the engine's planning
    (block accounting lives host-side in ``runtime/block_pool.py``).
    """
    paged: bool
    spec: CacheSpec

    def init_slot_state(self, max_batch: int, max_seq: int,
                        abstract: bool = False, shardings=None): ...

    def slot_update(self, state, sub, slots): ...

    def slot_reset(self, state, slots, pos_values, rec=None): ...

    def spec_commit(self, state, rec_stack, advance): ...

    def slot_extract(self, state, slots): ...

    def slot_restore(self, state, slots, pos_values, rec): ...


@dataclasses.dataclass(frozen=True)
class DenseCacheOps:
    """Per-slot ``max_seq``-long caches (the classic layout)."""
    cfg: ArchConfig
    paged: bool = False

    @property
    def spec(self) -> CacheSpec:
        return self.cfg.cache_spec()

    def init_slot_state(self, max_batch: int, max_seq: int,
                        abstract: bool = False, shardings=None):
        st = T.init_slot_state(self.cfg, max_batch, max_seq, abstract)
        return st if abstract else place_slot_state(st, shardings)

    def slot_update(self, state, sub, slots):
        return T.slot_update(state, sub, slots)

    def slot_reset(self, state, slots, pos_values, rec=None):
        return PG.slot_reset(state, slots, pos_values, rec)

    def spec_commit(self, state, rec_stack, advance):
        return T.spec_commit(state, rec_stack, advance)

    def slot_extract(self, state, slots):
        return T.slot_extract(state, slots)

    def slot_restore(self, state, slots, pos_values, rec):
        return PG.slot_restore(state, slots, pos_values, rec)


@dataclasses.dataclass(frozen=True)
class PagedCacheOps:
    """Shared block-pool caches behind per-slot block tables.

    ``num_blocks * page_size`` tokens of K/V memory total — resident
    memory scales with live tokens, not ``max_batch * max_seq`` — and
    full pages are shareable between slots (the radix prefix cache).
    """
    cfg: ArchConfig
    num_blocks: int
    page_size: int
    paged: bool = True

    @property
    def spec(self) -> CacheSpec:
        return self.cfg.cache_spec()

    def init_slot_state(self, max_batch: int, max_seq: int,
                        abstract: bool = False, shardings=None):
        st = PG.init_paged_slot_state(self.cfg, max_batch, max_seq,
                                      self.num_blocks, self.page_size,
                                      abstract)
        return st if abstract else place_slot_state(st, shardings)

    def slot_update(self, state, sub, slots):
        raise NotImplementedError(
            "paged admissions extend in place through the block tables "
            "(slot_reset + verify_step + spec_commit); there is no "
            "separate prefill state to scatter")

    def slot_reset(self, state, slots, pos_values, rec=None):
        return PG.slot_reset(state, slots, pos_values, rec)

    def spec_commit(self, state, rec_stack, advance):
        return T.spec_commit(state, rec_stack, advance)

    def slot_extract(self, state, slots):
        return PG.slot_extract(state, slots)

    def slot_restore(self, state, slots, pos_values, rec):
        return PG.slot_restore(state, slots, pos_values, rec)


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig

    # -- parameters ---------------------------------------------------------
    def params_spec(self):
        return T.params_spec(self.cfg)

    def init(self, key: jax.Array):
        return pspec.materialize(self.params_spec(), key)

    def abstract_params(self):
        return pspec.abstract(self.params_spec())

    def n_params(self) -> int:
        return pspec.n_params(self.params_spec())

    def n_active_params(self) -> int:
        """Active parameters per token (MoE discounts inactive experts)."""
        cfg = self.cfg
        total = self.n_params()
        if not cfg.n_experts:
            return total
        per_expert = 3 * cfg.d_model * cfg.moe_d_ff * cfg.n_layers
        inactive = per_expert * (cfg.n_experts - cfg.top_k)
        return total - inactive

    # -- cache format --------------------------------------------------------
    def with_cache_dtype(self, cache_dtype) -> "Model":
        """Same architecture with the serving-cache storage format swapped.

        Accepts a :class:`~repro.configs.base.CacheSpec` (the full format:
        dtype, scale block, paging) or the legacy string spelling —
        ``"int8"`` turns on the per-block-scaled quantized caches
        (:mod:`repro.core.quant_cache`); ``None`` or a float name keeps
        full-precision caches.  Parameter shapes/specs are unchanged —
        only ``init_decode_state``/``init_slot_state`` layouts and the
        decode read/write paths differ.
        """
        if isinstance(cache_dtype, CacheSpec):
            return self.with_cache_spec(cache_dtype)
        if cache_dtype in (None, "none", "float", "fp32", "fp16", "bf16"):
            return self
        if cache_dtype == "int8":
            if self.cfg.cache_quant == "int8":
                return self
            return Model(dataclasses.replace(self.cfg, cache_quant="int8"))
        raise ValueError(f"unknown cache_dtype {cache_dtype!r}; expected "
                         f"a CacheSpec, 'int8', a float dtype name, or None")

    def with_cache_spec(self, spec: CacheSpec) -> "Model":
        """Same architecture with ``cfg.cache`` pinned to ``spec``.

        Clears the legacy ``kv_cache_bits``/``cache_quant`` knobs so the
        spec is the one spelling in play (mixing them raises in
        :meth:`ArchConfig.cache_spec`).
        """
        if self.cfg.cache == spec:
            return self
        return Model(dataclasses.replace(self.cfg, cache=spec,
                                         kv_cache_bits=16,
                                         cache_quant="none"))

    def cache_ops(self, num_blocks: Optional[int] = None,
                  page_size: Optional[int] = None) -> "CacheOps":
        """The :class:`CacheOps` backend for this model's resolved
        :class:`CacheSpec` — :class:`PagedCacheOps` when ``spec.paged``
        (``num_blocks`` required; ``page_size`` defaults to the spec's),
        else :class:`DenseCacheOps`."""
        spec = self.cfg.cache_spec()
        if not spec.paged:
            return DenseCacheOps(self.cfg)
        if num_blocks is None:
            raise ValueError("paged cache backend needs num_blocks (the "
                             "pool size bounds resident cache memory)")
        return PagedCacheOps(self.cfg, num_blocks,
                             page_size or spec.page_size)

    # -- compute ------------------------------------------------------------
    def forward(self, params, batch, pol: Optional[ExecutionPolicy] = None):
        return T.forward(params, batch, self.cfg, pol)

    def loss(self, params, batch, pol: Optional[ExecutionPolicy] = None):
        return T.loss_fn(params, batch, self.cfg, pol)

    def prefill(self, params, batch, pol: Optional[ExecutionPolicy] = None,
                headroom: int = 64, lengths=None):
        """``lengths`` (B,): true prompt lengths of a right-padded batch
        (serving shape buckets); see :func:`repro.models.transformer.prefill`."""
        return T.prefill(params, batch, self.cfg, pol, headroom=headroom,
                         lengths=lengths)

    def decode_step(self, params, state, batch,
                    pol: Optional[ExecutionPolicy] = None):
        return T.decode_step(params, state, batch, self.cfg, pol)

    def init_decode_state(self, batch: int, max_seq: int,
                          abstract: bool = False):
        return T.init_decode_state(self.cfg, batch, max_seq, abstract)

    # -- speculative decode --------------------------------------------------
    def verify_step(self, params, state, batch,
                    pol: Optional[ExecutionPolicy] = None):
        """Score k+1 drafted positions per row in one call.

        Returns ``(logits (B,K,V), state, rec_stack)``; commit the host's
        per-row accepted lengths with :meth:`spec_commit`.  The scan body
        is the exact single-token decode computation, so greedy outputs
        are bit-identical to plain :meth:`decode_step` chains.
        """
        return T.verify_step(params, state, batch, self.cfg, pol)

    def spec_commit(self, state, rec_stack, advance):
        """Advance per-row ``pos`` by the accepted length (0..k+1) and roll
        recurrent state back to the matching verify checkpoint."""
        return T.spec_commit(state, rec_stack, advance)

    def verify_commit_greedy(self, params, state, batch, caps,
                             pol: Optional[ExecutionPolicy] = None):
        """Fused greedy spec step: verify + longest-prefix accept + commit
        in one program; returns ``(ids, advance, state)``."""
        return T.verify_commit_greedy(params, state, batch, caps, self.cfg,
                                      pol)

    # -- serving slots (continuous batching) --------------------------------
    def init_slot_state(self, max_batch: int, max_seq: int,
                        abstract: bool = False, shardings=None):
        """Persistent decode-slot state with a per-slot ``pos`` vector.

        ``shardings`` places each leaf on a serving mesh at construction
        (see :func:`repro.parallel.sharding.slot_state_shardings`).
        """
        st = T.init_slot_state(self.cfg, max_batch, max_seq, abstract)
        return st if abstract else place_slot_state(st, shardings)

    def slot_update(self, state, sub, slots):
        """Insert a prefill's per-request state into decode slots.

        The state-scatter seam of the continuous-batching engine: works for
        attention KV caches and recurrent (rwkv/mamba) state alike.  Slot
        indices >= max_batch are dropped (admission-group padding).
        """
        return T.slot_update(state, sub, slots)

    def slot_extract(self, state, slots):
        """Gather per-slot state rows at slot indices — the scatter seam
        read in reverse, used by the serving snapshot.  Leaves come back
        in their raw storage dtype so a restore is bit-identical."""
        return T.slot_extract(state, slots)

    # -- inputs -------------------------------------------------------------
    def input_specs(self, batch: int, seq: int, kind: str = "train"
                    ) -> Dict[str, jax.ShapeDtypeStruct]:
        """ShapeDtypeStruct stand-ins for every model input (dry-run)."""
        cfg = self.cfg
        d = {}
        s = seq if kind != "decode" else 1
        if cfg.input_kind == "tokens":
            d["tokens"] = jax.ShapeDtypeStruct((batch, s), jnp.int32)
        else:
            d["frames"] = jax.ShapeDtypeStruct((batch, s, cfg.d_model),
                                               jnp.bfloat16)
        if kind == "train":
            if cfg.n_codebooks:
                d["labels"] = jax.ShapeDtypeStruct((batch, s, cfg.n_codebooks),
                                                   jnp.int32)
            else:
                d["labels"] = jax.ShapeDtypeStruct((batch, s), jnp.int32)
        return d

    def make_batch(self, key, batch: int, seq: int, kind: str = "train"):
        """Concrete random batch matching input_specs (smoke tests)."""
        cfg = self.cfg
        specs = self.input_specs(batch, seq, kind)
        out = {}
        for name, sds in specs.items():
            if sds.dtype == jnp.int32:
                key, k = jax.random.split(key)
                out[name] = jax.random.randint(k, sds.shape, 0,
                                               cfg.vocab_size, jnp.int32)
            else:
                key, k = jax.random.split(key)
                out[name] = jax.random.normal(k, sds.shape, jnp.float32
                                              ).astype(sds.dtype)
        return out


def build_model(cfg: ArchConfig) -> Model:
    return Model(cfg)


def draft_arch(target: ArchConfig, n_layers: int = 2, d_model: int = 64,
               n_heads: int = 2, d_ff: int = 256) -> ArchConfig:
    """A tiny dense LM sharing ``target``'s token space, for drafting.

    Speculative decoding only needs the draft and target vocabularies to
    agree — everything else is chosen for cheapness: a 2-layer dense
    attention stack with a linear cache (no MoE routing, no recurrent
    leaves, no sliding window), which is exactly what
    :class:`repro.runtime.drafter.DraftModelDrafter`'s position-reset
    rollback requires.  RoPE theta follows the target so positional
    geometry is at least family-resemblant on long prompts.
    """
    if target.input_kind != "tokens" or target.n_codebooks:
        raise ValueError(f"cannot derive a token draft model from "
                         f"{target.name!r} (input_kind="
                         f"{target.input_kind!r}, n_codebooks="
                         f"{target.n_codebooks})")
    return ArchConfig(
        name=f"{target.name}-draft", family="dense",
        n_layers=n_layers, d_model=d_model, n_heads=n_heads,
        n_kv_heads=n_heads, d_ff=d_ff, vocab_size=target.vocab_size,
        rope_theta=target.rope_theta, tie_embeddings=True,
        remat=False, dtype=target.dtype,
    )
