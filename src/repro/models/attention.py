"""GQA attention: naive, chunked (flash-style online softmax), and decode.

The chunked path is the memory-roofline workhorse for prefill_32k — it never
materialises the (S x S) score matrix, scanning KV blocks with running
max/sum statistics (the standard online-softmax recurrence) in pure JAX so
it lowers/shards through pjit like everything else.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ExecutionPolicy
from repro.core.quant_cache import dequantize_blocked, quantize_blocked
from repro.models import layers as L
from repro.parallel.sharding import constrain, get_abstract_mesh

Array = jax.Array

NEG_INF = -1e30
# FxP8 (Q3.4) KV-cache quantization constants — the paper's 8-bit format
# applied to the decode cache (kv_cache_bits=8).
KV_Q_SCALE = 16.0


def quantize_kv(x: Array) -> Array:
    return jnp.clip(jnp.round(x.astype(jnp.float32) * KV_Q_SCALE),
                    -127, 127).astype(jnp.int8)


def dequantize_kv(x: Array, dtype) -> Array:
    if x.dtype != jnp.int8:
        return x.astype(dtype)
    return (x.astype(jnp.float32) * (1.0 / KV_Q_SCALE)).astype(dtype)


def _causal_window_mask(q_pos: Array, k_pos: Array, window) -> Array:
    """True = attend.  q_pos (Sq,), k_pos (Sk,); window traced or python."""
    d = q_pos[:, None] - k_pos[None, :]
    mask = d >= 0
    return jnp.logical_and(mask, d < window)


def _split_heads(x: Array, n_heads: int) -> Array:
    b, s, _ = x.shape
    return x.reshape(b, s, n_heads, -1)


class AttnParams(NamedTuple):
    wq: Array
    wk: Array
    wv: Array
    wo: Array
    bq: Optional[Array] = None
    bk: Optional[Array] = None
    bv: Optional[Array] = None


def qkv(x: Array, p: AttnParams, cfg: ArchConfig, pol: ExecutionPolicy,
        positions: Array) -> Tuple[Array, Array, Array]:
    dh = cfg.head_dim_
    q = _split_heads(L.dense(x, p.wq, pol, p.bq), cfg.n_heads)
    k = _split_heads(L.dense(x, p.wk, pol, p.bk), cfg.n_kv_heads)
    v = _split_heads(L.dense(x, p.wv, pol, p.bv), cfg.n_kv_heads)
    if cfg.family != "ssm":
        ang = L.rope_angles(positions, dh, cfg.rope_theta)
        q = L.apply_rope(q, ang)
        k = L.apply_rope(k, ang)
    # TP layout choice: head-sharded attention when heads divide the model
    # axis (no resharding between projection and attention); otherwise
    # query-sequence sharding (k/v replicated) — the misaligned-heads fix
    # recorded in EXPERIMENTS.md #Perf.
    mesh = get_abstract_mesh()
    tp = mesh.shape.get("model", 1) if mesh is not None and not mesh.empty \
        else 1
    if tp > 1 and cfg.n_heads % tp == 0:
        q = constrain(q, ("batch", None, "heads", None))
        k = constrain(k, ("batch", None, "kv_heads", None))
        v = constrain(v, ("batch", None, "kv_heads", None))
    else:
        q = constrain(q, ("batch", "seq", None, None))
        k = constrain(k, ("batch", None, None, None))
        v = constrain(v, ("batch", None, None, None))
    return q, k, v


def naive_attention(q: Array, k: Array, v: Array, cfg: ArchConfig,
                    pol: ExecutionPolicy, q_pos: Array, k_pos: Array,
                    window) -> Array:
    """Materialised-scores attention (small seq / reference)."""
    b, sq, hq, dh = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, sq, hkv, g, dh)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k) / jnp.sqrt(float(dh))
    mask = _causal_window_mask(q_pos, k_pos, window)
    scores = jnp.where(mask[None, None, None], scores.astype(jnp.float32),
                       NEG_INF)
    probs = L.softmax(scores, pol).astype(q.dtype)
    ctx = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return ctx.reshape(b, sq, hq, dh)


def chunked_attention(q: Array, k: Array, v: Array, cfg: ArchConfig,
                      pol: ExecutionPolicy, q_pos: Array, k_pos: Array,
                      window, chunk: int) -> Array:
    """Flash-style online-softmax over KV chunks; O(S*chunk) live memory."""
    b, sq, hq, dh = q.shape
    sk = k.shape[1]
    hkv = k.shape[2]
    g = hq // hkv
    chunk = min(chunk, sk)
    assert sk % chunk == 0, (sk, chunk)
    n_chunks = sk // chunk
    qg = q.reshape(b, sq, hkv, g, dh)
    scale = 1.0 / jnp.sqrt(float(dh))

    kc = k.reshape(b, n_chunks, chunk, hkv, dh).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, chunk, hkv, dh).transpose(1, 0, 2, 3, 4)
    kpc = k_pos.reshape(n_chunks, chunk)

    def step(carry, xs):
        m_prev, l_prev, o_prev = carry            # (b,hkv,g,sq[,dh])
        k_i, v_i, kp_i = xs
        s = jnp.einsum("bskgd,btkd->bkgst", qg, k_i).astype(jnp.float32) * scale
        mask = _causal_window_mask(q_pos, kp_i, window)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_i = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_i[..., None])
        alpha = jnp.exp(m_prev - m_i)
        l_i = l_prev * alpha + jnp.sum(p, axis=-1)
        o_i = o_prev * alpha[..., None] + jnp.einsum(
            "bkgst,btkd->bkgsd", p.astype(q.dtype), v_i).astype(jnp.float32)
        return (m_i, l_i, o_i), None

    # carries shard like q: over heads when aligned, else over the query
    # sequence (keeps the online-softmax state at 1/tp per device)
    m0 = constrain(jnp.full((b, hkv, g, sq), NEG_INF, jnp.float32),
                   ("batch", "kv_heads", None, "seq"))
    l0 = constrain(jnp.zeros((b, hkv, g, sq), jnp.float32),
                   ("batch", "kv_heads", None, "seq"))
    o0 = constrain(jnp.zeros((b, hkv, g, sq, dh), jnp.float32),
                   ("batch", "kv_heads", None, "seq", None))
    (m, l, o), _ = jax.lax.scan(step, (m0, l0, o0), (kc, vc, kpc))
    o = o / jnp.maximum(l[..., None], 1e-30)
    ctx = o.transpose(0, 3, 1, 2, 4).reshape(b, sq, hq, dh)
    return ctx.astype(q.dtype)


def attention(q, k, v, cfg: ArchConfig, pol: ExecutionPolicy, q_pos, k_pos,
              window=None) -> Array:
    window = window if window is not None else jnp.int32(2 ** 30)
    sk = k.shape[1]
    impl = cfg.attn_impl
    if impl == "auto":
        impl = "chunked" if sk > 2048 else "naive"
    if impl == "chunked":
        return chunked_attention(q, k, v, cfg, pol, q_pos, k_pos, window,
                                 cfg.attn_chunk)
    return naive_attention(q, k, v, cfg, pol, q_pos, k_pos, window)


# ---------------------------------------------------------------------------
# Decode (single-token) with a preallocated cache
# ---------------------------------------------------------------------------

def _attend_decode(q: Array, keys: Array, vals: Array, pos: Array,
                   pol: ExecutionPolicy, window) -> Array:
    """Single-token attend over a (B,S,Hkv,dh) key/value view.

    The mask/softmax/einsum half of :func:`decode_attention`, shared by
    the dense and paged layouts: both present the same logical
    (B, S, Hkv, dh) view, so the math (and its bit pattern) is layout-
    independent.
    """
    b, _, hq, dh = q.shape
    s_max = keys.shape[1]
    hkv = keys.shape[2]
    g = hq // hkv
    qg = q.reshape(b, 1, hkv, g, dh)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, keys) / jnp.sqrt(float(dh))
    # ring-buffer positions: slot t holds absolute position
    #   p_t = t            if t <= pos (current wrap)  [no-wrap case]
    # with wrapping, valid entries are the last min(pos+1, s_max) writes.
    per_row = jnp.ndim(pos) == 1
    t = jnp.arange(s_max)
    age = jnp.mod((pos[:, None] if per_row else pos) - t, s_max)  # 0 = newest
    valid = age < jnp.minimum((pos[:, None] if per_row else pos) + 1, s_max)
    in_window = age < window
    mask = jnp.logical_and(valid, in_window)
    if per_row:                             # (B, S): own history per slot
        mask = mask[:, None, None, None, :]
    else:
        mask = mask[None, None, None, None, :]
    scores = jnp.where(mask, scores.astype(jnp.float32), NEG_INF)
    probs = L.softmax(scores, pol).astype(q.dtype)
    ctx = jnp.einsum("bkgst,btkd->bskgd", probs, vals)
    return ctx.reshape(b, 1, hq, dh)


def _attend_verify(q: Array, keys: Array, vals: Array, posv: Array,
                   pol: ExecutionPolicy, window,
                   old_keys: Optional[Array] = None,
                   old_vals: Optional[Array] = None) -> Array:
    """K-candidate attend over a (B,S,Hkv,dh) view (see verify_attention).

    Shared mask/softmax/einsum half of the verify pass; per-query
    numerics are exactly :func:`_attend_decode` at that position, for
    both the dense and paged layouts.

    With ``old_keys``/``old_vals`` (the pre-write cache view) the cache
    is a **ring**: every candidate write landed at its wrapped slot, so
    a later candidate ``j`` has evicted absolute position
    ``pos + j - s_max`` — an entry that is still inside query ``i``'s
    window for ``j > i``.  Instead of masking those columns out, each
    query selects per-column between the old and new view (old where the
    column holds a strictly-later candidate's write), which restores
    exactly what plain decode attended to at that position.  The select
    happens on the gathered K/V (one fused einsum per call), so the FP
    contraction order over columns — and with it bit-exactness vs plain
    ring decode — is unchanged.
    """
    b, kq, hq, dh = q.shape
    s_max = keys.shape[1]
    hkv = keys.shape[2]
    g = hq // hkv
    offs = jnp.arange(kq, dtype=posv.dtype)
    wpos = posv[:, None] + offs[None, :]                  # (B,K) absolute
    qg = q.reshape(b, kq, hkv, g, dh)
    t = jnp.arange(s_max)
    age = jnp.mod(wpos[..., None] - t, s_max)             # (B,K,S); 0=self
    valid = age < jnp.minimum(wpos[..., None] + 1, s_max)
    in_window = age < window
    # this call's candidate columns: slot t holds candidate j = d when
    # d < K; query i must not see the *new* value of j > i
    d = jnp.mod(t[None, None, :] - posv[:, None, None], s_max)
    later = (d > offs[None, :, None]) & (d < kq)          # (B,K,S)
    if old_keys is not None:
        # ring mode: query i sees the pre-write (evicted) entry at a
        # later candidate's slot; the age mask decides whether that old
        # position was ever written at all
        sel = later[..., None, None]                      # (B,K,S,1,1)
        keys_q = jnp.where(sel, old_keys[:, None], keys[:, None])
        vals_q = jnp.where(sel, old_vals[:, None], vals[:, None])
        scores = jnp.einsum("bskgd,bstkd->bkgst", qg,
                            keys_q) / jnp.sqrt(float(dh))
        mask = valid & in_window
    else:
        # linear mode: a write landed only when pos + d < s_max (OOB
        # writes drop) — a dropped overflow write never shadows the old
        # entry that still lives at its wrapped index
        future = later & (posv[:, None, None] + d < s_max)
        scores = jnp.einsum("bskgd,btkd->bkgst", qg,
                            keys) / jnp.sqrt(float(dh))
        mask = valid & in_window & ~future
    mask = mask[:, None, None]                            # (B,1,1,K,S)
    scores = jnp.where(mask, scores.astype(jnp.float32), NEG_INF)
    probs = L.softmax(scores, pol).astype(q.dtype)
    if old_keys is not None:
        ctx = jnp.einsum("bkgst,bstkd->bskgd", probs, vals_q)
    else:
        ctx = jnp.einsum("bkgst,btkd->bskgd", probs, vals)
    return ctx.reshape(b, kq, hq, dh)


def decode_attention(q: Array, k_new: Array, v_new: Array, cache_k: Array,
                     cache_v: Array, pos: Array, cfg: ArchConfig,
                     pol: ExecutionPolicy, window,
                     scale_k: Optional[Array] = None,
                     scale_v: Optional[Array] = None):
    """q/k_new/v_new: (B,1,H*,dh); cache: (B,S,Hkv,dh) ring-written at pos.

    ``pos`` is the tokens-seen counter: a scalar (every row at the same
    position — the classic single-stream path) or a ``(B,)`` vector (the
    serving engine's per-slot positions, where each decode slot was
    prefilled at a different time and length).

    With ``scale_k``/``scale_v`` (B,S,Hkv,nb) the cache is the per-block
    int8 format of :mod:`repro.core.quant_cache`: each new K/V vector is
    quantized on write (its scale lands at the same ring slot) and the
    whole cache is dequantized on read.  Without them, an int8 cache is
    the legacy fixed-scale Q3.4 format (:data:`KV_Q_SCALE`).

    Returns (ctx (B,1,Hq,dh), cache_k, cache_v) — plus the updated
    (scale_k, scale_v) when per-block scales are in play.
    """
    b, _, hq, dh = q.shape
    s_max = cache_k.shape[1]
    slot = jnp.mod(pos, s_max)
    blocked = scale_k is not None
    if blocked:
        k_w, k_s = quantize_blocked(k_new)
        v_w, v_s = quantize_blocked(v_new)
    else:
        quant = cache_k.dtype == jnp.int8
        k_w = quantize_kv(k_new) if quant else k_new.astype(cache_k.dtype)
        v_w = quantize_kv(v_new) if quant else v_new.astype(cache_v.dtype)
    per_row = jnp.ndim(pos) == 1
    if per_row:
        # batched scatter: each row's new K/V lands at its own column
        # (a one-column write, not a full-cache select)
        rows = jnp.arange(b)
        cache_k = cache_k.at[rows, slot].set(k_w[:, 0])
        cache_v = cache_v.at[rows, slot].set(v_w[:, 0])
        if blocked:
            scale_k = scale_k.at[rows, slot].set(k_s[:, 0])
            scale_v = scale_v.at[rows, slot].set(v_s[:, 0])
    else:
        cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k_w, slot,
                                                      axis=1)
        cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v_w, slot,
                                                      axis=1)
        if blocked:
            scale_k = jax.lax.dynamic_update_slice_in_dim(scale_k, k_s,
                                                          slot, axis=1)
            scale_v = jax.lax.dynamic_update_slice_in_dim(scale_v, v_s,
                                                          slot, axis=1)
    keys = (dequantize_blocked(cache_k, scale_k, q.dtype) if blocked
            else dequantize_kv(cache_k, q.dtype))
    vals = (dequantize_blocked(cache_v, scale_v, q.dtype) if blocked
            else dequantize_kv(cache_v, q.dtype))
    ctx = _attend_decode(q, keys, vals, pos, pol, window)
    if blocked:
        return ctx, cache_k, cache_v, scale_k, scale_v
    return ctx, cache_k, cache_v


def verify_attention(q: Array, k_new: Array, v_new: Array, cache_k: Array,
                     cache_v: Array, pos: Array, cfg: ArchConfig,
                     pol: ExecutionPolicy, window,
                     scale_k: Optional[Array] = None,
                     scale_v: Optional[Array] = None,
                     ring: bool = False):
    """Speculative verify: K candidate positions scored in one pass.

    q/k_new/v_new: (B,K,H*,dh) — row b's candidates sit at absolute
    positions ``pos[b] .. pos[b]+K-1``.  All K K/V columns are written
    first, then every query is masked to its own committed history plus
    the *earlier* candidates of this call:

      * the age mask is the decode mask per candidate position,
      * ``ring=False`` (a cache at least ``max_seq`` long): the cache is
        treated as linear — writes past the cache end are dropped, and
        candidate columns ``j > i`` (this call's future writes) are
        explicitly invisible to query ``i`` even when the age mask
        saturates at a full cache, so a dropped overflow write never
        shadows the old entry that still lives at its wrapped index;
      * ``ring=True`` (a sliding-window ring shorter than the stream,
        e.g. the long_500k preset): every candidate write ring-wraps and
        lands, and query ``i`` reads the **pre-write** value at a later
        candidate's slot — the entry candidate ``j > i`` evicted is
        still inside query ``i``'s window, exactly as plain decode saw
        it.  The raw evicted columns are returned as an extra trailing
        tuple ``(ev_k, ev_v[, ev_sk, ev_sv])`` of shape (B,K,...) so the
        commit can restore the slots of rejected candidates.

    Per-query numerics are the plain :func:`decode_attention` ops at the
    same position, which is what keeps greedy spec decoding bit-identical
    to single-token decode.  With ``scale_k``/``scale_v`` the cache is the
    per-block int8 format (see :func:`decode_attention`): candidate scales
    land beside their values with the same drop/wrap semantics, so a
    rejected write's scale is just as invisible as its value until
    overwritten.  ``ring`` must be a static Python bool (it selects the
    traced program).  Callers guard ``K <= window`` in ring mode — a
    single call must not wrap onto its own writes.

    Returns (ctx (B,K,Hq,dh), cache_k, cache_v) — plus the updated
    (scale_k, scale_v) when per-block scales are in play, plus the
    evicted-column tuple as the last element in ring mode.
    """
    b, kq, hq, dh = q.shape
    s_max = cache_k.shape[1]
    posv = pos if jnp.ndim(pos) == 1 else jnp.broadcast_to(pos, (b,))
    offs = jnp.arange(kq, dtype=posv.dtype)
    wpos = posv[:, None] + offs[None, :]                  # (B,K) absolute
    blocked = scale_k is not None
    if blocked:
        k_w, k_s = quantize_blocked(k_new)
        v_w, v_s = quantize_blocked(v_new)
    else:
        quant = cache_k.dtype == jnp.int8
        k_w = quantize_kv(k_new) if quant else k_new.astype(cache_k.dtype)
        v_w = quantize_kv(v_new) if quant else v_new.astype(cache_v.dtype)
    rows = jnp.arange(b)[:, None]
    old_keys = old_vals = None
    evicted = ()
    if ring:
        # ring-cache write: every column wraps and lands; keep the
        # pre-write view so earlier queries can still read what a later
        # candidate evicted, and hand the raw evicted columns back so
        # :func:`~repro.models.transformer.spec_commit` can restore the
        # ones whose candidate the host rejects (a rejected wrapped
        # write would otherwise shadow live history)
        old_keys = (dequantize_blocked(cache_k, scale_k, q.dtype) if blocked
                    else dequantize_kv(cache_k, q.dtype))
        old_vals = (dequantize_blocked(cache_v, scale_v, q.dtype) if blocked
                    else dequantize_kv(cache_v, q.dtype))
        slots = jnp.mod(wpos, s_max)
        evicted = (cache_k[rows, slots], cache_v[rows, slots])
        if blocked:
            evicted += (scale_k[rows, slots], scale_v[rows, slots])
        cache_k = cache_k.at[rows, slots].set(k_w)
        cache_v = cache_v.at[rows, slots].set(v_w)
        if blocked:
            scale_k = scale_k.at[rows, slots].set(k_s)
            scale_v = scale_v.at[rows, slots].set(v_s)
    else:
        # linear-cache write: out-of-range columns drop (never wrap)
        cache_k = cache_k.at[rows, wpos].set(k_w, mode="drop")
        cache_v = cache_v.at[rows, wpos].set(v_w, mode="drop")
        if blocked:
            scale_k = scale_k.at[rows, wpos].set(k_s, mode="drop")
            scale_v = scale_v.at[rows, wpos].set(v_s, mode="drop")
    keys = (dequantize_blocked(cache_k, scale_k, q.dtype) if blocked
            else dequantize_kv(cache_k, q.dtype))
    vals = (dequantize_blocked(cache_v, scale_v, q.dtype) if blocked
            else dequantize_kv(cache_v, q.dtype))
    ctx = _attend_verify(q, keys, vals, posv, pol, window,
                         old_keys=old_keys, old_vals=old_vals)
    if ring:
        if blocked:
            return ctx, cache_k, cache_v, scale_k, scale_v, evicted
        return ctx, cache_k, cache_v, evicted
    if blocked:
        return ctx, cache_k, cache_v, scale_k, scale_v
    return ctx, cache_k, cache_v


# ---------------------------------------------------------------------------
# Paged decode/verify: pooled cache addressed through per-slot block tables
# ---------------------------------------------------------------------------
# Pool layout (per layer): (N, page, Hkv, dh); a (B, P) int32 block table
# maps logical page p of slot b to a pool block.  The sentinel value N
# marks an unallocated page: gathers through it clamp (jax gather
# semantics) into in-pool garbage the decode age mask already excludes,
# and writes through it drop — so the jitted program never needs to know
# which pages are live.  See models/paged.py for the invariants.

def paged_gather(pool: Array, table: Array) -> Array:
    """Logical (B, P*page, ...) view of a pooled cache via block tables."""
    b, p = table.shape
    g = pool[table]                              # (B, P, page, ...)
    return g.reshape((b, p * pool.shape[1]) + pool.shape[2:])


def _paged_write(pool: Array, idx: Array, new: Array) -> Array:
    """Scatter rows into a pool through flat token indices (drop OOB)."""
    n, page = pool.shape[:2]
    flat = pool.reshape((n * page,) + pool.shape[2:])
    flat = flat.at[idx].set(new, mode="drop")
    return flat.reshape(pool.shape)


def paged_decode_attention(q: Array, k_new: Array, v_new: Array,
                           pool_k: Array, pool_v: Array, table: Array,
                           pos: Array, cfg: ArchConfig,
                           pol: ExecutionPolicy, window,
                           scale_k: Optional[Array] = None,
                           scale_v: Optional[Array] = None):
    """:func:`decode_attention` over a pooled cache (see module note).

    The new K/V vector lands at flat pool index ``table[b, pos//page] *
    page + pos%page`` (drop through the sentinel / past logical
    capacity — the paged cache is linear, never ring-wrapped), then the
    pool is gathered back to the logical (B, S, Hkv, dh) view and the
    shared :func:`_attend_decode` half runs unchanged — which is what
    keeps paged decode bit-identical to the dense layout.
    """
    b = q.shape[0]
    n, page = pool_k.shape[:2]
    s_log = table.shape[1] * page
    posv = pos if jnp.ndim(pos) == 1 else jnp.broadcast_to(pos, (b,))
    blocked = scale_k is not None
    if blocked:
        k_w, k_s = quantize_blocked(k_new)
        v_w, v_s = quantize_blocked(v_new)
    else:
        k_w = k_new.astype(pool_k.dtype)
        v_w = v_new.astype(pool_v.dtype)
    rows = jnp.arange(b)
    blk = table[rows, jnp.minimum(posv // page, table.shape[1] - 1)]
    idx = blk * page + jnp.mod(posv, page)
    idx = jnp.where(posv < s_log, idx, n * page)          # linear: drop OOB
    pool_k = _paged_write(pool_k, idx, k_w[:, 0])
    pool_v = _paged_write(pool_v, idx, v_w[:, 0])
    if blocked:
        scale_k = _paged_write(scale_k, idx, k_s[:, 0])
        scale_v = _paged_write(scale_v, idx, v_s[:, 0])
        keys = dequantize_blocked(paged_gather(pool_k, table),
                                  paged_gather(scale_k, table), q.dtype)
        vals = dequantize_blocked(paged_gather(pool_v, table),
                                  paged_gather(scale_v, table), q.dtype)
    else:
        keys = dequantize_kv(paged_gather(pool_k, table), q.dtype)
        vals = dequantize_kv(paged_gather(pool_v, table), q.dtype)
    ctx = _attend_decode(q, keys, vals, posv, pol, window)
    if blocked:
        return ctx, pool_k, pool_v, scale_k, scale_v
    return ctx, pool_k, pool_v


def paged_verify_attention(q: Array, k_new: Array, v_new: Array,
                           pool_k: Array, pool_v: Array, table: Array,
                           pos: Array, cfg: ArchConfig,
                           pol: ExecutionPolicy, window,
                           scale_k: Optional[Array] = None,
                           scale_v: Optional[Array] = None):
    """:func:`verify_attention` over a pooled cache.

    All K candidate columns scatter through the block tables first
    (sentinel/OOB writes drop — unallocated pages are never touched, so
    speculative garbage can only ever land in a slot's private frontier
    pages, never in radix-shared blocks), then the shared
    :func:`_attend_verify` half runs on the gathered logical view.  This
    is both the spec-decode verify pass and the admission extend pass
    (positions ``pos .. pos+K-1`` scored in one shot; rows the host did
    not admit simply have no pages allocated past their frontier and
    roll back via ``spec_commit(advance=0)``).
    """
    b, kq = q.shape[:2]
    n, page = pool_k.shape[:2]
    s_log = table.shape[1] * page
    posv = pos if jnp.ndim(pos) == 1 else jnp.broadcast_to(pos, (b,))
    offs = jnp.arange(kq, dtype=posv.dtype)
    wpos = posv[:, None] + offs[None, :]                  # (B,K) absolute
    blocked = scale_k is not None
    if blocked:
        k_w, k_s = quantize_blocked(k_new)
        v_w, v_s = quantize_blocked(v_new)
    else:
        k_w = k_new.astype(pool_k.dtype)
        v_w = v_new.astype(pool_v.dtype)
    rows = jnp.arange(b)[:, None]
    blk = table[rows, jnp.minimum(wpos // page, table.shape[1] - 1)]
    idx = blk * page + jnp.mod(wpos, page)
    idx = jnp.where(wpos < s_log, idx, n * page)          # linear: drop OOB
    pool_k = _paged_write(pool_k, idx, k_w)
    pool_v = _paged_write(pool_v, idx, v_w)
    if blocked:
        scale_k = _paged_write(scale_k, idx, k_s)
        scale_v = _paged_write(scale_v, idx, v_s)
        keys = dequantize_blocked(paged_gather(pool_k, table),
                                  paged_gather(scale_k, table), q.dtype)
        vals = dequantize_blocked(paged_gather(pool_v, table),
                                  paged_gather(scale_v, table), q.dtype)
    else:
        keys = dequantize_kv(paged_gather(pool_k, table), q.dtype)
        vals = dequantize_kv(paged_gather(pool_v, table), q.dtype)
    ctx = _attend_verify(q, keys, vals, posv, pol, window)
    if blocked:
        return ctx, pool_k, pool_v, scale_k, scale_v
    return ctx, pool_k, pool_v
