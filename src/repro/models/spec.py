"""Parameter specification substrate.

Models declare parameters as :class:`P` leaves (shape + dtype + logical
axes).  From one declaration tree we derive:

  * ``materialize`` — real initialised arrays (smoke tests / real training),
  * ``abstract``    — ShapeDtypeStructs (the dry-run: zero allocation),
  * ``shardings``   — NamedShardings via the logical-axis rule engine in
                      :mod:`repro.parallel.sharding`.

Logical axis names used across the zoo:
  batch, seq          — activation dims
  embed               — d_model
  vocab               — vocabulary
  heads, kv_heads     — attention head dims
  qkv, head_dim       — projection output dims
  mlp                 — FFN hidden
  experts, expert_mlp — MoE dims
  layers              — stacked-layer leading dim (never sharded)
  state               — SSM state
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array
Axes = Tuple[Optional[str], ...]


@dataclasses.dataclass(frozen=True)
class P:
    """Declaration of one parameter."""

    shape: Tuple[int, ...]
    axes: Axes
    dtype: Any = jnp.float32
    init: str = "normal"         # normal | zeros | ones | scaled
    scale: float = 0.02

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_spec(x) -> bool:
    return isinstance(x, P)


def tree_map_specs(fn: Callable[[P], Any], tree):
    return jax.tree_util.tree_map(fn, tree, is_leaf=is_spec)


def abstract(tree):
    """ShapeDtypeStruct tree — feeds .lower() without touching devices."""
    return tree_map_specs(lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype), tree)


def axes_tree(tree):
    return tree_map_specs(lambda p: p.axes, tree)


def n_params(tree) -> int:
    return sum(int(np.prod(p.shape)) for p in jax.tree_util.tree_leaves(
        tree_map_specs(lambda p: p, tree)) if isinstance(p, P))


def materialize(tree, key: jax.Array):
    """Initialise real arrays (used by smoke tests and the train examples)."""
    leaves, treedef = jax.tree_util.tree_flatten(
        tree_map_specs(lambda p: p, tree), is_leaf=is_spec)
    keys = jax.random.split(key, max(len(leaves), 1))

    def init_one(p: P, k):
        if p.init == "zeros":
            return jnp.zeros(p.shape, p.dtype)
        if p.init == "ones":
            return jnp.ones(p.shape, p.dtype)
        if p.init == "scaled":  # fan-in scaled normal
            fan_in = p.shape[-2] if len(p.shape) >= 2 else p.shape[-1]
            return (jax.random.normal(k, p.shape) / np.sqrt(fan_in)).astype(p.dtype)
        return (jax.random.normal(k, p.shape) * p.scale).astype(p.dtype)

    return jax.tree_util.tree_unflatten(
        treedef, [init_one(p, k) for p, k in zip(leaves, keys)])
