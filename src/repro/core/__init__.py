"""The paper's primary contribution, in JAX.

Layers:
  fixed_point  - FxP quantization substrate (raw int32 words).
  cordic       - linear / hyperbolic / vectoring CORDIC recurrences.
  activations  - DA-VINCI runtime-configurable AF with STE gradients.
  rpe          - 5+2-stage Reconfigurable Processing Engine + cycle model.
  sycore       - output-stationary systolic array model + dataflow oracle.
  caesar       - scheduler: workload mapping, pruning/quant co-design,
                 adaptive VMEM tiling for the Pallas path.
  pareto       - stage-count/precision error sweeps (paper Figs 4-6).
  pruning      - 40% magnitude + N:M structured sparsity.
  quantization - FxP8 (int8) production matmul path with STE.
"""
from repro.core.activations import CordicPolicy, activate  # noqa: F401
from repro.core.fixed_point import FXP4, FXP8, FXP16, FXP32, FxpFormat  # noqa: F401
from repro.core.pruning import PruningPolicy  # noqa: F401
from repro.core.quantization import QuantPolicy  # noqa: F401
from repro.core.rpe import RPE  # noqa: F401
from repro.core.sycore import SYCoreConfig  # noqa: F401
from repro.core.caesar import Caesar  # noqa: F401
