"""Pruning / sparsity co-design (CAESAR's quantization+pruning benefits).

The paper reports a 40% magnitude-pruning rate with no per-layer accuracy
loss (§4.2) and cites "commercial 4:9" structured pruning giving 1.7x
latency reduction (§4.3).  We implement both:

  * unstructured global/per-tensor magnitude pruning at a target rate,
  * N:M structured pruning (keep N largest of every M contiguous weights
    along the reduction axis) — the hardware-friendly format the SYCore
    address-mapper consumes,

plus mask management for prune-then-fine-tune training (gradients masked so
pruned weights stay zero) and sparsity bookkeeping that the CAESAR cycle
model uses to discount compute.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class PruningPolicy:
    """Sparsity configuration consumed by CAESAR.

    ``rate`` — unstructured magnitude-pruning fraction (paper: 0.40).
    ``n``/``m`` — optional N:M structured pattern (paper cites 4:9).
    """

    rate: float = 0.40
    n: Optional[int] = None
    m: Optional[int] = None

    @property
    def structured(self) -> bool:
        return self.n is not None and self.m is not None

    @property
    def effective_density(self) -> float:
        if self.structured:
            return self.n / self.m
        return 1.0 - self.rate


def magnitude_mask(w: Array, rate: float) -> Array:
    """Boolean keep-mask pruning the smallest-|w| ``rate`` fraction."""
    if rate <= 0.0:
        return jnp.ones_like(w, dtype=bool)
    k = int(round(w.size * rate))
    if k >= w.size:
        return jnp.zeros_like(w, dtype=bool)
    flat = jnp.abs(w).reshape(-1)
    # threshold = k-th smallest magnitude; ties keep the later weight.
    thresh = jnp.sort(flat)[k - 1] if k > 0 else -jnp.inf
    return (jnp.abs(w) > thresh)


def nm_mask(w: Array, n: int, m: int, axis: int = -1) -> Array:
    """N:M structured keep-mask along ``axis`` (pad-safe).

    Every group of ``m`` consecutive weights keeps its ``n`` largest
    magnitudes — this is the sparse format the paper's address mapper turns
    into compressed indices.
    """
    axis = axis % w.ndim
    w_moved = jnp.moveaxis(w, axis, -1)
    lead = w_moved.shape[:-1]
    size = w_moved.shape[-1]
    pad = (-size) % m
    w_pad = jnp.pad(w_moved, [(0, 0)] * (len(lead)) + [(0, pad)])
    groups = w_pad.reshape(*lead, -1, m)
    # rank within each group; keep the n largest magnitudes.
    order = jnp.argsort(jnp.abs(groups), axis=-1)  # ascending
    ranks = jnp.argsort(order, axis=-1)
    keep = ranks >= (m - n)
    keep = keep.reshape(*lead, -1)[..., :size]
    return jnp.moveaxis(keep, -1, axis)


def apply_policy(w: Array, policy: PruningPolicy, axis: int = -1) -> Tuple[Array, Array]:
    """Return (pruned weights, keep mask)."""
    if policy.structured:
        mask = nm_mask(w, policy.n, policy.m, axis)
    else:
        mask = magnitude_mask(w, policy.rate)
    return w * mask, mask


def prune_tree(params, policy: PruningPolicy, min_size: int = 1024,
               axis: int = -1):
    """Prune every weight matrix in a pytree (leaves with >=2 dims and
    >= min_size elements; embeddings/norms/biases are left dense).

    Returns (pruned_params, masks) with masks matching the pytree structure
    (None for unpruned leaves).
    """
    def prune_leaf(w):
        if not hasattr(w, "ndim") or w.ndim < 2 or w.size < min_size:
            return w, None
        pw, mask = apply_policy(w, policy, axis)
        return pw, mask

    flat, treedef = jax.tree_util.tree_flatten(params)
    pruned, masks = zip(*[prune_leaf(w) for w in flat]) if flat else ((), ())
    return (jax.tree_util.tree_unflatten(treedef, list(pruned)),
            jax.tree_util.tree_unflatten(treedef, list(masks)))


def mask_grads(grads, masks):
    """Zero gradients of pruned weights so fine-tuning preserves sparsity."""
    def f(g, m):
        return g if m is None else g * m
    return jax.tree_util.tree_map(f, grads, masks,
                                  is_leaf=lambda x: x is None)


def sparsity_stats(params, masks) -> Dict[str, float]:
    total = 0
    kept = 0
    flat_w = jax.tree_util.tree_leaves(params)
    flat_m = jax.tree_util.tree_flatten(masks, is_leaf=lambda x: x is None)[0]
    for w, m in zip(flat_w, flat_m):
        if m is None:
            continue
        total += int(w.size)
        kept += int(jnp.sum(m))
    return {
        "prunable_params": total,
        "kept_params": kept,
        "sparsity": 0.0 if total == 0 else 1.0 - kept / total,
    }
