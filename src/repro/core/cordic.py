"""CORDIC compute modes (Table 2 of the paper), bit-accurate in JAX.

Implements the three RPE datapaths on raw int32 fixed-point words:

  * linear rotation    — shift-add multiply-accumulate (the MAC stage),
  * hyperbolic rotation — sinh/cosh (=> exp, tanh, sigmoid, GeLU, ...),
  * linear vectoring   — iterative division (softmax / sigmoid denominators).

Every function mirrors what the 5+2-stage RPE does in hardware: arithmetic
shifts, adds/subs driven by a sign bit, and pre-baked angle constants
(``E_i = 2^-i`` for the linear stage, ``atanh(2^-i)`` for the hyperbolic
stage).  The Pallas kernels in :mod:`repro.kernels` re-implement the same
recurrences on VMEM tiles and are validated bit-exactly against this module.

Iteration defaults follow the paper's Pareto conclusion: 5 pipelined linear
stages, 5 hyperbolic micro-iterations and 4 division micro-iterations
("nine clock cycles — five for hyperbolic functions and four for division").
"""
from __future__ import annotations

import functools
import math
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fixed_point as fxp
from repro.core.fixed_point import FxpFormat

Array = jax.Array

# Paper's Pareto-optimal stage counts (Section 2.2.2).
N_LINEAR_STAGES = 5
N_HYPERBOLIC_STAGES = 5
N_DIVISION_STAGES = 4

LN2 = math.log(2.0)


# ---------------------------------------------------------------------------
# Iteration schedules and gain constants
# ---------------------------------------------------------------------------

def hyperbolic_sequence(n: int) -> Tuple[int, ...]:
    """Shift schedule for hyperbolic CORDIC: 1,2,3,4,4,5,... (repeat 4,13,40).

    The repeats are required for convergence of the hyperbolic recurrence
    (standard Walther result); hardware bakes this into the stage wiring.
    """
    seq = []
    i = 1
    repeat_at = {4, 13, 40}
    while len(seq) < n:
        seq.append(i)
        if i in repeat_at and len(seq) < n:
            seq.append(i)
        i += 1
    return tuple(seq[:n])


@functools.lru_cache(maxsize=None)
def hyperbolic_gain(n: int) -> float:
    """K_h = prod sqrt(1 - 2^-2i) over the shift schedule (~0.8282 as n->inf)."""
    k = 1.0
    for i in hyperbolic_sequence(n):
        k *= math.sqrt(1.0 - 2.0 ** (-2 * i))
    return k


def hyperbolic_range(n: int) -> float:
    """Max |z| for which hyperbolic rotation converges (~1.1182)."""
    return sum(math.atanh(2.0 ** (-i)) for i in hyperbolic_sequence(n))


# ---------------------------------------------------------------------------
# Linear rotation mode: y <- y0 + x0 * z0  (the MAC datapath)
# ---------------------------------------------------------------------------

def linear_rotate_raw(x: Array, y: Array, z: Array, fmt: FxpFormat,
                      n: int = N_LINEAR_STAGES, unroll: bool = True
                      ) -> Tuple[Array, Array]:
    """Raw-int linear CORDIC rotation.

    Computes ``y + x * z`` where ``z`` is interpreted in ``fmt`` and must be
    inside the convergence range |z| < 2.  ``x``/``y`` may live in any common
    scale; the result keeps that scale.  Returns ``(y_n, z_residual)``.

    ``unroll=True`` mirrors the paper's 5-stage *pipelined* MAC (each stage
    has its own hard-wired ``2^-i``); ``unroll=False`` is the *iterative*
    area-saving variant (single stage re-used, Section 2.2.1).
    """
    x = x.astype(jnp.int32)
    y = y.astype(jnp.int32)
    z = z.astype(jnp.int32)

    # E_i = 2^-i in fmt; underflows to 0 once i > frac_bits, exactly as the
    # hardware constant would.
    e_tbl = [fxp.constant(2.0 ** (-i), fmt) for i in range(n)]

    if unroll:
        yi, zi = y, z
        for i in range(n):
            delta = jnp.where(zi >= 0, jnp.int32(1), jnp.int32(-1))
            yi = yi + delta * fxp.ashr(x, i)
            zi = zi - delta * jnp.int32(e_tbl[i])
        return yi, zi

    e_arr = jnp.asarray(e_tbl, jnp.int32)

    def body(i, carry):
        yi, zi = carry
        delta = jnp.where(zi >= 0, jnp.int32(1), jnp.int32(-1))
        yi = yi + delta * jnp.right_shift(x, i)
        zi = zi - delta * e_arr[i]
        return yi, zi

    return jax.lax.fori_loop(0, n, body, (y, z))


def mac(x: Array, w: Array, acc: Array, fmt: FxpFormat,
        n: int = N_LINEAR_STAGES, rounding: str = "rne") -> Array:
    """Real-valued CORDIC MAC: ``acc + x*w`` with the RPE's 5-stage multiply.

    ``w`` plays the CORDIC ``z`` role and must satisfy |w| < 2 after
    quantization (CAESAR's per-tensor scaling guarantees this for weights).
    """
    x_raw = fxp.quantize(x, fmt, rounding)
    w_raw = fxp.quantize(w, fmt, rounding)
    acc_raw = fxp.quantize(acc, fmt, rounding)
    y_raw, _ = linear_rotate_raw(x_raw, acc_raw, w_raw, fmt, n)
    return fxp.dequantize(y_raw, fmt)


def multiply(x: Array, w: Array, fmt: FxpFormat, n: int = N_LINEAR_STAGES) -> Array:
    return mac(x, w, jnp.zeros_like(jnp.asarray(x, jnp.float32)), fmt, n)


# ---------------------------------------------------------------------------
# Hyperbolic rotation mode: (cosh z, sinh z)
# ---------------------------------------------------------------------------

def hyperbolic_rotate_raw(z: Array, fmt: FxpFormat,
                          n: int = N_HYPERBOLIC_STAGES,
                          unroll: bool = False) -> Tuple[Array, Array]:
    """Raw-int hyperbolic rotation. |z| (in fmt) must be < hyperbolic_range(n).

    Seeds x0 = 1/K_h so the gain is pre-compensated (free in hardware: it is
    just the reset constant of the x register).  Returns (cosh_raw, sinh_raw).
    """
    z = z.astype(jnp.int32)
    inv_gain = fxp.constant(1.0 / hyperbolic_gain(n), fmt)
    x = jnp.full_like(z, inv_gain)
    y = jnp.zeros_like(z)
    seq = hyperbolic_sequence(n)

    def stage(shift: int, carry):
        xi, yi, zi = carry
        delta = jnp.where(zi >= 0, jnp.int32(1), jnp.int32(-1))
        e_i = jnp.int32(fxp.constant(math.atanh(2.0 ** (-shift)), fmt))
        x_new = xi + delta * fxp.ashr(yi, shift)
        y_new = yi + delta * fxp.ashr(xi, shift)
        z_new = zi - delta * e_i
        return x_new, y_new, z_new

    carry = (x, y, z)
    if unroll:
        for s in seq:
            carry = stage(s, carry)
    else:
        shifts = jnp.asarray(seq, jnp.int32)

        def body(i, c):
            xi, yi, zi = c
            shift = shifts[i]
            delta = jnp.where(zi >= 0, jnp.int32(1), jnp.int32(-1))
            atanh_tbl = jnp.asarray(
                [fxp.constant(math.atanh(2.0 ** (-s)), fmt) for s in seq], jnp.int32)
            e_i = atanh_tbl[i]
            return (xi + delta * fxp.ashr(yi, shift),
                    yi + delta * fxp.ashr(xi, shift),
                    zi - delta * e_i)

        carry = jax.lax.fori_loop(0, n, body, carry)
    xo, yo, _ = carry
    return xo, yo


def cosh_sinh(a: Array, fmt: FxpFormat, n: int = N_HYPERBOLIC_STAGES
              ) -> Tuple[Array, Array]:
    """Real-valued cosh/sinh with input clamped to the convergence range."""
    rng = hyperbolic_range(n)
    a_raw = fxp.quantize(jnp.clip(a, -rng, rng), fmt)
    c_raw, s_raw = hyperbolic_rotate_raw(a_raw, fmt, n)
    return fxp.dequantize(c_raw, fmt), fxp.dequantize(s_raw, fmt)


def exp_fxp(a: Array, fmt: FxpFormat, n: int = N_HYPERBOLIC_STAGES,
            range_extend: bool = True) -> Array:
    """e^a via cosh+sinh.

    ``range_extend=True`` applies a = k*ln2 + r and shifts the result by k —
    a barrel shift in hardware.  The paper's RPE assumes bounded AF inputs
    (|a| <= ~1.1); we extend the range for fidelity at LLM scales and note
    the adaptation in DESIGN.md.  With ``range_extend=False`` inputs are
    clamped to the native convergence range (paper-faithful behaviour).
    """
    a = jnp.asarray(a, jnp.float32)
    if not range_extend:
        c, s = cosh_sinh(a, fmt, n)
        return c + s
    k = jnp.round(a / LN2)
    r = a - k * LN2
    c, s = cosh_sinh(r, fmt, n)
    e_r = c + s
    # ldexp == barrel shift of the raw word.
    return e_r * jnp.exp2(k)


# ---------------------------------------------------------------------------
# Linear vectoring mode: z <- z0 + y0/x0  (the division datapath)
# ---------------------------------------------------------------------------

def divide_raw(y: Array, x: Array, fmt: FxpFormat,
               n: int = N_DIVISION_STAGES, extra_start: int = 0
               ) -> Array:
    """Raw-int quotient y/x (both in a common scale), result in ``fmt``.

    Convergence requires |y/x| < 2^(1+extra_start); iterations run
    i = -extra_start .. n-1.  x must be > 0 (callers normalise the sign).
    """
    y = y.astype(jnp.int32)
    x = x.astype(jnp.int32)
    q = jnp.zeros_like(y)

    def shl_or_shr(v, i):
        if i >= 0:
            return fxp.ashr(v, i)
        return jnp.left_shift(v, -i)

    for i in range(-extra_start, n):
        delta = jnp.where(y >= 0, jnp.int32(1), jnp.int32(-1))
        e_i = jnp.int32(fxp.constant(2.0 ** (-i), fmt))
        y = y - delta * shl_or_shr(x, i)
        q = q + delta * e_i
    return q


def divide(num: Array, den: Array, fmt: FxpFormat,
           n: int = N_DIVISION_STAGES, extra_start: int = 0) -> Array:
    """Real-valued CORDIC division with sign normalisation."""
    num = jnp.asarray(num, jnp.float32)
    den = jnp.asarray(den, jnp.float32)
    sign = jnp.sign(den)
    sign = jnp.where(sign == 0, 1.0, sign)
    num_raw = fxp.quantize(num * sign, fmt)
    den_raw = fxp.quantize(jnp.abs(den), fmt)
    q_raw = divide_raw(num_raw, den_raw, fmt, n, extra_start)
    return fxp.dequantize(q_raw, fmt)


# ---------------------------------------------------------------------------
# Circular mode (sin/cos) — completes the "CORDIC is all you need" triad.
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def circular_gain(n: int) -> float:
    k = 1.0
    for i in range(n):
        k *= math.sqrt(1.0 + 2.0 ** (-2 * i))
    return k


def cos_sin(a: Array, fmt: FxpFormat, n: int = N_HYPERBOLIC_STAGES
            ) -> Tuple[Array, Array]:
    """cos/sin via circular rotation mode, |a| <= ~1.74 rad native range."""
    a_raw = fxp.quantize(a, fmt).astype(jnp.int32)
    inv_gain = fxp.constant(1.0 / circular_gain(n), fmt)
    x = jnp.full_like(a_raw, inv_gain)
    y = jnp.zeros_like(a_raw)
    z = a_raw
    for i in range(n):
        delta = jnp.where(z >= 0, jnp.int32(1), jnp.int32(-1))
        e_i = jnp.int32(fxp.constant(math.atan(2.0 ** (-i)), fmt))
        x, y, z = (x - delta * fxp.ashr(y, i),
                   y + delta * fxp.ashr(x, i),
                   z - delta * e_i)
    return fxp.dequantize(x, fmt), fxp.dequantize(y, fmt)


# ---------------------------------------------------------------------------
# Hyperbolic vectoring mode: sqrt (the paper's "square roots and more", §1)
# ---------------------------------------------------------------------------

def sqrt_fxp(a: Array, fmt: FxpFormat, n: int = N_HYPERBOLIC_STAGES,
             range_extend: bool = True) -> Array:
    """sqrt(a) via hyperbolic vectoring of (a + 1/4, a - 1/4).

    Driving y -> 0 leaves x_n = K_h * sqrt(x0^2 - y0^2) = K_h * sqrt(a).
    Native convergence needs a in ~[0.03, 2); ``range_extend`` normalises
    a = m * 4^e with m in [0.25, 1) and barrel-shifts the result by e
    (exactly the paper's adaptive fixed-point scaling).
    """
    a = jnp.asarray(a, jnp.float32)
    a = jnp.maximum(a, 0.0)
    if range_extend:
        # a = m * 2^(2e); frexp-style normalisation to [0.25, 1)
        e2 = jnp.ceil(jnp.log2(jnp.maximum(a, 1e-30)) / 2.0)
        m = a / jnp.exp2(2.0 * e2)
        root_m = sqrt_fxp(m, fmt, n, range_extend=False)
        return jnp.where(a == 0.0, 0.0, root_m * jnp.exp2(e2))

    # guard bits against per-stage truncation bias (the paper's 2N+K
    # internal precision, as in the AF kernels)
    import dataclasses as _dc
    gfmt = _dc.replace(fmt, total_bits=min(fmt.total_bits + 12, 32),
                       frac_bits=min(fmt.frac_bits + 10, 24))
    x = fxp.quantize(a + 0.25, gfmt).astype(jnp.int32)
    y = fxp.quantize(a - 0.25, gfmt).astype(jnp.int32)
    seq = hyperbolic_sequence(n)
    for shift in seq:
        delta = jnp.where(y < 0, jnp.int32(1), jnp.int32(-1))
        x, y = (x + delta * fxp.ashr(y, shift),
                y + delta * fxp.ashr(x, shift))
    inv_gain = 1.0 / hyperbolic_gain(n)
    return fxp.dequantize(x, gfmt) * inv_gain


def rsqrt_fxp(a: Array, fmt: FxpFormat, n: int = N_HYPERBOLIC_STAGES,
              n_div: int = N_DIVISION_STAGES) -> Array:
    """1/sqrt(a): sqrt on the hyperbolic stage, then the division stage —
    the full RMSNorm denominator on the RPE datapath."""
    root = sqrt_fxp(a, fmt, n)
    # normalise the denominator to m in (0.5, 1] so the quotient 1/m stays
    # in the divider's [1, 2) range; undo with a barrel shift
    k = jnp.ceil(jnp.log2(jnp.maximum(root, 1e-30)))
    m = root * jnp.exp2(-k)
    inv_m = divide(jnp.ones_like(m), m, fmt, max(n_div, fmt.frac_bits))
    return inv_m * jnp.exp2(-k)


def ln_fxp(a: Array, fmt: FxpFormat, n: int = N_HYPERBOLIC_STAGES,
           range_extend: bool = True) -> Array:
    """ln(a) = 2*atanh((a-1)/(a+1)) via hyperbolic *vectoring* of
    (a+1, a-1): driving y -> 0 accumulates z = atanh(y0/x0).

    Native convergence needs a in ~[0.2, 5); ``range_extend`` uses
    a = m * 2^k => ln(a) = ln(m) + k*ln2 (barrel shift + one constant MAC,
    both RPE-native).  Completes the paper's "trigonometric, hyperbolic,
    and logarithmic functions" claim (§1).
    """
    a = jnp.asarray(a, jnp.float32)
    a = jnp.maximum(a, 1e-30)
    if range_extend:
        k = jnp.round(jnp.log2(a))
        m = a / jnp.exp2(k)          # in [~0.7, ~1.41]
        return ln_fxp(m, fmt, n, range_extend=False) + k * LN2

    import dataclasses as _dc
    gfmt = _dc.replace(fmt, total_bits=min(fmt.total_bits + 12, 32),
                       frac_bits=min(fmt.frac_bits + 10, 24))
    x = fxp.quantize(a + 1.0, gfmt).astype(jnp.int32)
    y = fxp.quantize(a - 1.0, gfmt).astype(jnp.int32)
    z = jnp.zeros_like(x)
    for shift in hyperbolic_sequence(n):
        e_i = jnp.int32(fxp.constant_raw(math.atanh(2.0 ** (-shift)),
                                         gfmt.frac_bits))
        delta = jnp.where(y < 0, jnp.int32(1), jnp.int32(-1))
        x, y, z = (x + delta * fxp.ashr(y, shift),
                   y + delta * fxp.ashr(x, shift),
                   z - delta * e_i)
    return 2.0 * fxp.dequantize(z, gfmt)
