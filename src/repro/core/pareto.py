"""Pareto analysis of CORDIC stage count vs error (paper Figs 4-6, §2.1.3).

Sweeps bit precision (4/8/16/32) x iteration count for each AF and for the
linear-mode MAC, reporting the paper's four error metrics (eqs 4-7):
MSE, MAE, average relative error, and STD.  The knee of these curves is what
justifies the 5+2 RPE configuration.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cordic, fixed_point as fxp
from repro.core.activations import CordicPolicy, activate

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ParetoPoint:
    fn: str
    bits: int
    iterations: int
    mse: float
    mae: float
    avg_rel_err: float
    std: float

    def row(self) -> str:
        return (f"{self.fn},{self.bits},{self.iterations},"
                f"{self.mse:.3e},{self.mae:.3e},{self.avg_rel_err:.3e},{self.std:.3e}")


def error_metrics(y: Array, x: Array) -> Dict[str, float]:
    """Paper eqs (4)-(7); x = expected (exact), y = fixed-point CORDIC."""
    y = np.asarray(y, np.float64)
    x = np.asarray(x, np.float64)
    diff = y - x
    denom = np.where(np.abs(x) < 1e-6, 1e-6, np.abs(x))
    return {
        "mse": float(np.mean(diff ** 2)),
        "mae": float(np.mean(np.abs(diff))),
        "avg_rel_err": float(np.mean(np.abs(diff) / denom)),
        "std": float(np.sum((x - np.mean(y)) ** 2) / max(x.size - 1, 1)),
    }


def _policy(fn: str, bits: int, iters: int) -> CordicPolicy:
    return CordicPolicy(bits=bits, n_linear=iters, n_hyperbolic=iters,
                        n_division=iters, range_extend=True)


def sweep_activation(fn: str, bits_list: Sequence[int] = (4, 8, 16, 32),
                     iterations: Sequence[int] = tuple(range(2, 17)),
                     n_samples: int = 2048, input_range: float = 4.0,
                     seed: int = 0) -> List[ParetoPoint]:
    """Error sweep for one AF across (bits x iterations)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.uniform(-input_range, input_range, (n_samples,)),
                    jnp.float32)
    if fn == "softmax":
        x = x.reshape(-1, 16)
    exact = activate(x, fn, None)
    out = []
    for bits in bits_list:
        for it in iterations:
            got = activate(x, fn, _policy(fn, bits, it))
            m = error_metrics(got, exact)
            out.append(ParetoPoint(fn, bits, it, **m))
    return out


def sweep_mac(bits_list: Sequence[int] = (8, 16, 32),
              iterations: Sequence[int] = tuple(range(2, 17)),
              n_samples: int = 4096, seed: int = 0) -> List[ParetoPoint]:
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.uniform(-2, 2, (n_samples,)), jnp.float32)
    w = jnp.asarray(rng.uniform(-1.9, 1.9, (n_samples,)), jnp.float32)
    b = jnp.asarray(rng.uniform(-1, 1, (n_samples,)), jnp.float32)
    exact = b + x * w
    out = []
    for bits in bits_list:
        fmt = fxp.format_for_bits(bits)
        for it in iterations:
            got = cordic.mac(x, w, b, fmt, n=it)
            m = error_metrics(got, exact)
            out.append(ParetoPoint("mac", bits, it, **m))
    return out


def knee(points: List[ParetoPoint], metric: str = "mae",
         rel_improvement: float = 0.10) -> Dict[int, int]:
    """Per bit-width: smallest iteration count after which the next
    iteration improves ``metric`` by less than ``rel_improvement`` — the
    paper's justification for stopping at 5 stages."""
    res: Dict[int, int] = {}
    by_bits: Dict[int, List[ParetoPoint]] = {}
    for p in points:
        by_bits.setdefault(p.bits, []).append(p)
    for bits, ps in by_bits.items():
        ps = sorted(ps, key=lambda p: p.iterations)
        chosen = ps[-1].iterations
        for a, b in zip(ps, ps[1:]):
            cur = getattr(a, metric)
            nxt = getattr(b, metric)
            if cur <= 0 or (cur - nxt) / cur < rel_improvement:
                chosen = a.iterations
                break
        res[bits] = chosen
    return res


def full_report(iterations: Sequence[int] = tuple(range(2, 13)),
                n_samples: int = 1024) -> Dict[str, List[ParetoPoint]]:
    report = {}
    for fn in ("tanh", "sigmoid", "softmax"):
        report[fn] = sweep_activation(fn, (4, 8, 16, 32), iterations, n_samples)
    report["mac"] = sweep_mac((8, 16, 32), iterations, n_samples)
    return report
