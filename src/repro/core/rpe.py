"""Reconfigurable Processing Engine (RPE) — the paper's 5+2 CORDIC neuron.

Functional view: one object that exposes the two neuron tasks (MAC and AF)
through a runtime-selectable CORDIC datapath, plus the cycle-accurate
throughput model used by the SYCore/CAESAR schedulers and the benchmark
harness (paper §2.2-2.3).

Cycle model (paper values):
  * MAC: 5-stage pipeline, initiation interval 1 (one MAC/cycle after a
    5-cycle fill).
  * tanh/sigmoid: 9 cycles — 5 hyperbolic + 4 division (§4.3).
  * SoftMax: 5 hyperbolic cycles per element (FIFO fill, sum accumulates
    for free) + 4 division cycles per element (§2.3).
  * ReLU: 1 cycle (FSM case 3 bypass).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp

from repro.core import cordic, fixed_point as fxp
from repro.core.activations import CordicPolicy, activate

MAC_PIPELINE_DEPTH = 5
HYPERBOLIC_CYCLES = 5
DIVISION_CYCLES = 4
RELU_CYCLES = 1
# First output of a 32x32 output-stationary pass (paper §3.2): array skew
# (2*32-1 diagonal waves) abstracted to the paper's quoted figure.
ARRAY_FILL_CYCLES = 45


@dataclasses.dataclass(frozen=True)
class RPE:
    """One neuron engine with a fixed policy (the CAESAR per-layer config)."""

    policy: CordicPolicy = CordicPolicy()

    # -- datapath ---------------------------------------------------------
    def mac(self, x, w, acc):
        return cordic.mac(x, w, acc, self.policy.fmt, self.policy.n_linear)

    def af(self, x, name: str, axis: int = -1):
        return activate(x, name, self.policy, axis=axis)

    # -- cycle model ------------------------------------------------------
    def mac_cycles(self, n_macs: int, pipelined: bool = True) -> int:
        """Cycles for n back-to-back MACs on one RPE."""
        if pipelined:
            return MAC_PIPELINE_DEPTH + max(n_macs - 1, 0)
        return self.policy.n_linear * n_macs  # iterative variant (§2.2.1)

    def af_cycles(self, name: str, n_elements: int = 1) -> int:
        if name == "relu":
            return RELU_CYCLES * n_elements
        if name == "softmax":
            return (HYPERBOLIC_CYCLES + DIVISION_CYCLES) * n_elements
        if name in ("tanh", "sigmoid", "exp", "selu"):
            return (HYPERBOLIC_CYCLES + DIVISION_CYCLES) * n_elements
        if name in ("gelu", "swish", "silu"):
            # hyperbolic + division + extra linear-stage multiply
            return (HYPERBOLIC_CYCLES + DIVISION_CYCLES + MAC_PIPELINE_DEPTH) * n_elements
        return n_elements

    def neuron(self, x, w, bias, af: str = "relu"):
        """Full neuron: dot(x, w) + bias -> AF, all on the CORDIC datapath.

        x: (..., k), w: (k,), bias scalar.  The accumulation loop mirrors the
        output-stationary PE: partial sums stay put, inputs/weights stream.
        """
        fmt = self.policy.fmt
        acc = jnp.broadcast_to(jnp.asarray(bias, jnp.float32), x.shape[:-1])
        for k in range(x.shape[-1]):
            acc = self.mac(x[..., k], w[k], acc)
        return self.af(acc, af)


def throughput_gops(freq_mhz: float, n_rpes: int, pipelined: bool = True,
                    n_linear: int = cordic.N_LINEAR_STAGES) -> float:
    """Peak MAC throughput (GOPS, counting 2 ops/MAC) of an RPE array."""
    macs_per_cycle = 1.0 if pipelined else 1.0 / n_linear
    return 2.0 * n_rpes * macs_per_cycle * freq_mhz * 1e6 / 1e9
