"""Per-block int8 quantization for serving caches (KV + recurrent state).

The serving stack's quantized cache mode (``ServeEngine(cache_dtype=
"int8")``) stores every cache family — attention K/V, the rwkv wkv state,
the mamba ssm state — as int8 values plus one float32 scale per trailing
block:

    scale = max(|x_block|) / 127        (0 for an all-zero block)
    q     = clip(round(x / scale), -127, 127)
    x̂     = q * scale

with the block axis being the tensor's *trailing channel axis* (head_dim
for K/V, the value channel for wkv, the ssm state width for mamba).  The
default block spans the whole trailing axis — one scale per written
vector, i.e. per (slot, position, kv-head) for the KV cache — which is
what makes the format serving-safe:

  * quantization is **per-vector independent and deterministic**, so
    quantize-then-scatter equals scatter-then-quantize and any
    permutation of slots/positions commutes with it (the invariants
    ``tests/test_quant_numerics.py`` fuzzes);
  * a decode step touches only its own written vector — O(block) extra
    work per write, no cross-position rescaling ever;
  * round-trip error is bounded by ``scale / 2`` per element, i.e.
    ``max|x_block| / 254``.

This is deliberately distinct from the legacy fixed-scale Q3.4 format
(``ArchConfig.kv_cache_bits == 8``, ``models/attention.py::KV_Q_SCALE``):
that path is the paper's FxP8 study and keeps its global scale; this one
is the serving-memory lever (per-block scales track the actual dynamic
range, so logit error stays bounded on real activations).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array

# Guard for all-zero blocks: scale 0 would divide by zero during
# quantization; clamping the divisor (not the stored scale) keeps the
# stored scale exactly 0 so dequantization returns exact zeros.
_TINY = 1e-30


def quantize_blocked(x: Array, block: Optional[int] = None
                     ) -> Tuple[Array, Array]:
    """Quantize along the trailing axis in blocks of ``block`` channels.

    Returns ``(values int8, scales float32)`` with ``values.shape ==
    x.shape`` and ``scales.shape == x.shape[:-1] + (d // block,)``.
    ``block=None`` uses the whole trailing axis (one scale per vector).
    """
    d = x.shape[-1]
    block = d if block is None else int(block)
    if block < 1 or d % block != 0:
        raise ValueError(f"block {block} must divide the trailing axis {d}")
    nb = d // block
    xb = x.astype(jnp.float32).reshape(x.shape[:-1] + (nb, block))
    scale = jnp.max(jnp.abs(xb), axis=-1) * (1.0 / 127.0)
    q = jnp.clip(jnp.round(xb / jnp.maximum(scale, _TINY)[..., None]),
                 -127.0, 127.0)
    return q.astype(jnp.int8).reshape(x.shape), scale


def dequantize_blocked(q: Array, scale: Array,
                       dtype=jnp.float32) -> Array:
    """Inverse of :func:`quantize_blocked`: ``q * scale`` per block.

    ``q`` int8 (..., d); ``scale`` float32 (..., d // block).  The block
    width is recovered from the shapes.
    """
    d = q.shape[-1]
    nb = scale.shape[-1]
    if nb < 1 or d % nb != 0:
        raise ValueError(f"scale blocks {nb} must divide trailing axis {d}")
    block = d // nb
    xb = (q.astype(jnp.float32).reshape(q.shape[:-1] + (nb, block))
          * scale[..., None].astype(jnp.float32))
    return xb.reshape(q.shape).astype(dtype)
