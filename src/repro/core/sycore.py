"""SYCore — output-stationary systolic array of RPEs (paper §3).

Two faces:

1. A cycle/energy model of the 32x32 (4x4 sub-blocked) array, reproducing
   the paper's Table 3 mapping of VGG-16/CIFAR-100 (op cycles, utilization,
   execution time, power) — consumed by CAESAR and the benchmark harness.

2. A functional JAX emulation of the output-stationary dataflow
   (``output_stationary_matmul``) that the Pallas kernel mirrors tile-for-
   tile on TPU; used in tests to pin the dataflow semantics.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.rpe import ARRAY_FILL_CYCLES, MAC_PIPELINE_DEPTH

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class SYCoreConfig:
    rows: int = 32
    cols: int = 32
    sub_block: int = 4          # 4x4 RPE sub-blocks, power-gated when idle
    freq_mhz: float = 100.0     # paper's reference operating point
    rpe_power_uw: float = 109.8  # Table 5 (28nm, proposed MAC)
    pipelined: bool = True

    @property
    def n_rpes(self) -> int:
        return self.rows * self.cols

    @property
    def n_sub_blocks(self) -> int:
        return (self.rows // self.sub_block) * (self.cols // self.sub_block)


@dataclasses.dataclass(frozen=True)
class LayerMapping:
    """One row of the paper's Table 3."""

    name: str
    macs: int                  # dense MAC count
    mapped: Tuple[int, int]    # (rows, cols) of the array actually used
    op_cycles: int
    utilization: float         # fraction of the 32x32 array active
    exec_time_us: float
    power_mw: float

    def row(self) -> str:
        return (f"{self.name},{self.macs},{self.mapped[0]}x{self.mapped[1]},"
                f"{self.op_cycles},{100*self.utilization:.1f},"
                f"{self.exec_time_us:.2f},{self.power_mw:.3f}")


def _sub_block_round(n: int, sub: int) -> int:
    """Active PEs are allocated in sub-block granularity."""
    return int(math.ceil(n / sub) * sub)


def map_conv(cfg: SYCoreConfig, name: str, k: int, c_in: int, c_out: int,
             h: int, w: int, density: float = 1.0) -> LayerMapping:
    """Output-stationary conv mapping (paper §3.3).

    Output pixels are pinned to PEs; each PE accumulates its K*K*C_in dot
    product, swept over C_out.  When the spatial extent H*W is smaller than
    the array, CAESAR replicates the tile across idle sub-blocks to process
    multiple output channels in parallel (the Table-3 "Op. cycles" column:
    e.g. C2_1 runs 73728 K-MACs in 18432 cycles = 4-way replication).
    """
    spatial = h * w
    rows = min(_sub_block_round(min(h, cfg.rows), cfg.sub_block), cfg.rows)
    cols = min(_sub_block_round(min(w, cfg.cols), cfg.sub_block), cfg.cols)
    tile_pes = min(spatial, rows * cols)
    replication = max(1, (cfg.n_rpes // max(tile_pes, 1)))
    replication = min(replication, c_out)
    active = tile_pes * replication
    macs_dense = k * k * c_in * c_out * spatial
    macs = int(macs_dense * density)
    # Per-PE sequential MACs: K*K*C_in per output channel, c_out/replication
    # channel sweeps, spatial tiled over the mapped region.
    spatial_passes = math.ceil(spatial / tile_pes)
    op_cycles = int(math.ceil(k * k * c_in * density)
                    * math.ceil(c_out / replication) * spatial_passes)
    total_cycles = op_cycles + ARRAY_FILL_CYCLES
    t_us = total_cycles / cfg.freq_mhz
    power_mw = active * cfg.rpe_power_uw * 1e-3
    return LayerMapping(name, macs, (min(h, rows), min(w, cols)), op_cycles,
                        active / cfg.n_rpes, t_us, power_mw)


def map_fc(cfg: SYCoreConfig, name: str, d_in: int, d_out: int,
           density: float = 1.0) -> LayerMapping:
    """Fully-connected mapping: output neurons pinned across the array."""
    active = min(cfg.n_rpes, _sub_block_round(d_out, cfg.sub_block))
    macs = int(d_in * d_out * density)
    op_cycles = int(math.ceil(d_out / active) * math.ceil(d_in * density))
    total_cycles = op_cycles + ARRAY_FILL_CYCLES
    t_us = total_cycles / cfg.freq_mhz
    power_mw = active * cfg.rpe_power_uw * 1e-3
    return LayerMapping(name, macs, (active // cfg.cols or 1, cfg.cols),
                        op_cycles, active / cfg.n_rpes, t_us, power_mw)


def map_gemm(cfg: SYCoreConfig, name: str, m: int, k: int, n: int,
             density: float = 1.0) -> LayerMapping:
    """Generic GEMM (transformer projections / attention scores)."""
    tile_m, tile_n = min(m, cfg.rows), min(n, cfg.cols)
    active = _sub_block_round(tile_m, cfg.sub_block) * _sub_block_round(
        tile_n, cfg.sub_block)
    active = min(active, cfg.n_rpes)
    macs = int(m * k * n * density)
    tiles = math.ceil(m / cfg.rows) * math.ceil(n / cfg.cols)
    op_cycles = int(tiles * math.ceil(k * density))
    t_us = (op_cycles + ARRAY_FILL_CYCLES) / cfg.freq_mhz
    power_mw = active * cfg.rpe_power_uw * 1e-3
    return LayerMapping(name, macs, (tile_m, tile_n), op_cycles,
                        active / cfg.n_rpes, t_us, power_mw)


# ---------------------------------------------------------------------------
# Functional output-stationary dataflow (tile semantics for the Pallas kernel)
# ---------------------------------------------------------------------------

def output_stationary_matmul(x: Array, w: Array,
                             tile: Tuple[int, int, int] = (32, 32, 32)
                             ) -> Array:
    """Tiled matmul with explicit output-stationary accumulation.

    Partial sums stay pinned per (i, j) output tile while K-slices of inputs
    and weights stream through — exactly the SYCore dataflow and exactly the
    grid/accumulation structure of ``kernels/cordic_mac``.  Pure jnp; used
    as a semantics oracle, not a fast path.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2
    bm, bn, bk = tile
    pm, pn, pk = (-m) % bm, (-n) % bn, (-k) % bk
    xp = jnp.pad(x, ((0, pm), (0, pk)))
    wp = jnp.pad(w, ((0, pk), (0, pn)))
    gm, gn, gk = xp.shape[0] // bm, wp.shape[1] // bn, xp.shape[1] // bk
    out = jnp.zeros((xp.shape[0], wp.shape[1]), jnp.float32)
    for i in range(gm):
        for j in range(gn):
            acc = jnp.zeros((bm, bn), jnp.float32)  # output-stationary tile
            for s in range(gk):
                xs = jax.lax.dynamic_slice(xp, (i * bm, s * bk), (bm, bk))
                ws = jax.lax.dynamic_slice(wp, (s * bk, j * bn), (bk, bn))
                acc = acc + xs @ ws
            out = jax.lax.dynamic_update_slice(out, acc, (i * bm, j * bn))
    return out[:m, :n]
