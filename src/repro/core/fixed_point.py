"""Fixed-point (FxP) arithmetic substrate for CORDIC emulation.

The paper's RPE operates on adaptive fixed-point data ("FxP8/16/32"):
a signed two's-complement integer with a static binary point.  We model a
value v as   v = raw * 2**-frac_bits   with raw stored in int32 (the
hardware accumulator width; the paper notes MAC output precision grows as
2N+K).  All CORDIC iterations below run on the raw integers with
arithmetic shifts, exactly as the shift-add hardware would, so the JAX
reference and the Pallas kernels are bit-exact replicas of each other.

Rounding modes follow the paper's Section 1.1 (truncation vs
round-to-nearest-even).
"""
from __future__ import annotations

import dataclasses
from typing import Union

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class FxpFormat:
    """Q-format descriptor: ``total_bits`` wide, ``frac_bits`` fractional."""

    total_bits: int
    frac_bits: int
    signed: bool = True

    def __post_init__(self):
        if self.total_bits > 32:
            raise ValueError("raw storage is int32; total_bits must be <= 32")
        if self.frac_bits >= self.total_bits:
            raise ValueError("frac_bits must leave at least one integer bit")

    @property
    def int_bits(self) -> int:
        return self.total_bits - self.frac_bits - (1 if self.signed else 0)

    @property
    def scale(self) -> float:
        return float(2 ** self.frac_bits)

    @property
    def resolution(self) -> float:
        return float(2.0 ** (-self.frac_bits))

    @property
    def raw_max(self) -> int:
        return (1 << (self.total_bits - 1)) - 1 if self.signed else (1 << self.total_bits) - 1

    @property
    def raw_min(self) -> int:
        return -(1 << (self.total_bits - 1)) if self.signed else 0

    @property
    def max_value(self) -> float:
        return self.raw_max * self.resolution

    @property
    def min_value(self) -> float:
        return self.raw_min * self.resolution

    def with_frac(self, frac_bits: int) -> "FxpFormat":
        return dataclasses.replace(self, frac_bits=frac_bits)


# The paper's three evaluated precisions (Figs 4-6 sweep 4/8/16/32 bits).
FXP4 = FxpFormat(4, 2)
FXP8 = FxpFormat(8, 4)
FXP16 = FxpFormat(16, 8)
FXP32 = FxpFormat(32, 16)

_BY_BITS = {4: FXP4, 8: FXP8, 16: FXP16, 32: FXP32}


def format_for_bits(bits: int) -> FxpFormat:
    return _BY_BITS[bits]


def quantize(x: Union[Array, float], fmt: FxpFormat, rounding: str = "rne") -> Array:
    """Real -> raw int32, saturating.  ``rounding``: 'rne' | 'trunc'."""
    x = jnp.asarray(x, jnp.float32) * fmt.scale
    if rounding == "rne":
        raw = jnp.round(x)  # jnp.round is round-half-to-even
    elif rounding == "trunc":
        raw = jnp.floor(x)
    else:
        raise ValueError(f"unknown rounding mode {rounding!r}")
    raw = jnp.clip(raw, fmt.raw_min, fmt.raw_max)
    return raw.astype(jnp.int32)


def dequantize(raw: Array, fmt: FxpFormat) -> Array:
    return raw.astype(jnp.float32) * fmt.resolution


def saturate(raw: Array, fmt: FxpFormat) -> Array:
    """Clamp a wide accumulator back into the format's representable range."""
    return jnp.clip(raw, fmt.raw_min, fmt.raw_max).astype(jnp.int32)


def ashr(raw: Array, shift) -> Array:
    """Arithmetic shift right — the hardware's 2**-i (truncation toward -inf)."""
    return jnp.right_shift(raw, shift)


def constant(value: float, fmt: FxpFormat) -> int:
    """Python-level quantized constant (for angle/LUT tables baked at trace time)."""
    raw = int(np.round(value * fmt.scale))
    return int(np.clip(raw, fmt.raw_min, fmt.raw_max))


def constant_raw(value: float, frac_bits: int) -> int:
    """Unclamped constant at an arbitrary internal precision (guard bits)."""
    return int(np.round(value * 2.0 ** frac_bits))


def roundtrip(x: Array, fmt: FxpFormat, rounding: str = "rne") -> Array:
    """Quantize-dequantize: the value the hardware actually sees."""
    return dequantize(quantize(x, fmt, rounding), fmt)
