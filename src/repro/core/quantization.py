"""Quantized (FxP8) matmul path — the CORDIC MAC at production scale.

Hardware adaptation (see DESIGN.md): the paper's linear-mode CORDIC MAC is
an n-stage shift-add fixed-point multiplier.  On TPU the MXU already *is* a
systolic array with a native int8 x int8 -> int32 path, so the faithful
production mapping of "CORDIC(5) FxP8 MAC" is a symmetric int8 quantized
matmul whose precision is governed by the same Pareto analysis: 5 linear
stages resolve ~5 fractional bits, i.e. int8 with a power-of-two scale.

Bit-exact shift-add emulation lives in :mod:`repro.kernels.cordic_mac` and
is what we validate against; this module provides the scaled-int8 execution
path used inside the large-model layers, with straight-through gradients for
quantization-aware training (how the paper recovers pruning/quantization
accuracy, §4.2).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class QuantPolicy:
    """Per-layer quantization policy scheduled by CAESAR."""

    bits: int = 8
    per_channel: bool = True        # per-output-channel weight scales
    pow2_scale: bool = True         # power-of-two scales (pure barrel shift,
                                    # exactly what the RPE's shifter provides)
    act_bits: Optional[int] = 8     # None => activations stay bf16 (W8A16)

    @property
    def qmax(self) -> int:
        return (1 << (self.bits - 1)) - 1

    @property
    def act_qmax(self) -> int:
        assert self.act_bits is not None
        return (1 << (self.act_bits - 1)) - 1


def _round_scale_pow2(scale: Array) -> Array:
    return jnp.exp2(jnp.ceil(jnp.log2(jnp.maximum(scale, 1e-12))))


def quantize_weight(w: Array, policy: QuantPolicy, axis: int = -1
                    ) -> Tuple[Array, Array]:
    """Symmetric weight quantization -> (int8 raw, float scale).

    ``axis`` is the output-channel axis kept un-reduced by the matmul.
    """
    if policy.per_channel:
        reduce_axes = tuple(i for i in range(w.ndim) if i != axis % w.ndim)
        amax = jnp.max(jnp.abs(w), axis=reduce_axes, keepdims=True)
    else:
        amax = jnp.max(jnp.abs(w))
    scale = amax / policy.qmax
    if policy.pow2_scale:
        scale = _round_scale_pow2(scale)
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(w / scale), -policy.qmax, policy.qmax)
    return q.astype(jnp.int8), scale.astype(jnp.float32)


def quantize_act(x: Array, policy: QuantPolicy) -> Tuple[Array, Array]:
    """Dynamic per-tensor symmetric activation quantization."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax / policy.act_qmax, 1e-12)
    if policy.pow2_scale:
        scale = _round_scale_pow2(scale)
    q = jnp.clip(jnp.round(x / scale), -policy.act_qmax, policy.act_qmax)
    return q.astype(jnp.int8), scale.astype(jnp.float32)


def fake_quant(x: Array, policy: QuantPolicy) -> Array:
    """STE quantize-dequantize (QAT view of the tensor)."""

    @jax.custom_vjp
    def f(v):
        q, s = quantize_act(v, policy)
        return q.astype(jnp.float32) * s

    def fwd(v):
        return f(v), None

    def bwd(_, g):
        return (g,)

    f.defvjp(fwd, bwd)
    return f(x)


def int8_matmul(x_q: Array, w_q: Array, x_scale: Array, w_scale: Array,
                ) -> Array:
    """int8 x int8 -> int32 -> rescale.  Hits the MXU int8 path on TPU."""
    acc = jax.lax.dot_general(
        x_q, w_q,
        dimension_numbers=(((x_q.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)
    return acc.astype(jnp.float32) * x_scale * jnp.squeeze(w_scale)


def quantized_dense(x: Array, w: Array, policy: Optional[QuantPolicy]
                    ) -> Array:
    """Dense layer with the CORDIC-FxP8 execution path + STE backward.

    policy None  -> plain bf16/f32 matmul (baseline).
    act_bits None-> weight-only quantization (W8A16).
    else         -> W8A8 int8 matmul.
    """
    if policy is None:
        return x @ w

    @jax.custom_vjp
    def f(x_, w_):
        w_q, w_s = quantize_weight(w_, policy, axis=-1)
        if policy.act_bits is None:
            return x_ @ (w_q.astype(x_.dtype) * w_s.astype(x_.dtype))
        x_q, x_s = quantize_act(x_, policy)
        return int8_matmul(x_q, w_q, x_s, w_s).astype(x_.dtype)

    def fwd(x_, w_):
        return f(x_, w_), (x_, w_)

    def bwd(res, g):
        x_, w_ = res
        g2 = g.reshape(-1, g.shape[-1])
        x2 = x_.reshape(-1, x_.shape[-1])
        dx = (g @ w_.T).reshape(x_.shape)
        dw = x2.T @ g2
        return dx.astype(x_.dtype), dw.astype(w_.dtype)

    f.defvjp(fwd, bwd)
    return f(x, w)
