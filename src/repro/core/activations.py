"""DA-VINCI: Dynamically-configurable Activation functions via CORDIC.

One shared CORDIC datapath (hyperbolic rotation + linear vectoring + linear
rotation) realises every AF the paper lists — tanh, sigmoid, SoftMax, ReLU,
GeLU, SeLU, Swish — selected at runtime by ``sel_af`` (here: a string in the
:class:`CordicPolicy`).  The hyperbolic stage is shared across 6/7 functions
(the paper's "86% reuse factor"); division across 5/7 ("72%").

Gradients: the fixed-point CORDIC forward is a step function, so for
training we expose every AF through a straight-through estimator (STE): the
forward pass is the bit-accurate CORDIC value, the backward pass is the
analytic derivative of the exact function.  This is the standard
quantization-aware-training contract and matches how the paper fine-tunes
pruned/quantized models to recover accuracy (Section 4.2).
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import cordic, fixed_point as fxp
from repro.core.fixed_point import FxpFormat

Array = jax.Array

SUPPORTED_AFS = ("relu", "tanh", "sigmoid", "softmax", "gelu", "selu", "swish",
                 "silu", "exp", "identity")

_SELU_ALPHA = 1.6732632423543772
_SELU_LAMBDA = 1.0507009873554805
_GELU_C = math.sqrt(2.0 / math.pi)


@dataclasses.dataclass(frozen=True)
class CordicPolicy:
    """Runtime-reconfigurable RPE datapath configuration (the ``sel_*`` pins).

    ``n_linear/n_hyperbolic/n_division`` mirror the paper's 5+2 architecture
    defaults; ``bits`` selects FxP4/8/16/32; ``range_extend`` is our TPU-side
    fidelity adaptation (barrel-shift exponent scaling, see DESIGN.md).
    """

    bits: int = 16
    n_linear: int = cordic.N_LINEAR_STAGES
    n_hyperbolic: int = cordic.N_HYPERBOLIC_STAGES
    n_division: int = cordic.N_DIVISION_STAGES
    range_extend: bool = True
    rounding: str = "rne"

    @property
    def fmt(self) -> FxpFormat:
        return fxp.format_for_bits(self.bits)


DEFAULT_POLICY = CordicPolicy()
PAPER_FAITHFUL_POLICY = CordicPolicy(bits=8, range_extend=False)


# ---------------------------------------------------------------------------
# Raw (non-differentiable) CORDIC forward implementations
# ---------------------------------------------------------------------------

def _tanh_fwd(x: Array, p: CordicPolicy) -> Array:
    # tanh(a) = sinh(a)/cosh(a); for |a| beyond the hyperbolic range use
    # tanh(a) = (e^{2a}-1)/(e^{2a}+1) with the range-extended exp, computed
    # on the always-negative branch a = -|x| so e^{2a} stays in (0, 1].
    fmt = p.fmt
    if p.range_extend:
        e2a = cordic.exp_fxp(-2.0 * jnp.abs(x), fmt, p.n_hyperbolic, True)
        t_neg = cordic.divide(e2a - 1.0, e2a + 1.0, fmt,
                              max(p.n_division, fmt.frac_bits))
        return jnp.where(x >= 0, -t_neg, t_neg)
    c, s = cordic.cosh_sinh(x, fmt, p.n_hyperbolic)
    return cordic.divide(s, c, fmt, max(p.n_division, fmt.frac_bits))


def _sigmoid_fwd(x: Array, p: CordicPolicy) -> Array:
    # Paper eq (1c): sigmoid = 1/(1+e^-x) — hyperbolic stage then division
    # stage.  e^{-|x|} <= 1 keeps every intermediate in range; the positive
    # branch uses sigmoid(x) = 1 - sigmoid(-x).
    fmt = p.fmt
    e = cordic.exp_fxp(-jnp.abs(x), fmt, p.n_hyperbolic, p.range_extend)
    s = cordic.divide(jnp.ones_like(e), 1.0 + e, fmt,
                      max(p.n_division, fmt.frac_bits))
    return jnp.where(x >= 0, s, 1.0 - s)


def _exp_fwd(x: Array, p: CordicPolicy) -> Array:
    return cordic.exp_fxp(x, p.fmt, p.n_hyperbolic, p.range_extend)


def _softmax_fwd(x: Array, p: CordicPolicy, axis: int = -1) -> Array:
    # RPE flow: exponentials stream through the hyperbolic stage into the
    # FIFO while the running sum accumulates, then the division stage
    # normalises each entry (Section 2.3).  Max-subtraction keeps e^a in
    # (0, 1] so the fixed-point FIFO cannot overflow; the divider runs at
    # guarded precision (the paper's 2N+K overhead bits) with zero-skip
    # gating for underflowed exponentials.
    fmt = p.fmt
    m = jax.lax.stop_gradient(jnp.max(x, axis=axis, keepdims=True))
    e = cordic.exp_fxp(x - m, fmt, p.n_hyperbolic, p.range_extend)
    e = fxp.roundtrip(e, fmt)  # the FIFO stores fmt-width words
    tot = jnp.sum(e, axis=axis, keepdims=True)
    gfmt = dataclasses.replace(
        fmt, total_bits=min(fmt.total_bits + 8, 32),
        frac_bits=min(fmt.frac_bits + 4, 20))
    # Normalise the denominator into [1, 2) with a barrel shift so the
    # divider converges: q = (e >> k) / (tot >> k).
    k = jnp.ceil(jnp.log2(jnp.maximum(tot, 1e-30)))
    scale = jnp.exp2(k)
    q = cordic.divide(e / scale, tot / scale, gfmt,
                      max(p.n_division, gfmt.frac_bits))
    return jnp.where(e == 0.0, 0.0, q)


def _gelu_fwd(x: Array, p: CordicPolicy) -> Array:
    # tanh-form GeLU; the two extra multiplies run on the linear stage.
    fmt = p.fmt
    x_q = fxp.roundtrip(x, fmt, p.rounding)
    inner = _GELU_C * (x_q + 0.044715 * x_q * x_q * x_q)
    t = _tanh_fwd(inner, p)
    return 0.5 * x_q * (1.0 + t)


def _selu_fwd(x: Array, p: CordicPolicy) -> Array:
    e = cordic.exp_fxp(jnp.minimum(x, 0.0), p.fmt, p.n_hyperbolic, p.range_extend)
    neg = _SELU_ALPHA * (e - 1.0)
    return _SELU_LAMBDA * jnp.where(x > 0, fxp.roundtrip(x, p.fmt), neg)


def _swish_fwd(x: Array, p: CordicPolicy) -> Array:
    return fxp.roundtrip(x, p.fmt) * _sigmoid_fwd(x, p)


def _relu_fwd(x: Array, p: CordicPolicy) -> Array:
    # Single-cycle bypass (FSM case 3): just the sign mux + quantizer.
    return jnp.maximum(fxp.roundtrip(x, p.fmt, p.rounding), 0.0)


# ---------------------------------------------------------------------------
# Straight-through wrappers
# ---------------------------------------------------------------------------

def _ste(fwd_fn, exact_fn):
    @jax.custom_vjp
    def f(x):
        return fwd_fn(x)

    def f_fwd(x):
        return fwd_fn(x), x

    def f_bwd(x, g):
        out, vjp = jax.vjp(exact_fn, x)
        return vjp(g.astype(out.dtype))

    f.defvjp(f_fwd, f_bwd)
    return f


def _exact(name: str, axis: int = -1):
    return {
        "relu": jax.nn.relu,
        "tanh": jnp.tanh,
        "sigmoid": jax.nn.sigmoid,
        "softmax": partial(jax.nn.softmax, axis=axis),
        "gelu": partial(jax.nn.gelu, approximate=True),
        "selu": jax.nn.selu,
        "swish": jax.nn.silu,
        "silu": jax.nn.silu,
        "exp": jnp.exp,
        "identity": lambda x: x,
    }[name]


def activate(x: Array, name: str, policy: Optional[CordicPolicy] = None,
             axis: int = -1) -> Array:
    """Apply activation ``name``.

    ``policy=None`` selects the exact float implementation (the bf16
    baseline); otherwise the bit-accurate CORDIC forward with STE gradients.
    """
    if name not in SUPPORTED_AFS:
        raise ValueError(f"unsupported AF {name!r}; choose from {SUPPORTED_AFS}")
    if policy is None:
        return _exact(name, axis)(x)
    p = policy
    fwd = {
        "relu": partial(_relu_fwd, p=p),
        "tanh": partial(_tanh_fwd, p=p),
        "sigmoid": partial(_sigmoid_fwd, p=p),
        "softmax": partial(_softmax_fwd, p=p, axis=axis),
        "gelu": partial(_gelu_fwd, p=p),
        "selu": partial(_selu_fwd, p=p),
        "swish": partial(_swish_fwd, p=p),
        "silu": partial(_swish_fwd, p=p),
        "exp": partial(_exp_fwd, p=p),
        "identity": lambda x: fxp.roundtrip(x, p.fmt, p.rounding),
    }[name]
    return _ste(fwd, _exact(name, axis))(x)


def reuse_report() -> dict:
    """Which RPE stage each AF exercises (the paper's reuse-factor table)."""
    hyp = {"tanh", "sigmoid", "softmax", "gelu", "selu", "swish", "silu", "exp"}
    div = {"tanh", "sigmoid", "softmax", "gelu", "swish", "silu"}
    afs = [a for a in SUPPORTED_AFS if a not in ("identity",)]
    return {
        "hyperbolic_reuse": len(hyp & set(afs)) / len(afs),
        "division_reuse": len(div & set(afs)) / len(afs),
        "afs": afs,
    }
