"""CAESAR — Configurable and Adaptive Execution Scheduler (paper §3.2-3.3).

Three responsibilities, mirrored from the paper's control engine:

1. **Workload scheduling**: map a network's layer list onto the SYCore
   array, applying the quantization/pruning co-design discounts, and emit
   the per-layer cycle/utilization/time/power table (reproduces Table 3).

2. **Adaptive tiling for the TPU path**: choose Pallas block shapes that
   fit the VMEM budget with MXU-aligned (multiple-of-128) dims — the
   TPU-native analogue of choosing SYCore sub-block allocations.

3. **Precision/pruning policy book-keeping** for each layer (which the
   model layers consume via ``CordicPolicy``/``QuantPolicy``).
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence, Tuple

from repro.core.pruning import PruningPolicy
from repro.core.quantization import QuantPolicy
from repro.core.sycore import (LayerMapping, SYCoreConfig, map_conv, map_fc,
                               map_gemm)


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One schedulable layer.  kind: conv | fc | gemm | pool."""

    name: str
    kind: str
    # conv: (k, c_in, c_out, h, w); fc: (d_in, d_out); gemm: (m, k, n)
    dims: Tuple[int, ...]

    @property
    def macs(self) -> int:
        if self.kind == "conv":
            k, ci, co, h, w = self.dims
            return k * k * ci * co * h * w
        if self.kind == "fc":
            di, do = self.dims
            return di * do
        if self.kind == "gemm":
            m, k, n = self.dims
            return m * k * n
        return 0


@dataclasses.dataclass(frozen=True)
class Schedule:
    layers: Tuple[LayerMapping, ...]
    total_time_us: float
    total_energy_mj: float
    mean_utilization: float

    @property
    def frames_per_joule(self) -> float:
        return 1e3 / self.total_energy_mj if self.total_energy_mj else 0.0

    def csv(self) -> str:
        hdr = "layer,macs,mapped,op_cycles,util_pct,time_us,power_mw"
        return "\n".join([hdr] + [l.row() for l in self.layers])


class Caesar:
    """The control engine: owns array config + co-design policies."""

    def __init__(self, array: SYCoreConfig = SYCoreConfig(),
                 pruning: Optional[PruningPolicy] = PruningPolicy(rate=0.40),
                 quant: Optional[QuantPolicy] = QuantPolicy(bits=8)):
        self.array = array
        self.pruning = pruning
        self.quant = quant

    @property
    def density(self) -> float:
        return self.pruning.effective_density if self.pruning else 1.0

    def schedule(self, layers: Sequence[LayerSpec]) -> Schedule:
        mapped: List[LayerMapping] = []
        for spec in layers:
            if spec.kind == "conv":
                k, ci, co, h, w = spec.dims
                mapped.append(map_conv(self.array, spec.name, k, ci, co, h, w,
                                       self.density))
            elif spec.kind == "fc":
                di, do = spec.dims
                mapped.append(map_fc(self.array, spec.name, di, do,
                                     self.density))
            elif spec.kind == "gemm":
                m, k, n = spec.dims
                mapped.append(map_gemm(self.array, spec.name, m, k, n,
                                       self.density))
            elif spec.kind == "pool":
                continue  # pooling runs on the RISC-V host (paper §3.3)
            else:
                raise ValueError(f"unknown layer kind {spec.kind!r}")
        total_t = sum(l.exec_time_us for l in mapped)
        energy_mj = sum(l.exec_time_us * 1e-6 * l.power_mw for l in mapped)
        util = (sum(l.utilization for l in mapped) / len(mapped)) if mapped else 0.0
        return Schedule(tuple(mapped), total_t, energy_mj, util)


# ---------------------------------------------------------------------------
# Reference workloads
# ---------------------------------------------------------------------------

def vgg16_cifar100() -> List[LayerSpec]:
    """The paper's Table 3 workload (VGG-16 on 32x32 CIFAR-100 inputs)."""
    cfg = [
        ("C1_1", 3, 3, 64, 32, 32), ("C1_2", 3, 64, 64, 32, 32),
        ("C2_1", 3, 64, 128, 16, 16), ("C2_2", 3, 128, 128, 16, 16),
        ("C3_1", 3, 128, 256, 8, 8), ("C3_2", 3, 256, 256, 8, 8),
        ("C3_3", 3, 256, 256, 8, 8),
        ("C4_1", 3, 256, 512, 4, 4), ("C4_2", 3, 512, 512, 4, 4),
        ("C4_3", 3, 512, 512, 4, 4),
        ("C5_1", 3, 512, 512, 2, 2), ("C5_2", 3, 512, 512, 2, 2),
        ("C5_3", 3, 512, 512, 2, 2),
    ]
    layers = [LayerSpec(n, "conv", (k, ci, co, h, w)) for n, k, ci, co, h, w in cfg]
    layers += [LayerSpec("FC6", "fc", (512, 4096)),
               LayerSpec("FC7", "fc", (4096, 4096)),
               LayerSpec("FC8", "fc", (4096, 100))]
    return layers


def transformer_block_specs(name: str, seq: int, d_model: int, n_heads: int,
                            d_ff: int, n_kv_heads: Optional[int] = None
                            ) -> List[LayerSpec]:
    """Decompose one transformer block into SYCore GEMMs (paper Fig 1b)."""
    n_kv = n_kv_heads or n_heads
    d_head = d_model // n_heads
    return [
        LayerSpec(f"{name}.q", "gemm", (seq, d_model, d_model)),
        LayerSpec(f"{name}.kv", "gemm", (seq, d_model, 2 * n_kv * d_head)),
        LayerSpec(f"{name}.scores", "gemm", (seq, d_head, seq)),
        LayerSpec(f"{name}.ctx", "gemm", (seq, seq, d_head)),
        LayerSpec(f"{name}.o", "gemm", (seq, d_model, d_model)),
        LayerSpec(f"{name}.ffn_in", "gemm", (seq, d_model, d_ff)),
        LayerSpec(f"{name}.ffn_out", "gemm", (seq, d_ff, d_model)),
    ]


# ---------------------------------------------------------------------------
# Adaptive tiling for the TPU execution path
# ---------------------------------------------------------------------------

VMEM_BYTES = 16 * 1024 * 1024          # v5e VMEM per core
MXU_ALIGN = 128                         # MXU systolic dimension


def pick_block_shape(m: int, n: int, k: int, bytes_per_el: int = 2,
                     vmem_budget: float = 0.60,
                     max_block: int = 512) -> Tuple[int, int, int]:
    """Choose (bm, bn, bk) for an output-stationary Pallas matmul.

    Constraints (the CAESAR sub-block allocation problem, restated for VMEM):
      * all dims multiples of 128 (MXU-aligned) unless the problem is smaller,
      * x-tile + w-tile + out-tile (+int32 acc) fit in ``vmem_budget*VMEM``,
      * prefer large bk (amortise the output-stationary accumulate loop),
        then square-ish bm/bn (maximise reuse per byte streamed).
    """
    def align(v: int) -> int:
        if v >= MXU_ALIGN:
            return (v // MXU_ALIGN) * MXU_ALIGN
        # small problems: round up to the sublane tile (8) at least
        return max(8, 1 << (v - 1).bit_length())

    budget = VMEM_BYTES * vmem_budget
    bm = min(align(m), max_block)
    bn = min(align(n), max_block)
    bk = min(align(k), max_block)

    def footprint(bm, bn, bk):
        return (bm * bk + bk * bn) * bytes_per_el + bm * bn * 4

    # shrink in the order bk -> bm -> bn until we fit
    order = ["bk", "bm", "bn"]
    vals = {"bm": bm, "bn": bn, "bk": bk}
    idx = 0
    while footprint(vals["bm"], vals["bn"], vals["bk"]) > budget:
        key = order[idx % 3]
        if vals[key] > MXU_ALIGN:
            vals[key] //= 2
        idx += 1
        if idx > 64:
            break
    return vals["bm"], vals["bn"], vals["bk"]
