"""Fault-tolerance runtime: heartbeats, straggler mitigation, elastic
re-meshing.

On a real cluster these hooks wrap jax.distributed + the platform's health
APIs; the logic (detection thresholds, quorum decisions, re-mesh planning)
is host-side Python and is exactly what runs here.  The pieces:

  * :class:`HeartbeatMonitor` — per-worker liveness with configurable
    timeout; reports dead/slow workers.
  * :class:`StragglerDetector` — EWMA of per-step durations; a worker (or
    the local step itself) is a straggler when it exceeds ``factor`` x the
    fleet median.  Mitigation hook returns an action: "rebalance" (shrink
    that worker's microbatch share), or "evict" (treat as failed).
  * :func:`plan_elastic_remesh` — given a failed-chip count, choose the
    largest (data, model) mesh that fits the survivors while preserving the
    model-axis size (TP degree must not change — weights are sharded over
    it); batch re-shards over the shrunk data axis.
  * :class:`TrainSupervisor` — the restart loop: run steps, on failure
    restore the latest atomic checkpoint onto the new mesh (checkpoints
    store logical arrays, so re-sharding is free — see checkpoint/manager).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Tuple


class WorkerKilled(RuntimeError):
    """A worker died mid-run (real preemption or an injected fault).

    Raised by the serve loop's fault-injection hook
    (``ServeConfig.kill_at_step``) and caught by supervisors
    (:class:`TrainSupervisor`, ``runtime/supervisor.ServeSupervisor``) —
    anything else propagating it is a genuine crash.
    """


@dataclasses.dataclass
class WorkerState:
    last_beat: float
    step_ewma: float = 0.0
    alive: bool = True


class HeartbeatMonitor:
    def __init__(self, workers: List[str], timeout_s: float = 60.0,
                 clock: Callable[[], float] = time.monotonic):
        self._clock = clock
        self.timeout = timeout_s
        self.workers: Dict[str, WorkerState] = {
            w: WorkerState(last_beat=clock()) for w in workers}

    def beat(self, worker: str, now: Optional[float] = None):
        self.workers[worker].last_beat = (now if now is not None
                                          else self._clock())
        self.workers[worker].alive = True

    def add_worker(self, worker: str, now: Optional[float] = None):
        """Register a worker spawned after construction (a respawn gets a
        fresh beat — it is not born dead from its predecessor's silence)."""
        self.workers[worker] = WorkerState(
            last_beat=now if now is not None else self._clock())

    def mark_dead(self, worker: str):
        """Record an externally-confirmed death (e.g. a caught
        :class:`WorkerKilled`) without waiting out the timeout."""
        st = self.workers.get(worker)
        if st is not None:
            st.alive = False
            st.last_beat = float("-inf")

    def dead_workers(self, now: Optional[float] = None) -> List[str]:
        now = now if now is not None else self._clock()
        dead = []
        for name, st in self.workers.items():
            if now - st.last_beat > self.timeout:
                st.alive = False
                dead.append(name)
        return dead

    @property
    def alive_count(self) -> int:
        return sum(1 for s in self.workers.values() if s.alive)


class StragglerDetector:
    """EWMA step-duration tracking with median-relative thresholding."""

    def __init__(self, factor: float = 1.5, alpha: float = 0.2):
        self.factor = factor
        self.alpha = alpha
        self.ewma: Dict[str, float] = {}

    def record(self, worker: str, duration_s: float):
        prev = self.ewma.get(worker, duration_s)
        self.ewma[worker] = (1 - self.alpha) * prev + self.alpha * duration_s

    def _median(self) -> float:
        vals = sorted(self.ewma.values())
        if not vals:
            return 0.0
        mid = len(vals) // 2
        return (vals[mid] if len(vals) % 2 else
                0.5 * (vals[mid - 1] + vals[mid]))

    def stragglers(self) -> List[Tuple[str, float]]:
        med = self._median()
        if med <= 0:
            return []
        return [(w, v / med) for w, v in self.ewma.items()
                if v > self.factor * med]

    def mitigation(self, worker: str) -> str:
        """Policy: mild straggle -> rebalance its share; severe -> evict."""
        med = self._median()
        ratio = self.ewma.get(worker, med) / max(med, 1e-9)
        if ratio > 3.0:
            return "evict"
        if ratio > self.factor:
            return "rebalance"
        return "none"


def plan_elastic_remesh(n_alive_chips: int, model_parallel: int,
                        pod_size: Optional[int] = None
                        ) -> Tuple[int, int]:
    """Largest (data, model) mesh fitting the survivors.

    The model axis is pinned (weight shards must keep their TP degree); the
    data axis shrinks to the largest multiple that fits, optionally rounded
    to whole pods.  Returns (data, model).
    """
    if n_alive_chips < model_parallel:
        raise RuntimeError(
            f"cannot keep tp={model_parallel} with {n_alive_chips} chips")
    data = n_alive_chips // model_parallel
    if pod_size:
        chips = data * model_parallel
        full_pods = chips // pod_size
        if full_pods >= 1:
            data = (full_pods * pod_size) // model_parallel
    return max(data, 1), model_parallel


class TrainSupervisor:
    """Restart loop: run -> detect failure -> restore -> resume.

    ``run_fn(start_step, mesh_shape) -> (end_step, failure|None)`` executes
    training until completion or a simulated/real fault;
    ``restore_fn(mesh_shape) -> step`` restores the latest checkpoint onto
    the (possibly shrunk) mesh.
    """

    def __init__(self, run_fn, restore_fn, initial_mesh: Tuple[int, int],
                 max_restarts: int = 10):
        self.run_fn = run_fn
        self.restore_fn = restore_fn
        self.mesh = initial_mesh
        self.max_restarts = max_restarts
        self.history: List[Dict] = []

    def run(self, total_steps: int) -> int:
        step = 0
        restarts = 0
        while step < total_steps:
            step, failure = self.run_fn(step, self.mesh, total_steps)
            if failure is None:
                break
            restarts += 1
            if restarts > self.max_restarts:
                raise RuntimeError("restart budget exhausted")
            if failure.get("lost_chips"):
                alive = failure["alive_chips"]
                self.mesh = plan_elastic_remesh(alive, self.mesh[1])
            step = self.restore_fn(self.mesh)
            self.history.append({"restart": restarts, "resumed_at": step,
                                 "mesh": self.mesh, **failure})
        return step
