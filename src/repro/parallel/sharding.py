"""Logical-axis -> mesh-axis rule engine (DP / TP / EP / SP).

Rules map each logical axis name to an ordered list of candidate mesh-axis
tuples; the first candidate whose total size divides the dimension wins
(e.g. 40 experts cannot shard over model=16, so granite falls back to
sharding each expert's FFN instead).  This keeps every config compilable on
every mesh without per-arch hand-tuning — CAESAR's "adaptive resource
allocation" applied to the TPU mesh.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.models import spec as pspec

MeshAxes = Tuple[str, ...]

# Candidates per logical axis, in preference order.  () = replicate.
DEFAULT_RULES: Dict[str, List[MeshAxes]] = {
    # data parallel over pod x data (global batch divides across both)
    "batch": [("pod", "data"), ("data",), ()],
    # sequence parallelism for long-context activations
    "seq": [("model",), ()],
    "embed": [()],                       # keep d_model whole on activations
    "embed_w": [("data",), ()],          # FSDP-style weight shard (opt-in)
    "vocab": [("model",), ()],
    "heads": [("model",), ()],
    "kv_heads": [("model",), ()],        # falls back to replicate when kv < tp
    "head_dim": [()],
    "qkv": [("model",), ()],
    "mlp": [("model",), ()],
    "experts": [("model",), ()],
    # 2D expert sharding: when "experts" already took the model axis
    # (arctic: 128 % 16 == 0) the per-expert FFN dim shards over data so
    # the 469B expert slab spreads over all 256/512 chips; when experts
    # can't shard (granite: 40 % 16 != 0) this falls back to model.
    "expert_mlp": [("model",), ("data", "pod"), ("data",), ()],
    "state": [()],
    "layers": [()],
    "codebooks": [()],
    # serving mesh (runtime/mesh_serve.py): the slot batch axis of the
    # engine's decode state, and the paged engine's shared block pool.
    # Both fall back to replicate when the dim doesn't divide the mesh
    # (e.g. an odd num_blocks pool on 8 shards serves replicated rather
    # than refusing).
    "slots": [("data",), ()],
    "blocks": [("data",), ()],
    None: [()],
}


# Context-scoped rule override (sharding profiles, e.g. the pure-DP
# profile for small MoEs — see EXPERIMENTS.md #Perf).
import contextlib
import threading

_ACTIVE = threading.local()


@contextlib.contextmanager
def use_rules(rules: Dict[str, List[MeshAxes]]):
    prev = getattr(_ACTIVE, "rules", None)
    _ACTIVE.rules = rules
    try:
        yield
    finally:
        _ACTIVE.rules = prev


def active_rules() -> Dict[str, List[MeshAxes]]:
    return getattr(_ACTIVE, "rules", None) or DEFAULT_RULES


# Pure data parallelism: batch over every axis, weights replicated.  The
# right profile when a model is too small for tp=16 (granite's 1.5k d_model
# at tp=16 is collective-bound 8:1 — see EXPERIMENTS.md #Perf).
PURE_DP_RULES: Dict[str, List[MeshAxes]] = {
    "batch": [("pod", "data", "model"), ("data", "model"), ("data",), ()],
    None: [()],
}

# ZeRO-1-style optimizer-moment sharding to pair with PURE_DP_RULES:
# params replicate, but Adam moments spread over the whole mesh.
ZERO1_OPT_RULES: Dict[str, List[MeshAxes]] = {
    "embed": [("model",), ("data",), ()],
    "mlp": [("data",), ("model",), ()],
    "expert_mlp": [("data",), ()],
    "heads": [("data",), ("model",), ()],
    "kv_heads": [("data",), ()],
    "qkv": [("data",), ()],
    "vocab": [("model",), ()],
    "experts": [()],
    "layers": [()],
    None: [()],
}


def _axis_size(mesh: Mesh, axes: MeshAxes) -> int:
    s = 1
    for a in axes:
        s *= mesh.shape[a]
    return s


def spec_for(shape: Sequence[int], axes: Sequence[Optional[str]], mesh: Mesh,
             rules: Optional[Dict[str, List[MeshAxes]]] = None
             ) -> PartitionSpec:
    """Resolve one tensor's PartitionSpec; never assigns a mesh axis twice."""
    rules = rules or active_rules()
    used: set = set()
    entries = []
    for dim, name in zip(shape, axes):
        chosen: Optional[MeshAxes] = ()
        for cand in rules.get(name, [()]):
            if not all(a in mesh.shape for a in cand):
                continue
            if any(a in used for a in cand):
                continue
            if cand and dim % _axis_size(mesh, cand) != 0:
                continue
            chosen = cand
            break
        for a in chosen:
            used.add(a)
        if not chosen:
            entries.append(None)
        elif len(chosen) == 1:
            entries.append(chosen[0])
        else:
            entries.append(tuple(chosen))
    # trim trailing Nones (canonical form)
    while entries and entries[-1] is None:
        entries.pop()
    return PartitionSpec(*entries)


def tree_shardings(param_tree, mesh: Mesh,
                   rules: Optional[Dict[str, List[MeshAxes]]] = None):
    """NamedSharding tree for a P-spec tree (or abstract tree + axes tree)."""
    def one(p: pspec.P):
        return NamedSharding(mesh, spec_for(p.shape, p.axes, mesh, rules))
    return pspec.tree_map_specs(one, param_tree)


def tree_pspecs(param_tree, mesh: Mesh,
                rules: Optional[Dict[str, List[MeshAxes]]] = None):
    def one(p: pspec.P):
        return spec_for(p.shape, p.axes, mesh, rules)
    return pspec.tree_map_specs(one, param_tree)


def constrain(x: jax.Array, axes: Sequence[Optional[str]],
              rules: Optional[Dict[str, List[MeshAxes]]] = None) -> jax.Array:
    """Activation sharding constraint by logical axes (no-op outside a mesh)."""
    mesh = get_abstract_mesh()
    if mesh is None or mesh.empty:
        return x
    ps = spec_for(x.shape, axes, mesh, rules)
    return jax.lax.with_sharding_constraint(x, ps)


def get_abstract_mesh():
    try:
        m = jax.sharding.get_abstract_mesh()
        if m is not None and not m.empty:
            return m
    except Exception:
        pass
    try:
        from jax._src import mesh as mesh_lib
        return mesh_lib.thread_resources.env.physical_mesh
    except Exception:
        return None


def data_sharding(mesh: Mesh, *, batch_axes: MeshAxes = ("pod", "data")
                  ) -> NamedSharding:
    """Input-batch sharding: batch over every available DP axis."""
    axes = tuple(a for a in batch_axes if a in mesh.shape)
    return NamedSharding(mesh, PartitionSpec(axes if len(axes) > 1 else
                                             (axes[0] if axes else None)))


# -- serving slot state ------------------------------------------------------

# Leaves of DecodeState / PagedDecodeState whose *second* axis is the
# shared block pool rather than the slot batch (paged mode only — the
# recurrent leaves stay per-slot even in a paged state).
_POOL_LEAVES = ("cache_k", "cache_v", "scale_k", "scale_v")


def slot_leaf_axes(name: str, ndim: int, pooled: bool
                   ) -> Tuple[Optional[str], ...]:
    """Logical axes of one serving slot-state leaf.

    Every dense leaf is ``(L, B, ...)`` — layers leading, slot batch
    second; ``pos`` is ``(B,)`` and the paged ``block_tables`` are
    ``(B, P)``.  In a pooled (paged) state the K/V + scale leaves are
    ``(L, N_blocks, page, ...)`` and shard over the pool axis instead.
    """
    if name == "pos":
        return ("slots",) + (None,) * (ndim - 1)
    if name == "block_tables":
        return ("slots",) + (None,) * (ndim - 1)
    if pooled and name in _POOL_LEAVES:
        return ("layers", "blocks") + (None,) * (ndim - 2)
    return ("layers", "slots") + (None,) * (ndim - 2)


def slot_state_shardings(state, mesh: Mesh,
                         rules: Optional[Dict[str, List[MeshAxes]]] = None):
    """Per-leaf :class:`NamedSharding` for an engine slot state.

    ``state`` is a ``DecodeState`` / ``PagedDecodeState`` (concrete or
    abstract — only ``.shape``/``.ndim`` are read); returns the same
    namedtuple type with a sharding per populated leaf and ``None`` where
    the leaf is ``None``.  Divisibility fallback comes from the rule
    engine: a leaf whose slot (or pool) dim doesn't divide the mesh's
    data axis replicates instead of failing.
    """
    pooled = getattr(state, "block_tables", None) is not None
    out = {}
    for name in state._fields:
        leaf = getattr(state, name)
        if leaf is None:
            out[name] = None
            continue
        axes = slot_leaf_axes(name, leaf.ndim, pooled)
        out[name] = NamedSharding(mesh,
                                  spec_for(leaf.shape, axes, mesh, rules))
    return type(state)(**out)
