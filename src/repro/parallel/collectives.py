"""Distributed-optimization primitives: gradient compression and explicit
communication schedules.

  * **Error-feedback int8 gradient compression** — gradients compress to
    int8 (per-row absmax scales) before the data-parallel reduction;
    rounding residuals carry to the next step (EF-SGD), preserving
    convergence while cutting DP all-reduce bytes 2x vs bf16.
  * **Hierarchical pod all-reduce** — reduce-scatter intra-pod, all-reduce
    the 1/16-size shards across pods, all-gather intra-pod: inter-pod bytes
    drop by the intra-pod fan-in vs a flat all-reduce (the multi-pod mesh's
    thin axis).
  * **Ring all-reduce via ppermute** — the explicit 2(n-1)-step schedule,
    written out so chunks can interleave with other work (§Perf overlap
    experiment); numerically identical to psum.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as PS

from repro.compat import shard_map

Array = jax.Array


# ---------------------------------------------------------------------------
# Error-feedback int8 gradient compression
# ---------------------------------------------------------------------------

def compress_grad(g: Array) -> Tuple[Array, Array]:
    """g (fp) -> (int8 payload, fp32 per-row scale)."""
    g32 = g.astype(jnp.float32)
    if g.ndim == 0:
        scale = jnp.maximum(jnp.abs(g32) / 127.0, 1e-20)
        return jnp.round(g32 / scale).astype(jnp.int8), scale
    amax = jnp.max(jnp.abs(g32), axis=-1, keepdims=True)
    scale = jnp.maximum(amax / 127.0, 1e-20)
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_grad(q: Array, scale: Array, dtype=jnp.float32) -> Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compress_tree(grads, residuals):
    """Error-feedback compression over a pytree.

    Returns (tree of (q, scale) pairs, new residual tree).  The residual —
    what int8 rounding lost — is added back before the next compression,
    keeping the long-run gradient estimate unbiased (EF-SGD).
    """
    def one(g, r):
        g32 = g.astype(jnp.float32) + (r if r is not None else 0.0)
        q, s = compress_grad(g32)
        back = decompress_grad(q, s)
        return (q, s), g32 - back

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = (treedef.flatten_up_to(residuals) if residuals is not None
              else [None] * len(flat_g))
    pairs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    comp = jax.tree_util.tree_unflatten(treedef, [p[0] for p in pairs])
    res = jax.tree_util.tree_unflatten(treedef, [p[1] for p in pairs])
    return comp, res


def decompress_tree(comp, dtype=jnp.float32):
    is_pair = lambda x: (isinstance(x, tuple) and len(x) == 2
                         and hasattr(x[0], "dtype"))
    return jax.tree_util.tree_map(
        lambda qs: decompress_grad(qs[0], qs[1], dtype), comp,
        is_leaf=is_pair)


def init_residuals(grads):
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads)


# ---------------------------------------------------------------------------
# Explicit collective schedules
# ---------------------------------------------------------------------------

def hierarchical_allreduce(x: Array, mesh: Mesh, *, pod_axis: str = "pod",
                           data_axis: str = "data") -> Array:
    """x: (n_pod, n_data, *leaf) per-device contributions; returns the same
    shape where every slice holds the global sum.

    Schedule: psum_scatter intra-pod -> psum across pods on 1/n_data shards
    -> all-gather intra-pod.  Inter-pod traffic = leaf_bytes / n_data.
    """
    if pod_axis not in mesh.shape:
        def f1(xs):
            return jax.lax.psum(xs[0], data_axis)[None]
        return shard_map(f1, mesh=mesh, in_specs=PS(data_axis),
                         out_specs=PS(data_axis), check_vma=False)(x)

    def f(xs):
        v = xs[0, 0]                                    # this device's grad
        scattered = jax.lax.psum_scatter(v, data_axis, scatter_dimension=0,
                                         tiled=True)    # intra-pod RS
        reduced = jax.lax.psum(scattered, pod_axis)     # thin inter-pod hop
        full = jax.lax.all_gather(reduced, data_axis, axis=0,
                                  tiled=True)           # intra-pod AG
        return full[None, None]

    return shard_map(f, mesh=mesh, in_specs=PS(pod_axis, data_axis),
                     out_specs=PS(pod_axis, data_axis), check_vma=False)(x)


def per_shard_sums(x: Array, mesh: Mesh, axis: str = "data",
                   weights=None) -> Array:
    """Per-shard sums of a slot-batch leaf, all-gathered everywhere.

    ``x``: ``(B, ...)`` sharded (or shardable) over ``axis``; returns an
    ``(n_shards,)`` float32 vector where entry *s* is the sum of shard
    *s*'s rows — the serving mesh's balance telemetry (live tokens per
    shard) computed with one tiny all-gather instead of pulling the whole
    leaf to the host.  ``weights`` optionally masks rows first (e.g. a
    ``(B,)`` live-slot indicator), letting retired slots' stale ``pos``
    drop out of the sum.
    """
    def f(xs, ws):
        local = jnp.sum(xs.astype(jnp.float32) * ws.astype(jnp.float32))
        return jax.lax.all_gather(local, axis)

    if weights is None:
        weights = jnp.ones((x.shape[0],), jnp.float32)
    flat = x.reshape(x.shape[0], -1).sum(axis=-1)   # (B,) row totals
    return shard_map(f, mesh=mesh, in_specs=(PS(axis), PS(axis)),
                     out_specs=PS(), check_vma=False)(flat, weights)


def ring_allreduce(x: Array, mesh: Mesh, axis: str = "data") -> Array:
    """x: (n, *leaf) per-device contributions -> (n, *leaf) of global sums.

    Explicit 2(n-1)-step ring: reduce-scatter then all-gather, one chunk in
    flight per step (the overlap-friendly schedule).
    """
    n = mesh.shape[axis]
    perm = [(i, (i + 1) % n) for i in range(n)]

    def f(xs):
        v = xs[0]                                   # (*leaf)
        leaf_shape = v.shape
        chunks = v.reshape(n, -1)                   # n ring chunks
        idx = jax.lax.axis_index(axis)

        # reduce-scatter: after n-1 steps we own chunk (idx+1) % n
        buf = jnp.take(chunks, idx % n, axis=0)
        for s in range(n - 1):
            buf = jax.lax.ppermute(buf, axis, perm)
            j = (idx - s - 1) % n
            buf = buf + jnp.take(chunks, j, axis=0)

        # all-gather: circulate the owned chunk around the ring
        out = jnp.zeros_like(chunks)
        out = out.at[(idx + 1) % n].set(buf)
        cur = buf
        for s in range(n - 1):
            cur = jax.lax.ppermute(cur, axis, perm)
            out = out.at[(idx - s) % n].set(cur)
        return out.reshape(leaf_shape)[None]

    return shard_map(f, mesh=mesh, in_specs=PS(axis),
                     out_specs=PS(axis), check_vma=False)(x)
