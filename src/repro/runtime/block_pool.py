"""Host-side block accounting for the paged slot cache.

Two small pure-python pieces the :class:`~repro.runtime.serve_loop.
ServeEngine` drives between jitted steps:

:class:`BlockAllocator`
    Refcounted free list over the ``num_blocks`` pool blocks of
    :func:`repro.models.paged.init_paged_slot_state`.  A block is held
    once per slot whose table references it plus once by the radix cache
    if a trie node owns it; it returns to the free list when the count
    hits zero.  ``assert_balanced()`` is the leak oracle the tests pin
    across retire/refill and spec rollback.

:class:`RadixCache`
    A page-granular prefix trie over prompt tokens.  Each node is one
    *full* page (``page_size`` tokens) that some admitted prompt
    prefilled; it owns a cache reference on its pool block and, for
    recurrent families, an exact-f32 host snapshot of the recurrent
    state at the page boundary.  Admissions walk the trie and reference
    the matched blocks directly in the new slot's table — the prefix is
    never recomputed and never copied (shared pages sit strictly behind
    every reader's write frontier, so they are immutable by
    construction; there is nothing to copy-on-write).  Nodes are evicted
    LRU-leaf-first when the allocator runs dry.

Nothing here touches jax: tables are host numpy, passed to the jitted
programs as plain arguments each step.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np


class BlockAllocator:
    """Refcounted free-list allocator over pool block ids ``0..n-1``."""

    def __init__(self, num_blocks: int):
        if num_blocks < 1:
            raise ValueError(f"num_blocks must be >= 1, got {num_blocks}")
        self.num_blocks = num_blocks
        self._refs = np.zeros(num_blocks, np.int32)
        self._free: List[int] = list(range(num_blocks - 1, -1, -1))

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        return self.num_blocks - len(self._free)

    def alloc(self) -> Optional[int]:
        """Take a free block at refcount 1, or None when dry."""
        if not self._free:
            return None
        blk = self._free.pop()
        self._refs[blk] = 1
        return blk

    def ref(self, blk: int) -> None:
        """Add a reference to a live block (prefix sharing)."""
        if self._refs[blk] <= 0:
            raise ValueError(f"ref of dead block {blk}")
        self._refs[blk] += 1

    def free(self, blk: int) -> None:
        """Drop one reference; the block is recycled at zero."""
        if self._refs[blk] <= 0:
            raise ValueError(f"double free of block {blk}")
        self._refs[blk] -= 1
        if self._refs[blk] == 0:
            self._free.append(blk)

    def refcount(self, blk: int) -> int:
        return int(self._refs[blk])

    def assert_balanced(self) -> None:
        """Leak oracle: every block is free xor referenced, exactly."""
        live = int(np.count_nonzero(self._refs))
        if live + len(self._free) != self.num_blocks:
            raise AssertionError(
                f"block leak: {live} referenced + {len(self._free)} free "
                f"!= {self.num_blocks} total")
        if len(set(self._free)) != len(self._free):
            raise AssertionError("free list contains duplicates")
        for blk in self._free:
            if self._refs[blk] != 0:
                raise AssertionError(f"block {blk} free with refcount "
                                     f"{self._refs[blk]}")


@dataclasses.dataclass
class RadixNode:
    """One full prefilled page: a trie edge keyed by its page of tokens."""
    key: Tuple[int, ...]                       # the page's tokens
    block: Optional[int]                       # pool block (None for ssm)
    rec: Optional[Dict[str, np.ndarray]]       # (L, ...) state at page end
    children: Dict[Tuple[int, ...], "RadixNode"] = \
        dataclasses.field(default_factory=dict)
    parent: Optional["RadixNode"] = None
    last_used: int = 0


class RadixCache:
    """Page-granular prefix trie over prompt tokens (see module doc)."""

    def __init__(self, allocator: Optional[BlockAllocator], page_size: int):
        self.allocator = allocator          # None for pure-recurrent (ssm)
        self.page_size = page_size
        self.root = RadixNode(key=(), block=None, rec=None)
        self._clock = 0
        self.hits = 0                       # pages served from the trie
        self.misses = 0                     # pages prefilled fresh

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    def __len__(self) -> int:
        def count(n: RadixNode) -> int:
            return sum(1 + count(c) for c in n.children.values())
        return count(self.root)

    def match(self, tokens: np.ndarray
              ) -> Tuple[int, List[RadixNode]]:
        """Longest full-page prefix of ``tokens`` present in the trie.

        Returns ``(matched_tokens, nodes)`` — ``matched_tokens`` is a
        multiple of ``page_size``, **capped below ``len(tokens)``** so at
        least one suffix token always remains to be computed (the extend
        pass must produce the prompt's next-token logits).  ``nodes`` are
        the matched pages in order; the caller takes its own block
        references before using them.
        """
        page = self.page_size
        limit = (len(tokens) - 1) // page       # full pages usable
        now = self._tick()
        nodes: List[RadixNode] = []
        cur = self.root
        for p in range(limit):
            key = tuple(int(t) for t in tokens[p * page:(p + 1) * page])
            nxt = cur.children.get(key)
            if nxt is None:
                break
            nxt.last_used = now
            nodes.append(nxt)
            cur = nxt
        return len(nodes) * page, nodes

    def insert(self, tokens: np.ndarray, n_tokens: int,
               blocks: List[Optional[int]],
               recs: Optional[List[Dict[str, np.ndarray]]] = None) -> int:
        """Register the full pages of ``tokens[:n_tokens]`` in the trie.

        ``blocks[p]`` is the pool block holding logical page ``p`` (None
        for pure-recurrent families); ``recs[p]`` the recurrent-state
        snapshot at the end of page ``p``.  Pages already present are
        left alone (first write wins — the existing block is the one
        other slots may already share); new nodes take a cache reference
        on their block.  Returns the number of nodes added.
        """
        page = self.page_size
        full = n_tokens // page
        now = self._tick()
        cur = self.root
        added = 0
        for p in range(full):
            key = tuple(int(t) for t in tokens[p * page:(p + 1) * page])
            nxt = cur.children.get(key)
            if nxt is None:
                blk = blocks[p] if blocks else None
                node = RadixNode(key=key, block=blk,
                                 rec=(recs[p] if recs else None),
                                 parent=cur, last_used=now)
                if blk is not None:
                    self.allocator.ref(blk)
                cur.children[key] = node
                nxt = node
                added += 1
            else:
                nxt.last_used = now
            cur = nxt
        return added

    def evict(self, need: int) -> int:
        """Free >= ``need`` blocks by dropping LRU leaf nodes.

        Only leaves can go (an inner node's block sits under its
        children's prefixes); a leaf whose block other slots still
        reference can be dropped from the trie too — the slots keep
        their references, only the cache's own reference is returned.
        Returns the number of blocks actually freed to the free list.
        """
        freed = 0
        while freed < need:
            leaves: List[RadixNode] = []

            def walk(n: RadixNode) -> None:
                for c in n.children.values():
                    if c.children:
                        walk(c)
                    else:
                        leaves.append(c)
            walk(self.root)
            if not leaves:
                break
            victim = min(leaves, key=lambda n: n.last_used)
            del victim.parent.children[victim.key]
            if victim.block is not None:
                before = self.allocator.free_blocks
                self.allocator.free(victim.block)
                freed += self.allocator.free_blocks - before
            else:
                freed += 1          # recurrent-only node: nothing pooled
        return freed

    def clear(self) -> None:
        """Drop every node (and the cache's block references)."""
        def drop(n: RadixNode) -> None:
            for c in n.children.values():
                drop(c)
                if c.block is not None:
                    self.allocator.free(c.block)
        drop(self.root)
        self.root.children.clear()
