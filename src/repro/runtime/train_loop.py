"""Training runtime: sharded train_step factory + fault-tolerant Trainer.

train_step composition (all policy-driven):
  loss (CE + MoE aux) -> grads [-> EF-int8 compression -> decompress]
  [-> pruning-mask projection] -> AdamW (optionally int8 moments) -> params

The step is one jit with explicit in/out shardings derived from the logical
rule engine, so it lowers identically on 1 chip, 256 (single pod) or 512
(multi-pod) — the same callable the dry-run lowers.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as PS

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import ArchConfig, ExecutionPolicy
from repro.data.pipeline import SyntheticStream
from repro.models.model_zoo import Model
from repro.optim import adamw
from repro.parallel import collectives, sharding as shd


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: adamw.AdamWConfig = adamw.AdamWConfig()
    grad_accum: int = 1
    grad_compression: bool = False    # EF-int8 DP compression
    log_every: int = 10
    ckpt_every: int = 200
    ckpt_dir: Optional[str] = None
    ckpt_keep: int = 3


def make_train_step(model: Model, tcfg: TrainConfig,
                    pol: Optional[ExecutionPolicy] = None):
    """Returns step(params, opt_state, resid, batch, masks) -> (...)"""
    ocfg = tcfg.optimizer

    def loss_of(params, batch):
        loss, metrics = model.loss(params, batch, pol)
        return loss, metrics

    def step(params, opt_state, resid, batch, masks):
        if tcfg.grad_accum > 1:
            # split the batch into microbatches along batch dim; accumulate
            def micro(i, carry):
                gsum, lsum = carry
                mb = jax.tree_util.tree_map(
                    lambda x: jax.lax.dynamic_slice_in_dim(
                        x, i * (x.shape[0] // tcfg.grad_accum),
                        x.shape[0] // tcfg.grad_accum, axis=0), batch)
                (l, _), g = jax.value_and_grad(loss_of, has_aux=True)(
                    params, mb)
                gsum = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g)
                return gsum, lsum + l

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, loss_sum = jax.lax.fori_loop(
                0, tcfg.grad_accum, micro, (zeros, jnp.float32(0.0)))
            grads = jax.tree_util.tree_map(
                lambda g: g / tcfg.grad_accum, grads)
            loss = loss_sum / tcfg.grad_accum
            metrics = {}
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_of, has_aux=True)(params, batch)

        if tcfg.grad_compression:
            comp, resid = collectives.compress_tree(grads, resid)
            grads = collectives.decompress_tree(comp)

        new_params, new_opt, om = adamw.update(ocfg, grads, opt_state,
                                               params, masks)
        out_metrics = {"loss": loss, **om}
        out_metrics.update({k: v for k, v in (metrics or {}).items()})
        return new_params, new_opt, resid, out_metrics

    return step


def shard_train_state(model: Model, mesh: Mesh):
    """(param shardings, batch sharding fn) for the mesh."""
    p_sh = shd.tree_shardings(model.params_spec(), mesh)

    def batch_shardings(batch_specs):
        def one(sds):
            # batch dim over every DP axis present
            axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
            spec = [axes if len(axes) > 1 else (axes[0] if axes else None)]
            spec += [None] * (len(sds.shape) - 1)
            return NamedSharding(mesh, PS(*spec))
        return jax.tree_util.tree_map(one, batch_specs)

    return p_sh, batch_shardings


class Trainer:
    """Host-side loop: data, jit'd step, checkpointing, failure recovery."""

    def __init__(self, model: Model, tcfg: TrainConfig,
                 stream: SyntheticStream,
                 pol: Optional[ExecutionPolicy] = None,
                 masks=None):
        self.model = model
        self.tcfg = tcfg
        self.stream = stream
        self.pol = pol
        self.masks = masks if masks is not None else jax.tree_util.tree_map(
            lambda _: None, model.params_spec())
        self.step_fn = jax.jit(make_train_step(model, tcfg, pol),
                               donate_argnums=(0, 1, 2))
        self.ckpt = (CheckpointManager(tcfg.ckpt_dir, keep=tcfg.ckpt_keep)
                     if tcfg.ckpt_dir else None)
        self.metrics_log = []

    def init_state(self, seed: int = 0):
        params = self.model.init(jax.random.PRNGKey(seed))
        opt_state = adamw.init(self.tcfg.optimizer, params)
        resid = (collectives.init_residuals(params)
                 if self.tcfg.grad_compression else jnp.zeros(()))
        return params, opt_state, resid

    def restore_or_init(self, seed: int = 0):
        params, opt_state, resid = self.init_state(seed)
        start = 0
        if self.ckpt and self.ckpt.latest_step() is not None:
            state = self.ckpt.restore({"params": params,
                                       "opt": opt_state,
                                       "resid": resid})
            params, opt_state, resid = (state["params"], state["opt"],
                                        state["resid"])
            start = self.ckpt.metadata()["step"] + 1
        return params, opt_state, resid, start

    def run(self, steps: int, seed: int = 0,
            fault_at: Optional[int] = None) -> Dict[str, Any]:
        """Train; ``fault_at`` injects a crash (test hook) after that step's
        checkpoint boundary to exercise restart."""
        params, opt_state, resid, start = self.restore_or_init(seed)
        t0 = time.time()
        losses = []
        for step in range(start, steps):
            batch = {k: jnp.asarray(v)
                     for k, v in self.stream.batch_at(step).items()}
            params, opt_state, resid, m = self.step_fn(
                params, opt_state, resid, batch, self.masks)
            if step % self.tcfg.log_every == 0 or step == steps - 1:
                losses.append((step, float(m["loss"])))
            if self.ckpt and self.tcfg.ckpt_every and \
                    step % self.tcfg.ckpt_every == 0 and step > start:
                self.ckpt.save(step, {"params": params, "opt": opt_state,
                                      "resid": resid})
            if fault_at is not None and step == fault_at:
                if self.ckpt:
                    self.ckpt.wait()
                raise RuntimeError(f"injected fault at step {step}")
        if self.ckpt:
            self.ckpt.save(steps - 1, {"params": params, "opt": opt_state,
                                       "resid": resid})
            self.ckpt.wait()
        return {"losses": losses, "wall_s": time.time() - t0,
                "params": params, "final_loss": losses[-1][1] if losses
                else float("nan")}
