"""Sharded serving: slot state over a device mesh + prefill/decode split.

:class:`MeshServeEngine` is :class:`~repro.runtime.serve_loop.ServeEngine`
with two orthogonal upgrades, both reached through the seams the base
loop exposes (``_init_state`` / ``_free_slots`` / ``_poll_admissions`` /
the ``_prefill_args``/``_finish_admit`` admission split):

**Slot state sharded over a mesh data axis.**  Every slot leaf — dense
K/V, the paged block pool, int8 scale leaves, recurrent (rwkv/mamba)
state, per-slot ``pos`` — is placed with a ``NamedSharding`` resolved by
the logical-axis rule engine (:mod:`repro.parallel.sharding`:
``"slots"``/``"blocks"`` shard over ``data``, with the usual
divisibility fallback to replicate).  The engine's jitted programs are
*unchanged*: XLA's SPMD partitioner splits each bucketed prefill /
decode / insert program over the shards, so the one-trace-per-bucket
discipline holds exactly as on one device, and — because slot decode is
batch-parallel with no cross-slot reductions — per-request outputs are
**bit-identical** to the single-device engine across dense/ssm/hybrid ×
fp32/int8 × dense/paged (asserted by ``tests/test_mesh_serving.py`` and
the CI-gated ``mesh`` bench suite).

Admission routing is shard-aware: slot *i* lives on shard
``i // (max_batch / n_shards)``, free slots are offered to the scheduler
least-loaded-shard-first, and a retire refills its own shard before a
busier one grows — retire-and-refill stays shard-local, so slot traffic
never migrates state across the mesh.

**Prefill workers off the decode critical path.**  With
``ServeConfig(prefill_workers=N)``, dense admissions run their bucketed
prefill on a thread pool (the apex actor/learner topology: workers
produce, the decode loop consumes).  The scheduler reserves the target
slots, submits the prefill, and keeps decoding; finished prefills land
through ``_finish_admit`` on the scheduler thread, which owns the slot
state (the insert scatter is the same ``slot_update`` seam, so outputs
are unaffected — only the *stall* moves off the decode path).  Paged
admissions extend the shared pool state in place and therefore stay
inline; snapshot() drains in-flight prefills first so a checkpoint never
loses an admitted-but-unlanded request.

On CPU the whole subsystem is exercisable with fake devices::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        python -m benchmarks.run mesh

which is how the ``mesh-smoke`` CI lane runs it.
"""
from __future__ import annotations

import collections
from concurrent.futures import Future, ThreadPoolExecutor
from typing import List, Optional

import numpy as np

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.parallel import collectives
from repro.parallel import sharding as shard
from repro.runtime.serve_loop import Request, ServeConfig, ServeEngine


def route_free_slots(live: List[bool], reserved, n_shards: int
                     ) -> List[int]:
    """Free slot indices, least-loaded shard first (ties: lowest shard,
    then lowest slot).

    Pure routing policy, unit-testable without a mesh: ``live[i]`` marks
    slot *i* occupied, ``reserved`` holds slots pledged to in-flight
    prefills (counted as load, excluded from the result), and slots are
    striped over shards contiguously — shard *s* owns
    ``[s*B/n, (s+1)*B/n)``.  Within one shard, slots stay in index order,
    so a retire-and-refill lands back in the shard that freed it unless a
    strictly less-loaded shard exists.
    """
    b = len(live)
    if b % n_shards != 0:
        raise ValueError(f"{b} slots cannot stripe over {n_shards} shards")
    per = b // n_shards
    load = [0] * n_shards
    for i in range(b):
        if live[i] or i in reserved:
            load[i // per] += 1
    free = [i for i in range(b) if not live[i] and i not in reserved]
    free.sort(key=lambda i: (load[i // per], i))
    return free


class MeshServeEngine(ServeEngine):
    """Slot-sharded, prefill-disaggregated serve engine (module doc)."""

    def __init__(self, model, params, config: Optional[ServeConfig] = None,
                 mesh: Optional[Mesh] = None, **legacy_kwargs):
        if config is None and legacy_kwargs:
            config = ServeConfig(**legacy_kwargs)
            legacy_kwargs = {}
        config = config or ServeConfig()
        if mesh is None:
            devices = jax.devices()
            n = config.num_shards or len(devices)
            if n > len(devices):
                raise ValueError(
                    f"num_shards={n} but only {len(devices)} devices are "
                    f"visible (CI fakes more with "
                    f"XLA_FLAGS=--xla_force_host_platform_device_count=N)")
            mesh = Mesh(np.array(devices[:n]), ("data",))
        if "data" not in mesh.shape:
            raise ValueError("the serving mesh needs a 'data' axis "
                             f"(got axes {tuple(mesh.shape)})")
        n_shards = mesh.shape["data"]
        if config.max_batch % n_shards != 0:
            raise ValueError(
                f"max_batch {config.max_batch} must divide evenly over "
                f"{n_shards} mesh shards")
        super().__init__(model, params, config, **legacy_kwargs)
        self.mesh = mesh
        self.n_shards = n_shards
        self._shard_sz = self.max_batch // n_shards
        # replicate params: every shard decodes its own slot rows against
        # a full copy (data parallelism over slots, not tensor parallelism
        # — the "model" axis profiles in parallel/sharding.py are the
        # training-side story)
        self.params = jax.device_put(
            self.params, NamedSharding(mesh, PartitionSpec()))
        # -- prefill workers -------------------------------------------------
        workers = config.prefill_workers
        if workers and self.paged:
            # paged admission mutates the shared pool state in place
            # (slot_reset + extend + commit against self._state); running
            # it concurrently with decode would race the state handoff,
            # so the pool serves inline and the knob is a documented no-op
            workers = 0
        self._pool = (ThreadPoolExecutor(max_workers=workers,
                                         thread_name_prefix="prefill")
                      if workers else None)
        # (group, free, slots, future) per in-flight async prefill
        self._inflight: collections.deque = collections.deque()
        self._reserved: set = set()
        self.metrics["async_prefills"] = 0

    # -- sharded state ------------------------------------------------------

    def _init_state(self):
        abs_st = self.ops.init_slot_state(self.max_batch, self.max_seq,
                                          abstract=True)
        shardings = shard.slot_state_shardings(abs_st, self.mesh)
        return self.ops.init_slot_state(self.max_batch, self.max_seq,
                                        shardings=shardings)

    def shard_of(self, slot: int) -> int:
        """Which mesh shard owns slot index ``slot``."""
        return slot // self._shard_sz

    def shard_loads(self) -> List[int]:
        """Occupied (or prefill-reserved) slots per shard, host view."""
        load = [0] * self.n_shards
        for i, s in enumerate(self._slots):
            if s is not None or i in self._reserved:
                load[self.shard_of(i)] += 1
        return load

    def shard_live_tokens(self) -> List[float]:
        """Committed tokens per shard, summed on-device.

        The cross-shard balance telemetry: masks the sharded ``pos``
        vector by host liveness (retired slots keep stale ``pos``) and
        reduces with one tiny all-gather
        (:func:`repro.parallel.collectives.per_shard_sums`) instead of
        pulling slot state to the host.
        """
        if self._state is None or self._state.pos is None:
            return [0.0] * self.n_shards
        live = np.array([1.0 if s is not None else 0.0
                         for s in self._slots], np.float32)
        sums = collectives.per_shard_sums(self._state.pos, self.mesh,
                                          weights=live)
        return [float(v) for v in np.asarray(sums)]

    # -- shard-aware admission routing --------------------------------------

    def _free_slots(self) -> List[int]:
        return route_free_slots([s is not None for s in self._slots],
                                self._reserved, self.n_shards)

    # -- async prefill (the prefill/decode split) ----------------------------

    def _admit(self, group: List[Request], free: List[int],
               done: List[Request]) -> None:
        if self._pool is None:
            super()._admit(group, free, done)
            return
        inputs, lengths, slots = self._prefill_args(group, free)
        taken = free[:len(group)]
        self._reserved.update(taken)
        for j, r in enumerate(group):
            self.events.append(("prefill", r.rid, taken[j],
                                int(self.metrics["decode_steps"])))
        fut: Future = self._pool.submit(self._prefill, self.params,
                                        inputs, lengths)
        self._inflight.append((group, free, slots, fut))
        self.metrics["async_prefills"] += len(group)

    def _poll_admissions(self, done: List[Request]) -> None:
        n = len(self._inflight)
        for _ in range(n):
            group, free, slots, fut = self._inflight.popleft()
            if not fut.done():
                self._inflight.append((group, free, slots, fut))
                continue
            self._reserved.difference_update(free[:len(group)])
            logits, sub = fut.result()   # re-raises worker exceptions
            self._finish_admit(group, free, logits, sub, slots, done)

    def _admissions_inflight(self) -> bool:
        return bool(self._inflight)

    def _drain_admissions(self, done: List[Request]) -> None:
        """Block until every in-flight prefill has landed in a slot."""
        while self._inflight:
            self._inflight[0][3].result()   # wait, don't spin
            self._poll_admissions(done)

    def snapshot(self) -> int:
        # an admitted-but-unlanded request is in no queue and no slot; a
        # snapshot taken in that window would silently drop it, so land
        # in-flight prefills first (prefill is pure compute — draining
        # costs one admission latency, never corrupts state)
        self._drain_admissions(self._done_live)
        return super().snapshot()
