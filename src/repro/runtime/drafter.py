"""Drafters for speculative decoding: propose k cheap continuation tokens
per slot, which one bucketed ``verify_step`` call scores all at once.

The engine contract (``runtime/serve_loop.py``) is deliberately tiny: a
drafter opens one :class:`DraftSession` per request via
:meth:`Drafter.begin` (seeded with the prompt + first token, and told
which engine ``slot``/``rid`` it is drafting for), the engine feeds every
accepted token back through :meth:`DraftSession.extend`, asks for
proposals with :meth:`DraftSession.draft` (or, for batched drafters, one
:meth:`Drafter.draft_all` call covering every drafting slot per engine
step), and calls :meth:`DraftSession.close` when the request retires.
Returning fewer than ``k`` tokens — or none — is always safe: the engine
pads the verify window and unproposed positions simply never match,
degrading to plain decode for that step.

Two drafters ship:

* :class:`NGramDrafter` — the zero-parameter baseline (prompt-lookup /
  n-gram decoding): find the most recent earlier occurrence of the longest
  suffix n-gram of the context and propose the tokens that followed it.
  No model FLOPs; O(k · max_ngram) dict operations per step.

* :class:`DraftModelDrafter` — a tiny LM drafts by actually decoding.  It
  holds one batched decode state (``model_zoo`` ``prefill`` /
  ``slot_update`` / ``decode_step``, the same seam the main engine uses)
  with one row per engine slot, and advances **all drafting slots in one
  jitted decode step per draft position** — the draft cost is one tiny
  batched program per position, not one program per slot.  Prompt seeding
  buckets to powers of two exactly like the main engine, so the drafter
  adds ``len(buckets)`` prefill traces and one decode trace, ever.  Slots
  where the draft model has no signal (top-1 probability below
  ``min_conf``) tier down to an :class:`NGramDrafter` fallback.

``make_drafter`` is the factory the CLI flags route through.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


class DraftSession:
    """Per-request drafting state.  Subclasses override the first two.

    Rollback contract: ``draft`` must not commit its own proposals — only
    tokens fed back through ``extend`` are part of the request's stream.
    A drafter is free to *speculatively* advance internal state during
    ``draft`` as long as the next ``extend``/``draft`` observes exactly
    the extended stream (the n-gram session keeps an undo log; the
    draft-model session re-synchronises its decode position).
    """

    def extend(self, tokens: Sequence[int]) -> None:
        """Feed tokens the engine committed (accepted drafts + the
        correction/bonus token of each verify step)."""
        raise NotImplementedError

    def draft(self, k: int) -> List[int]:
        """Propose 0..k continuation tokens (python ints)."""
        raise NotImplementedError

    def close(self) -> None:
        """The request retired: release any per-slot resources.  Safe to
        call more than once; the default is a no-op."""


class Drafter:
    """Drafter factory: one :class:`DraftSession` per request.

    ``begin`` receives the engine's ``slot`` index and request id so a
    batched drafter can key device-side state by slot; drafters that keep
    everything host-side ignore them.  A drafter with ``batched = True``
    additionally implements :meth:`draft_all`, which the engine calls
    once per step instead of per-slot :meth:`DraftSession.draft`.
    """

    batched = False

    def begin(self, context: Sequence[int], slot: Optional[int] = None,
              rid: Optional[int] = None) -> DraftSession:
        """``context``: the request's prompt + first emitted token."""
        raise NotImplementedError

    def draft_all(self, want: Dict[int, int]) -> Dict[int, List[int]]:
        """Batched drafting: ``want`` maps slot index -> k; returns
        slot -> 0..k proposed tokens.  Only for ``batched`` drafters."""
        raise NotImplementedError


class NGramDrafter(Drafter):
    """Prompt-lookup drafter: longest-suffix n-gram matching.

    For ``n = max_ngram .. min_ngram``, take the context's final n-gram
    and find its most recent *earlier* occurrence; on a hit, propose the
    tokens that followed it, then re-match on the extended pseudo-context
    until ``k`` tokens are proposed.  ``max_context`` bounds the seed
    context so session setup stays O(max_context) regardless of prompt
    length.
    """

    def __init__(self, max_ngram: int = 3, min_ngram: int = 1,
                 max_context: int = 512):
        if not 1 <= min_ngram <= max_ngram:
            raise ValueError(f"need 1 <= min_ngram <= max_ngram, got "
                             f"{min_ngram}..{max_ngram}")
        self.max_ngram = max_ngram
        self.min_ngram = min_ngram
        self.max_context = max_context

    def begin(self, context: Sequence[int], slot: Optional[int] = None,
              rid: Optional[int] = None) -> "_NGramSession":
        return _NGramSession(self, context)

    # convenience for tests / one-shot use
    def draft(self, context: Sequence[int], k: int) -> List[int]:
        return self.begin(context).draft(k)


class _NGramSession(DraftSession):
    """Incremental n-gram index over one request's context.

    ``last`` maps an n-gram tuple to the (latest, previous) *end*
    positions of its occurrences in ``ctx``.  ``extend`` registers the
    appended tokens; ``draft`` speculatively extends the context with its
    own proposals (recording an undo log) so a run or cycle keeps
    proposing through the whole window, then rolls the index back.
    """

    def __init__(self, drafter: NGramDrafter, context: Sequence[int]):
        self.max_ngram = drafter.max_ngram
        self.min_ngram = drafter.min_ngram
        self.ctx: List[int] = [int(t) for t in
                               context[-drafter.max_context:]]
        self.last: Dict[Tuple[int, ...],
                        Tuple[int, Optional[Tuple[int, ...]]]] = {}
        for end in range(1, len(self.ctx) + 1):
            self._register(end, None)

    def _register(self, end: int, undo: Optional[list]) -> None:
        ctx = self.ctx
        for n in range(self.min_ngram, self.max_ngram + 1):
            if end >= n:
                key = tuple(ctx[end - n:end])
                prev = self.last.get(key)
                if undo is not None:
                    undo.append((key, prev))
                self.last[key] = (end, prev)

    def extend(self, tokens: Sequence[int]) -> None:
        for t in tokens:
            self.ctx.append(int(t))
            self._register(len(self.ctx), None)

    def _lookup(self, k: int) -> List[int]:
        ctx = self.ctx
        n_ctx = len(ctx)
        for n in range(min(self.max_ngram, n_ctx - 1), self.min_ngram - 1,
                       -1):
            hit = self.last.get(tuple(ctx[n_ctx - n:]))
            if hit is None:
                continue
            # most recent *earlier* occurrence: the suffix registers
            # itself at n_ctx, so fall back to the previous occurrence
            end = hit[0]
            if end == n_ctx:
                if hit[1] is None:
                    continue
                end = hit[1][0]
            return ctx[end:end + k]
        return []

    def draft(self, k: int) -> List[int]:
        out: List[int] = []
        undo: list = []
        while len(out) < k:
            cont = self._lookup(k - len(out))
            if not cont:
                break
            for t in cont:
                out.append(t)
                self.ctx.append(t)
                self._register(len(self.ctx), undo)
        # roll the speculative extension back: the engine only commits
        # verified tokens, via extend()
        if out:
            del self.ctx[len(self.ctx) - len(out):]
            for key, prev in reversed(undo):
                if prev is None:
                    del self.last[key]
                else:
                    self.last[key] = prev
        return out


class DraftModelDrafter(Drafter):
    """Tiny-LM drafter over the ``model_zoo`` slot-state seam.

    One batched decode state mirrors the engine's slots (row ``slot`` of
    the draft cache belongs to engine slot ``slot``); each engine step
    runs the draft model forward once per draft position **across all
    drafting slots at once** — a single jitted ``decode_step`` trace with
    fixed ``(max_batch, 1)`` shape, mirroring the main engine's trace
    discipline.  Prompt seeding prefills through the same pow-2 buckets.

    The draft model must be a pure-KV-cache family (attention only, no
    recurrent leaves) with a linear cache: rollback after rejected
    proposals is then just a position reset — stale speculative writes
    sit past the committed position, invisible under the age mask until
    overwritten (the same invariant the main engine's verify relies on).

    Tiering: a slot whose top-1 draft probability drops below
    ``min_conf`` stops contributing draft-model tokens for the step; if
    it contributed none, its per-request :class:`NGramDrafter` fallback
    session proposes instead.  ``model_dispatches`` /
    ``fallback_dispatches`` count which tier served each drafting slot.
    """

    batched = True
    _SUSPEND_AFTER = 8   # consecutive all-fallback rounds before suspending
    _PROBE_EVERY = 64    # suspended rounds between single-slot probes
    _RESEED_FEEDS = 8    # catch-up gap beyond which re-seeding wins

    def __init__(self, model, params, max_batch: int, max_seq: int,
                 min_conf: float = 0.10, min_bucket: int = 16,
                 fallback: Optional[Drafter] = None, headroom: int = 64):
        cfg = model.cfg
        if cfg.input_kind != "tokens" or cfg.n_codebooks:
            raise ValueError("draft model needs a plain token vocabulary")
        self.model = model
        self.params = params
        self.max_batch = int(max_batch)
        self.min_conf = float(min_conf)
        self.min_bucket = int(min_bucket)
        self.fallback = NGramDrafter() if fallback is None else fallback
        self.ops = model.cache_ops()
        # headroom past max_seq: speculative continuations near a
        # request's end of budget must not ring-wrap the draft cache
        self._alloc = int(max_seq) + int(headroom)
        abs_state = self.ops.init_slot_state(self.max_batch, self._alloc,
                                             abstract=True)
        for name in ("x_prev", "cm_prev", "wkv", "conv_tail", "ssm_h"):
            if getattr(abs_state, name, None) is not None:
                raise ValueError(
                    f"draft model family {cfg.family!r} keeps recurrent "
                    f"state ({name}); the drafter's position-reset "
                    f"rollback needs a pure-KV-cache (attention) family")
        if (abs_state.cache_k is not None
                and abs_state.cache_k.shape[2] < self._alloc):
            raise ValueError("draft model allocates a ring cache; the "
                             "drafter needs a linear cache for rollback")
        self._bucket_cap = 1 << (self._alloc.bit_length() - 1)
        self._state = None
        # per-slot host mirror: the committed token stream, how many of
        # its tokens have valid K/V in the draft cache (cache_pos), and
        # the in-flight draft bookkeeping extend() resolves
        self._stream: Dict[int, List[int]] = {}
        self._cache_pos: Dict[int, int] = {}
        self._inflight: Dict[int, Tuple[int, int, str]] = {}
        self._ngram: Dict[int, DraftSession] = {}
        # tier dispatch counters (per drafting slot-step)
        self.model_dispatches = 0
        self.fallback_dispatches = 0
        # tier suspension: after _SUSPEND_AFTER consecutive draft_all
        # rounds in which the model tier placed nothing (every drafting
        # slot tiered down), stop dispatching the draft model and serve
        # the fallback directly — its k sequential decode dispatches per
        # round are pure overhead on an uninformative model.  Every
        # _PROBE_EVERY suspended rounds a single-slot probe runs through
        # the model tier; any model-tier yield lifts the suspension.
        # The cache catches up lazily: suspended rounds leave _cache_pos
        # untouched, and the next real round reseeds/feeds the gap.
        self._dry_rounds = 0
        self._suspended_rounds = 0
        # retrace telemetry, same contract as the engine's trace_counts
        import collections
        import jax
        self.trace_counts = collections.Counter()

        def _prefill_fn(p, inputs, lengths):
            self.trace_counts["draft_prefill"] += 1
            return model.prefill(p, inputs, headroom=0, lengths=lengths)

        def _insert_fn(st, sub, slots):
            self.trace_counts["draft_insert"] += 1
            return self.ops.slot_update(st, sub, slots)

        def _decode_fn(p, st, toks):
            self.trace_counts["draft_decode"] += 1
            import jax.numpy as jnp
            logits, st2 = model.decode_step(p, st, {"tokens": toks})
            lg = logits.reshape(toks.shape[0], -1).astype(jnp.float32)
            ids = jnp.argmax(lg, axis=-1).astype(jnp.int32)
            conf = jnp.max(jax.nn.softmax(lg, axis=-1), axis=-1)
            return ids, conf, st2

        def _set_pos_fn(st, posv):
            self.trace_counts["draft_reset"] += 1
            return st._replace(pos=posv)

        self._prefill = jax.jit(_prefill_fn)
        self._insert = jax.jit(_insert_fn)
        self._decode = jax.jit(_decode_fn)
        self._set_pos = jax.jit(_set_pos_fn)

    # -- session plumbing ---------------------------------------------------

    def _bucket(self, n: int) -> int:
        b = self.min_bucket
        while b < n:
            b *= 2
        return min(b, self._bucket_cap)

    def begin(self, context: Sequence[int], slot: Optional[int] = None,
              rid: Optional[int] = None) -> "DraftSession":
        if slot is None:
            # no slot identity: nothing to key device state by — serve
            # this request from the fallback tier alone
            return self.fallback.begin(context, slot=slot, rid=rid)
        # host-only: device seeding is deferred to the first real draft
        # round (draft_all reseeds any slot whose gap outgrew the feeds),
        # so admissions while the model tier is suspended cost nothing
        self._stream[slot] = [int(t) for t in context]
        self._cache_pos[slot] = 0
        self._inflight.pop(slot, None)
        self._ngram[slot] = self.fallback.begin(context, slot=slot, rid=rid)
        return _DraftModelSession(self, slot)

    def warm(self) -> None:
        """Pre-compile every pow-2 prefill bucket plus the decode and
        position-reset traces.  Call before serving (a no-op once any
        session is live): benchmark warmup traces are short, so without
        this the first long stream pays a bucket compile mid-replay."""
        if self._stream:
            return
        if self._state is None:
            self._state = self.ops.init_slot_state(self.max_batch,
                                                   self._alloc)
        lengths = np.ones((self.max_batch,), np.int32)
        # scatter index == max_batch is out of bounds -> dropped write:
        # compiles the trace without touching any slot
        slots = np.full((self.max_batch,), self.max_batch, np.int32)
        b = self.min_bucket
        while True:
            arr = np.zeros((self.max_batch, b), np.int32)
            _, sub = self._prefill(self.params, {"tokens": arr}, lengths)
            self._state = self._insert(self._state, sub, slots)
            if b >= self._bucket_cap:
                break
            b *= 2
        toks = np.zeros((self.max_batch, 1), np.int32)
        _, _, self._state = self._decode(self.params, self._state, toks)
        self._state = self._set_pos(
            self._state, np.zeros((self.max_batch,), np.int32))

    def _reseed(self, slot: int) -> None:
        """(Re)prefill a slot's draft cache from its committed stream.

        One bucketed prefill + scatter caches everything but the newest
        token — used lazily at a slot's first real draft round and
        whenever the catch-up gap after suspended rounds outgrows what
        lockstep feeds amortize."""
        if self._state is None:
            self._state = self.ops.init_slot_state(self.max_batch,
                                                   self._alloc)
        ctx = self._stream[slot]
        seed = ctx[:-1][:self._bucket_cap]  # cache all but the last token
        bucket = self._bucket(max(len(seed), 1))
        arr = np.zeros((self.max_batch, bucket), np.int32)
        lengths = np.ones((self.max_batch,), np.int32)
        slots = np.full((self.max_batch,), self.max_batch, np.int32)
        arr[0, :len(seed)] = seed
        lengths[0] = max(len(seed), 1)
        slots[0] = slot
        _, sub = self._prefill(self.params, {"tokens": arr}, lengths)
        self._state = self._insert(self._state, sub, slots)
        self._cache_pos[slot] = len(seed)

    # -- the batched draft step ---------------------------------------------

    def draft_all(self, want: Dict[int, int]) -> Dict[int, List[int]]:
        want = {s: k for s, k in want.items() if k > 0
                and s in self._stream}
        if not want:
            return {}
        host_only = None     # slots served by the fallback, device untouched
        if self._dry_rounds >= self._SUSPEND_AFTER:
            self._suspended_rounds += 1
            if self._suspended_rounds % self._PROBE_EVERY:
                host_only = set(want)
            else:
                # probe the model tier with the single cheapest slot —
                # one reseed + k decode steps, not a full-batch round
                probe = min(want, key=lambda s: (len(self._stream[s])
                                                 - self._cache_pos[s]))
                host_only = set(want) - {probe}
        if host_only:
            # model tier suspended: serve the fallback without touching
            # the device; _cache_pos stays put (no _inflight entry ->
            # extend() leaves it unchanged) and the next real round's
            # reseed/feeds replay the gap
            host_out: Dict[int, List[int]] = {}
            for s in sorted(host_only):
                self.fallback_dispatches += 1
                host_out[s] = self._ngram[s].draft(want[s])
            want = {s: k for s, k in want.items() if s not in host_only}
            if not want:
                return host_out
        else:
            host_out = {}
        b = self.max_batch
        rows = sorted(want)
        # a never-seeded slot (begin defers device work) or one far
        # behind (lazy catch-up after suspended rounds) is cheaper to
        # (re)seed with one bucketed prefill than to replay
        # token-by-token through the lockstep loop — and prefill keeps
        # the context's FP accumulation order identical to begin-time
        # seeding
        for s in rows:
            if (self._cache_pos[s] == 0 and len(self._stream[s]) > 1) \
                    or (len(self._stream[s]) - self._cache_pos[s]
                        > self._RESEED_FEEDS):
                self._reseed(s)
        if self._state is None:     # every row small enough to feed inline
            self._state = self.ops.init_slot_state(self.max_batch,
                                                   self._alloc)
        # feeds before proposing: the not-yet-cached stream suffix (>= 1:
        # the newest committed token is always pending)
        feeds = {s: len(self._stream[s]) - self._cache_pos[s] for s in rows}
        steps = max(feeds[s] + want[s] - 1 for s in rows)
        # reset drafting rows to their committed position; live rows that
        # sit this step out keep theirs, so ride-along writes land past
        # their valid prefix (junk-permitted, rewritten on next catch-up)
        pos0 = np.zeros((b,), np.int32)
        for s, cp in self._cache_pos.items():
            if s < b:
                pos0[s] = cp
        self._state = self._set_pos(self._state, pos0)
        outs: Dict[int, List[int]] = {s: [] for s in rows}
        alive = {s: True for s in rows}
        toks = np.zeros((b, 1), np.int32)
        consumed = {s: 0 for s in rows}   # own proposals consumed
        for s in rows:
            toks[s, 0] = self._stream[s][self._cache_pos[s]]
        for step in range(steps):
            ids_d, conf_d, self._state = self._decode(self.params,
                                                      self._state, toks)
            ids = np.asarray(ids_d)
            conf = np.asarray(conf_d)
            nxt = np.zeros((b, 1), np.int32)
            any_alive = False
            for s in rows:
                fed = step + 1
                if fed < feeds[s]:
                    # still catching up on committed tokens
                    nxt[s, 0] = self._stream[s][self._cache_pos[s] + fed]
                    any_alive = True
                    continue
                if alive[s] and len(outs[s]) < want[s] \
                        and conf[s] >= self.min_conf:
                    outs[s].append(int(ids[s]))
                else:
                    alive[s] = False
                if alive[s] and len(outs[s]) < want[s]:
                    any_alive = True
                # feed the model its own greedy continuation (rows past
                # their window ride along; their writes roll back)
                nxt[s, 0] = int(ids[s])
                consumed[s] = max(0, fed - feeds[s])
            toks = nxt
            if not any_alive:
                break
        result: Dict[int, List[int]] = {}
        placed = False
        for s in rows:
            self._cache_pos[s] = len(self._stream[s])   # caught up
            if outs[s]:
                self.model_dispatches += 1
                self._inflight[s] = (len(self._stream[s]), consumed[s],
                                     "model")
                result[s] = outs[s]
                placed = True
            else:
                # no signal: tier down to the n-gram fallback
                self.fallback_dispatches += 1
                self._inflight[s] = (len(self._stream[s]), consumed[s],
                                     "fallback")
                result[s] = self._ngram[s].draft(want[s])
        if placed:
            self._dry_rounds = 0
            self._suspended_rounds = 0
        else:
            self._dry_rounds += 1
        result.update(host_out)
        return result

    # -- called by the per-slot session -------------------------------------

    def _extend(self, slot: int, tokens: Sequence[int]) -> None:
        toks = [int(t) for t in tokens]
        stream = self._stream.get(slot)
        if stream is None:
            return
        flight = self._inflight.pop(slot, None)
        stream.extend(toks)
        if flight is not None:
            base, consumed, tier = flight
            accepted = len(toks) - 1
            if tier == "model":
                # accepted proposals were already decoded by the draft
                # model itself — their K/V is valid; anything past the
                # consumed count (or rejected) re-feeds next round
                self._cache_pos[slot] = base + min(accepted, consumed)
            else:
                self._cache_pos[slot] = base
        ng = self._ngram.get(slot)
        if ng is not None:
            ng.extend(toks)

    def _close(self, slot: int) -> None:
        self._stream.pop(slot, None)
        self._cache_pos.pop(slot, None)
        self._inflight.pop(slot, None)
        ng = self._ngram.pop(slot, None)
        if ng is not None:
            ng.close()


class _DraftModelSession(DraftSession):
    """Slot-bound view over a :class:`DraftModelDrafter`.

    ``draft`` exists for API completeness (and for engines that do not
    batch): it runs a one-slot ``draft_all``.  The serving engine calls
    ``Drafter.draft_all`` directly instead.
    """

    def __init__(self, drafter: DraftModelDrafter, slot: int):
        self.drafter = drafter
        self.slot = slot

    def extend(self, tokens: Sequence[int]) -> None:
        self.drafter._extend(self.slot, tokens)

    def draft(self, k: int) -> List[int]:
        return self.drafter.draft_all({self.slot: k}).get(self.slot, [])

    def close(self) -> None:
        self.drafter._close(self.slot)


def make_drafter(kind: str, *, model=None, params=None,
                 target=None, target_params=None,
                 max_batch: int = 8, max_seq: int = 256,
                 seed: int = 0, **kwargs) -> Drafter:
    """Factory behind ``--drafter``: ``"ngram"`` or ``"draft_model"``.

    ``"draft_model"`` drafts with ``model``/``params`` when given;
    otherwise it derives a tiny dense LM from ``target`` (the serving
    model — vocabulary must match) via
    :func:`repro.models.model_zoo.draft_arch` and initialises it with
    ``seed``.  Extra ``kwargs`` pass through to the drafter class.
    """
    if kind == "ngram":
        return NGramDrafter(**kwargs)
    if kind == "draft_model":
        if model is None:
            if target is None:
                raise ValueError("draft_model needs either model=/params= "
                                 "or target= (the serving model) to "
                                 "derive a tiny draft LM from")
            import jax
            from repro.models.model_zoo import build_model, draft_arch
            model = build_model(draft_arch(target.cfg))
            params = model.init(jax.random.PRNGKey(seed))
        elif params is None:
            raise ValueError("draft_model with model= also needs params=")
        return DraftModelDrafter(model, params, max_batch=max_batch,
                                 max_seq=max_seq, **kwargs)
    raise ValueError(f"unknown drafter kind {kind!r}; expected 'ngram' or "
                     f"'draft_model'")
