"""Drafters for speculative decoding: propose k cheap continuation tokens
per slot, which one bucketed ``verify_step`` call scores all at once.

The engine contract (``runtime/serve_loop.py``) is deliberately tiny so a
draft *model* can slot in later: a drafter opens one :class:`DraftSession`
per request (seeded with the prompt + first token), the engine feeds every
accepted token back through :meth:`DraftSession.extend`, and
:meth:`DraftSession.draft` returns up to ``k`` proposed continuation
tokens.  Returning fewer — or none — is always safe: the engine pads the
verify window and unproposed positions simply never match, degrading to
plain decode for that step.

:class:`NGramDrafter` is the zero-parameter baseline (prompt-lookup /
n-gram decoding): find the most recent earlier occurrence of the longest
suffix n-gram of the context and propose the tokens that followed it,
re-matching on the extended pseudo-context until ``k`` tokens are drafted
(a single backward match truncates exactly where the drafter should shine
— inside a token run or short cycle).  It costs no model FLOPs, and its
session keeps an incremental n-gram index so the per-step host cost is
O(k · max_ngram) dict operations, not a context rescan.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple


class DraftSession:
    """Per-request drafting state.  Subclasses override both methods."""

    def extend(self, tokens: Sequence[int]) -> None:
        """Feed tokens the engine committed (accepted drafts + the
        correction/bonus token of each verify step)."""
        raise NotImplementedError

    def draft(self, k: int) -> List[int]:
        """Propose 0..k continuation tokens (python ints)."""
        raise NotImplementedError


class Drafter:
    """Drafter factory: one :class:`DraftSession` per request.

    Subclass for a draft *model* (the hook recorded in ROADMAP.md): the
    session would hold the draft model's decode state and advance it in
    ``extend`` — the engine neither knows nor cares how proposals are made,
    only that they are cheap enough for the per-slot host path.
    """

    def begin(self, context: Sequence[int]) -> DraftSession:
        """``context``: the request's prompt + first emitted token."""
        raise NotImplementedError


class NGramDrafter(Drafter):
    """Prompt-lookup drafter: longest-suffix n-gram matching.

    For ``n = max_ngram .. min_ngram``, take the context's final n-gram
    and find its most recent *earlier* occurrence; on a hit, propose the
    tokens that followed it, then re-match on the extended pseudo-context
    until ``k`` tokens are proposed.  ``max_context`` bounds the seed
    context so session setup stays O(max_context) regardless of prompt
    length.
    """

    def __init__(self, max_ngram: int = 3, min_ngram: int = 1,
                 max_context: int = 512):
        if not 1 <= min_ngram <= max_ngram:
            raise ValueError(f"need 1 <= min_ngram <= max_ngram, got "
                             f"{min_ngram}..{max_ngram}")
        self.max_ngram = max_ngram
        self.min_ngram = min_ngram
        self.max_context = max_context

    def begin(self, context: Sequence[int]) -> "_NGramSession":
        return _NGramSession(self, context)

    # convenience for tests / one-shot use
    def draft(self, context: Sequence[int], k: int) -> List[int]:
        return self.begin(context).draft(k)


class _NGramSession(DraftSession):
    """Incremental n-gram index over one request's context.

    ``last`` maps an n-gram tuple to the (latest, previous) *end*
    positions of its occurrences in ``ctx``.  ``extend`` registers the
    appended tokens; ``draft`` speculatively extends the context with its
    own proposals (recording an undo log) so a run or cycle keeps
    proposing through the whole window, then rolls the index back.
    """

    def __init__(self, drafter: NGramDrafter, context: Sequence[int]):
        self.max_ngram = drafter.max_ngram
        self.min_ngram = drafter.min_ngram
        self.ctx: List[int] = [int(t) for t in
                               context[-drafter.max_context:]]
        self.last: Dict[Tuple[int, ...],
                        Tuple[int, Optional[Tuple[int, ...]]]] = {}
        for end in range(1, len(self.ctx) + 1):
            self._register(end, None)

    def _register(self, end: int, undo: Optional[list]) -> None:
        ctx = self.ctx
        for n in range(self.min_ngram, self.max_ngram + 1):
            if end >= n:
                key = tuple(ctx[end - n:end])
                prev = self.last.get(key)
                if undo is not None:
                    undo.append((key, prev))
                self.last[key] = (end, prev)

    def extend(self, tokens: Sequence[int]) -> None:
        for t in tokens:
            self.ctx.append(int(t))
            self._register(len(self.ctx), None)

    def _lookup(self, k: int) -> List[int]:
        ctx = self.ctx
        n_ctx = len(ctx)
        for n in range(min(self.max_ngram, n_ctx - 1), self.min_ngram - 1,
                       -1):
            hit = self.last.get(tuple(ctx[n_ctx - n:]))
            if hit is None:
                continue
            # most recent *earlier* occurrence: the suffix registers
            # itself at n_ctx, so fall back to the previous occurrence
            end = hit[0]
            if end == n_ctx:
                if hit[1] is None:
                    continue
                end = hit[1][0]
            return ctx[end:end + k]
        return []

    def draft(self, k: int) -> List[int]:
        out: List[int] = []
        undo: list = []
        while len(out) < k:
            cont = self._lookup(k - len(out))
            if not cont:
                break
            for t in cont:
                out.append(t)
                self.ctx.append(t)
                self._register(len(self.ctx), undo)
        # roll the speculative extension back: the engine only commits
        # verified tokens, via extend()
        if out:
            del self.ctx[len(self.ctx) - len(out):]
            for key, prev in reversed(undo):
                if prev is None:
                    del self.last[key]
                else:
                    self.last[key] = prev
        return out
