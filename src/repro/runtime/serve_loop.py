"""Serving runtime: continuous-batching engine over prefill/decode steps.

Production shape: a request queue, a batch scheduler that packs admitted
requests into fixed decode slots (the jit'd decode_step has a static batch),
per-slot completion tracking, and jit'd prefill/decode callables shared
across requests.  This is the "serve a small model with batched requests"
driver of deliverable (b).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import common as kernel_common
from repro.models.model_zoo import Model


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (S,) int32 tokens (or (S,D) frames)
    max_new_tokens: int = 16
    output: Optional[np.ndarray] = None
    submitted_at: float = 0.0
    done_at: float = 0.0


class ServeEngine:
    def __init__(self, model: Model, params, max_batch: int = 8,
                 max_seq: int = 256, greedy: bool = True):
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        # Warm boot: pull the persistent tuned-block table (written by
        # `python -m benchmarks.tune`) into the substrate before the first
        # trace, so serving never re-derives — or worse, never measures —
        # its kernel tiles.  Missing/stale tables load as empty.
        self.tuned_blocks = kernel_common.load_tuned_table()
        cfg = model.cfg
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b))
        self._decode = jax.jit(
            lambda p, st, b: model.decode_step(p, st, b))
        self.metrics: Dict[str, float] = {"prefill_tokens": 0,
                                          "decode_tokens": 0}

    def _pad_prompts(self, reqs: List[Request]) -> Dict[str, jnp.ndarray]:
        cfg = self.model.cfg
        s = max(len(r.prompt) for r in reqs)
        b = len(reqs)
        if cfg.input_kind == "tokens":
            toks = np.zeros((b, s), np.int32)
            for i, r in enumerate(reqs):
                toks[i, s - len(r.prompt):] = r.prompt  # left-pad
            return {"tokens": jnp.asarray(toks)}
        d = cfg.d_model
        frames = np.zeros((b, s, d), np.float32)
        for i, r in enumerate(reqs):
            frames[i, s - len(r.prompt):] = r.prompt
        return {"frames": jnp.asarray(frames)}

    def serve(self, requests: List[Request]) -> List[Request]:
        """Continuous batching: admit up to max_batch, prefill together,
        decode in lockstep, retire finished slots and refill."""
        pending = list(requests)
        for r in pending:
            r.submitted_at = time.time()
        done: List[Request] = []

        while pending:
            batch = pending[:self.max_batch]
            pending = pending[self.max_batch:]
            inputs = self._pad_prompts(batch)
            logits, state = self._prefill(self.params, inputs)
            self.metrics["prefill_tokens"] += sum(len(r.prompt)
                                                  for r in batch)
            b = len(batch)
            outs = [[] for _ in range(b)]
            next_tok = jnp.argmax(logits.reshape(b, -1), axis=-1)
            steps = max(r.max_new_tokens for r in batch)
            for t in range(steps):
                for i in range(b):
                    if t < batch[i].max_new_tokens:
                        outs[i].append(int(next_tok[i]))
                if self.model.cfg.input_kind == "tokens":
                    nb = {"tokens": next_tok[:, None].astype(jnp.int32)}
                else:  # frame stubs decode over embedded tokens
                    nb = {"frames": jnp.zeros(
                        (b, 1, self.model.cfg.d_model), jnp.float32)}
                logits, state = self._decode(self.params, state, nb)
                v = logits.reshape(b, -1)
                next_tok = jnp.argmax(v, axis=-1)
                self.metrics["decode_tokens"] += b
            for i, r in enumerate(batch):
                r.output = np.asarray(outs[i][:r.max_new_tokens])
                r.done_at = time.time()
                done.append(r)
        return done
