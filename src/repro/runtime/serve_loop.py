"""Serving runtime: slot-based continuous batching over bucketed shapes.

The paper's SYCore keeps one reconfigurable engine resident and streams
heterogeneous workloads through it; the serving analogue is **continuous
batching**: ``max_batch`` persistent decode slots, an admission queue with
arrival times, retire-and-refill on *every* decode step (a finished short
request frees its slot immediately — it never rides dead-weight until the
slowest request in a gang finishes), and a scheduler that prefills newly
admitted requests into free slots while occupied slots keep decoding.

Shapes are **bucketed** so the jit'd callables — and the tuned-block table
keyed on kernel call shapes — are reused across admissions instead of
retracing per batch composition:

  * prefill:  (B = max_batch, S = next-pow2 prompt bucket), prompts
    right-padded, true lengths passed to ``model.prefill(lengths=...)``
  * decode:   (B = max_batch, 1) every step, against the fixed-shape slot
    state from ``model.init_slot_state`` (per-slot ``pos``)
  * insert:   ``model.slot_update`` scatters a prefill's per-request state
    (attention KV *and* rwkv/mamba recurrent state) into slot indices;
    admission groups are padded with a sentinel slot that the scatter drops

Per-request outputs are bit-identical to single-stream decoding: the
model-level seam masks pad steps out of recurrent state updates and each
slot decodes against its own positions (see ``tests/test_serving.py``).

**Speculative decoding** (``spec_k > 0``): a pluggable drafter
(``runtime/drafter.py``; n-gram prompt lookup by default,
``drafter="draft_model"`` for the tiered tiny-LM drafter) proposes up to
``k`` tokens per slot and one bucketed ``verify_step`` call scores all
``k+1`` positions in a single pass — per-query verify numerics are the
exact single-token decode ops, so greedy outputs stay bit-identical to
plain decode while accepted prefixes advance a slot by up to ``k+1``
tokens per engine step (greedy engines fuse verify + longest-prefix
accept + commit into one program).  Batched drafters
(``Drafter.batched``) get one ``draft_all`` call covering every drafting
slot per step instead of per-slot sessions.  Ring caches (long-context
sliding-window presets) verify too: candidate columns wrap on write and
rejected wrapped writes restore on commit, so the only constraint is
that the ``k+1`` verify window fits the ring.  With ``spec_adaptive``,
each slot tracks a trailing-acceptance EWMA and walks its own draft
budget between 0 (plain decode, which is already the engine's free
fallback) and ``spec_k_max`` — undraftable traffic stops paying verify
width, draftable traffic keeps the full window.  Temperature slots use
the rejection-sampling fallback (see ``_accept_sampled``).  Acceptance
bookkeeping lands in the typed :class:`ServeMetrics`
(``spec_acceptance`` / ``tokens_per_step`` / ``spec_k_hist``).

``GangServeEngine`` preserves the previous lockstep scheduler as the
benchmark baseline (``benchmarks/serve_bench.py`` replays the same trace
through both and reports the throughput/latency gap).
"""
from __future__ import annotations

import collections
import dataclasses
import time
import warnings
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import CacheSpec
from repro.kernels import common as kernel_common
from repro.models.model_zoo import Model
from repro.parallel.fault_tolerance import WorkerKilled
from repro.runtime.block_pool import BlockAllocator, RadixCache
from repro.runtime.drafter import (Drafter, DraftSession, NGramDrafter,
                                   make_drafter)

# Serving snapshot format version (bumped on any layout/meta change; a
# restore refuses snapshots it does not understand instead of guessing).
SNAPSHOT_VERSION = 1

ADMISSION_POLICIES = ("reject-new", "shed-oldest", "shed-lowest-budget")


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Every knob of :class:`ServeEngine`, validated in one place.

    Replaces the kwarg sprawl of the original constructor (``max_batch``,
    ``max_seq``, ``greedy``, ... each positional-ish and undocumented);
    the old kwargs still work for one release through a deprecation shim.

    ``cache`` pins the slot-cache storage format (dtype, scale block,
    paged on/off — see :class:`repro.configs.base.CacheSpec`); the legacy
    ``cache_dtype`` string survives for compatibility but cannot be
    combined with ``cache``.  When the resolved spec is paged:

      * ``num_blocks`` sizes the shared block pool (default: full
        occupancy, ``max_batch * max_seq / page_size`` — size it *below*
        that to cap resident cache memory by live tokens instead of
        worst case);
      * ``prefix_cache`` keeps a radix trie over admitted prompts so an
        admission sharing a full-page prefix with earlier traffic
        references those blocks instead of recomputing them.

    Robustness knobs (all off by default — the engine's historical
    contract, "every request is served, over-budget raises", holds
    untouched unless a knob turns a policy on):

      * ``max_queue`` bounds the *arrived-but-unadmitted* queue;
        ``admission_policy`` picks the victim when it overflows —
        ``"reject-new"`` sheds the newcomer, ``"shed-oldest"`` sheds the
        longest-waiting entry, ``"shed-lowest-budget"`` sheds the
        smallest ``max_new_tokens`` (cheapest work to redo elsewhere).
        Shed requests come back with ``status="shed"`` and empty output.
      * ``snapshot_dir`` + ``snapshot_every`` persist an atomic, versioned
        slot snapshot every N decode steps (see :meth:`ServeEngine.snapshot`);
        a fresh engine restores it and resumed requests complete
        bit-identically.
      * ``kill_at_step`` injects a fault: the serve loop raises
        :class:`~repro.parallel.fault_tolerance.WorkerKilled` after that
        decode step, abandoning live state exactly like a preempted host
        (the chaos-harness hook; see ``runtime/supervisor.py``).

    Mesh knobs (consumed by
    :class:`repro.runtime.mesh_serve.MeshServeEngine`; the base engine
    validates but ignores them):

      * ``num_shards`` shards the slot batch axis over that many devices
        of the serving mesh (None = every visible device);
      * ``prefill_workers`` sizes the async prefill thread pool that
        keeps long prompts off the decode critical path (0 = prefill
        inline on the scheduler thread, the single-device behaviour).
    """

    max_batch: int = 8
    max_seq: int = 256
    greedy: bool = True
    min_bucket: int = 16
    # speculative decoding: spec_k > 0 turns it on; drafter is a Drafter
    # instance or a factory name ("ngram" | "draft_model", resolved by
    # the engine through runtime.drafter.make_drafter); spec_adaptive
    # walks each slot's draft budget between 0 and spec_k_max (defaults
    # to spec_k) by trailing acceptance
    spec_k: int = 0
    spec_k_max: Optional[int] = None
    spec_adaptive: bool = False
    drafter: Optional[Any] = None          # Drafter | "ngram" | "draft_model"
    cache_dtype: Optional[str] = None      # legacy string; prefer `cache`
    cache: Optional[CacheSpec] = None
    num_blocks: Optional[int] = None
    prefix_cache: bool = True
    # backpressure / fault tolerance
    max_queue: Optional[int] = None
    admission_policy: str = "reject-new"
    snapshot_dir: Optional[str] = None
    snapshot_every: int = 0
    kill_at_step: Optional[int] = None
    # serving mesh (MeshServeEngine)
    num_shards: Optional[int] = None
    prefill_workers: int = 0

    def __post_init__(self):
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")
        if self.max_seq < 1:
            raise ValueError(f"max_seq must be >= 1, got {self.max_seq}")
        if self.min_bucket < 1:
            raise ValueError(f"min_bucket must be >= 1, got "
                             f"{self.min_bucket}")
        if self.spec_k < 0:
            raise ValueError(f"spec_k must be >= 0, got {self.spec_k}")
        if self.spec_k_max is not None:
            if self.spec_k < 1:
                raise ValueError("spec_k_max needs spec_k > 0 (spec_k is "
                                 "the starting draft budget, spec_k_max "
                                 "the adaptive ceiling)")
            if self.spec_k_max < self.spec_k:
                raise ValueError(f"spec_k_max {self.spec_k_max} must be "
                                 f">= spec_k {self.spec_k}")
        if self.spec_adaptive and self.spec_k < 1:
            raise ValueError("spec_adaptive needs spec_k > 0")
        if (isinstance(self.drafter, str)
                and self.drafter not in ("ngram", "draft_model")):
            raise ValueError(f"unknown drafter name {self.drafter!r}; "
                             f"expected 'ngram' or 'draft_model' (or pass "
                             f"a Drafter instance)")
        if self.cache is not None and self.cache_dtype is not None:
            raise ValueError("cache (a CacheSpec) and the legacy "
                             "cache_dtype string are two spellings of the "
                             "same thing; pass exactly one")
        if self.num_blocks is not None and self.num_blocks < 1:
            raise ValueError(f"num_blocks must be >= 1, got "
                             f"{self.num_blocks}")
        if self.max_queue is not None and self.max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got "
                             f"{self.max_queue}")
        if self.admission_policy not in ADMISSION_POLICIES:
            raise ValueError(f"admission_policy must be one of "
                             f"{ADMISSION_POLICIES}, got "
                             f"{self.admission_policy!r}")
        if self.snapshot_every < 0:
            raise ValueError(f"snapshot_every must be >= 0, got "
                             f"{self.snapshot_every}")
        if self.snapshot_every and self.snapshot_dir is None:
            raise ValueError("snapshot_every > 0 needs a snapshot_dir")
        if self.kill_at_step is not None and self.kill_at_step < 1:
            raise ValueError(f"kill_at_step must be >= 1, got "
                             f"{self.kill_at_step}")
        if self.num_shards is not None and self.num_shards < 1:
            raise ValueError(f"num_shards must be >= 1, got "
                             f"{self.num_shards}")
        if (self.num_shards is not None
                and self.max_batch % self.num_shards != 0):
            raise ValueError(
                f"max_batch {self.max_batch} must divide evenly into "
                f"num_shards {self.num_shards} (every shard owns "
                f"max_batch / num_shards slots)")
        if self.prefill_workers < 0:
            raise ValueError(f"prefill_workers must be >= 0, got "
                             f"{self.prefill_workers}")

    # -- shared CLI plumbing -------------------------------------------------
    # launch/serve.py and examples/serve_batch.py used to carry identical
    # copies of these flags and their cross-checks; the one spelling lives
    # here now (add_args -> check_args -> from_args).

    @staticmethod
    def add_args(ap) -> None:
        """Install the engine's shared flags on an ArgumentParser."""
        ap.add_argument("--max-batch", type=int, default=4)
        ap.add_argument("--max-seq", type=int, default=256)
        ap.add_argument("--spec", type=int, default=0, metavar="K",
                        help="speculative decoding: draft K tokens per "
                             "slot per step (greedy outputs stay "
                             "bit-identical to plain decode)")
        ap.add_argument("--spec-k-max", type=int, default=None,
                        metavar="K", help="adaptive draft-budget ceiling "
                        "(defaults to --spec; implies a K+1-wide verify "
                        "window)")
        ap.add_argument("--spec-adaptive", action="store_true",
                        help="walk each slot's draft budget between 0 and "
                             "--spec-k-max by trailing acceptance")
        ap.add_argument("--drafter", choices=("ngram", "draft_model"),
                        default=None,
                        help="drafter tier: n-gram prompt lookup "
                             "(default) or the batched tiny-LM drafter "
                             "with n-gram fallback")
        ap.add_argument("--paged", action="store_true",
                        help="paged slot memory + radix prefix cache: K/V "
                             "lives in a shared block pool, shared-prefix "
                             "admissions reuse already-prefilled pages")
        ap.add_argument("--page-size", type=int, default=16,
                        help="tokens per cache page (--paged)")
        ap.add_argument("--snapshot-dir", default=None, metavar="DIR",
                        help="slot snapshot directory: enables periodic "
                             "snapshots and (with --kill-at-step) "
                             "preempt-and-resume")
        ap.add_argument("--snapshot-every", type=int, default=8,
                        metavar="STEPS",
                        help="snapshot cadence in decode steps "
                             "(--snapshot-dir)")
        ap.add_argument("--kill-at-step", type=int, default=None,
                        metavar="N",
                        help="chaos: kill the worker after decode step N "
                             "and let the supervisor restore + resume "
                             "(needs --snapshot-dir)")
        ap.add_argument("--mesh-shards", type=int, default=0, metavar="N",
                        help="shard the slot state over an N-way mesh "
                             "data axis (MeshServeEngine; outputs stay "
                             "bit-identical; fake devices on CPU with "
                             "XLA_FLAGS=--xla_force_host_platform_"
                             "device_count=N)")
        ap.add_argument("--prefill-workers", type=int, default=0,
                        metavar="N",
                        help="run dense prefills on N worker threads off "
                             "the decode critical path (needs "
                             "--mesh-shards; paged admissions stay "
                             "inline)")

    @staticmethod
    def check_args(ap, args, gang: bool = False) -> None:
        """The cross-flag ap.error checks both serving CLIs share.
        ``gang`` is the caller's --gang value (the lockstep baseline
        supports none of the engine features)."""
        if gang:
            for flag, name in ((args.spec, "--spec"),
                               (args.paged, "--paged"),
                               (args.snapshot_dir, "--snapshot-dir"),
                               (args.mesh_shards, "--mesh-shards")):
                if flag:
                    ap.error(f"{name} needs the continuous engine "
                             f"(drop --gang)")
        if args.kill_at_step is not None and not args.snapshot_dir:
            ap.error("--kill-at-step needs --snapshot-dir to recover from")
        if args.prefill_workers and not args.mesh_shards:
            ap.error("--prefill-workers needs --mesh-shards")
        if (args.drafter or args.spec_k_max or args.spec_adaptive) \
                and not args.spec:
            ap.error("--drafter/--spec-k-max/--spec-adaptive need --spec K")

    @classmethod
    def from_args(cls, args, incarnation: int = 0,
                  **overrides) -> "ServeConfig":
        """Build a ServeConfig from ``add_args``-parsed flags.

        ``incarnation`` guards the injected fault: only the first engine
        a supervisor spawns carries ``kill_at_step`` (the respawn must
        run the trace to completion).  ``overrides`` replace any derived
        kwarg (e.g. a caller-adjusted ``max_seq`` or custom ``cache``).
        """
        kw = dict(
            max_batch=args.max_batch, max_seq=args.max_seq,
            spec_k=args.spec, spec_k_max=args.spec_k_max,
            spec_adaptive=args.spec_adaptive, drafter=args.drafter,
            cache=(CacheSpec(paged=True, page_size=args.page_size)
                   if args.paged else None),
            num_shards=args.mesh_shards or None,
            prefill_workers=args.prefill_workers,
            snapshot_dir=args.snapshot_dir,
            snapshot_every=(args.snapshot_every if args.snapshot_dir
                            else 0),
            kill_at_step=(args.kill_at_step if incarnation == 0
                          else None))
        kw.update(overrides)
        return cls(**kw)


@dataclasses.dataclass
class ServeMetrics:
    """Typed engine metrics (one field per counter the dict used to hold).

    The engine historically exposed ``metrics`` as a plain dict, and the
    benches/gates index it with strings — so this dataclass keeps the
    mapping surface (``m["key"]``, ``"key" in m``, ``m.get``) over its
    typed fields, routes unknown keys to ``extras`` (the mesh engine's
    ``async_prefills`` lives there), and ``to_dict()`` flattens back to
    the exact dict shape the bench JSON writers have always serialized.
    """

    # token/step counters (accumulate over the engine lifetime)
    prefill_tokens: int = 0
    decode_tokens: int = 0
    decode_steps: int = 0
    # per-serve() averages/rates (recomputed at the end of each call)
    queue_wait_s: float = 0.0
    slot_occupancy: float = 0.0
    wall_s: float = 0.0
    tok_s: float = 0.0
    # speculative decode: drafted vs accepted counters, derived rates,
    # tier dispatch counts, and the per-slot draft-budget histogram
    # (spec_k value -> slot-steps spent at that budget)
    spec_steps: int = 0
    draft_tokens: int = 0
    draft_accepted: int = 0
    spec_acceptance: float = 0.0
    tokens_per_step: float = 0.0
    model_drafts: int = 0
    fallback_drafts: int = 0
    spec_k_hist: Dict[int, int] = dataclasses.field(default_factory=dict)
    # paged mode: prompt tokens served from the radix prefix cache and
    # the block pool's high-water mark
    prefix_hit_tokens: int = 0
    peak_blocks: int = 0
    # mesh mode: decode steps taken while a prefill was in flight
    overlap_steps: int = 0
    # backpressure + fault tolerance
    queue_depth: int = 0
    peak_queue_depth: int = 0
    shed_count: int = 0
    timeout_count: int = 0
    snapshots: int = 0
    snapshot_s: float = 0.0
    restore_s: float = 0.0
    # escape hatch for engine subclasses (ServeMetrics is the base
    # engine's contract; a subclass counter is not a schema change)
    extras: Dict[str, float] = dataclasses.field(default_factory=dict)

    def _is_field(self, key: str) -> bool:
        return key in self.__dataclass_fields__ and key != "extras"

    def __getitem__(self, key: str):
        if self._is_field(key):
            return getattr(self, key)
        return self.extras[key]

    def __setitem__(self, key: str, value) -> None:
        if self._is_field(key):
            setattr(self, key, value)
        else:
            self.extras[key] = value

    def __contains__(self, key: str) -> bool:
        return self._is_field(key) or key in self.extras

    def get(self, key: str, default=None):
        return self[key] if key in self else default

    def to_dict(self) -> Dict[str, Any]:
        """The flat dict the bench JSON writers serialize (bit-compatible
        with the pre-dataclass metrics dict, plus the new fields)."""
        d = {k: getattr(self, k) for k in self.__dataclass_fields__
             if k not in ("extras", "spec_k_hist")}
        d["spec_k_hist"] = dict(self.spec_k_hist)
        d.update(self.extras)
        return d


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (S,) int32 tokens (or (S,D) frames)
    max_new_tokens: int = 16
    arrival_s: float = 0.0        # arrival offset from serve() start
    # per-request sampling params (engine greedy=True overrides all)
    temperature: float = 0.0      # 0 => greedy
    top_k: int = 0                # 0 => full distribution
    seed: int = 0
    # wall-clock budget from submission; None = wait forever.  An expired
    # waiting request sheds; an expired *live* request retires gracefully
    # with whatever it produced (status "timeout", partial output).
    deadline_s: Optional[float] = None
    output: Optional[np.ndarray] = None
    # terminal disposition: "done" (full budget), "shed" (backpressure
    # victim, empty output), "timeout" (deadline expired)
    status: str = "pending"
    submitted_at: float = 0.0     # absolute arrival time
    admitted_at: float = 0.0      # absolute prefill time
    done_at: float = 0.0


@dataclasses.dataclass
class _Slot:
    """Live decode-slot bookkeeping (host side)."""
    req: Request
    next_token: int               # last sampled token, fed next step
    produced: int                 # tokens emitted so far (incl. prefill's)
    tokens: List[int]
    rng: Optional[np.random.Generator]
    # per-request drafting state (spec mode only): seeded with prompt +
    # first token, extended with every committed token
    session: Optional[DraftSession] = None
    # host mirror of the device-side committed position (tokens in cache);
    # drives paged-mode page allocation ahead of each step's writes
    pos: int = 0
    # adaptive speculative decoding: trailing-acceptance EWMA, the slot's
    # current draft budget (0 = plain decode), and the probe countdown
    # that lets a k=0 slot periodically re-test draftability
    spec_ewma: float = 0.5
    spec_k: int = 0
    spec_probe: int = 0


@dataclasses.dataclass
class _Parked:
    """A snapshotted in-flight request awaiting re-admission.

    Produced by :meth:`ServeEngine.restore_snapshot`; consumed by
    ``_admit_restored`` when the serve loop reaches the request's rid.
    ``leaves`` hold the per-slot state in raw storage dtype (dense KV
    trimmed to ``pos`` tokens; recurrent + scale leaves as stored);
    ``pages`` hold the referenced pool blocks per leaf (paged mode),
    denormalized per request — restored slots never share pages, even
    where the dead engine's radix cache had them shared (identical bytes
    either way, so resumed decoding is unaffected).
    """
    tokens: List[int]
    next_token: int
    produced: int
    pos: int
    rng_state: Optional[dict]
    leaves: Dict[str, np.ndarray]
    pages: Dict[str, np.ndarray]


def next_pow2(n: int) -> int:
    b = 1
    while b < n:
        b *= 2
    return b


class ServeEngine:
    """Continuous-batching serve engine (slot scheduler, bucketed shapes)."""

    def __init__(self, model: Model, params,
                 config: Optional[ServeConfig] = None, **legacy_kwargs):
        if config is None:
            # deprecation shim: the pre-ServeConfig kwarg spelling
            # (``ServeEngine(m, p, max_batch=4, ...)``) still works for
            # one release; unknown names fail in ServeConfig as before
            config = ServeConfig(**legacy_kwargs)
            if legacy_kwargs:
                warnings.warn(
                    "ServeEngine(max_batch=..., ...) kwargs are "
                    "deprecated; pass ServeEngine(model, params, "
                    "ServeConfig(...))", DeprecationWarning, stacklevel=2)
        elif legacy_kwargs:
            raise TypeError("pass either a ServeConfig or legacy kwargs, "
                            "not both")
        self.config = config
        # cache format: `cache` (CacheSpec) is the one spelling going
        # forward (dtype + scale blocks + paging); cache_dtype="int8"
        # survives as the legacy string.  Scale leaves are ordinary pytree
        # leaves of the slot state, so bucketing/trace discipline is
        # untouched either way — same trace counts, ~4x smaller K/V +
        # wkv/ssm state in int8.
        if config.cache is not None:
            model = model.with_cache_spec(config.cache)
        elif config.cache_dtype is not None:
            model = model.with_cache_dtype(config.cache_dtype)
        self.model = model
        self.params = params
        max_batch = self.max_batch = config.max_batch
        max_seq = self.max_seq = config.max_seq
        self.greedy = config.greedy
        self.min_bucket = config.min_bucket
        spec_k = config.spec_k
        drafter = config.drafter
        # -- paged slot memory + radix prefix cache ------------------------
        # (cfg-less stand-in models — the warm-boot test's stub — serve
        # nothing and get the dense ops seam lazily, so guard the lookups)
        cfg = getattr(model, "cfg", None)
        spec = cfg.cache_spec() if cfg is not None else None
        self.paged = spec is not None and spec.paged
        if self.paged:
            if model.cfg.input_kind != "tokens":
                raise ValueError("paged serving admits through the extend "
                                 "(verify) pass, which needs token inputs")
            if max_seq % spec.page_size != 0:
                raise ValueError(f"max_seq {max_seq} must be a multiple of "
                                 f"page_size {spec.page_size}")
            self.page_size = spec.page_size
            self._n_pages = max_seq // spec.page_size
            num_blocks = (config.num_blocks
                          or max_batch * self._n_pages)
            self.ops = model.cache_ops(num_blocks=num_blocks,
                                       page_size=spec.page_size)
            pooled = model.cfg.family != "ssm"   # ssm: recurrent-only
            self.allocator = (BlockAllocator(num_blocks) if pooled
                              else None)
            self.radix = (RadixCache(self.allocator, spec.page_size)
                          if config.prefix_cache else None)
            # authoritative block tables live host-side; every jitted call
            # gets the current numpy copy (cheap C++ argument path) and
            # the device echo in the returned state is ignored
            self._tables = np.full((max_batch, self._n_pages),
                                   num_blocks, np.int32)
        else:
            self.ops = (model.cache_ops() if hasattr(model, "cache_ops")
                        else None)
            self.allocator = None
            self.radix = None
            self._tables = None
        # speculative decoding: a drafter proposes up to spec_k tokens per
        # slot and one bucketed verify call scores all spec_k+1 positions
        # in a single pass; greedy outputs stay bit-identical to plain
        # decode (per-query verify numerics are the exact decode ops).
        if spec_k and (model.cfg.input_kind != "tokens"
                       or model.cfg.n_codebooks):
            raise ValueError("speculative decoding needs a plain token "
                             "vocabulary (input_kind='tokens', no "
                             "codebook factorisation)")
        k_max = int(config.spec_k_max or spec_k)
        if spec_k and not self.paged:
            # derive the ring-cache predicate from the allocation itself
            # (abstract: no memory): a slot K/V cache shorter than max_seq
            # is a ring.  Ring verify wraps candidate writes and restores
            # rejected wrapped columns on commit (models/attention.py),
            # so the one hard constraint left is that the whole k+1
            # verify window fits the ring — wider would evict columns the
            # same verify still reads.  Paged caches are linear by
            # construction (their init refuses ring configs).
            abs_state = self.ops.init_slot_state(max_batch, max_seq,
                                                 abstract=True)
            if (abs_state.cache_k is not None
                    and abs_state.cache_k.shape[2] < max_seq
                    and k_max + 1 > abs_state.cache_k.shape[2]):
                raise ValueError(
                    f"speculative verify window k+1={k_max + 1} exceeds "
                    f"the sliding-window ring cache "
                    f"({abs_state.cache_k.shape[2]} slots); lower "
                    f"spec_k/spec_k_max below the window")
        self.spec_k = int(spec_k)
        self.spec_k_max = k_max
        self.spec_adaptive = bool(config.spec_adaptive)
        if spec_k and isinstance(drafter, str):
            # factory names resolve here because the draft-model tier
            # needs the serving model to derive its tiny LM from
            drafter = make_drafter(drafter, target=model,
                                   max_batch=max_batch, max_seq=max_seq)
        self.drafter = (drafter or NGramDrafter()) if spec_k else None
        # Warm boot: pull the persistent tuned-block table (written by
        # `python -m benchmarks.tune`) into the substrate before the first
        # trace, so serving never re-derives — or worse, never measures —
        # its kernel tiles.  Missing/stale tables load as empty.
        self.tuned_blocks = kernel_common.load_tuned_table()
        # Retrace telemetry: each counter bumps only when jax *traces* the
        # wrapped python callable, so a steady-state engine shows
        # len(buckets) prefill traces and exactly one decode trace
        # (asserted by tests/test_serving.py::test_bucket_reuse_no_retrace).
        self.trace_counts: collections.Counter = collections.Counter()

        def _prefill_fn(p, inputs, lengths):
            self.trace_counts["prefill"] += 1
            return model.prefill(p, inputs, headroom=0, lengths=lengths)

        def _decode_fn(p, st, inputs):
            self.trace_counts["decode"] += 1
            return model.decode_step(p, st, inputs)

        def _insert_fn(st, sub, slots):
            self.trace_counts["insert"] += 1
            return self.ops.slot_update(st, sub, slots)

        def _reset_fn(st, slots, pos_values, rec):
            self.trace_counts["reset"] += 1
            return self.ops.slot_reset(st, slots, pos_values, rec)

        def _extend_fn(p, st, toks, adv):
            # paged admission: score the whole suffix window in one
            # verify pass and commit the per-row suffix lengths in the
            # same program (advance 0 restores non-admitted rows exactly
            # from their checkpoint-0 state; their stray K/V writes sit
            # past pos, invisible until overwritten — the spec-decode
            # rollback invariant).  rec_stack is returned so the radix
            # cache can snapshot recurrent state at page boundaries.
            self.trace_counts["extend"] += 1
            logits, st2, rec = model.verify_step(p, st, {"tokens": toks})
            ids = jnp.argmax(logits, axis=-1)
            st2 = model.spec_commit(st2, rec, adv)
            return ids, logits, st2, rec

        def _verify_fn(p, st, toks):
            self.trace_counts["verify"] += 1
            logits, st2, rec = model.verify_step(p, st, {"tokens": toks})
            # greedy targets computed in the same dispatch: the host pulls
            # (B, K) ints per step, never the logits (sampling slots pull
            # the full rows lazily — the logits stay on device otherwise)
            ids = jnp.argmax(logits, axis=-1)
            return ids, logits, st2, rec

        def _commit_fn(st, rec, adv):
            self.trace_counts["commit"] += 1
            return model.spec_commit(st, rec, adv)

        def _verify_greedy_fn(p, st, toks, caps):
            self.trace_counts["verify"] += 1
            return model.verify_commit_greedy(p, st, {"tokens": toks}, caps)

        def _slot_restore_fn(st, slots, pos_values, rec):
            # snapshot restore: raw-dtype pos + recurrent-leaf scatter
            # (bucket-padded to max_batch rows, sentinel rows drop — one
            # trace per engine, same discipline as _reset)
            self.trace_counts["restore"] += 1
            return self.ops.slot_restore(st, slots, pos_values, rec)

        self._prefill = jax.jit(_prefill_fn)
        # the old slot state is dead the moment a step returns: donate it
        # so XLA updates the caches in place (donation is a no-op warning
        # on CPU, so only ask for it on accelerators)
        donate = kernel_common.platform() != "cpu"
        self._decode = jax.jit(_decode_fn,
                               donate_argnums=(1,) if donate else ())
        self._insert = jax.jit(_insert_fn,
                               donate_argnums=(0,) if donate else ())
        self._reset = jax.jit(_reset_fn,
                              donate_argnums=(0,) if donate else ())
        self._extend = jax.jit(_extend_fn,
                               donate_argnums=(1,) if donate else ())
        self._verify = jax.jit(_verify_fn,
                               donate_argnums=(1,) if donate else ())
        self._commit = jax.jit(_commit_fn,
                               donate_argnums=(0,) if donate else ())
        self._verify_greedy = jax.jit(_verify_greedy_fn,
                                      donate_argnums=(1,) if donate else ())
        self._slot_restore = jax.jit(_slot_restore_fn,
                                     donate_argnums=(0,) if donate else ())
        # slot state allocates lazily on the first serve(): construction
        # stays cheap (warm boot = load the tuned table, nothing else)
        self._state = None
        self._slots: List[Optional[_Slot]] = [None] * max_batch
        # prompt buckets are powers of two (the ssm/hybrid chunked scans
        # also require pow2-friendly lengths), so the largest bucket is
        # the largest power of two that fits the slot cache
        self._bucket_cap = 1 << (max_seq.bit_length() - 1)
        # scheduler telemetry for the most recent serve() call:
        # ("admit"|"retire", rid, slot, decode_step); slot -1 marks a
        # request retired straight from prefill (1-token budget)
        self.events: List[tuple] = []
        self.step_walls: List[float] = []
        # typed metrics; keeps the historical dict surface (see
        # ServeMetrics) so benches and gates index it unchanged
        self.metrics = ServeMetrics()
        self._occ_num = 0
        self._occ_den = 0
        self._wait_sum = 0.0
        self._n_done = 0
        # -- fault tolerance -----------------------------------------------
        # snapshotted requests awaiting re-admission (rid -> _Parked)
        self._parked: Dict[int, _Parked] = {}
        # serve()'s live queues, lifted to attributes so a mid-trace
        # snapshot can persist not-yet-admitted and finished requests too
        self._pending: collections.deque = collections.deque()
        self._waiting: collections.deque = collections.deque()
        self._done_live: List[Request] = []
        self._ckpt: Optional[CheckpointManager] = None
        self._kill_fired = False
        self._last_snap_step = -1
        # supervisor hook: called once per serve-loop iteration (e.g.
        # HeartbeatMonitor.beat bound to this worker's name)
        self.heartbeat: Optional[Callable[[], None]] = None

    # -- scheduling ---------------------------------------------------------

    def _bucket(self, n: int) -> int:
        return min(max(self.min_bucket, next_pow2(n)), self._bucket_cap)

    def _validate(self, requests: List[Request]) -> None:
        # rids key scheduling events, snapshot/restore and re-admission;
        # a duplicate would silently corrupt accounting, so refuse early.
        live = {s.req.rid for s in self._slots if s is not None}
        seen: set = set()
        for r in requests:
            if r.rid in seen or r.rid in live:
                where = "another live request" if r.rid in live \
                    else "another request in this batch"
                raise ValueError(
                    f"duplicate request id {r.rid} (also used by {where}): "
                    f"request ids key scheduling, snapshot/restore and "
                    f"re-admission — give every request a unique rid")
            seen.add(r.rid)
        for r in requests:
            need = len(r.prompt) + r.max_new_tokens
            if need > self.max_seq:
                raise ValueError(
                    f"request {r.rid}: prompt {len(r.prompt)} + "
                    f"max_new {r.max_new_tokens} exceeds max_seq "
                    f"{self.max_seq}; requests are never silently dropped")
            if len(r.prompt) > self._bucket_cap:
                raise ValueError(
                    f"request {r.rid}: prompt {len(r.prompt)} exceeds the "
                    f"largest prompt bucket ({self._bucket_cap}) for "
                    f"max_seq {self.max_seq}")
            if r.max_new_tokens < 1:
                raise ValueError(f"request {r.rid}: max_new_tokens < 1")
            if len(r.prompt) < 1:
                raise ValueError(f"request {r.rid}: empty prompt")
            if self.allocator is not None:
                pages = min(-(-need // self.page_size), self._n_pages)
                if pages > self.allocator.num_blocks:
                    raise ValueError(
                        f"request {r.rid}: needs {pages} pages but the "
                        f"block pool only holds "
                        f"{self.allocator.num_blocks}; raise num_blocks")

    def _pull_logits(self, logits, sampling: bool):
        """Host-side view of a step's logits: greedy needs only B ints
        (device argmax); only steps where some live request actually
        samples pull the full (B, vocab) float rows."""
        b = self.max_batch
        if self.greedy or not sampling:
            return np.asarray(jnp.argmax(logits.reshape(b, -1),
                                         axis=-1)), None
        return None, np.asarray(logits.astype(jnp.float32)).reshape(b, -1)

    def _next_token(self, slot: _Slot, i: int, ids, rows) -> int:
        return (int(ids[i]) if rows is None
                else self._select_token(slot, rows[i]))

    def _dist(self, slot: _Slot, row: np.ndarray) -> np.ndarray:
        """The request's sampling distribution over one logits row
        (temperature + top_k), shared by plain sampling and the
        spec-decode rejection-sampling fallback."""
        r = slot.req
        z = row.astype(np.float64) / max(r.temperature, 1e-6)
        k = min(int(r.top_k), z.size)   # top_k >= vocab == no filter
        if 0 < k < z.size:
            kth = np.partition(z, -k)[-k]
            z = np.where(z >= kth, z, -np.inf)
        z -= z.max()
        p = np.exp(z)
        p /= p.sum()
        return p

    def _select_token(self, slot: _Slot, row: np.ndarray) -> int:
        if self.greedy or slot.req.temperature <= 0.0:
            return int(np.argmax(row))
        p = self._dist(slot, row)
        return int(slot.rng.choice(len(p), p=p))

    def _retire(self, i: Optional[int], slot: _Slot, done: List[Request]
                ) -> None:
        if slot.session is not None:
            # explicit close() is the drafter API's retire contract:
            # batched drafters free the request's device-side row
            slot.session.close()
            slot.session = None
        r = slot.req
        r.output = np.asarray(slot.tokens[:r.max_new_tokens])
        r.done_at = time.monotonic()
        if r.status == "pending":       # deadline retire pre-sets "timeout"
            r.status = "done"
        done.append(r)
        self._n_done += 1
        self.events.append(("retire", r.rid, -1 if i is None else i,
                            int(self.metrics["decode_steps"])))
        if i is not None:
            if self.paged:
                self._free_slot_pages(i)
            self._slots[i] = None

    # -- mesh seams ----------------------------------------------------------
    # Overridden by runtime/mesh_serve.py's MeshServeEngine; the base
    # implementations are the exact single-device behaviour the loop had
    # before the seams existed.

    def _init_state(self):
        """Allocate the slot-batch state (first serve() call).  The mesh
        engine overrides this to place every leaf with a NamedSharding
        over the serving mesh's data axis."""
        return self.ops.init_slot_state(self.max_batch, self.max_seq)

    def _free_slots(self) -> List[int]:
        """Free slot indices in admission-preference order.  The base
        engine fills lowest-index first; the mesh engine orders by shard
        load (least-loaded shard wins) and excludes slots reserved by
        in-flight async prefills."""
        return [i for i, s in enumerate(self._slots) if s is None]

    def _poll_admissions(self, done: List[Request]) -> None:
        """Complete any finished async prefills (mesh engine hook).  The
        base engine prefills inline, so there is never anything to poll."""

    def _admissions_inflight(self) -> bool:
        """Whether async prefills are still pending (keeps the serve loop
        alive while a prefill worker owns the only remaining work)."""
        return False

    # -- paged slot memory ---------------------------------------------------

    def _st(self):
        """The jit-call view of the slot state.  Paged engines substitute
        the authoritative host block tables on every call (numpy rides the
        cheap C++ argument path); the device echo in the returned state is
        one step stale the moment the host reallocates a page."""
        if not self.paged:
            return self._state
        return self._state._replace(block_tables=self._tables)

    def _alloc_block(self) -> Optional[int]:
        """One free pool block, evicting radix LRU leaves if dry."""
        blk = self.allocator.alloc()
        if blk is None and self.radix is not None:
            if self.radix.evict(1):
                blk = self.allocator.alloc()
        if blk is not None:
            self.metrics["peak_blocks"] = max(
                self.metrics["peak_blocks"], self.allocator.used_blocks)
        return blk

    def _ensure_pages(self, i: int, last_pos: int) -> None:
        """Allocate slot ``i``'s table entries for every page a step may
        write, up to absolute position ``last_pos`` (writes past
        ``max_seq`` drop at the model layer, so the cap is harmless)."""
        if self.allocator is None:       # recurrent-only: nothing pooled
            return
        sentinel = self.allocator.num_blocks
        row = self._tables[i]
        last = min(last_pos, self.max_seq - 1) // self.page_size
        for p in range(last + 1):
            if row[p] == sentinel:
                blk = self._alloc_block()
                if blk is None:
                    # every block is pinned by some live slot: with the
                    # default full-occupancy pool this is unreachable, an
                    # undersized pool oversubscribed by live tokens has no
                    # page to give (requests are never silently dropped)
                    raise RuntimeError(
                        f"block pool exhausted: slot {i} needs page {p} "
                        f"and eviction freed nothing; raise num_blocks")
                row[p] = blk

    def _free_slot_pages(self, i: int) -> None:
        """Return every block slot ``i`` references (retire path)."""
        if self.allocator is None:
            return
        sentinel = self.allocator.num_blocks
        row = self._tables[i]
        for p in range(self._n_pages):
            if row[p] != sentinel:
                self.allocator.free(int(row[p]))
        row[:] = sentinel

    def _admit_paged(self, group: List[Request], free: List[int],
                     done: List[Request]) -> List[Request]:
        """Extend-admission into paged slots; returns requests deferred
        for lack of blocks (the caller requeues them, order preserved).

        Per request: walk the radix trie for the longest full-page prompt
        prefix, take cache references on the matched blocks, allocate
        private pages for the suffix, then one ``slot_reset`` (resume
        ``pos`` at the matched length, load the page-boundary recurrent
        snapshot) and one bucket-padded extend program — a ``verify_step``
        over the suffix window committed by its per-row suffix lengths —
        compute every admitted request's prompt continuation at once.
        Rows not being admitted ride along with advance 0: the commit
        restores their exact pre-call state from checkpoint 0 and their
        stray K/V writes sit past ``pos`` (or drop at table sentinels),
        invisible until overwritten — the spec-decode rollback invariant.
        """
        b = self.max_batch
        page = self.page_size
        now = time.monotonic()
        plan = []                     # (req, slot, matched, nodes)
        leftover: List[Request] = []
        free_iter = iter(free)
        for r in group:
            m, nodes = (self.radix.match(r.prompt)
                        if self.radix is not None else (0, []))
            if self.allocator is not None:
                taken: List[int] = []
                for node in nodes:    # slot's own refs on shared pages
                    self.allocator.ref(node.block)
                    taken.append(node.block)
                new_blocks: List[int] = []
                dry = False
                for _ in range(m // page, (len(r.prompt) - 1) // page + 1):
                    blk = self._alloc_block()
                    if blk is None:
                        dry = True
                        break
                    new_blocks.append(blk)
                if dry:               # roll this request back, keep going
                    for blk in taken + new_blocks:
                        self.allocator.free(blk)
                    leftover.append(r)
                    continue
                slot_i = next(free_iter)
                row = self._tables[slot_i]
                for p, node in enumerate(nodes):
                    row[p] = node.block
                for q, blk in enumerate(new_blocks):
                    row[m // page + q] = blk
            else:
                slot_i = next(free_iter)
            if self.radix is not None:
                self.radix.hits += m // page
                self.radix.misses += (len(r.prompt) - 1) // page + 1 \
                    - m // page
            plan.append((r, slot_i, m, nodes))
        if not plan:
            return leftover

        # one reset program: pos + recurrent snapshots for warm slots
        # (rec keys are the state's recurrent fields — fixed per family,
        # so the reset trace is reused across admissions)
        slots_arr = np.full((b,), b, np.int32)     # sentinel rows drop
        pos_vals = np.zeros((b,), np.int32)
        rec: Dict[str, np.ndarray] = {}
        for name in ("x_prev", "cm_prev", "wkv", "conv_tail", "ssm_h"):
            leaf = getattr(self._state, name, None)
            if leaf is not None:
                rec[name] = np.zeros((leaf.shape[0], b) + tuple(
                    leaf.shape[2:]), np.float32)
        for j, (r, slot_i, m, nodes) in enumerate(plan):
            slots_arr[j] = slot_i
            pos_vals[j] = m
            if m and rec:
                snap = nodes[-1].rec
                for name, arr in rec.items():
                    arr[:, j] = snap[name]
        self._state = self._reset(self._st(), slots_arr, pos_vals, rec)

        # one extend program at the suffix bucket
        bucket = self._bucket(max(len(r.prompt) - m
                                  for r, _, m, _ in plan))
        toks = np.zeros((b, bucket), np.int32)
        adv = np.zeros((b,), np.int32)
        for r, slot_i, m, _ in plan:
            sfx = len(r.prompt) - m
            toks[slot_i, :sfx] = r.prompt[m:]
            adv[slot_i] = sfx
        ids_dev, logits, self._state, rec_stack = self._extend(
            self.params, self._st(), toks, adv)
        ids = np.asarray(ids_dev)                         # (B, bucket)
        rows = None
        if not self.greedy and any(r.temperature > 0.0
                                   for r, _, _, _ in plan):
            rows = np.asarray(logits.astype(jnp.float32))  # (B, bkt, V)
        rec_np = ({name: np.asarray(stk, np.float32)
                   for name, stk in rec_stack.items()}
                  if self.radix is not None else {})

        for r, slot_i, m, nodes in plan:
            sfx = len(r.prompt) - m
            r.admitted_at = now
            self._wait_sum += max(0.0, now - r.submitted_at)
            self.metrics["prefill_tokens"] += sfx
            self.metrics["prefix_hit_tokens"] += m
            self.events.append(("admit", r.rid, slot_i,
                                int(self.metrics["decode_steps"])))
            rng = (np.random.default_rng([r.seed, r.rid])
                   if not self.greedy and r.temperature > 0.0 else None)
            slot = _Slot(req=r, next_token=0, produced=0, tokens=[],
                         rng=rng, pos=len(r.prompt))
            if rows is None:
                slot.next_token = int(ids[slot_i, sfx - 1])
            else:
                slot.next_token = self._select_token(
                    slot, rows[slot_i, sfx - 1])
            slot.tokens.append(slot.next_token)
            slot.produced = 1
            if self.spec_k:
                slot.spec_k = self.spec_k
                slot.session = self.drafter.begin(
                    [int(t) for t in r.prompt] + [slot.next_token],
                    slot=slot_i, rid=r.rid)
            if self.radix is not None and len(r.prompt) // page:
                # register this prompt's full pages; snapshot recurrent
                # state at each page boundary from the extend checkpoints
                # (checkpoint j = state after j suffix tokens, so the
                # page-p boundary sits at j = (p+1)*page - m)
                full = len(r.prompt) // page
                blocks = ([int(self._tables[slot_i, p])
                           for p in range(full)]
                          if self.allocator is not None else None)
                recs = []
                for p in range(full):
                    j = (p + 1) * page - m
                    recs.append({name: stk[j, :, slot_i].copy()
                                 for name, stk in rec_np.items()}
                                if j >= 1 else None)
                self.radix.insert(r.prompt, len(r.prompt), blocks, recs)
            if slot.produced >= r.max_new_tokens:   # 1-token request
                self._free_slot_pages(slot_i)
                self._retire(None, slot, done)
            else:
                self._slots[slot_i] = slot
        return leftover

    def _prefill_args(self, group: List[Request], free: List[int]):
        """Bucket-pad an admission group into prefill arguments.

        Returns ``(inputs, lengths, slots)`` — pure array construction,
        shared by the inline admission path and the mesh engine's async
        prefill workers (the arrays are what a worker thread hands to the
        jitted prefill; ``slots`` drives the insert scatter afterwards).
        """
        cfg = self.model.cfg
        b = self.max_batch
        bucket = self._bucket(max(len(r.prompt) for r in group))
        if cfg.input_kind == "tokens":
            arr = np.zeros((b, bucket), np.int32)
        else:
            arr = np.zeros((b, bucket, cfg.d_model), np.float32)
        lengths = np.ones((b,), np.int32)       # dummy rows: length 1
        slots = np.full((b,), b, np.int32)      # sentinel: scatter drops
        for j, r in enumerate(group):
            arr[j, :len(r.prompt)] = r.prompt
            lengths[j] = len(r.prompt)
            slots[j] = free[j]
        key = "tokens" if cfg.input_kind == "tokens" else "frames"
        return {key: arr}, lengths, slots

    def _admit(self, group: List[Request], free: List[int],
               done: List[Request]) -> None:
        """Prefill a bucket-padded admission group into free slots."""
        inputs, lengths, slots = self._prefill_args(group, free)
        logits, sub = self._prefill(self.params, inputs, lengths)
        self._finish_admit(group, free, logits, sub, slots, done)

    def _finish_admit(self, group: List[Request], free: List[int],
                      logits, sub, slots: np.ndarray,
                      done: List[Request]) -> None:
        """Insert prefilled sub-state into the slot batch + bookkeeping.

        The second half of :meth:`_admit`, split out so the mesh engine's
        prefill workers can run the prefill off-thread and hand
        ``(logits, sub)`` back to the scheduler thread, which owns the
        slot state and performs the insert scatter.
        """
        self._state = self._insert(self._state, sub, slots)
        ids, rows = self._pull_logits(
            logits, any(r.temperature > 0.0 for r in group))
        now = time.monotonic()
        for j, r in enumerate(group):
            r.admitted_at = now
            self._wait_sum += max(0.0, now - r.submitted_at)
            self.metrics["prefill_tokens"] += len(r.prompt)
            self.events.append(("admit", r.rid, free[j],
                                int(self.metrics["decode_steps"])))
            rng = (np.random.default_rng([r.seed, r.rid])
                   if not self.greedy and r.temperature > 0.0 else None)
            slot = _Slot(req=r, next_token=0, produced=0, tokens=[], rng=rng,
                         pos=len(r.prompt))
            slot.next_token = self._next_token(slot, j, ids, rows)
            slot.tokens.append(slot.next_token)
            slot.produced = 1
            if self.spec_k:
                slot.spec_k = self.spec_k
                slot.session = self.drafter.begin(
                    [int(t) for t in r.prompt] + [slot.next_token],
                    slot=free[j], rid=r.rid)
            if slot.produced >= r.max_new_tokens:
                self._retire(None, slot, done)     # 1-token request
            else:
                self._slots[free[j]] = slot

    def _plain_step(self, active: List[int], done: List[Request]) -> None:
        """One single-token decode step for every slot (fixed B).  Also
        the speculative engine's fallback when no slot drafted anything —
        a (B, k+1) verify that can only emit one token per slot would cost
        ~2x the plain program for the same result."""
        cfg = self.model.cfg
        b = self.max_batch
        tokens = np.zeros((b, 1), np.int32)
        for i in active:
            tokens[i, 0] = self._slots[i].next_token
        # numpy leaves go straight to the jitted callable: its C++
        # argument path transfers them ~10x cheaper than an explicit
        # python-level jnp.asarray + device_put per step
        if cfg.input_kind == "tokens":
            nb = {"tokens": tokens}
        else:               # frame stubs decode over embedded tokens
            nb = {"frames": np.zeros((b, 1, cfg.d_model), np.float32)}
        if self.paged:      # this step writes each slot's position `pos`
            for i in active:
                self._ensure_pages(i, self._slots[i].pos)
        logits, self._state = self._decode(self.params, self._st(), nb)
        ids, rows = self._pull_logits(
            logits, any(self._slots[i].rng is not None for i in active))
        self.metrics["decode_steps"] += 1
        self.metrics["decode_tokens"] += len(active)
        self._occ_num += len(active)
        self._occ_den += b

        # retire-and-refill: a finished slot frees this very step
        for i in active:
            slot = self._slots[i]
            slot.next_token = self._next_token(slot, i, ids, rows)
            slot.tokens.append(slot.next_token)
            slot.produced += 1
            slot.pos += 1
            if slot.session is not None:
                slot.session.extend([slot.next_token])
            if slot.produced >= slot.req.max_new_tokens:
                self._retire(i, slot, done)

    # -- speculative decoding ----------------------------------------------

    def _accept_greedy(self, ids_row: np.ndarray, drafts: List[int],
                       cap: int) -> List[int]:
        """Longest matching prefix: accept drafts while they equal the
        model's greedy choice, then append the first correction (the
        bonus token when every draft matched) — exactly the tokens plain
        greedy decode would have produced, one step at a time."""
        a = 0
        while a < cap and int(ids_row[a]) == drafts[a]:
            a += 1
        return drafts[:a] + [int(ids_row[a])]

    def _accept_sampled(self, slot: _Slot, rows: np.ndarray,
                        drafts: List[int], cap: int) -> List[int]:
        """Rejection-sampling fallback for temperature slots.  The drafter
        proposes deterministically (q = a point mass), so the standard
        speculative acceptance rule reduces to: accept draft d with
        probability p(d); on rejection sample from the residual p with d
        removed, renormalised — the emitted stream is distributed exactly
        as plain sampling from p."""
        out: List[int] = []
        a = 0
        while a < cap:
            p = self._dist(slot, rows[a])
            t = drafts[a]
            if slot.rng.random() < p[t]:
                out.append(t)
                a += 1
                continue
            q = p.copy()
            q[t] = 0.0
            s = q.sum()
            if s <= 0.0:            # p was a point mass on the draft
                out.append(int(np.argmax(p)))
            else:
                out.append(int(slot.rng.choice(len(q), p=q / s)))
            return out
        p = self._dist(slot, rows[a])         # bonus position
        out.append(int(slot.rng.choice(len(p), p=p)))
        return out

    # adaptive spec_k: EWMA smoothing weight, the shrink/grow thresholds
    # (hysteresis band between them holds k steady), and how many steps a
    # k=0 slot rides plain decode before probing with a single draft
    _SPEC_ALPHA = 0.3
    _SPEC_LO = 0.2
    _SPEC_HI = 0.5
    _PROBE_EVERY = 16

    def _want_k(self, slot: _Slot) -> int:
        """This step's draft budget for one slot.  Fixed engines always
        ask for the full window; adaptive engines ask for the slot's
        current budget, with a periodic 1-token probe out of k=0 so a
        workload that turns draftable can climb back."""
        if not self.spec_adaptive:
            return self.spec_k_max
        if slot.spec_k == 0:
            slot.spec_probe += 1
            if slot.spec_probe >= self._PROBE_EVERY:
                slot.spec_probe = 0
                return 1
            return 0
        return slot.spec_k

    def _spec_step(self, active: List[int], done: List[Request]) -> None:
        """One speculative engine step: draft, verify, commit, retire.

        Fixed shapes keep one verify trace: every step scores
        (B, spec_k_max+1) tokens; slots with fewer (or no) drafts pad the
        window and simply fail to match there.  Rejected positions roll
        back on commit — recurrent state to its per-step checkpoint,
        linear-cache K/V writes stay masked until the real token
        overwrites them, ring-cache wrapped writes restore their evicted
        columns (see ``models/transformer.py::verify_step``).  Batched
        drafters draft every participating slot in one ``draft_all``
        call; adaptive engines drop low-acceptance slots to k=0, which
        routes whole steps to the (cheaper) plain program below."""
        b = self.max_batch
        k = self.spec_k_max
        toks = np.zeros((b, k + 1), np.int32)
        # per-row ceiling on accepted drafts: real draft count and what is
        # left of the budget after the correction/bonus token; -1 keeps
        # empty slots from advancing at all
        caps = np.full((b,), -1, np.int32)
        drafts: Dict[int, List[int]] = {}
        hist = self.metrics.spec_k_hist
        want: Dict[int, int] = {}
        for i in active:
            slot = self._slots[i]
            hist[slot.spec_k] = hist.get(slot.spec_k, 0) + 1
            want[i] = max(0, min(self._want_k(slot),
                                 slot.req.max_new_tokens - slot.produced
                                 - 1))
        if getattr(self.drafter, "batched", False):
            got = self.drafter.draft_all(
                {i: w for i, w in want.items() if w > 0})
        else:
            got = {i: self._slots[i].session.draft(w)
                   for i, w in want.items() if w > 0}
        for i in active:
            slot = self._slots[i]
            d = got.get(i, [])[:want[i]]
            drafts[i] = d
            toks[i, 0] = slot.next_token
            if d:
                toks[i, 1:1 + len(d)] = d
            caps[i] = min(len(d), slot.req.max_new_tokens - slot.produced
                          - 1)
        if not any(caps[i] > 0 for i in active):
            # nothing worth verifying this step (no drafts, or every slot
            # is one token from its budget): the plain program emits the
            # identical tokens at a fraction of the verify cost
            self._plain_step(active, done)
            return
        emitted: Dict[int, List[int]] = {}
        if self.paged:      # the verify window writes pos..pos+k per slot
            for i in active:
                self._ensure_pages(i, self._slots[i].pos + k)
        if self.greedy:
            # fused path: verify + longest-prefix accept + commit in one
            # dispatch; the host pulls (B, k+1) ids + (B,) advances
            ids_dev, adv_dev, self._state = self._verify_greedy(
                self.params, self._st(), toks, caps)
            ids = np.asarray(ids_dev)
            adv = np.asarray(adv_dev)
            for i in active:
                a = int(adv[i]) - 1
                out = drafts[i][:a] + [int(ids[i, a])]
                emitted[i] = out
                self.metrics["draft_tokens"] += len(drafts[i])
                self.metrics["draft_accepted"] += a
        else:
            # two-phase path: sampling slots need the host-side rejection
            # test, so acceptance happens between verify and commit
            ids_dev, logits, self._state, rec = self._verify(
                self.params, self._st(), toks)
            sampling = any(self._slots[i].rng is not None for i in active)
            ids = np.asarray(ids_dev)                         # (B, k+1)
            rows = (np.asarray(logits.astype(jnp.float32))    # (B, k+1, V)
                    if sampling else None)
            advance = np.zeros((b,), np.int32)
            for i in active:
                slot = self._slots[i]
                if slot.rng is None:
                    out = self._accept_greedy(ids[i], drafts[i], caps[i])
                else:
                    out = self._accept_sampled(slot, rows[i], drafts[i],
                                               caps[i])
                advance[i] = len(out)
                emitted[i] = out
                self.metrics["draft_tokens"] += len(drafts[i])
                self.metrics["draft_accepted"] += len(out) - 1
            self._state = self._commit(self._st(), rec, advance)
        self.metrics["decode_steps"] += 1
        self.metrics["spec_steps"] += 1
        self.metrics["decode_tokens"] += sum(len(v) for v in emitted.values())
        self._occ_num += len(active)
        self._occ_den += b
        for i in active:
            slot = self._slots[i]
            out = emitted[i]
            if self.spec_adaptive and drafts[i]:
                # trailing-acceptance EWMA drives the slot's budget:
                # below the low-water mark shrink toward 0 (plain decode,
                # already the engine's free fallback), above the
                # high-water mark grow back toward spec_k_max
                rate = (len(out) - 1) / len(drafts[i])
                slot.spec_ewma += self._SPEC_ALPHA * (rate - slot.spec_ewma)
                if slot.spec_ewma < self._SPEC_LO:
                    slot.spec_k = max(0, slot.spec_k - 1)
                elif slot.spec_ewma > self._SPEC_HI:
                    slot.spec_k = min(self.spec_k_max, slot.spec_k + 1)
            slot.tokens.extend(out)
            slot.session.extend(out)
            slot.produced += len(out)
            slot.next_token = out[-1]
            old_pos = slot.pos
            slot.pos += len(out)
            if self.paged and self.allocator is not None:
                # spec rollback returns blocks: pages allocated for the
                # verify window but unreached by the committed advance go
                # straight back to the pool (their rejected writes are
                # dead — those positions recompute on a later step)
                sentinel = self.allocator.num_blocks
                last_ens = min(old_pos + k, self.max_seq - 1) \
                    // self.page_size
                for p in range(slot.pos // self.page_size + 1,
                               last_ens + 1):
                    if self._tables[i, p] != sentinel:
                        self.allocator.free(int(self._tables[i, p]))
                        self._tables[i, p] = sentinel
            if slot.produced >= slot.req.max_new_tokens:
                self._retire(i, slot, done)

    # -- snapshot / restore --------------------------------------------------

    _KV_LEAVES = ("cache_k", "cache_v", "scale_k", "scale_v")

    def _ckpt_mgr(self) -> CheckpointManager:
        if self.config.snapshot_dir is None:
            raise ValueError("snapshot/restore needs ServeConfig."
                             "snapshot_dir")
        if self._ckpt is None:
            # sync save: an async writer would race the host-authoritative
            # block tables (live numpy) mutating under the next admission
            self._ckpt = CheckpointManager(self.config.snapshot_dir,
                                           keep=3, async_save=False)
        return self._ckpt

    def snapshot(self) -> int:
        """Persist an atomic, versioned snapshot of every in-flight,
        queued and finished request; returns the step id (the engine's
        decode-step counter).

        Per live slot: prompt, emitted tokens, sampling RNG state, and the
        per-slot state leaves in **raw storage dtype** (int8 + scales
        verbatim) via the ``slot_extract`` gather seam — dense KV trimmed
        to ``pos`` tokens; paged KV as the referenced pool pages in
        logical order (the block table travels implicitly as that
        ordering).  A fresh engine — any ``max_batch``/pool size with the
        same model fingerprint — restores it and resumed requests
        complete bit-identically to an uninterrupted run.
        """
        mgr = self._ckpt_mgr()
        t_start = time.perf_counter()
        state = self._state
        if (not self.paged and state is not None
                and state.cache_k is not None
                and state.cache_k.shape[2] < self.max_seq):
            raise ValueError(
                "cannot snapshot a ring-cache engine (slot cache shorter "
                "than max_seq): ring positions alias, so a linear per-slot "
                "extract does not exist (ROADMAP: ring paging is open)")
        step = int(self.metrics["decode_steps"])
        arrays: Dict[str, np.ndarray] = {}
        slots_meta: List[dict] = []
        live = [(i, s) for i, s in enumerate(self._slots) if s is not None]
        if live:
            idx = np.asarray([i for i, _ in live], np.int32)
            sub = self.ops.slot_extract(state, idx)
            pos_dev = np.asarray(sub.pos)
            host: Dict[str, np.ndarray] = {}
            for name in sub._fields:
                if name in ("pos", "block_tables"):
                    continue
                leaf = getattr(sub, name)
                if leaf is not None:
                    host[name] = np.asarray(leaf)
        for j, (i, slot) in enumerate(live):
            r = slot.req
            pos = int(pos_dev[j])
            arrays[f"slot{j}.prompt"] = np.asarray(r.prompt)
            arrays[f"slot{j}.tokens"] = np.asarray(slot.tokens, np.int32)
            for name, arr in host.items():
                if name in self._KV_LEAVES and not self.paged:
                    arrays[f"slot{j}.{name}"] = arr[:, j, :pos].copy()
                else:
                    arrays[f"slot{j}.{name}"] = arr[:, j].copy()
            if self.paged and self.allocator is not None:
                n_used = (pos - 1) // self.page_size + 1
                ids = np.asarray([int(self._tables[i, p])
                                  for p in range(n_used)], np.int32)
                for name in self._KV_LEAVES:
                    leaf = getattr(state, name)
                    if leaf is not None:
                        arrays[f"slot{j}.pages.{name}"] = \
                            np.asarray(leaf[:, ids])
            slots_meta.append({
                "j": j, "rid": r.rid, "produced": slot.produced,
                "next_token": int(slot.next_token), "pos": pos,
                "max_new_tokens": r.max_new_tokens,
                "temperature": r.temperature, "top_k": r.top_k,
                "seed": r.seed, "deadline_s": r.deadline_s,
                "rng": (slot.rng.bit_generator.state
                        if slot.rng is not None else None),
            })
        queue_meta: List[dict] = []
        for qj, r in enumerate(list(self._waiting) + list(self._pending)):
            arrays[f"queue{qj}.prompt"] = np.asarray(r.prompt)
            queue_meta.append({
                "j": qj, "rid": r.rid,
                "max_new_tokens": r.max_new_tokens,
                "temperature": r.temperature, "top_k": r.top_k,
                "seed": r.seed, "deadline_s": r.deadline_s})
        done_meta: List[dict] = []
        for dj, r in enumerate(self._done_live):
            arrays[f"done{dj}.output"] = (
                np.asarray(r.output) if r.output is not None
                else np.zeros((0,), np.int32))
            done_meta.append({"j": dj, "rid": r.rid, "status": r.status})
        meta = {
            "snapshot_version": SNAPSHOT_VERSION,
            "fingerprint": (self.model.cfg.fingerprint()
                            if getattr(self.model, "cfg", None) is not None
                            else None),
            "engine": {"max_batch": self.max_batch,
                       "max_seq": self.max_seq, "greedy": self.greedy,
                       "paged": self.paged, "spec_k": self.spec_k,
                       "page_size": (self.page_size if self.paged
                                     else None)},
            "slots": slots_meta, "queue": queue_meta, "done": done_meta,
        }
        mgr.save(step, arrays, metadata=meta)
        self.metrics["snapshots"] += 1
        self.metrics["snapshot_s"] += time.perf_counter() - t_start
        return step

    def restore_snapshot(self, step: Optional[int] = None
                         ) -> Tuple[List[Request], List[Request]]:
        """Load a snapshot (latest step by default) into this engine;
        returns ``(requests, completed)``.

        Call on a **fresh** engine, then ``serve(requests)``: snapshotted
        in-flight requests re-enter through their saved state (parked by
        rid until the scheduler reaches them — a smaller ``max_batch``
        simply queues the overflow) and complete bit-identically;
        snapshotted-but-unadmitted requests re-admit from scratch.
        ``completed`` carries the dead engine's already-finished requests
        (outputs + status) for a supervisor to merge by rid.  The model
        fingerprint and sampling mode must match; capacity may differ as
        long as each request still fits (``prompt + max_new <= max_seq``,
        each slot's pages fit the pool).
        """
        mgr = self._ckpt_mgr()
        t_start = time.perf_counter()
        arrays, meta = mgr.load_arrays(step)
        if meta.get("snapshot_version") != SNAPSHOT_VERSION:
            raise ValueError(
                f"snapshot version {meta.get('snapshot_version')!r} is "
                f"not supported (this engine speaks {SNAPSHOT_VERSION})")
        fp = (self.model.cfg.fingerprint()
              if getattr(self.model, "cfg", None) is not None else None)
        if meta.get("fingerprint") != fp:
            raise ValueError(
                f"snapshot fingerprint mismatch: taken under "
                f"{meta.get('fingerprint')}, this engine is {fp} — "
                f"restoring across architectures or cache formats cannot "
                f"be bit-identical")
        eng = meta.get("engine", {})
        if bool(eng.get("greedy")) != bool(self.greedy):
            raise ValueError("snapshot sampling mode (greedy="
                             f"{eng.get('greedy')}) differs from this "
                             f"engine's (greedy={self.greedy})")
        requests: List[Request] = []
        for srec in meta.get("slots", []):
            j = srec["j"]
            prompt = arrays[f"slot{j}.prompt"]
            r = Request(rid=srec["rid"], prompt=prompt,
                        max_new_tokens=srec["max_new_tokens"],
                        temperature=srec["temperature"],
                        top_k=srec["top_k"], seed=srec["seed"],
                        deadline_s=srec.get("deadline_s"))
            need = len(prompt) + r.max_new_tokens
            if need > self.max_seq:
                raise ValueError(
                    f"restored request {r.rid} needs {need} cache "
                    f"positions but this engine's max_seq is "
                    f"{self.max_seq}")
            leaves: Dict[str, np.ndarray] = {}
            pages: Dict[str, np.ndarray] = {}
            pre = f"slot{j}."
            for key, arr in arrays.items():
                if not key.startswith(pre):
                    continue
                name = key[len(pre):]
                if name in ("prompt", "tokens"):
                    continue
                if name.startswith("pages."):
                    pages[name[len("pages."):]] = arr
                else:
                    leaves[name] = arr
            if self.paged and self.allocator is not None and pages:
                n_used = next(iter(pages.values())).shape[1]
                if n_used > self.allocator.num_blocks:
                    raise ValueError(
                        f"restored request {r.rid} holds {n_used} pages "
                        f"but this engine's pool only has "
                        f"{self.allocator.num_blocks} blocks; raise "
                        f"num_blocks")
            self._parked[r.rid] = _Parked(
                tokens=[int(t) for t in arrays[f"slot{j}.tokens"]],
                next_token=int(srec["next_token"]),
                produced=int(srec["produced"]), pos=int(srec["pos"]),
                rng_state=srec.get("rng"), leaves=leaves, pages=pages)
            requests.append(r)
        for qrec in meta.get("queue", []):
            requests.append(Request(
                rid=qrec["rid"], prompt=arrays[f"queue{qrec['j']}.prompt"],
                max_new_tokens=qrec["max_new_tokens"],
                temperature=qrec["temperature"], top_k=qrec["top_k"],
                seed=qrec["seed"], deadline_s=qrec.get("deadline_s")))
        completed = [
            Request(rid=drec["rid"], prompt=np.zeros((0,), np.int32),
                    output=arrays[f"done{drec['j']}.output"],
                    status=drec.get("status", "done"))
            for drec in meta.get("done", [])]
        self.metrics["restore_s"] += time.perf_counter() - t_start
        return requests, completed

    def _admit_restored(self, group: List[Request], free: List[int],
                        done: List[Request]) -> List[Request]:
        """Re-admit parked (snapshot-restored) requests into free slots;
        returns requests deferred for lack of pool blocks (paged only).

        Dense engines rebuild a bucket-padded sub-state from the stored
        raw leaves and reuse the ``_insert`` scatter program (the same
        trace a prefill admission of that bucket uses — restore never
        retraces a warm engine).  Paged engines allocate fresh blocks,
        write the stored pages back with fixed-shape *eager* pool updates
        (nothing traced), and scatter pos + recurrent leaves through the
        one jitted ``slot_restore`` program.
        """
        t_start = time.perf_counter()
        b = self.max_batch
        entries = [(r, self._parked[r.rid]) for r in group]
        leftover: List[Request] = []
        placed: List[tuple] = []
        if self.paged:
            free_iter = iter(free)
            for r, e in entries:
                new_ids: List[int] = []
                if self.allocator is not None:
                    n_used = (e.pos - 1) // self.page_size + 1
                    dry = False
                    for _ in range(n_used):
                        blk = self._alloc_block()
                        if blk is None:
                            dry = True
                            break
                        new_ids.append(blk)
                    if dry:           # roll back, requeue, keep going
                        for blk in new_ids:
                            self.allocator.free(blk)
                        leftover.append(r)
                        continue
                slot_i = next(free_iter)
                if self.allocator is not None:
                    for p, blk in enumerate(new_ids):
                        self._tables[slot_i, p] = blk
                placed.append((r, e, slot_i, new_ids))
            if placed and self.allocator is not None:
                all_ids = np.concatenate(
                    [np.asarray(ids, np.int32)
                     for _, _, _, ids in placed])
                updates: Dict[str, Any] = {}
                for name in self._KV_LEAVES:
                    tgt = getattr(self._state, name)
                    if tgt is None:
                        continue
                    pgs = np.concatenate(
                        [e.pages[name] for _, e, _, _ in placed], axis=1)
                    updates[name] = tgt.at[:, all_ids].set(
                        jnp.asarray(pgs, tgt.dtype))
                self._state = self._state._replace(**updates)
            if placed:
                slots_arr = np.full((b,), b, np.int32)
                pos_vals = np.zeros((b,), np.int32)
                rec_names = [
                    n for n in ("x_prev", "cm_prev", "wkv", "conv_tail",
                                "ssm_h", "wkv_scale", "ssm_scale")
                    if getattr(self._state, n, None) is not None]
                rec = {n: np.zeros(
                    (getattr(self._state, n).shape[0], b)
                    + tuple(getattr(self._state, n).shape[2:]),
                    getattr(self._state, n).dtype) for n in rec_names}
                for g, (r, e, slot_i, _) in enumerate(placed):
                    slots_arr[g] = slot_i
                    pos_vals[g] = e.pos
                    for n in rec_names:
                        rec[n][:, g] = e.leaves[n]
                self._state = self._slot_restore(self._st(), slots_arr,
                                                 pos_vals, rec)
        else:
            state = self._state
            max_pos = max(e.pos for _, e in entries)
            cache_len = (state.cache_k.shape[2]
                         if state.cache_k is not None else None)
            bk = self._bucket(max_pos)
            if cache_len is not None and bk < max_pos:
                bk = cache_len     # non-pow2 max_seq tail: one-off shape
            fields: Dict[str, Any] = {}
            for name in state._fields:
                leaf = getattr(state, name)
                if leaf is None:
                    fields[name] = None
                elif name == "pos":
                    fields[name] = np.zeros((b,), np.int32)
                elif name in self._KV_LEAVES:
                    fields[name] = np.zeros(
                        (leaf.shape[0], b, bk) + tuple(leaf.shape[3:]),
                        leaf.dtype)
                else:
                    fields[name] = np.zeros(
                        (leaf.shape[0], b) + tuple(leaf.shape[2:]),
                        leaf.dtype)
            slots_arr = np.full((b,), b, np.int32)
            for g, (r, e) in enumerate(entries):
                slots_arr[g] = free[g]
                fields["pos"][g] = e.pos
                for name, arr in e.leaves.items():
                    if name in self._KV_LEAVES:
                        fields[name][:, g, :e.pos] = arr
                    else:
                        fields[name][:, g] = arr
                placed.append((r, e, free[g], []))
            sub = type(state)(**fields)
            self._state = self._insert(self._state, sub, slots_arr)

        now = time.monotonic()
        step = int(self.metrics["decode_steps"])
        for r, e, slot_i, _ in placed:
            r.admitted_at = now
            self._wait_sum += max(0.0, now - r.submitted_at)
            self.events.append(("restore", r.rid, slot_i, step))
            rng = None
            if e.rng_state is not None:
                rng = np.random.default_rng()
                rng.bit_generator.state = e.rng_state
            slot = _Slot(req=r, next_token=e.next_token,
                         produced=e.produced, tokens=list(e.tokens),
                         rng=rng, pos=e.pos)
            if self.spec_k:
                slot.spec_k = self.spec_k
                slot.session = self.drafter.begin(
                    [int(t) for t in r.prompt] + slot.tokens[:1],
                    slot=slot_i, rid=r.rid)
                if len(slot.tokens) > 1:
                    slot.session.extend(slot.tokens[1:])
            self._slots[slot_i] = slot
            del self._parked[r.rid]
        self.metrics["restore_s"] += time.perf_counter() - t_start
        return leftover

    # -- backpressure / fault injection -------------------------------------

    def _shed(self, r: Request, done: List[Request], status: str) -> None:
        """Terminal no-service disposition: empty output, counted."""
        r.status = status
        r.output = np.zeros((0,), np.int32)
        r.done_at = time.monotonic()
        self.metrics["shed_count" if status == "shed"
                     else "timeout_count"] += 1
        self.events.append((status, r.rid, -1,
                            int(self.metrics["decode_steps"])))
        done.append(r)

    def _enqueue(self, r: Request, done: List[Request]) -> None:
        """Admit an arrival to the bounded waiting queue, shedding per
        the configured policy on overflow."""
        mq = self.config.max_queue
        w = self._waiting
        if mq is None or len(w) < mq:
            w.append(r)
        else:
            pol = self.config.admission_policy
            if pol == "reject-new":
                self._shed(r, done, "shed")
            elif pol == "shed-oldest":
                victim = w.popleft()
                w.append(r)
                self._shed(victim, done, "shed")
            else:                       # shed-lowest-budget
                lo = min(range(len(w)),
                         key=lambda i: w[i].max_new_tokens)
                if w[lo].max_new_tokens < r.max_new_tokens:
                    victim = w[lo]
                    del w[lo]
                    w.append(r)
                    self._shed(victim, done, "shed")
                else:                   # ties shed the newcomer
                    self._shed(r, done, "shed")
        self.metrics["queue_depth"] = len(w)
        self.metrics["peak_queue_depth"] = max(
            self.metrics["peak_queue_depth"], len(w))

    def _sweep_deadlines(self, done: List[Request]) -> None:
        """Expire deadlined requests: waiting ones shed outright; live
        ones retire gracefully with their partial output."""
        now = time.monotonic()
        w = self._waiting
        for _ in range(len(w)):         # rotate in place, order kept
            r = w.popleft()
            if (r.deadline_s is not None
                    and now - r.submitted_at >= r.deadline_s):
                self._shed(r, done, "timeout")
            else:
                w.append(r)
        for i, slot in enumerate(self._slots):
            if slot is None:
                continue
            r = slot.req
            if (r.deadline_s is not None
                    and now - r.submitted_at >= r.deadline_s):
                r.status = "timeout"
                self.metrics["timeout_count"] += 1
                self._retire(i, slot, done)

    def _tick(self) -> None:
        """Per-iteration housekeeping: heartbeat, snapshot cadence, fault
        injection.  Runs *after* the decode step so snapshots capture a
        consistent post-step state; the injected kill does NOT snapshot
        first — hard-kill semantics, forcing restore to replay from the
        last cadence snapshot (replayed steps are deterministic, so the
        resumed outputs stay bit-identical)."""
        if self.heartbeat is not None:
            self.heartbeat()
        ds = int(self.metrics["decode_steps"])
        ev = self.config.snapshot_every
        if (ev and self.config.snapshot_dir is not None and ds
                and ds % ev == 0 and ds != self._last_snap_step):
            self.snapshot()
            self._last_snap_step = ds
        if (self.config.kill_at_step is not None and not self._kill_fired
                and ds >= self.config.kill_at_step):
            self._kill_fired = True
            raise WorkerKilled(
                f"injected fault: worker killed after decode step {ds}")

    # -- the loop -----------------------------------------------------------

    def serve(self, requests: List[Request]) -> List[Request]:
        """Run the trace to completion; returns requests in finish order.

        Requests become visible to the scheduler at ``arrival_s`` seconds
        after the call (0 = immediately); every request is served —
        over-budget requests raise instead of being dropped — unless a
        backpressure policy (``max_queue``/``deadline_s``) explicitly
        sheds it, in which case it returns with a terminal ``status`` and
        empty/partial output.  Requests whose rid matches a
        :meth:`restore_snapshot` parked entry resume from their
        snapshotted state instead of prefilling.
        """
        self._validate(requests)
        if self._state is None:
            self._state = self._init_state()
        # events and the averaged metrics (queue_wait_s, slot_occupancy)
        # describe this call's trace; the token/step counters accumulate
        # over the engine lifetime.
        self.events = []
        # monotonic timestamp after every decode step (this call only):
        # consecutive diffs are the decode-stall distribution the mesh
        # bench reads (a long inline prefill shows up as one huge gap)
        self.step_walls: List[float] = []
        self._occ_num = self._occ_den = 0
        self._wait_sum = 0.0
        self._n_done = 0
        t0 = time.monotonic()
        for r in requests:
            r.submitted_at = t0 + r.arrival_s
        # pending = not yet arrived; waiting = arrived, unadmitted (the
        # bounded admission queue).  Instance attributes so a mid-trace
        # snapshot persists them alongside the slots.
        self._pending = collections.deque(
            sorted(requests, key=lambda r: (r.arrival_s, r.rid)))
        self._waiting = collections.deque()
        done: List[Request] = []
        self._done_live = done

        while (self._pending or self._waiting or self._admissions_inflight()
               or any(s is not None for s in self._slots)):
            now_rel = time.monotonic() - t0
            while (self._pending
                   and self._pending[0].arrival_s <= now_rel):
                self._enqueue(self._pending.popleft(), done)
            self._sweep_deadlines(done)
            # land any prefills the worker pool finished since last step
            # (mesh engine; inline engines never have admissions in flight)
            self._poll_admissions(done)

            # admission: refill free slots from the waiting queue;
            # snapshot-restored rids re-enter through their saved state
            free = self._free_slots()
            group: List[Request] = []
            while self._waiting and len(group) < len(free):
                group.append(self._waiting.popleft())
            admitted_any = False
            if group:
                parked = [r for r in group if r.rid in self._parked]
                fresh = [r for r in group if r.rid not in self._parked]
                nfree = free
                if parked:
                    leftover = self._admit_restored(parked, nfree, done)
                    for r in reversed(leftover):
                        self._waiting.appendleft(r)
                    n_placed = len(parked) - len(leftover)
                    admitted_any = n_placed > 0
                    nfree = nfree[n_placed:]
                if fresh and self.paged:
                    # extend-admission; requests the pool cannot hold yet
                    # go back to the queue head (order preserved) and wait
                    # for a retirement to return blocks
                    leftover = self._admit_paged(fresh, nfree, done)
                    for r in reversed(leftover):
                        self._waiting.appendleft(r)
                    admitted_any = (admitted_any
                                    or len(leftover) < len(fresh))
                elif fresh:
                    self._admit(fresh, nfree, done)
                    admitted_any = True
            self.metrics["queue_depth"] = len(self._waiting)

            active = [i for i, s in enumerate(self._slots) if s is not None]
            if (group and not admitted_any and not active
                    and not self._admissions_inflight()):
                raise RuntimeError(
                    "block pool exhausted: no queued request fits "
                    "with every slot idle; raise num_blocks")
            if not active:
                if self._admissions_inflight():
                    # nothing to decode until a prefill worker delivers
                    time.sleep(0.0005)
                elif self._pending and not self._waiting:
                    # idle: wait for the next arrival
                    time.sleep(min(
                        0.005,
                        max(0.0, self._pending[0].arrival_s
                            - (time.monotonic() - t0))))
                continue

            if self.spec_k:
                # speculative step: draft k per slot, verify k+1 at once,
                # commit a variable 0..k+1 advance per slot (falls back to
                # a plain step when no slot has anything worth verifying)
                self._spec_step(active, done)
            else:
                self._plain_step(active, done)
            self.step_walls.append(time.monotonic())
            if self._admissions_inflight():
                # a decode step ran while a prefill was still in flight —
                # the prefill/decode split working as intended (always 0
                # on the inline admission path)
                self.metrics["overlap_steps"] += 1
            # heartbeat + snapshot cadence + injected faults (may raise
            # WorkerKilled out of this call — the supervisor's job)
            self._tick()

        self.metrics["queue_depth"] = 0
        self.metrics["queue_wait_s"] = self._wait_sum / max(self._n_done, 1)
        self.metrics["slot_occupancy"] = self._occ_num / max(self._occ_den, 1)
        self.metrics["spec_acceptance"] = (
            self.metrics["draft_accepted"]
            / max(self.metrics["draft_tokens"], 1))
        self.metrics["tokens_per_step"] = (
            self.metrics["decode_tokens"]
            / max(self.metrics["decode_steps"], 1))
        self.metrics["wall_s"] = time.monotonic() - t0
        self.metrics["tok_s"] = (
            sum(len(r.output) for r in done if r.output is not None)
            / max(self.metrics["wall_s"], 1e-9))
        # tiered drafters expose which tier served each drafting slot-step
        self.metrics["model_drafts"] = int(
            getattr(self.drafter, "model_dispatches", 0))
        self.metrics["fallback_drafts"] = int(
            getattr(self.drafter, "fallback_dispatches", 0))
        return done


class GangServeEngine:
    """The pre-continuous-batching scheduler, kept as the benchmark
    baseline: packs up to ``max_batch`` requests, prefills them together
    (left-padded to the longest prompt — a fresh trace per composition),
    decodes the gang in lockstep until the *slowest* request finishes, and
    only then admits more.  ``benchmarks/serve_bench.py`` replays the same
    trace through this and :class:`ServeEngine` to measure the gap."""

    def __init__(self, model: Model, params, max_batch: int = 8,
                 max_seq: int = 256, greedy: bool = True):
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.tuned_blocks = kernel_common.load_tuned_table()
        self._prefill = jax.jit(
            lambda p, batch: model.prefill(p, batch))
        self._decode = jax.jit(
            lambda p, st, batch: model.decode_step(p, st, batch))
        self.metrics: Dict[str, float] = {"prefill_tokens": 0,
                                          "decode_tokens": 0}

    def _pad_prompts(self, reqs: List[Request]) -> Dict[str, jnp.ndarray]:
        cfg = self.model.cfg
        s = max(len(r.prompt) for r in reqs)
        b = len(reqs)
        if cfg.input_kind == "tokens":
            toks = np.zeros((b, s), np.int32)
            for i, r in enumerate(reqs):
                toks[i, s - len(r.prompt):] = r.prompt  # left-pad
            return {"tokens": jnp.asarray(toks)}
        d = cfg.d_model
        frames = np.zeros((b, s, d), np.float32)
        for i, r in enumerate(reqs):
            frames[i, s - len(r.prompt):] = r.prompt
        return {"frames": jnp.asarray(frames)}

    def serve(self, requests: List[Request]) -> List[Request]:
        """Gang scheduling: admit up to max_batch, prefill together,
        decode in lockstep, admit the next gang when all finish."""
        pending = sorted(requests, key=lambda r: (r.arrival_s, r.rid))
        t0 = time.monotonic()
        for r in pending:
            r.submitted_at = t0 + r.arrival_s
        done: List[Request] = []

        while pending:
            batch = pending[:self.max_batch]
            pending = pending[self.max_batch:]
            # gang admission waits until every member of the batch has
            # arrived (it cannot start a partial gang and refill later) —
            # keeps latencies non-negative and wall clocks comparable with
            # the continuous engine replaying the same arrival trace.
            wait = t0 + max(r.arrival_s for r in batch) - time.monotonic()
            if wait > 0:
                time.sleep(wait)
            inputs = self._pad_prompts(batch)
            logits, state = self._prefill(self.params, inputs)
            self.metrics["prefill_tokens"] += sum(len(r.prompt)
                                                  for r in batch)
            b = len(batch)
            outs = [[] for _ in range(b)]
            next_tok = jnp.argmax(logits.reshape(b, -1), axis=-1)
            steps = max(r.max_new_tokens for r in batch)
            for t in range(steps):
                for i in range(b):
                    if t < batch[i].max_new_tokens:
                        outs[i].append(int(next_tok[i]))
                if self.model.cfg.input_kind == "tokens":
                    nb = {"tokens": next_tok[:, None].astype(jnp.int32)}
                else:  # frame stubs decode over embedded tokens
                    nb = {"frames": jnp.zeros(
                        (b, 1, self.model.cfg.d_model), jnp.float32)}
                logits, state = self._decode(self.params, state, nb)
                v = logits.reshape(b, -1)
                next_tok = jnp.argmax(v, axis=-1)
                self.metrics["decode_tokens"] += b
            for i, r in enumerate(batch):
                r.output = np.asarray(outs[i][:r.max_new_tokens])
                r.done_at = time.monotonic()
                done.append(r)
        return done
