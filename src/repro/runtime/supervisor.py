"""Serve-engine supervision: run, detect death, restore, re-admit.

The serving analogue of :class:`repro.parallel.fault_tolerance.TrainSupervisor`:
a :class:`ServeSupervisor` owns an engine *factory* rather than an engine —
on a worker death (a :class:`~repro.parallel.fault_tolerance.WorkerKilled`
escaping ``serve()``, whether injected by ``ServeConfig.kill_at_step`` or a
real preemption signal translated by the host runtime) it abandons the dead
engine wholesale, builds a fresh one, restores the latest slot snapshot
from ``ServeConfig.snapshot_dir``, and re-admits the survivors.

Recovery is **hard-kill** shaped: nothing is read from the dead engine's
memory.  Everything the new engine knows comes from the last cadence
snapshot — in-flight requests resume from their snapshotted state
bit-identically; requests that finished *after* that snapshot (their
outputs died with the worker) and requests the snapshot never saw are
replayed from scratch, which is equally bit-identical because per-request
decoding is deterministic given (prompt, sampling params, seed).  The
:class:`~repro.parallel.fault_tolerance.HeartbeatMonitor` records each
incarnation's liveness (``serve()`` beats it every loop iteration), so an
external health plane sees the same death/respawn sequence the supervisor
acts on.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

from repro.parallel.fault_tolerance import HeartbeatMonitor, WorkerKilled
from repro.runtime.serve_loop import Request, ServeEngine


def _clone(r: Request) -> Request:
    """A fresh, unserved copy of a request (replay-from-scratch path).

    The original object may have been mutated by the dead engine
    (``submitted_at``, partial bookkeeping); replays must start clean, and
    arrive immediately — their original arrival offset already elapsed in
    the first incarnation's lifetime.
    """
    return Request(rid=r.rid, prompt=r.prompt,
                   max_new_tokens=r.max_new_tokens, arrival_s=0.0,
                   temperature=r.temperature, top_k=r.top_k, seed=r.seed,
                   deadline_s=r.deadline_s)


@dataclasses.dataclass
class RestartRecord:
    """One recovery cycle, for telemetry/assertions."""
    restart: int
    restored_step: Optional[int]        # None = no snapshot had landed
    resumed_rids: List[int]             # restored mid-flight from the snapshot
    replayed_rids: List[int]            # re-run from scratch
    recovered_rids: List[int]           # finished outputs carried over


class ServeSupervisor:
    """Run a serve trace to completion across worker deaths.

    ``engine_factory(incarnation) -> ServeEngine`` builds each worker;
    incarnation 0 is the initial engine, 1.. are post-crash respawns (the
    factory decides whether respawns keep injecting faults, get a smaller
    pool, a different ``max_batch``, ...).  Every engine's config must
    point at the same ``snapshot_dir``.
    """

    def __init__(self, engine_factory: Callable[[int], ServeEngine],
                 max_restarts: int = 5,
                 monitor: Optional[HeartbeatMonitor] = None,
                 worker_name: str = "serve"):
        self.engine_factory = engine_factory
        self.max_restarts = max_restarts
        self.monitor = monitor or HeartbeatMonitor([], timeout_s=60.0)
        self.worker_name = worker_name
        self.history: List[RestartRecord] = []
        self.engine: Optional[ServeEngine] = None   # current incarnation

    def _spawn(self, incarnation: int) -> ServeEngine:
        name = (self.worker_name if incarnation == 0
                else f"{self.worker_name}-r{incarnation}")
        engine = self.engine_factory(incarnation)
        self.monitor.add_worker(name)
        engine.heartbeat = lambda: self.monitor.beat(name)
        self.engine = engine
        self._name = name
        return engine

    def run(self, requests: List[Request]) -> List[Request]:
        """Serve ``requests`` to completion; returns them rid-ordered.

        Every submitted rid appears exactly once in the result with a
        terminal status — completed, shed, or timed out — no matter how
        many times the worker died along the way.
        """
        engine = self._spawn(0)
        outstanding: List[Request] = list(requests)
        results: Dict[int, Request] = {}
        restarts = 0
        while True:
            try:
                for r in engine.serve(outstanding):
                    results.setdefault(r.rid, r)
                break
            except WorkerKilled:
                self.monitor.mark_dead(self._name)
                restarts += 1
                if restarts > self.max_restarts:
                    raise RuntimeError(
                        f"restart budget exhausted after {restarts - 1} "
                        f"recoveries")
                engine = self._spawn(restarts)
                try:
                    survivors, completed = engine.restore_snapshot()
                    step = engine._ckpt.latest_step()
                except FileNotFoundError:
                    survivors, completed, step = [], [], None
                for r in completed:
                    results.setdefault(r.rid, r)
                known = ({r.rid for r in survivors}
                         | {r.rid for r in completed} | set(results))
                replay = [_clone(r) for r in requests
                          if r.rid not in known]
                outstanding = survivors + replay
                self.history.append(RestartRecord(
                    restart=restarts, restored_step=step,
                    resumed_rids=[r.rid for r in survivors],
                    replayed_rids=[r.rid for r in replay],
                    recovered_rids=[r.rid for r in completed]))
                if not outstanding:
                    break
        return [results[rid] for rid in sorted(results)]
