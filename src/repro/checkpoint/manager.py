"""Checkpoint/restart substrate (fault tolerance deliverable).

Design for thousands of nodes:
  * **Atomic steps** — each checkpoint is written to ``step_N.tmp`` and
    renamed only after every shard file + metadata fsyncs; a crash mid-write
    can never corrupt the restore point.
  * **Async save** — device->host transfer happens on the caller thread
    (cheap), serialization happens on a background thread so the train loop
    resumes immediately (overlaps I/O with compute).
  * **Elastic re-sharding** — checkpoints are stored as full logical arrays
    (unsharded npz shards by pytree leaf).  Restore takes *any* target mesh
    and re-applies the sharding rules, so a job can come back on a different
    topology (e.g. 512 -> 448 chips after losing a pod slice).
  * **Retention** — keep the latest K checkpoints, delete older atomically.

On a real multi-host cluster each host would write only its addressable
shards (jax.experimental.array_serialization); the single-process layout
here keeps the same commit protocol and restore semantics.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, state: Dict[str, Any],
             metadata: Optional[Dict[str, Any]] = None) -> None:
        """state: pytree dict (params/opt_state/etc.).  Non-blocking when
        async_save: device arrays are snapshotted to host first."""
        self.wait()
        host_state = jax.tree_util.tree_map(np.asarray, state)
        meta = dict(metadata or {})
        meta.update({"step": step, "time": time.time()})

        def _write():
            try:
                tmp = os.path.join(self.dir, f"step_{step}.tmp")
                final = os.path.join(self.dir, f"step_{step}")
                if os.path.exists(tmp):
                    shutil.rmtree(tmp)
                os.makedirs(tmp)
                flat = _flatten_with_paths(host_state)
                # npz can't round-trip ml_dtypes (bfloat16 etc.); store such
                # arrays as raw uint views + a dtype sidecar.
                store = {}
                dtypes = {}
                for k, v in flat.items():
                    dtypes[k] = str(v.dtype)
                    if v.dtype.kind not in "fiub":
                        v = v.view(np.uint16 if v.dtype.itemsize == 2
                                   else np.uint8)
                    store[k] = v
                meta["dtypes"] = dtypes
                np.savez(os.path.join(tmp, "arrays.npz"), **store)
                with open(os.path.join(tmp, "meta.json"), "w") as f:
                    json.dump(meta, f)
                    f.flush()
                    os.fsync(f.fileno())
                # os.replace cannot overwrite a non-empty directory; a
                # re-save at the same step (e.g. a serve snapshot retaken
                # at an unchanged decode step after restart) replaces the
                # committed dir wholesale.
                if os.path.isdir(final):
                    shutil.rmtree(final)
                os.replace(tmp, final)       # the atomic commit point
                self._gc()
            except BaseException as e:       # surfaced on next save/wait
                self._error = e

        if self.async_save:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()
            self._raise_if_failed()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_if_failed()

    def _raise_if_failed(self):
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError(f"async checkpoint save failed: {err!r}")

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    # -- restore --------------------------------------------------------------
    def all_steps(self):
        steps = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    steps.append(int(name.split("_")[1]))
                except ValueError:
                    continue
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template, step: Optional[int] = None,
                shardings=None) -> Any:
        """Restore into the structure of ``template``.

        ``shardings``: optional matching tree of NamedShardings for the
        *target* mesh — this is the elastic-rescale path: the stored logical
        arrays are placed with the new partitioning regardless of the mesh
        they were saved under.
        """
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        path = os.path.join(self.dir, f"step_{step}")
        arrays = np.load(os.path.join(path, "arrays.npz"))
        with open(os.path.join(path, "meta.json")) as f:
            dtypes = json.load(f).get("dtypes", {})
        flat_t, treedef = jax.tree_util.tree_flatten_with_path(template)
        flat_s = (jax.tree_util.tree_flatten_with_path(shardings)[0]
                  if shardings is not None else [(None, None)] * len(flat_t))
        leaves = []
        for (tpath, tleaf), (_, sh) in zip(flat_t, flat_s):
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                           for p in tpath)
            arr = arrays[key]
            want = dtypes.get(key)
            if want and str(arr.dtype) != want:
                import ml_dtypes
                arr = arr.view(np.dtype(getattr(ml_dtypes, want)))
            if sh is not None:
                leaves.append(jax.device_put(arr, sh))
            else:
                leaves.append(jnp.asarray(arr, dtype=tleaf.dtype)
                              if hasattr(tleaf, "dtype") else jnp.asarray(arr))
        return jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(template), leaves)

    def load_arrays(self, step: Optional[int] = None
                    ) -> "tuple[Dict[str, np.ndarray], Dict[str, Any]]":
        """Load a checkpoint as a flat ``{path_key: ndarray}`` dict + meta.

        The template-free restore path: callers that saved a flat dict of
        host arrays (the serving snapshot) get back exactly what they
        stored — dtype sidecar applied (bf16 etc. un-viewed), no jax
        placement, no structure to pre-build.
        """
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        path = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
        dtypes = meta.get("dtypes", {})
        out: Dict[str, np.ndarray] = {}
        with np.load(os.path.join(path, "arrays.npz")) as arrays:
            for key in arrays.files:
                arr = arrays[key]
                want = dtypes.get(key)
                if want and str(arr.dtype) != want:
                    import ml_dtypes
                    arr = arr.view(np.dtype(getattr(ml_dtypes, want)))
                out[key] = arr
        return out, meta

    def metadata(self, step: Optional[int] = None) -> Dict[str, Any]:
        step = step if step is not None else self.latest_step()
        with open(os.path.join(self.dir, f"step_{step}", "meta.json")) as f:
            return json.load(f)
