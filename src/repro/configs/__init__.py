from repro.configs.base import (ArchConfig, CacheSpec, ExecutionPolicy,
                                ShapeConfig, LM_SHAPES, BF16_EXEC,
                                CORDIC_EXEC, shape_applicable)  # noqa: F401
from repro.configs.registry import ARCHS, get_arch  # noqa: F401
