"""llava-next-mistral-7b [vlm] 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000.  Mistral-7B backbone; the anyres-tiling vision frontend is a
STUB — input_specs() provides precomputed patch embeddings.
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-mistral-7b", family="vlm",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab_size=32000,
    rope_theta=1000000.0, input_kind="frames", activation="silu",
)
