"""musicgen-medium [audio] 48L d_model=1536 24H (GQA kv=24) d_ff=6144
vocab=2048.  Decoder-only over EnCodec tokens; the EnCodec frontend is a
STUB — input_specs() provides precomputed frame embeddings.
[arXiv:2306.05284; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-medium", family="audio",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24,
    d_ff=6144, vocab_size=2048,
    n_codebooks=4, input_kind="frames", activation="gelu",
)
