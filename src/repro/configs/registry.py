"""Architecture registry: --arch <id> resolution."""
from __future__ import annotations

from typing import Dict

from repro.configs.base import ArchConfig
from repro.configs import (arctic_480b, glm4_9b, granite_moe_3b, hymba_1_5b,
                           llava_next_mistral_7b, musicgen_medium,
                           phi3_medium_14b, qwen2_5_14b, rwkv6_3b,
                           stablelm_12b)

ARCHS: Dict[str, ArchConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (glm4_9b, stablelm_12b, qwen2_5_14b, phi3_medium_14b,
              arctic_480b, granite_moe_3b, rwkv6_3b, musicgen_medium,
              hymba_1_5b, llava_next_mistral_7b)
}

ALIASES = {
    "glm4": "glm4-9b", "stablelm": "stablelm-12b", "qwen2.5-14b": "qwen2.5-14b",
    "qwen": "qwen2.5-14b", "phi3": "phi3-medium-14b", "arctic": "arctic-480b",
    "granite": "granite-moe-3b-a800m", "granite-moe-3b-a800m": "granite-moe-3b-a800m",
    "rwkv6": "rwkv6-3b", "musicgen": "musicgen-medium", "hymba": "hymba-1.5b",
    "llava": "llava-next-mistral-7b", "llava-next-mistral-7b": "llava-next-mistral-7b",
}


def get_arch(name: str) -> ArchConfig:
    key = ALIASES.get(name, name)
    if key not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[key]
