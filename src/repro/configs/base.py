"""Config system: architecture + execution + shape descriptors.

Every assigned architecture is a :class:`ArchConfig`; the paper's technique
enters through :class:`ExecutionPolicy` (CORDIC FxP8 matmul path, DA-VINCI
AFs, CAESAR pruning) which every layer consults.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from repro.core.activations import CordicPolicy
from repro.core.pruning import PruningPolicy
from repro.core.quantization import QuantPolicy


@dataclasses.dataclass(frozen=True)
class ExecutionPolicy:
    """How linear algebra + AFs execute (the RPE's runtime configuration).

    matmul:
      "bf16"         — plain MXU bf16 (reference baseline)
      "fxp8"         — CORDIC-equivalent int8 quantized path (production
                       mapping of the paper's 5-stage FxP8 MAC; W8A8)
      "fxp8_weight"  — W8A16 (weight-only)
      "cordic_kernel"— bit-exact Pallas shift-add kernel (validation scale)
    af: None  => exact float AFs;  CordicPolicy => DA-VINCI CORDIC AFs.
    """

    matmul: str = "bf16"
    af: Optional[CordicPolicy] = None
    pruning: Optional[PruningPolicy] = None
    quant: QuantPolicy = QuantPolicy()
    softmax_cordic: bool = False    # CORDIC softmax in attention (fidelity
                                    # study; exact softmax otherwise)
    moe_pure_dp: bool = False       # treat the whole mesh as data-parallel
                                    # for MoE (small models over-sharded at
                                    # tp=16; see EXPERIMENTS.md #Perf)
    fsdp_int8_gather: bool = False  # FxP8 transport for FSDP expert-weight
                                    # all-gathers (CAESAR co-design on
                                    # collectives)

    def tag(self) -> str:
        parts = [self.matmul]
        if self.af is not None:
            parts.append(f"af{self.af.bits}")
        if self.pruning is not None:
            parts.append(f"p{int(self.pruning.rate * 100)}")
        return "-".join(parts)


BF16_EXEC = ExecutionPolicy()
# Paper-faithful production policy: FxP8 MACs + CORDIC AFs + 40% pruning.
CORDIC_EXEC = ExecutionPolicy(matmul="fxp8", af=CordicPolicy(bits=16),
                              pruning=PruningPolicy(rate=0.40))


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """One architecture from the assigned pool."""

    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    # transformer details
    rope_theta: float = 10000.0
    qkv_bias: bool = False
    norm_eps: float = 1e-5
    activation: str = "silu"       # FFN activation (DA-VINCI selectable)
    tie_embeddings: bool = False
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    dense_residual: bool = False   # arctic: dense FFN + MoE in parallel
    capacity_factor: float = 1.25
    # SSM / hybrid
    ssm_state: int = 0
    ssm_conv: int = 4
    sliding_window: int = 0        # hybrid local-attention window
    global_attn_every: int = 0     # hybrid: every k-th layer is global
    # modality stub ("tokens" | "frames")
    input_kind: str = "tokens"
    n_codebooks: int = 0           # musicgen EnCodec codebooks
    # execution
    exec_policy: ExecutionPolicy = BF16_EXEC
    # attention implementation: "auto" | "naive" | "chunked"
    attn_impl: str = "auto"
    attn_chunk: int = 1024
    kv_cache_bits: int = 16        # 8 => FxP8 (Q3.4) quantized KV cache
    cache_quant: str = "none"      # "int8" => per-block-scaled serving
                                   # caches (core/quant_cache.py); distinct
                                   # from the fixed-scale kv_cache_bits=8
    fuse_moe_ffn_ar: bool = False  # fuse dense-residual FFN into the MoE
                                   # psum (one AR per layer instead of two)
    remat: bool = True
    dtype: str = "bfloat16"

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def supports_long_context(self) -> bool:
        return self.family in ("ssm", "hybrid")

    def scaled(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ArchConfig":
        """Smoke-test configuration of the same family (tiny dims)."""
        kw = dict(
            n_layers=2,
            d_model=64,
            n_heads=4,
            n_kv_heads=max(1, min(self.n_kv_heads, 2)),
            d_ff=128,
            vocab_size=256,
            head_dim=16,
            sliding_window=min(self.sliding_window, 32) if self.sliding_window else 0,
        )
        if self.n_experts:
            kw.update(n_experts=4, top_k=min(self.top_k, 2), moe_d_ff=32,
                      capacity_factor=2.0)
        if self.ssm_state:
            kw.update(ssm_state=8)
        if self.n_codebooks:
            kw.update(n_codebooks=2)
        kw["attn_chunk"] = 16
        kw["remat"] = False
        return self.scaled(**kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell (the assigned shape set)."""

    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


LM_SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


def shape_applicable(arch: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether this (arch x shape) cell runs; reason if skipped."""
    if shape.name == "long_500k" and not arch.supports_long_context:
        return False, ("pure full-attention arch: 500k decode would need a "
                       "524288-token dense KV cache per sequence — "
                       "sub-quadratic families only (see DESIGN.md)")
    return True, ""
