"""Config system: architecture + execution + shape descriptors.

Every assigned architecture is a :class:`ArchConfig`; the paper's technique
enters through :class:`ExecutionPolicy` (CORDIC FxP8 matmul path, DA-VINCI
AFs, CAESAR pruning) which every layer consults.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from repro.core.activations import CordicPolicy
from repro.core.pruning import PruningPolicy
from repro.core.quantization import QuantPolicy


@dataclasses.dataclass(frozen=True)
class ExecutionPolicy:
    """How linear algebra + AFs execute (the RPE's runtime configuration).

    matmul:
      "bf16"         — plain MXU bf16 (reference baseline)
      "fxp8"         — CORDIC-equivalent int8 quantized path (production
                       mapping of the paper's 5-stage FxP8 MAC; W8A8)
      "fxp8_weight"  — W8A16 (weight-only)
      "cordic_kernel"— bit-exact Pallas shift-add kernel (validation scale)
    af: None  => exact float AFs;  CordicPolicy => DA-VINCI CORDIC AFs.
    """

    matmul: str = "bf16"
    af: Optional[CordicPolicy] = None
    pruning: Optional[PruningPolicy] = None
    quant: QuantPolicy = QuantPolicy()
    softmax_cordic: bool = False    # CORDIC softmax in attention (fidelity
                                    # study; exact softmax otherwise)
    moe_pure_dp: bool = False       # treat the whole mesh as data-parallel
                                    # for MoE (small models over-sharded at
                                    # tp=16; see EXPERIMENTS.md #Perf)
    fsdp_int8_gather: bool = False  # FxP8 transport for FSDP expert-weight
                                    # all-gathers (CAESAR co-design on
                                    # collectives)

    def tag(self) -> str:
        parts = [self.matmul]
        if self.af is not None:
            parts.append(f"af{self.af.bits}")
        if self.pruning is not None:
            parts.append(f"p{int(self.pruning.rate * 100)}")
        return "-".join(parts)


BF16_EXEC = ExecutionPolicy()
# Paper-faithful production policy: FxP8 MACs + CORDIC AFs + 40% pruning.
CORDIC_EXEC = ExecutionPolicy(matmul="fxp8", af=CordicPolicy(bits=16),
                              pruning=PruningPolicy(rate=0.40))


@dataclasses.dataclass(frozen=True)
class CacheSpec:
    """The one description of a serving cache's storage format.

    Replaces the two historical knobs that grew side by side —
    ``ArchConfig.kv_cache_bits`` (the paper's fixed-scale Q3.4 FxP8 study)
    and ``ArchConfig.cache_quant`` (the per-block-scaled int8 serving
    mode) — with a single validated spec:

      dtype:      "native" (the model compute dtype), "int8" (per-block
                  f32 scales, :mod:`repro.core.quant_cache`) or "fxp8"
                  (legacy fixed Q3.4 scale, ``attention.KV_Q_SCALE``).
      block:      scale-block width in trailing channels for ``int8``
                  (``None`` = one scale per written vector, the
                  serving-safe default; must divide the trailing axis).
      paged:      store slot K/V (and int8 scale leaves) in a shared
                  fixed-size block pool addressed through per-slot block
                  tables instead of a dense ``max_batch x max_seq``
                  allocation (``models/paged.py``).
      page_size:  tokens per pool page when ``paged``; int8 scales are
                  grouped per page, so quantization granularity aligns
                  with the paging granularity by construction.

    Build one directly (``ArchConfig(..., cache=CacheSpec(dtype="int8"))``)
    or let :meth:`ArchConfig.cache_spec` derive it from the legacy
    fields.  Setting ``cache`` *and* a legacy knob is an error — there
    must be exactly one spelling of the cache format in play.
    """

    dtype: str = "native"          # "native" | "int8" | "fxp8"
    block: Optional[int] = None    # int8 scale-block width (None = vector)
    paged: bool = False
    page_size: int = 16

    def __post_init__(self):
        if self.dtype not in ("native", "int8", "fxp8"):
            raise ValueError(
                f"CacheSpec.dtype must be 'native', 'int8' or 'fxp8', "
                f"got {self.dtype!r}")
        if self.block is not None and self.block < 1:
            raise ValueError(f"CacheSpec.block must be >= 1, got "
                             f"{self.block}")
        if self.paged and self.page_size < 1:
            raise ValueError(f"CacheSpec.page_size must be >= 1, got "
                             f"{self.page_size}")
        if self.paged and self.dtype == "fxp8":
            raise ValueError("paged caches support 'native' and 'int8' "
                             "storage; the legacy fixed-scale 'fxp8' "
                             "format is a single-stream study, not a "
                             "serving format")

    @property
    def quantized(self) -> bool:
        return self.dtype == "int8"


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """One architecture from the assigned pool."""

    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    # transformer details
    rope_theta: float = 10000.0
    qkv_bias: bool = False
    norm_eps: float = 1e-5
    activation: str = "silu"       # FFN activation (DA-VINCI selectable)
    tie_embeddings: bool = False
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    dense_residual: bool = False   # arctic: dense FFN + MoE in parallel
    capacity_factor: float = 1.25
    # SSM / hybrid
    ssm_state: int = 0
    ssm_conv: int = 4
    sliding_window: int = 0        # hybrid local-attention window
    global_attn_every: int = 0     # hybrid: every k-th layer is global
    # modality stub ("tokens" | "frames")
    input_kind: str = "tokens"
    n_codebooks: int = 0           # musicgen EnCodec codebooks
    # execution
    exec_policy: ExecutionPolicy = BF16_EXEC
    # attention implementation: "auto" | "naive" | "chunked"
    attn_impl: str = "auto"
    attn_chunk: int = 1024
    # Serving-cache storage format.  `cache` (a CacheSpec) is the one
    # spelling going forward; `kv_cache_bits` / `cache_quant` are the two
    # legacy knobs it unifies, kept so existing configs keep loading —
    # setting a legacy knob *and* `cache` raises in `cache_spec()`.
    cache: Optional["CacheSpec"] = None
    kv_cache_bits: int = 16        # LEGACY: 8 => FxP8 (Q3.4) KV cache
    cache_quant: str = "none"      # LEGACY: "int8" => per-block scales
    fuse_moe_ffn_ar: bool = False  # fuse dense-residual FFN into the MoE
                                   # psum (one AR per layer instead of two)
    remat: bool = True
    dtype: str = "bfloat16"

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def cache_spec(self) -> "CacheSpec":
        """The resolved serving-cache format (one source of truth).

        ``cache`` wins when set; otherwise the legacy knobs are
        translated.  Mixing the spellings — a ``CacheSpec`` *and* a
        non-default ``kv_cache_bits``/``cache_quant`` — is an error, as
        is combining the two legacy quantized formats.
        """
        legacy = []
        if self.kv_cache_bits == 8:
            legacy.append("kv_cache_bits=8")
        elif self.kv_cache_bits != 16:
            raise ValueError(f"kv_cache_bits must be 8 or 16, got "
                             f"{self.kv_cache_bits}")
        if self.cache_quant == "int8":
            legacy.append("cache_quant='int8'")
        elif self.cache_quant != "none":
            raise ValueError(f"unknown cache_quant {self.cache_quant!r}; "
                             f"expected 'none' or 'int8'")
        if self.cache is not None:
            if legacy:
                raise ValueError(
                    f"ArchConfig.cache={self.cache} conflicts with the "
                    f"legacy spelling {' + '.join(legacy)}: the cache "
                    f"format has exactly one spelling — drop the legacy "
                    f"knob and put the format in CacheSpec")
            return self.cache
        if len(legacy) == 2:
            raise ValueError(
                "cache_quant='int8' (per-block scales) and "
                "kv_cache_bits=8 (fixed Q3.4 scale) are mutually "
                "exclusive KV-cache formats; use "
                "cache=CacheSpec(dtype=...) to pick one")
        if self.cache_quant == "int8":
            return CacheSpec(dtype="int8")
        if self.kv_cache_bits == 8:
            return CacheSpec(dtype="fxp8")
        return CacheSpec()

    @property
    def supports_long_context(self) -> bool:
        return self.family in ("ssm", "hybrid")

    def fingerprint(self) -> Dict[str, object]:
        """Serving-identity descriptor for snapshot compatibility.

        Two configs with equal fingerprints produce byte-compatible
        decode-state leaves (same shapes, dtypes and compute), so a slot
        snapshot taken under one restores bit-identically under the
        other.  Deliberately *excludes* engine capacity (``max_batch``,
        pool size) — snapshots restore into differently-sized engines —
        and includes everything that alters per-token state or logits:
        architecture dims, family, execution policy tag, and the
        resolved cache format.
        """
        spec = self.cache_spec()
        return {
            "name": self.name, "family": self.family,
            "n_layers": self.n_layers, "d_model": self.d_model,
            "n_heads": self.n_heads, "n_kv_heads": self.n_kv_heads,
            "d_ff": self.d_ff, "vocab_size": self.vocab_size,
            "head_dim": self.head_dim_, "rope_theta": self.rope_theta,
            "ssm_state": self.ssm_state, "ssm_conv": self.ssm_conv,
            "sliding_window": self.sliding_window,
            "global_attn_every": self.global_attn_every,
            "n_experts": self.n_experts, "top_k": self.top_k,
            "activation": self.activation, "dtype": self.dtype,
            "exec": self.exec_policy.tag(),
            "cache": {"dtype": spec.dtype, "block": spec.block,
                      "paged": spec.paged, "page_size": spec.page_size},
        }

    def scaled(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ArchConfig":
        """Smoke-test configuration of the same family (tiny dims)."""
        kw = dict(
            n_layers=2,
            d_model=64,
            n_heads=4,
            n_kv_heads=max(1, min(self.n_kv_heads, 2)),
            d_ff=128,
            vocab_size=256,
            head_dim=16,
            sliding_window=min(self.sliding_window, 32) if self.sliding_window else 0,
        )
        if self.n_experts:
            kw.update(n_experts=4, top_k=min(self.top_k, 2), moe_d_ff=32,
                      capacity_factor=2.0)
        if self.ssm_state:
            kw.update(ssm_state=8)
        if self.n_codebooks:
            kw.update(n_codebooks=2)
        kw["attn_chunk"] = 16
        kw["remat"] = False
        return self.scaled(**kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell (the assigned shape set)."""

    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


LM_SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


def shape_applicable(arch: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether this (arch x shape) cell runs; reason if skipped."""
    if shape.name == "long_500k" and not arch.supports_long_context:
        return False, ("pure full-attention arch: 500k decode would need a "
                       "524288-token dense KV cache per sequence — "
                       "sub-quadratic families only (see DESIGN.md)")
    return True, ""
