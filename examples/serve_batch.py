"""Continuous-batching serving demo (deliverable (b): serve a small model
with batched requests).

    PYTHONPATH=src python examples/serve_batch.py [--arch rwkv6-3b]

Uses the reduced config of any assigned architecture; measures prefill and
decode throughput of the engine.
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs import get_arch
from repro.models.model_zoo import build_model
from repro.runtime.serve_loop import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, max_batch=4)
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(args.requests):
        n = int(rng.integers(4, 20))
        if cfg.input_kind == "tokens":
            prompt = rng.integers(0, cfg.vocab_size, n).astype(np.int32)
        else:
            prompt = rng.standard_normal((n, cfg.d_model)).astype(np.float32)
        reqs.append(Request(i, prompt, max_new_tokens=args.max_new))
    t0 = time.time()
    done = engine.serve(reqs)
    dt = time.time() - t0
    lat = [1e3 * (r.done_at - r.submitted_at) for r in done]
    print(f"{args.arch} (reduced): {len(done)} requests in {dt:.2f}s")
    print(f"  prefill {engine.metrics['prefill_tokens']} tok, "
          f"decode {engine.metrics['decode_tokens']} tok "
          f"({engine.metrics['decode_tokens']/dt:.1f} tok/s)")
    print(f"  latency p50={np.percentile(lat, 50):.0f}ms "
          f"p95={np.percentile(lat, 95):.0f}ms")


if __name__ == "__main__":
    main()
