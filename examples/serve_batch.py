"""Continuous-batching serving demo: replay an arrival trace through the
slot-based engine (deliverable (b): serve a small model with batched
requests).

    PYTHONPATH=src python examples/serve_batch.py [--arch rwkv6-3b] [--gang]

Requests arrive over time (Poisson-ish gaps), are admitted into free
decode slots as they arrive, and retire the moment their budget is done —
the engine reports throughput, latency percentiles, queue wait and slot
occupancy.  ``--gang`` replays the same trace through the old lockstep
scheduler for comparison (see also ``python -m benchmarks.serve_bench``).
"""
import argparse
import pathlib
import sys
import time

_ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(_ROOT / "src"))
sys.path.insert(0, str(_ROOT))  # benchmarks package (shared make_trace)

import jax
import numpy as np

from benchmarks.serve_bench import (make_prefix_trace, make_spec_trace,
                                    make_trace)
from repro.configs import CacheSpec, get_arch
from repro.models.model_zoo import build_model
from repro.runtime.serve_loop import (GangServeEngine, ServeConfig,
                                      ServeEngine)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--gang", action="store_true",
                    help="use the old lockstep scheduler instead")
    ServeConfig.add_args(ap)           # the shared engine flag set
    ap.set_defaults(max_seq=64)        # demo-sized sequences
    args = ap.parse_args()
    ServeConfig.check_args(ap, args, gang=args.gang)

    cfg = get_arch(args.arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    # the draftable spec trace carries longer outputs than the default
    # mixed trace: give its requests room
    max_seq = max(args.max_seq, 128) if args.spec else args.max_seq

    def make_engine(incarnation=0):
        config = ServeConfig.from_args(
            args, incarnation=incarnation, max_seq=max_seq,
            cache=(CacheSpec(paged=True, page_size=8) if args.paged
                   else None))
        if args.mesh_shards:
            from repro.runtime.mesh_serve import MeshServeEngine
            return MeshServeEngine(model, params, config)
        return ServeEngine(model, params, config)

    if args.gang:
        engine = GangServeEngine(model, params, max_batch=args.max_batch,
                                 max_seq=max_seq)
    else:
        engine = make_engine()
    # spec mode replays the draftable motif trace — the workload where
    # prompt-lookup drafting earns its verify width; paged mode the
    # shared-prefix trace where the radix cache earns its pages
    reqs = (make_spec_trace(cfg, args.requests) if args.spec
            else make_prefix_trace(cfg, args.requests) if args.paged
            else make_trace(cfg, args.requests))
    t0 = time.time()
    if args.kill_at_step is not None:
        from repro.runtime.supervisor import ServeSupervisor
        sup = ServeSupervisor(make_engine)
        done = sup.run(reqs)
        engine = sup.engine
        for h in sup.history:
            print(f"chaos: restart {h.restart} restored step "
                  f"{h.restored_step}; resumed {h.resumed_rids}, "
                  f"replayed {h.replayed_rids}, recovered "
                  f"{h.recovered_rids}")
    else:
        done = engine.serve(reqs)
    dt = time.time() - t0
    lat = [1e3 * (r.done_at - r.submitted_at) for r in done]
    toks = sum(len(r.output) for r in done)
    name = "gang" if args.gang else "continuous"
    print(f"{args.arch} (reduced, {name}): {len(done)} requests in {dt:.2f}s"
          f" -> {toks / dt:.1f} tok/s")
    print(f"  prefill {engine.metrics['prefill_tokens']} tok, "
          f"decode {engine.metrics['decode_tokens']} tok")
    print(f"  latency p50={np.percentile(lat, 50):.0f}ms "
          f"p99={np.percentile(lat, 99):.0f}ms")
    if not args.gang:
        print(f"  queue wait {engine.metrics['queue_wait_s'] * 1e3:.0f}ms, "
              f"slot occupancy {engine.metrics['slot_occupancy']:.0%}, "
              f"{engine.trace_counts['prefill']} prefill trace(s) over "
              f"{engine.metrics['decode_steps']} decode steps")
    if args.mesh_shards:
        print(f"  mesh: {engine.n_shards} shards, loads "
              f"{engine.shard_loads()}, "
              f"{engine.metrics['async_prefills']:.0f} async prefills, "
              f"{engine.metrics['overlap_steps']:.0f} overlapped steps")
    if args.spec:
        print(f"  spec ({args.drafter or 'ngram'}): acceptance "
              f"{engine.metrics['spec_acceptance']:.0%}, "
              f"{engine.metrics['tokens_per_step']:.2f} tokens/step, "
              f"k hist {dict(sorted(engine.metrics.spec_k_hist.items()))}")
    if args.paged:
        print(f"  paged: prefix hits "
              f"{engine.metrics['prefix_hit_tokens']:.0f} tok "
              f"(computed {engine.metrics['prefill_tokens']:.0f}), "
              f"peak blocks {engine.metrics['peak_blocks']:.0f}")
    if args.snapshot_dir:
        print(f"  snapshots: {engine.metrics['snapshots']:.0f} taken "
              f"({engine.metrics['snapshot_s'] * 1e3:.0f} ms total), "
              f"restore {engine.metrics['restore_s'] * 1e3:.0f} ms")


if __name__ == "__main__":
    main()
