"""Paper Fig 11 as a runnable example: train f32, evaluate under CORDIC
FxP8 execution, prune 40%, QAT-recover.  (Also run by benchmarks/run.py.)

    PYTHONPATH=src python examples/train_cordic_classifier.py
"""
import sys

sys.path.insert(0, "src")
sys.path.insert(0, ".")

from benchmarks import accuracy_bench


def main():
    rows = []
    accuracy_bench.run(rows)
    for name, _, derived in rows:
        print(f"{name:28s} {derived}")


if __name__ == "__main__":
    main()
