"""Regenerate the paper's Pareto figures (Figs 4-6) as CSV.

    PYTHONPATH=src python examples/pareto_sweep.py > pareto.csv
"""
import sys

sys.path.insert(0, "src")

from repro.core import pareto


def main():
    print("fn,bits,iterations,mse,mae,avg_rel_err,std")
    report = pareto.full_report(iterations=tuple(range(2, 13)),
                                n_samples=1024)
    for fn, pts in report.items():
        for p in pts:
            print(p.row())
    knees = {fn: pareto.knee(pts, "mae") for fn, pts in report.items()}
    print(f"# knees (iterations where improvement < 10%): {knees}",
          file=sys.stderr)


if __name__ == "__main__":
    main()
