"""Quickstart: the paper's CORDIC stack end to end in two minutes on CPU.

    PYTHONPATH=src python examples/quickstart.py

1. bit-exact 5-stage CORDIC MAC (Pallas kernel vs signed-digit oracle),
2. DA-VINCI activations vs exact,
3. a reduced glm4-family model trained for 30 steps under the paper's
   FxP8 execution policy, then served with batched requests.
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import CORDIC_EXEC, get_arch
from repro.core import fixed_point as fxp
from repro.core.activations import CordicPolicy, activate
from repro.data.pipeline import DataConfig, SyntheticStream
from repro.kernels.cordic_mac.kernel import cordic_matmul_raw
from repro.kernels.cordic_mac.ref import cordic_matmul_raw_ref
from repro.models.model_zoo import build_model
from repro.optim.adamw import AdamWConfig
from repro.runtime.serve_loop import Request, ServeEngine
from repro.runtime.train_loop import TrainConfig, Trainer


def main():
    rng = np.random.default_rng(0)

    print("== 1. CORDIC MAC kernel (SYCore dataflow, bit-exact) ==")
    fmt = fxp.FXP16
    x = fxp.quantize(jnp.array(rng.uniform(-2, 2, (32, 32)), jnp.float32), fmt)
    w = fxp.quantize(jnp.array(rng.uniform(-1.9, 1.9, (32, 32)), jnp.float32), fmt)
    got = cordic_matmul_raw(x, w, fmt=fmt, n_stages=5, block=(16, 16, 16))
    want = cordic_matmul_raw_ref(x, w, fmt=fmt, n_stages=5)
    print("   kernel == signed-digit oracle:", bool((got == want).all()))

    print("== 2. DA-VINCI reconfigurable AFs ==")
    pol = CordicPolicy(bits=16)
    xs = jnp.linspace(-4, 4, 9)
    for af in ("tanh", "sigmoid", "gelu", "swish"):
        err = float(jnp.abs(activate(xs, af, pol) - activate(xs, af, None)).max())
        print(f"   {af:8s} max|err| = {err:.4f}")

    print("== 3. Train a reduced glm4 under the FxP8 policy ==")
    cfg = get_arch("glm4-9b").reduced()
    model = build_model(cfg)
    stream = SyntheticStream(DataConfig(vocab_size=cfg.vocab_size,
                                        seq_len=32, global_batch=4, seed=0))
    trainer = Trainer(model, TrainConfig(
        optimizer=AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=30),
        log_every=10), stream, pol=CORDIC_EXEC)
    out = trainer.run(30)
    print("   loss:", " -> ".join(f"{l:.3f}" for _, l in out["losses"]))

    print("== 4. Serve batched requests ==")
    engine = ServeEngine(model, out["params"])
    reqs = [Request(i, rng.integers(0, cfg.vocab_size, 6).astype(np.int32),
                    max_new_tokens=4) for i in range(3)]
    for r in engine.serve(reqs):
        print(f"   req {r.rid}: -> {list(r.output)}")
    print("done.")


if __name__ == "__main__":
    main()
