"""End-to-end training driver: a ~100M-parameter glm4-family LM trained for
a few hundred steps with checkpointing and (optional) fault injection.

    PYTHONPATH=src python examples/train_lm_100m.py --steps 300
    PYTHONPATH=src python examples/train_lm_100m.py --steps 40 --demo

(--demo shrinks batch/seq so a CPU run finishes in minutes; the default
shape is sized for a real accelerator.)  The same Trainer underlies
launch/train.py; add --fault-at N to exercise crash->restore->resume.
"""
import argparse
import sys
import tempfile

sys.path.insert(0, "src")

from repro.configs import get_arch
from repro.data.pipeline import DataConfig, SyntheticStream
from repro.models.model_zoo import build_model
from repro.optim.adamw import AdamWConfig
from repro.runtime.train_loop import TrainConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--demo", action="store_true")
    ap.add_argument("--fault-at", type=int, default=None)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    # ~100M params: 12L x d768 x 12H, 32k vocab
    cfg = get_arch("glm4-9b").scaled(
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=4, d_ff=2048,
        vocab_size=32768, head_dim=64, remat=False)
    model = build_model(cfg)
    print(f"model: {model.n_params():,} params")

    if args.demo:
        batch, seq = 4, 128
    else:
        batch, seq = 32, 1024
    stream = SyntheticStream(DataConfig(vocab_size=cfg.vocab_size,
                                        seq_len=seq, global_batch=batch,
                                        seed=0))
    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="lm100m_ckpt_")
    tcfg = TrainConfig(
        optimizer=AdamWConfig(lr=3e-4, warmup_steps=max(args.steps // 20, 1),
                              total_steps=args.steps),
        ckpt_dir=ckpt_dir, ckpt_every=max(args.steps // 5, 10),
        log_every=max(args.steps // 20, 1))
    trainer = Trainer(model, tcfg, stream)
    try:
        out = trainer.run(args.steps, fault_at=args.fault_at)
    except RuntimeError as e:
        if "injected fault" not in str(e):
            raise
        print(f"! {e} — restoring from {ckpt_dir} and resuming")
        trainer = Trainer(model, tcfg, stream)
        out = trainer.run(args.steps)
    for step, loss in out["losses"]:
        print(f"step {step:5d}  loss {loss:.4f}")
    print(f"wall {out['wall_s']:.1f}s, checkpoints in {ckpt_dir}")


if __name__ == "__main__":
    main()
